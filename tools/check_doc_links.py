#!/usr/bin/env python3
"""Docs link checker: every relative link and anchor must resolve.

Scans README.md and docs/*.md for markdown links, and fails (exit 1, one
line per problem) when a relative link points at a file that does not exist
or an anchor that no heading in the target file produces. External links
(``scheme://`` or ``mailto:``) are ignored — this gate is about keeping the
repo's own cross-references from rotting, not about the internet.

Anchors are matched against GitHub's heading slugification (lowercase, strip
punctuation, spaces to hyphens, ``-1``/``-2`` suffixes for duplicates), so a
link that works in the repo browser passes and one that 404s fails.

Usage: python tools/check_doc_links.py [root]   (root defaults to the repo)
Stdlib only; wired into the CI lint job and tests/test_docs.py.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — target captured up to the first unescaped ')'; images too.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE = re.compile(r"^(```|~~~)")
_EXTERNAL = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")  # scheme: (http, mailto, ...)
_HEADING = re.compile(r"^(#{1,6})\s+(.*)$")


def _strip_code(text: str) -> str:
    """Drop fenced code blocks and inline code spans (links there aren't links)."""
    out: list[str] = []
    in_fence = False
    for line in text.splitlines():
        if _FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(re.sub(r"`[^`]*`", "", line))
    return "\n".join(out)


def _slugify(heading: str) -> str:
    """GitHub's anchor algorithm: lowercase, drop punctuation, spaces->hyphens."""
    # headings may themselves contain markdown links/code: use the visible text
    heading = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)
    heading = heading.replace("`", "")
    heading = heading.strip().lower()
    heading = re.sub(r"[^\w\- ]", "", heading)  # \w keeps unicode letters + _
    return heading.replace(" ", "-")


def _anchors(md_path: Path) -> set[str]:
    slugs: dict[str, int] = {}
    out: set[str] = set()
    in_fence = False
    for line in md_path.read_text(encoding="utf-8").splitlines():
        if _FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if not m:
            continue
        slug = _slugify(m.group(2))
        n = slugs.get(slug, 0)
        slugs[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def check_file(md_path: Path, root: Path) -> list[str]:
    problems: list[str] = []
    for target in _LINK.findall(_strip_code(md_path.read_text(encoding="utf-8"))):
        if _EXTERNAL.match(target):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            dest = (md_path.parent / path_part).resolve()
            try:
                dest.relative_to(root)
            except ValueError:
                problems.append(f"{md_path}: link escapes the repo: {target}")
                continue
            if not dest.exists():
                problems.append(f"{md_path}: broken link: {target}")
                continue
        else:
            dest = md_path
        if anchor:
            if dest.suffix.lower() not in (".md", ".markdown"):
                problems.append(f"{md_path}: anchor into non-markdown: {target}")
            elif anchor.lower() not in _anchors(dest):
                problems.append(f"{md_path}: broken anchor: {target}")
    return problems


def main(argv: list[str]) -> int:
    root = Path(argv[1]).resolve() if len(argv) > 1 else Path(__file__).resolve().parents[1]
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    problems: list[str] = []
    checked = 0
    for f in files:
        if not f.exists():
            problems.append(f"missing expected doc: {f}")
            continue
        checked += 1
        problems.extend(check_file(f, root))
    for p in problems:
        print(p, file=sys.stderr)
    print(f"check_doc_links: {checked} files, {len(problems)} problems")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
