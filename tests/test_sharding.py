"""Logical-axis sharding rules: divisibility fallbacks, param/cache spec tables."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (DEFAULT_RULES, axis_rules, cache_pspecs,
                                        dispatch_groups, logical_pspec, param_pspecs,
                                        shard)
from repro.models import model as M


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_logical_pspec_no_mesh_is_fully_replicated():
    assert logical_pspec((8, 16), ("batch", "d_ff"), mesh=None) == P(None, None)


def test_logical_pspec_divisibility_drops_axis():
    mesh = _mesh11()
    # axis size 1 -> never partition (divisible but pointless); spec stays None
    spec = logical_pspec((9, 16), ("heads", "d_ff"), mesh=mesh)
    assert spec == P(None, None)


def test_param_pspecs_cover_every_leaf():
    """Every parameter of every architecture resolves to a PartitionSpec."""
    mesh = _mesh11()
    for arch in ("smollm_135m", "jamba_v0_1_52b", "qwen2_moe_a2_7b", "xlstm_350m",
                 "whisper_medium", "llama_3_2_vision_11b", "arctic_480b"):
        cfg = get_config(arch).reduced(n_periods=1)
        shapes = jax.eval_shape(lambda c=cfg: M.init_params(c, jax.random.PRNGKey(0)))
        specs = param_pspecs(shapes, mesh)
        leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        leaves_p = jax.tree.leaves(shapes)
        assert len(leaves_s) == len(leaves_p)
        for sp, leaf in zip(leaves_s, leaves_p):
            assert isinstance(sp, P)
            assert len(sp) == leaf.ndim


def test_cache_pspecs_cover_every_leaf():
    mesh = _mesh11()
    for arch in ("qwen3_1_7b", "jamba_v0_1_52b", "xlstm_350m", "whisper_medium"):
        cfg = get_config(arch).reduced(n_periods=1)
        enc = (jnp.zeros((2, cfg.encoder_seq, cfg.d_model))
               if cfg.arch_type == "audio" else None)
        cache = jax.eval_shape(
            lambda c=cfg, e=enc: M.init_cache(c, None, 2, 32, enc_out=e))
        specs = cache_pspecs(cache, mesh)
        leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_s) == len(jax.tree.leaves(cache))


def test_shard_is_identity_without_mesh():
    x = jnp.ones((4, 8))
    y = shard(x, ("batch", "d_ff"))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_dispatch_groups_divisibility():
    assert dispatch_groups(1024) == 1          # no mesh active
    with axis_rules(_mesh11()):
        # mesh axes of size 1 -> one group
        assert dispatch_groups(1024) == 1


def test_rules_table_sanity():
    assert DEFAULT_RULES["batch"] == ("pod", "data")
    assert "model" in DEFAULT_RULES["experts"]
    assert "model" in DEFAULT_RULES["kv_seq"]
