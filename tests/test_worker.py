"""Real JAX data plane: prefill/decode/extend, preemption persistence, migration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine.sampler import SamplerConfig, sample
from repro.engine.worker import PrefixCacheIndex, RolloutWorker
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, KEY)
    return cfg, params


def test_prefill_decode_extend_flow(setup):
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=64, worker_id=0)
    w.prefill(1, [5, 7, 9, 11])
    out = w.decode([1], 5)
    assert len(out[1]) == 5
    w.extend(1, [101, 102])                       # tool output absorbed, no recompute
    out2 = w.decode([1], 3)
    assert len(out2[1]) == 3
    seq = w.store[1]
    assert len(seq.tokens) == 4 + 5 + 2 + 3


def test_batched_decode_multiple_sequences(setup):
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=64, worker_id=0)
    w.prefill(1, [5, 7, 9])
    w.prefill(2, [5, 7, 9, 13, 17])               # different length: per-slot positions
    out = w.decode([1, 2], 4)
    assert len(out[1]) == 4 and len(out[2]) == 4


def test_decode_greedy_matches_model(setup):
    """Worker greedy decode == direct model decode (the engine adds no math)."""
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=64, worker_id=0,
                      sampler=SamplerConfig(temperature=0.0))
    prompt = [5, 7, 9, 11]
    w.prefill(1, prompt)
    got = w.decode([1], 4)[1]

    arr = jnp.asarray(prompt, jnp.int32)[None]
    logits, _, cache = M.forward_full(cfg, params, {"tokens": arr}, capacity=64)
    want = []
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        want.append(int(tok[0, 0]))
        lg, cache = M.decode_step(cfg, params, cache, tok)
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    assert got == want


def test_migration_preserves_decoding_state(setup):
    """KV migration: the destination continues exactly where the source stopped."""
    cfg, params = setup
    w0 = RolloutWorker(cfg, params, capacity=64, worker_id=0,
                       sampler=SamplerConfig(temperature=0.0))
    w1 = RolloutWorker(cfg, params, capacity=64, worker_id=1,
                       sampler=SamplerConfig(temperature=0.0))
    w0.prefill(1, [5, 7, 9, 11])
    w0.decode([1], 3)
    # reference: stay on w0
    ref = RolloutWorker(cfg, params, capacity=64, worker_id=0,
                        sampler=SamplerConfig(temperature=0.0))
    ref.prefill(2, [5, 7, 9, 11])
    ref.decode([2], 3)
    pkg = w0.migrate_out(1)
    assert 1 not in w0.store
    w1.migrate_in(pkg)
    got = w1.decode([1], 4)[1]
    want = ref.decode([2], 4)[2]
    assert got == want


def test_decode_skips_finished_sequences(setup):
    """Regression: decode() used to resume a sequence whose ``finished`` flag was
    already set and append tokens past its stop token; a finished sequence must
    contribute an empty stream and stay frozen."""
    cfg, params = setup
    probe = RolloutWorker(cfg, params, capacity=64, worker_id=0,
                          sampler=SamplerConfig(temperature=0.0))
    probe.prefill(1, [5, 7, 9, 11])
    stop = probe.decode([1], 3)[1][1]             # greedy token at step 2 = stop
    w = RolloutWorker(cfg, params, capacity=64, worker_id=0,
                      sampler=SamplerConfig(temperature=0.0))
    w.prefill(1, [5, 7, 9, 11])
    first = w.decode([1], 5, stop_token=stop)
    assert first[1][-1] == stop and w.store[1].finished
    frozen = list(w.store[1].tokens)
    again = w.decode([1], 4, stop_token=stop)     # scheduler re-requests it
    assert again == {1: []}
    assert w.store[1].tokens == frozen            # nothing decoded past the stop
    assert w.store[1].finished


def test_migration_carries_preempted_flag(setup):
    """Regression: migrate_out dropped ``preempted`` — a preempted trajectory
    migrated during a tool call arrived at the destination as active.  The flag
    must survive the transfer, and preempt -> migrate -> resume must decode
    exactly what a preempt -> resume on one worker would have."""
    cfg, params = setup
    w0 = RolloutWorker(cfg, params, capacity=64, worker_id=0,
                       sampler=SamplerConfig(temperature=0.0))
    w1 = RolloutWorker(cfg, params, capacity=64, worker_id=1,
                       sampler=SamplerConfig(temperature=0.0))
    w0.prefill(1, [5, 7, 9, 11])
    w0.decode([1], 2)
    w0.preempt(1)
    pkg = w0.migrate_out(1)
    assert pkg["preempted"] is True and pkg["finished"] is False
    w1.migrate_in(pkg)
    assert w1.store[1].preempted                  # arrives preempted, not active
    # reference: preempt/resume without migration
    ref = RolloutWorker(cfg, params, capacity=64, worker_id=0,
                        sampler=SamplerConfig(temperature=0.0))
    ref.prefill(2, [5, 7, 9, 11])
    ref.decode([2], 2)
    ref.preempt(2)
    got = w1.decode([1], 3)[1]                    # resume on the destination
    want = ref.decode([2], 3)[2]
    assert got == want
    assert not w1.store[1].preempted


def test_preemption_persists_cache(setup):
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=64, worker_id=0,
                      sampler=SamplerConfig(temperature=0.0))
    w.prefill(1, [5, 7, 9, 11])
    first = w.decode([1], 2)[1]
    w.preempt(1)                                  # mask flip: lane stays resident
    assert 1 in w.store and w.store[1].preempted
    resumed = w.decode([1], 2)[1]                 # continues from persisted state
    assert not w.store[1].preempted               # decode implicitly resumes
    assert len(first) == 2 and len(resumed) == 2


def test_prefix_cache_index():
    idx = PrefixCacheIndex()
    idx.insert([1, 2, 3, 4])
    assert idx.match_len([1, 2, 3, 4, 5]) == 4
    assert idx.match_len([1, 2, 9]) == 2
    assert idx.match_len([9]) == 0
    assert idx.hits == 2 and idx.lookups == 3


def test_sampler_top_p_and_greedy():
    logits = jnp.asarray([[0.0, 0.0, 10.0, 0.0]])
    assert int(sample(KEY, logits, SamplerConfig(temperature=0.0))[0]) == 2
    # top_p=0.01 keeps only the argmax bucket
    toks = [int(sample(jax.random.PRNGKey(i), logits,
                       SamplerConfig(temperature=1.0, top_p=0.01))[0])
            for i in range(10)]
    assert set(toks) == {2}


def test_profiler_produces_monotone_interference(setup):
    """§5.2 loop closure: profile the REAL engine, get a usable F(batch)."""
    from repro.engine.profiler import measured_interference, profile_decode
    cfg, params = setup
    prof = profile_decode(cfg, params, batch_sizes=(1, 2, 4), capacity=64,
                          context=16, steps=2, warmup=1)
    assert set(prof) == {1, 2, 4}
    assert all(v > 0 for v in prof.values())
    F = measured_interference(cfg, params, batch_sizes=(1, 2, 4), capacity=64,
                              context=16, steps=2, warmup=1)
    assert F(1) == 1.0
    assert F(4) >= F(2) >= F(1)
    # and it plugs straight into the placement DP
    from repro.core.placement import presorted_dp
    res = presorted_dp([100.0, 50, 10, 5], 2, F)
    assert res.makespan > 0


def test_kv_bytes_stable_across_pool_growth(setup):
    """Regression: kv_bytes reports the per-lane footprint from the lane *shapes*,
    so the figure is identical before and after pool growth (the old computation
    divided the live pool by the current max_slots, tying the answer to growth
    timing).  Pins the dense (``paged=False``) fallback layout — paged lanes
    price resident pages instead (tests/test_paging.py)."""
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=32, max_slots=2,
                      sampler=SamplerConfig(temperature=0.0), paged=False)
    w.prefill(1, [5, 7])
    before = w.kv_bytes(1)
    w.prefill(2, [5, 9])
    w.prefill(3, [5, 11])                         # overflow: pool doubles
    assert w.pool_grows == 1
    assert w.kv_bytes(1) == before                # post-growth call, same figure
    # and it matches an independently-constructed batch-1 lane
    lane = M.init_cache(cfg, params, 1, 32)
    want = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(lane))
    assert before == want


def test_dispatch_stats_report_admission_split(setup):
    """dispatch_stats surfaces the measured reuse/prefill token split the
    controller's placement telemetry consumes."""
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=64, max_slots=4,
                      sampler=SamplerConfig(temperature=0.0), chunk_size=8)
    w.prefill(1, [5, 7, 9, 11])
    w.prefill(2, [5, 7, 9, 11])                   # sibling: implants the prompt
    s = w.dispatch_stats()
    assert s["prefilled_tokens"] == 4 and s["reused_tokens"] == 4
    assert s["full_hits"] == 1 and s["lookups"] == 2
    assert s["prefill_dispatches"] == 1           # one chunk; sibling copied, no chunks


def test_decode_zero_tokens_is_a_noop(setup):
    """Edge: decode(n_tokens=0) returns empty streams without touching state."""
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=64, max_slots=2,
                      sampler=SamplerConfig(temperature=0.0))
    w.prefill(1, [5, 7, 9])
    assert w.decode([1], 0) == {1: []}
    assert w.store[1].tokens == [5, 7, 9]
