"""Sort-initialized simulated annealing (Algorithm 2)."""

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.placement import InterferenceModel, presorted_dp
from repro.core.resource_manager import (WorkerLatencyModel, _perturb,
                                         _random_allocation, homogeneous_allocation,
                                         sort_initialized_sa)

F = InterferenceModel.analytic(0.05)


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 64), st.integers(0, 10_000))
def test_random_allocation_conserves_budget(budget, seed):
    rng = np.random.default_rng(seed)
    alloc = _random_allocation(rng, budget, (1, 2, 4, 8))
    assert sum(alloc) == budget
    assert all(d in (1, 2, 4, 8) for d in alloc)
    assert alloc == sorted(alloc, reverse=True)


@settings(max_examples=60, deadline=None)
@given(st.integers(8, 64), st.integers(0, 10_000))
def test_perturbation_preserves_budget_and_degrees(budget, seed):
    rng = np.random.default_rng(seed)
    alloc = _random_allocation(rng, budget, (1, 2, 4, 8))
    for _ in range(20):
        alloc = _perturb(rng, alloc, (1, 2, 4, 8))
        assert sum(alloc) == budget
        assert all(d in (1, 2, 4, 8) for d in alloc)
        assert alloc == sorted(alloc, reverse=True)


def test_sa_budget_and_quality():
    rng = np.random.default_rng(0)
    lengths = np.concatenate([rng.pareto(1.3, 200) * 800 + 50, [40_000, 38_000]])
    res = sort_initialized_sa(lengths, budget=32, interference=F, seed=0)
    assert sum(res.degrees) == 32
    assert res.degrees == sorted(res.degrees, reverse=True)
    # SA must beat the homogeneous strawmen under its own objective
    lat = WorkerLatencyModel()
    for mp in (1, 8):
        alloc = homogeneous_allocation(32, mp)
        hom = presorted_dp(lengths, len(alloc), F,
                           base_token_time=lat.token_times(alloc, len(lengths) / len(alloc)))
        assert res.makespan <= hom.makespan * 1.05
    # best-so-far history is monotone non-increasing
    assert all(a >= b - 1e-9 for a, b in zip(res.history, res.history[1:]))


def test_latency_model_tradeoff():
    """Fig 7: at small batch latency falls with MP (the tail's regime); at saturation
    per-chip throughput falls with MP (the bulk's regime) — the trade-off Algorithm 2
    navigates."""
    lat = WorkerLatencyModel(t1=0.02)
    t_small = [lat.base_token_time(mp, batch=8) for mp in (1, 2, 4, 8)]
    assert t_small == sorted(t_small, reverse=True)     # latency improves with MP
    per_chip = [1 / (lat.base_token_time(mp, batch=64) * mp) for mp in (1, 2, 4, 8)]
    assert per_chip == sorted(per_chip, reverse=True)   # efficiency degrades with MP


def test_sa_deterministic_given_seed():
    rng = np.random.default_rng(1)
    lengths = rng.pareto(1.5, 100) * 500 + 10
    a = sort_initialized_sa(lengths, 16, F, seed=42)
    b = sort_initialized_sa(lengths, 16, F, seed=42)
    assert a.degrees == b.degrees and a.makespan == b.makespan
