"""Sort-initialized simulated annealing (Algorithm 2)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.placement import InterferenceModel, presorted_dp
from repro.core.resource_manager import (WorkerLatencyModel, _perturb,
                                         _random_allocation, homogeneous_allocation,
                                         sort_initialized_sa)

F = InterferenceModel.analytic(0.05)


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 64), st.integers(0, 10_000))
def test_random_allocation_conserves_budget(budget, seed):
    rng = np.random.default_rng(seed)
    alloc = _random_allocation(rng, budget, (1, 2, 4, 8))
    assert sum(alloc) == budget
    assert all(d in (1, 2, 4, 8) for d in alloc)
    assert alloc == sorted(alloc, reverse=True)


@settings(max_examples=60, deadline=None)
@given(st.integers(8, 64), st.integers(0, 10_000))
def test_perturbation_preserves_budget_and_degrees(budget, seed):
    rng = np.random.default_rng(seed)
    alloc = _random_allocation(rng, budget, (1, 2, 4, 8))
    for _ in range(20):
        alloc = _perturb(rng, alloc, (1, 2, 4, 8))
        assert sum(alloc) == budget
        assert all(d in (1, 2, 4, 8) for d in alloc)
        assert alloc == sorted(alloc, reverse=True)


def test_sa_budget_and_quality():
    rng = np.random.default_rng(0)
    lengths = np.concatenate([rng.pareto(1.3, 200) * 800 + 50, [40_000, 38_000]])
    res = sort_initialized_sa(lengths, budget=32, interference=F, seed=0)
    assert sum(res.degrees) == 32
    assert res.degrees == sorted(res.degrees, reverse=True)
    # SA must beat the homogeneous strawmen under its own objective
    lat = WorkerLatencyModel()
    for mp in (1, 8):
        alloc = homogeneous_allocation(32, mp)
        hom = presorted_dp(lengths, len(alloc), F,
                           base_token_time=lat.token_times(alloc, len(lengths) / len(alloc)))
        assert res.makespan <= hom.makespan * 1.05
    # best-so-far history is monotone non-increasing
    assert all(a >= b - 1e-9 for a, b in zip(res.history, res.history[1:]))


def test_latency_model_tradeoff():
    """Fig 7: at small batch latency falls with MP (the tail's regime); at saturation
    per-chip throughput falls with MP (the bulk's regime) — the trade-off Algorithm 2
    navigates."""
    lat = WorkerLatencyModel(t1=0.02)
    t_small = [lat.base_token_time(mp, batch=8) for mp in (1, 2, 4, 8)]
    assert t_small == sorted(t_small, reverse=True)     # latency improves with MP
    per_chip = [1 / (lat.base_token_time(mp, batch=64) * mp) for mp in (1, 2, 4, 8)]
    assert per_chip == sorted(per_chip, reverse=True)   # efficiency degrades with MP


def test_sa_deterministic_given_seed():
    rng = np.random.default_rng(1)
    lengths = rng.pareto(1.5, 100) * 500 + 10
    a = sort_initialized_sa(lengths, 16, F, seed=42)
    b = sort_initialized_sa(lengths, 16, F, seed=42)
    assert a.degrees == b.degrees and a.makespan == b.makespan


# ------------------------------------------------------------- §6 calibration

def test_latency_model_fit_recovers_synthetic_ground_truth():
    """Exact observations from a known (t1, overlap) are recovered to machine
    precision, and the fitted token-time curve tracks the truth at every MP
    degree — the §6 calibration contract (constants replaced by observations)."""
    truth = WorkerLatencyModel(t1=0.02, overlap=0.3)
    obs = [(mp, b, truth.base_token_time(mp, b))
           for mp in (1, 2, 4, 8) for b in (1.0, 3.0, 6.0)]
    fit = WorkerLatencyModel.fit(obs, comm_batch_coef=truth.comm_batch_coef)
    assert fit.t1 == pytest.approx(truth.t1, rel=1e-9)
    assert fit.overlap == pytest.approx(truth.overlap, rel=1e-9)
    for mp in (1, 2, 4, 8, 16):          # curve parity, incl. extrapolated degree
        assert fit.base_token_time(mp, 4.0) == pytest.approx(
            truth.base_token_time(mp, 4.0), rel=1e-9)


def test_latency_model_fit_tolerates_noise():
    rng = np.random.default_rng(3)
    truth = WorkerLatencyModel(t1=0.015, overlap=0.25)
    obs = [(mp, b, truth.base_token_time(mp, b) * float(rng.uniform(0.95, 1.05)))
           for mp in (1, 2, 4, 8) for b in (1.0, 2.0, 4.0, 8.0)]
    fit = WorkerLatencyModel.fit(obs, comm_batch_coef=truth.comm_batch_coef)
    assert fit.t1 == pytest.approx(truth.t1, rel=0.15)
    for mp in (1, 2, 4, 8):
        assert fit.base_token_time(mp, 2.0) == pytest.approx(
            truth.base_token_time(mp, 2.0), rel=0.15)


def test_latency_model_fit_degenerate_single_degree():
    """One distinct MP degree cannot identify overlap: the prior shape is kept
    and only t1 rescales to match the observed mean."""
    prior = WorkerLatencyModel()
    fit = WorkerLatencyModel.fit([(2, 1.0, 0.004), (2, 1.0, 0.006)])
    assert fit.overlap == prior.overlap
    assert fit.base_token_time(2, 1.0) == pytest.approx(0.005, rel=1e-9)
    with pytest.raises(ValueError):
        WorkerLatencyModel.fit([])
