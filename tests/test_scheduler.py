"""Progressive priority scheduling (Algorithm 1) and baseline disciplines."""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.scheduler import make_scheduler
from repro.core.trajectory import Trajectory


def _traj(pred, submit=0.0, pid=0):
    t = Trajectory(prompt_id=pid, sample_id=0, prompt_tokens=10)
    t.predicted_remaining = float(pred)
    t.submit_time = submit
    return t


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0, 1e6), min_size=1, max_size=30))
def test_pps_pops_longest_first(preds):
    s = make_scheduler("pps")
    for p in preds:
        s.submit(_traj(p), 0.0)
    out = [s.pop(0.0).predicted_total for _ in range(len(preds))]
    assert out == sorted(out, reverse=True)
    assert s.pop(0.0) is None


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0, 1e6), min_size=1, max_size=30))
def test_sjf_pops_shortest_first(preds):
    s = make_scheduler("sjf")
    for p in preds:
        s.submit(_traj(p), 0.0)
    out = [s.pop(0.0).predicted_total for _ in range(len(preds))]
    assert out == sorted(out)


def test_rr_is_submission_order():
    s = make_scheduler("rr")
    ts = [_traj(100 - i) for i in range(5)]
    for i, t in enumerate(ts):
        s.submit(t, float(i))
    assert [s.pop(9.0).traj_id for _ in range(5)] == [t.traj_id for t in ts]


def test_fcfs_orders_by_trajectory_arrival():
    s = make_scheduler("fcfs")
    a, b = _traj(1, submit=5.0), _traj(2, submit=1.0)
    s.submit(a, 10.0)
    s.submit(b, 11.0)           # later step submission, earlier trajectory arrival
    assert s.pop(0.0) is b


def test_pps_preemption_picks_lowest_priority_victim():
    s = make_scheduler("pps")
    active = [_traj(50), _traj(10), _traj(30)]
    for t in active:
        t.priority = t.predicted_total
    incoming = _traj(100)
    s.submit(incoming, 0.0)
    victim = s.preempt_victim(active)
    assert victim is active[1]                      # lowest priority active
    # no preemption when pending does not outrank the weakest active
    s2 = make_scheduler("pps")
    s2.submit(_traj(5), 0.0)
    assert s2.preempt_victim(active) is None


def test_resubmit_updates_priority_without_duplication():
    s = make_scheduler("pps")
    t = _traj(10)
    s.submit(t, 0.0)
    t.predicted_remaining = 1000.0
    s.submit(t, 1.0)                                # refreshed prediction re-queues
    assert len(s) == 1
    assert s.pop(1.0) is t
    assert s.pop(1.0) is None
