"""Progressive priority scheduling (Algorithm 1) and baseline disciplines."""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.scheduler import make_scheduler
from repro.core.trajectory import Trajectory


def _traj(pred, submit=0.0, pid=0):
    t = Trajectory(prompt_id=pid, sample_id=0, prompt_tokens=10)
    t.predicted_remaining = float(pred)
    t.submit_time = submit
    return t


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0, 1e6), min_size=1, max_size=30))
def test_pps_pops_longest_first(preds):
    s = make_scheduler("pps")
    for p in preds:
        s.submit(_traj(p), 0.0)
    out = [s.pop(0.0).predicted_total for _ in range(len(preds))]
    assert out == sorted(out, reverse=True)
    assert s.pop(0.0) is None


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0, 1e6), min_size=1, max_size=30))
def test_sjf_pops_shortest_first(preds):
    s = make_scheduler("sjf")
    for p in preds:
        s.submit(_traj(p), 0.0)
    out = [s.pop(0.0).predicted_total for _ in range(len(preds))]
    assert out == sorted(out)


def test_rr_is_submission_order():
    s = make_scheduler("rr")
    ts = [_traj(100 - i) for i in range(5)]
    for i, t in enumerate(ts):
        s.submit(t, float(i))
    assert [s.pop(9.0).traj_id for _ in range(5)] == [t.traj_id for t in ts]


def test_fcfs_orders_by_trajectory_arrival():
    s = make_scheduler("fcfs")
    a, b = _traj(1, submit=5.0), _traj(2, submit=1.0)
    s.submit(a, 10.0)
    s.submit(b, 11.0)           # later step submission, earlier trajectory arrival
    assert s.pop(0.0) is b


def test_pps_preemption_picks_lowest_priority_victim():
    s = make_scheduler("pps")
    active = [_traj(50), _traj(10), _traj(30)]
    for t in active:
        t.priority = t.predicted_total
    incoming = _traj(100)
    s.submit(incoming, 0.0)
    victim = s.preempt_victim(active)
    assert victim is active[1]                      # lowest priority active
    # no preemption when pending does not outrank the weakest active
    s2 = make_scheduler("pps")
    s2.submit(_traj(5), 0.0)
    assert s2.preempt_victim(active) is None


def test_preemption_floor_blocks_cold_predictor_thrash():
    """Regression: with a cold predictor every priority is 0 and the purely
    multiplicative hysteresis is vacuous (top > 0 * margin always preempts),
    causing eviction thrash.  The additive floor requires a real priority gap."""
    s = make_scheduler("pps")
    active = [_traj(0), _traj(0)]
    for t in active:
        t.priority = t.predicted_total
    s.submit(_traj(0), 0.0)                         # cold incoming: priority 0
    assert s.preempt_victim(active) is None         # 0 > 0 + floor is false
    # still no eviction below the floor...
    s2 = make_scheduler("pps")
    s2.submit(_traj(s2.preemption_floor * 0.5), 0.0)
    assert s2.preempt_victim(active) is None
    # ...but a clear gap preempts
    s3 = make_scheduler("pps")
    s3.submit(_traj(s3.preemption_floor + 1.0), 0.0)
    assert s3.preempt_victim(active) is active[0]


def test_preemption_no_thrash_on_equal_priorities():
    """Two equal-priority requests must never evict each other back and forth."""
    s = make_scheduler("pps")
    a, b = _traj(100), _traj(100)
    a.priority = a.predicted_total
    s.submit(b, 0.0)
    assert s.preempt_victim([a]) is None            # equal: margin+floor hold
    # swap roles: still no eviction, so no ping-pong cycle exists
    s2 = make_scheduler("pps")
    b.priority = b.predicted_total
    s2.submit(a, 0.0)
    assert s2.preempt_victim([b]) is None


def test_resubmit_updates_priority_without_duplication():
    s = make_scheduler("pps")
    t = _traj(10)
    s.submit(t, 0.0)
    t.predicted_remaining = 1000.0
    s.submit(t, 1.0)                                # refreshed prediction re-queues
    assert len(s) == 1
    assert s.pop(1.0) is t
    assert s.pop(1.0) is None
