"""Deterministic mini-hypothesis used when the real `hypothesis` is not installed.

The property-test modules import via

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st

so tier-1 collection never fails on a missing optional dependency, and the
invariants still run against a seeded deterministic sample instead of being
skipped.  Install the real engine (``pip install -r requirements-dev.txt``) for
full shrinking/coverage; this fallback supports exactly the strategy surface the
repo's tests use: ``integers``, ``floats``, ``lists``, ``tuples``.
"""

from __future__ import annotations

import functools
import random

_FALLBACK_EXAMPLES = 25          # per-test cap: cheap but enough to trip invariants


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*elements: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elements))


st = _Strategies()


def settings(**kwargs):
    """Accepts (and mostly ignores) hypothesis settings; honours max_examples."""
    def deco(fn):
        fn._fallback_max_examples = min(kwargs.get("max_examples",
                                                   _FALLBACK_EXAMPLES),
                                        _FALLBACK_EXAMPLES)
        return fn
    return deco


def given(*strategies: _Strategy):
    """Run the test body over a deterministic, per-test seeded example stream."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", _FALLBACK_EXAMPLES)
            rng = random.Random(fn.__qualname__)      # deterministic per test
            for _ in range(n):
                fn(*(s.example(rng) for s in strategies))
        # hide the wrapped signature: pytest must not read the strategy-filled
        # parameters as fixtures (real hypothesis rewrites the signature too)
        del wrapper.__wrapped__
        return wrapper
    return deco
