"""Per-architecture smoke tests (deliverable f) + decode/full-forward consistency.

Every assigned architecture instantiates a REDUCED variant (<=2 periods, d_model<=256,
<=4 experts) and runs one forward/train step on CPU, asserting shapes and no NaNs.
The consistency test proves the serving path (prefill -> cached decode) computes the
same function as the full forward — the property the real rollout engine relies on.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.models import model as M
from repro.rl.grpo import GRPOConfig, make_train_step
from repro.rl.optimizer import AdamW

KEY = jax.random.PRNGKey(0)


def reduced(name):
    full = get_config(name)
    periods = 2 if len(full.block_pattern) == 1 else 1
    cfg = full.reduced(n_periods=periods)
    if cfg.n_experts:   # no-drop capacity so decode == full forward exactly
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k + 1)
    return cfg


def make_batch(cfg, B, S, key=KEY):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.arch_type == "audio":
        batch["encoder_embeds"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = jax.random.normal(key, (B, cfg.image_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(arch)
    params = M.init_params(cfg, KEY)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits, aux = M.forward_full(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not np.isnan(np.asarray(logits)).any()
    assert np.isfinite(float(aux))

    # one GRPO train step
    opt = AdamW(lr=1e-4)
    step = make_train_step(cfg, GRPOConfig(group_size=2), opt)
    tb = dict(batch)
    tb["loss_mask"] = jnp.ones((B, S), jnp.float32)
    tb["advantages"] = jnp.asarray([1.0, -1.0])
    tb["old_logprobs"] = jnp.zeros((B, S), jnp.float32)
    params2, _, metrics = step(params, opt.init(params), tb)
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually moved
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_decode_matches_full_forward(arch):
    cfg = reduced(arch)
    params = M.init_params(cfg, KEY)
    B, S, extra = 2, 12, 3
    tokens = jax.random.randint(KEY, (B, S + extra), 0, cfg.vocab)
    bf = make_batch(cfg, B, S + extra)
    bf["tokens"] = tokens
    bp = dict(bf, tokens=tokens[:, :S])
    full_logits, _ = M.forward_full(cfg, params, bf)
    lg, _, cache = M.forward_full(cfg, params, bp, capacity=S + extra + 1)
    errs = [float(np.abs(np.asarray(lg[:, -1]) - np.asarray(full_logits[:, S - 1])).max())]
    for t in range(extra):
        dl, cache = M.decode_step(cfg, params, cache, tokens[:, S + t][:, None])
        errs.append(float(np.abs(np.asarray(dl) - np.asarray(full_logits[:, S + t])).max()))
    assert max(errs) < 2e-3, errs


def test_sliding_window_decode_consistency():
    """Windowed ring cache == full cache while context fits the window."""
    cfg = reduced("qwen3_1_7b")
    cfg_w = cfg.with_sliding_window(64)      # window larger than the test context
    params = M.init_params(cfg_w, KEY)
    B, S = 2, 12
    tokens = jax.random.randint(KEY, (B, S + 2), 0, cfg.vocab)
    full_logits, _ = M.forward_full(cfg_w, params, {"tokens": tokens})
    _, _, cache = M.forward_full(cfg_w, params, {"tokens": tokens[:, :S]}, capacity=64)
    dl, cache = M.decode_step(cfg_w, params, cache, tokens[:, S][:, None])
    assert float(np.abs(np.asarray(dl) - np.asarray(full_logits[:, S])).max()) < 2e-3


def test_sliding_window_truncates_attention():
    """With a small window, distant tokens must stop influencing the output."""
    cfg = dataclasses.replace(reduced("qwen3_1_7b"), sliding_window=4)
    params = M.init_params(cfg, KEY)
    B, S = 1, 12
    t1 = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % cfg.vocab)     # differs outside the window
    l1, _ = M.forward_full(cfg, params, {"tokens": t1})
    l2, _ = M.forward_full(cfg, params, {"tokens": t2})
    # last-position logits see only the last 4 tokens -> identical
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]), atol=1e-5)


def test_param_counts_scale_with_config():
    small = M.param_count(M.init_params(reduced("smollm_135m"), KEY))
    moe = M.param_count(M.init_params(reduced("qwen2_moe_a2_7b"), KEY))
    assert small > 0 and moe > small  # experts add parameters


def test_mlstm_chunked_equals_sequential():
    """The chunk-recurrent mLSTM (train path) must equal the step recurrence."""
    import repro.models.layers as L
    cfg = reduced("xlstm_350m")
    params = M.init_params(cfg, KEY)
    p = None           # find an mlstm mixer param set (period-stacked; take period 0)
    for k, v in params["blocks"].items():
        if "mlstm" in k:
            p = jax.tree.map(lambda x: x[0], v["mixer"])
            break
    assert p is not None
    B, S, D = 2, 37, cfg.d_model
    x = jax.random.normal(KEY, (B, S, D)) * 0.5
    full = L.mlstm_full(p, x, cfg)
    # sequential: feed tokens one by one through mlstm_step
    di = cfg.xlstm_expand * cfg.d_model
    hd = di // cfg.n_heads
    state = {"C": jnp.zeros((B, cfg.n_heads, hd, hd)),
             "n": jnp.zeros((B, cfg.n_heads, hd)),
             "m": jnp.full((B, cfg.n_heads), -1e30)}
    outs = []
    for t in range(S):
        o, state = L.mlstm_step(p, x[:, t:t+1], cfg, state)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq), atol=2e-4)


def test_mamba_fused_scan_equals_naive():
    """The fused chunked SSM scan must equal the naive full recurrence."""
    import repro.models.layers as L
    cfg = reduced("jamba_v0_1_52b")
    params = M.init_params(cfg, KEY)
    p = None
    for k, v in params["blocks"].items():
        if "mamba" in k:
            p = jax.tree.map(lambda x: x[0], v["mixer"])
            break
    B, S = 2, 41
    xc = jax.random.normal(KEY, (B, S, cfg.ssm_expand * cfg.d_model)) * 0.3
    fused = L._mamba_scan_fused(p, xc, cfg)
    # naive: sequential recurrence
    a, b, Cm = L._mamba_inner(p, xc, cfg)
    h = jnp.zeros(a.shape[:1] + a.shape[2:])
    ys = []
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        ys.append(jnp.einsum("bdn,bn->bd", h, Cm[:, t].astype(jnp.float32)))
    naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(naive), atol=1e-4)
