"""Discrete-event rollout simulator: conservation, determinism, policy matrix."""

import copy

import pytest

from repro.core.predictor import ProgressivePredictor
from repro.engine.simulator import RolloutSimulator, SimConfig, simulate
from repro.engine.workload import WorkloadConfig, generate, replay_finished


@pytest.fixture(scope="module")
def bench():
    hist = replay_finished(generate(WorkloadConfig(task="coding", n_prompts=16,
                                                   group_size=8, seed=1)))
    pred = ProgressivePredictor().fit_trajectories(hist)
    batch = generate(WorkloadConfig(task="coding", n_prompts=12, group_size=8, seed=2))
    return batch, pred


POLICIES = [
    dict(scheduler="pps", placement="heddle"),
    dict(scheduler="pps", placement="heddle", migration=False),
    dict(scheduler="rr", placement="cache_aware", degrees=(1,) * 8),
    dict(scheduler="rr", placement="least_load", degrees=(1,) * 8),
    dict(scheduler="rr", placement="hybrid", degrees=(1,) * 8),
    dict(scheduler="fcfs", placement="heddle", migration=False, degrees=(1,) * 8),
    dict(scheduler="sjf", placement="heddle", migration=False, degrees=(1,) * 8),
    dict(scheduler="pps", placement="heddle", degrees=(4, 4, 2, 2, 1, 1, 1, 1)),
]


@pytest.mark.parametrize("kw", POLICIES)
def test_every_policy_completes_all_trajectories(bench, kw):
    batch, pred = bench
    r = simulate(copy.deepcopy(batch), pred, gpu_budget=8, max_batch=16, seed=0, **kw)
    assert all(t.finished for t in r.trajectories)
    assert r.makespan > 0
    # token conservation: every planned token was generated
    expect = sum(t.true_total_tokens for t in batch)
    assert r.total_tokens == expect
    # steps executed exactly as planned
    for t in r.trajectories:
        assert t.num_steps == t.true_num_steps


def test_simulation_is_deterministic(bench):
    batch, pred = bench
    a = simulate(copy.deepcopy(batch), pred, gpu_budget=8, max_batch=16, seed=0)
    b = simulate(copy.deepcopy(batch), pred, gpu_budget=8, max_batch=16, seed=0)
    assert a.makespan == b.makespan
    assert a.migrations == b.migrations


def test_queueing_appears_under_slot_pressure(bench):
    batch, pred = bench
    r = simulate(copy.deepcopy(batch), pred, gpu_budget=2, max_batch=4,
                 scheduler="rr", placement="cache_aware", degrees=(1, 1), seed=0)
    delays = [t.total_queue_delay for t in r.trajectories]
    assert max(delays) > 0.0


def test_makespan_lower_bound(bench):
    """No trajectory can beat its bare generation + tool time."""
    batch, pred = bench
    cfg = SimConfig(gpu_budget=8, max_batch=16, seed=0)
    r = RolloutSimulator(copy.deepcopy(batch), pred, cfg).run()
    t1 = cfg.base_token_time
    for t in r.trajectories:
        bare = t.true_total_tokens * t1 / 8 + t.total_tool_time  # fastest possible (mp8)
        assert t.completion_time() >= bare * 0.5


def test_interference_slows_down_crowded_workers(bench):
    batch, pred = bench
    fast = simulate(copy.deepcopy(batch), pred, gpu_budget=8, max_batch=16,
                    kv_weight_ratio=0.0, seed=0, placement="cache_aware",
                    scheduler="rr", degrees=(1,) * 8)
    slow = simulate(copy.deepcopy(batch), pred, gpu_budget=8, max_batch=16,
                    kv_weight_ratio=0.05, seed=0, placement="cache_aware",
                    scheduler="rr", degrees=(1,) * 8)
    assert slow.makespan > fast.makespan


def test_measured_reuse_rate_scales_cache_miss_prefill(bench):
    """The simulator's cache model consumes the engine's *measured* radix reuse:
    lower measured reuse means a sibling arrival re-prefills more of the shared
    prompt, so miss tokens grow monotonically as the rate drops (rate=1.0 is the
    paper's assumed-full-reuse default)."""
    batch, pred = bench
    kw = dict(gpu_budget=8, max_batch=16, scheduler="rr",
              placement="least_load", degrees=(1,) * 8, seed=0)
    assumed = simulate(copy.deepcopy(batch), pred, **kw)
    full = simulate(copy.deepcopy(batch), pred, measured_reuse_rate=1.0, **kw)
    half = simulate(copy.deepcopy(batch), pred, measured_reuse_rate=0.5, **kw)
    none = simulate(copy.deepcopy(batch), pred, measured_reuse_rate=0.0, **kw)
    assert assumed.cache_miss_prefill_tokens == full.cache_miss_prefill_tokens
    assert full.cache_miss_prefill_tokens <= half.cache_miss_prefill_tokens \
        <= none.cache_miss_prefill_tokens


def test_controller_aggregates_engine_dispatch_stats():
    """Engine dispatch_stats -> controller.record_worker_stats ->
    measured_reuse_rate: the number SimConfig.measured_reuse_rate consumes."""
    from repro.core.controller import HeddleController
    from repro.core.placement import InterferenceModel
    from repro.core.resource_manager import WorkerLatencyModel

    ctrl = HeddleController(ProgressivePredictor(), InterferenceModel.analytic(0.02),
                            WorkerLatencyModel(), gpu_budget=2)
    assert ctrl.measured_reuse_rate is None          # no telemetry yet
    ctrl.record_worker_stats(0, {"reused_tokens": 30, "prefilled_tokens": 70})
    ctrl.record_worker_stats(1, {"reused_tokens": 10, "prefilled_tokens": 90})
    assert ctrl.measured_reuse_rate == pytest.approx(0.2)
    cfg = SimConfig(measured_reuse_rate=ctrl.measured_reuse_rate)
    assert cfg.measured_reuse_rate == pytest.approx(0.2)
