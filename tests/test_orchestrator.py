"""Unified orchestrator: sim-vs-engine decision-trace parity, backend contract,
per-(traj, step) tool seeding, and the RL trainer's path through the stack."""

import copy
import math

import jax
import pytest

from repro.configs import get_config
from repro.engine.runtime import (RuntimeConfig, build_workbench, make_runtime,
                                  run_on_sim)
from repro.models import model as M

SEED = 5          # the seeded long-tail workload bench_rollout pins


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm_135m").reduced(n_periods=1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _parity_pair(cfg, params, migration: bool):
    """Run one workload on the real engine and on its analytic twin.

    An infinite migration link makes transfer time the pure base latency on
    both sides (the engine prices *measured* lane bytes, the sim analytic KV
    bytes — with finite bandwidth those differ and may reorder co-timed
    events; decision parity is about scheduling, not the transfer-time model).
    """
    batch, predictor = build_workbench(n_prompts=6, group_size=4, seed=SEED)
    twin = copy.deepcopy(batch)
    rcfg = RuntimeConfig(scheduler="pps", migration=migration, max_active=2,
                         quantum=8, link_bandwidth=math.inf, trace=True,
                         seed=SEED)
    eng = make_runtime(cfg, params, batch, predictor, n_workers=2,
                       config=rcfg).run()
    sim = run_on_sim(twin, predictor, n_workers=2, config=rcfg)
    return eng, sim


def test_decision_trace_parity_with_migration(setup):
    """The tentpole invariant: same workload + same policy => the SimBackend
    and the EngineBackend produce the IDENTICAL (event, traj, worker) decision
    sequence — scheduling is a property of the policy, not the substrate."""
    cfg, params = setup
    eng, sim = _parity_pair(cfg, params, migration=True)
    assert eng.preemptions > 0 and eng.migrations > 0   # the test must bite
    assert len(eng.trace) == len(sim.trace) > 0
    assert eng.trace == sim.trace
    # identical decisions under identical pricing => identical virtual time
    assert eng.makespan == sim.makespan
    assert eng.preemptions == sim.preemptions
    assert eng.migrations == sim.migrations


def test_decision_trace_parity_migration_off(setup):
    cfg, params = setup
    eng, sim = _parity_pair(cfg, params, migration=False)
    assert eng.migrations == sim.migrations == 0
    assert eng.preemptions > 0
    assert eng.trace == sim.trace
    assert eng.makespan == sim.makespan


def test_core_exports_orchestrator_api():
    """core's public API includes the orchestrator/backend seam."""
    import repro.core as core

    for name in ("Orchestrator", "OrchestratorConfig", "OrchestratorResult",
                 "ExecutionBackend", "StepOutcome"):
        assert hasattr(core, name), name
        assert name in core.__all__


def test_simulate_and_runtime_share_the_loop():
    """Both public entry points must drive core.orchestrator (no twin loops)."""
    import inspect

    from repro.engine import runtime, simulator

    assert "Orchestrator" in inspect.getsource(simulator.RolloutSimulator.run)
    assert "Orchestrator" in inspect.getsource(runtime.RolloutRuntime.run)
    assert not hasattr(runtime.RolloutRuntime, "_on_worker_ready")


# ------------------------------------------------- tool seeding (regression)

def test_tool_environment_latency_independent_of_invocation_order():
    """Regression: sampled tool latencies must be seeded per (traj, step), not
    per call sequence — two backends interleaving the batch differently (or a
    different scheduling order) must observe identical latencies."""
    from repro.engine.runtime import ToolEnvironment

    a = ToolEnvironment(seed=7)
    b = ToolEnvironment(seed=7)
    # a: trajectory 3 first; b: lots of other traffic first, then trajectory 3
    lat_a = [a.sample_latency(3, s) for s in range(4)]
    for other in (11, 12, 13):
        for s in range(4):
            b.sample_latency(other, s)
    lat_b = [b.sample_latency(3, s) for s in reversed(range(4))]
    assert lat_a == list(reversed(lat_b))
    assert len(set(lat_a)) > 1                       # distinct streams per step


def test_tool_executor_seeded_per_traj_step():
    """Regression: ToolExecutor used one sequential rng — outcome depended on
    global invocation order across trajectories."""
    from repro.engine.tools import TOOL_PROFILES, ToolExecutor

    x = ToolExecutor(TOOL_PROFILES["coding"], seed=3)
    y = ToolExecutor(TOOL_PROFILES["coding"], seed=3)
    first = x.invoke(traj_id=5, step=0)
    x.invoke(traj_id=6, step=0)                      # interleaved other traffic
    for _ in range(3):
        y.invoke(traj_id=9, step=2)
    assert y.invoke(traj_id=5, step=0) == first
    assert x.invoke(traj_id=5, step=1) != first      # per-step streams differ


# ------------------------------------------------- RL training on the stack

def test_trainer_rollout_runs_through_the_orchestrator(setup):
    """HeddleTrainer.rollout() is no longer a static side-car loop: its
    trajectories flow through real scheduler queues (nonzero queue delay) and,
    once the predictor has history, preemptive execution engages."""
    import repro.rl.data as D
    from repro.rl.loop import HeddleTrainer, TrainerConfig

    cfg, _ = setup
    tr = HeddleTrainer(cfg, TrainerConfig(group_size=4, n_workers=2, seed=0))
    total_preempt = 0
    for it in range(2):
        records = tr.rollout(D.sample_tasks(4, seed=1_000 + it))
        assert len(records) == 16
        ro = tr.last_rollout
        assert ro is not None
        assert ro.queue_delay_mean > 0.0             # real queueing happened
        assert all(t.finished for t in ro.trajectories)
        assert all(t.worker_id is not None for t in ro.trajectories)
        total_preempt += ro.preemptions
        tr.update(records)
    # after the first refit the progressive predictions differentiate the
    # batch and Algorithm 1's preemptive execution engages
    assert total_preempt > 0
