"""Unified orchestrator: sim-vs-engine decision-trace parity, backend contract,
per-(traj, step) tool seeding, and the RL trainer's path through the stack."""

import copy
import math

import jax
import pytest

from repro.configs import get_config
from repro.engine.runtime import (RuntimeConfig, build_workbench, make_runtime,
                                  run_on_sim)
from repro.models import model as M

SEED = 5          # the seeded long-tail workload bench_rollout pins


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm_135m").reduced(n_periods=1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _parity_pair(cfg, params, migration: bool):
    """Run one workload on the real engine and on its analytic twin.

    An infinite migration link makes transfer time the pure base latency on
    both sides (the engine prices *measured* lane bytes, the sim analytic KV
    bytes — with finite bandwidth those differ and may reorder co-timed
    events; decision parity is about scheduling, not the transfer-time model).
    """
    batch, predictor = build_workbench(n_prompts=6, group_size=4, seed=SEED)
    twin = copy.deepcopy(batch)
    # sanitize=True: the TraceSanitizer validates every decision on BOTH
    # backends (and run() raises on any invariant violation), so parity is
    # proven over a stream that is itself checked for causal legality
    rcfg = RuntimeConfig(scheduler="pps", migration=migration, max_active=2,
                         quantum=8, link_bandwidth=math.inf, trace=True,
                         seed=SEED, sanitize=True)
    eng = make_runtime(cfg, params, batch, predictor, n_workers=2,
                       config=rcfg).run()
    sim = run_on_sim(twin, predictor, n_workers=2, config=rcfg)
    assert eng.sanitizer["violations"] == sim.sanitizer["violations"] == 0
    return eng, sim


def test_decision_trace_parity_with_migration(setup):
    """The tentpole invariant: same workload + same policy => the SimBackend
    and the EngineBackend produce the IDENTICAL (event, traj, worker) decision
    sequence — scheduling is a property of the policy, not the substrate."""
    cfg, params = setup
    eng, sim = _parity_pair(cfg, params, migration=True)
    assert eng.preemptions > 0 and eng.migrations > 0   # the test must bite
    assert len(eng.trace) == len(sim.trace) > 0
    assert eng.trace == sim.trace
    # identical decisions under identical pricing => identical virtual time
    assert eng.makespan == sim.makespan
    assert eng.preemptions == sim.preemptions
    assert eng.migrations == sim.migrations


def test_decision_trace_parity_migration_off(setup):
    cfg, params = setup
    eng, sim = _parity_pair(cfg, params, migration=False)
    assert eng.migrations == sim.migrations == 0
    assert eng.preemptions > 0
    assert eng.trace == sim.trace
    assert eng.makespan == sim.makespan


def test_core_exports_orchestrator_api():
    """core's public API includes the orchestrator/backend seam."""
    import repro.core as core

    for name in ("Orchestrator", "OrchestratorConfig", "OrchestratorResult",
                 "ExecutionBackend", "StepOutcome"):
        assert hasattr(core, name), name
        assert name in core.__all__


def test_simulate_and_runtime_share_the_loop():
    """Both public entry points must drive core.orchestrator (no twin loops)."""
    import inspect

    from repro.engine import runtime, simulator

    assert "Orchestrator" in inspect.getsource(simulator.RolloutSimulator.run)
    assert "Orchestrator" in inspect.getsource(runtime.RolloutRuntime.run)
    assert not hasattr(runtime.RolloutRuntime, "_on_worker_ready")


# ------------------------------------------------- tool seeding (regression)

def test_tool_environment_latency_independent_of_invocation_order():
    """Regression: sampled tool latencies must be seeded per (traj, step), not
    per call sequence — two backends interleaving the batch differently (or a
    different scheduling order) must observe identical latencies."""
    from repro.engine.runtime import ToolEnvironment

    a = ToolEnvironment(seed=7)
    b = ToolEnvironment(seed=7)
    # a: trajectory 3 first; b: lots of other traffic first, then trajectory 3
    lat_a = [a.sample_latency(3, s) for s in range(4)]
    for other in (11, 12, 13):
        for s in range(4):
            b.sample_latency(other, s)
    lat_b = [b.sample_latency(3, s) for s in reversed(range(4))]
    assert lat_a == list(reversed(lat_b))
    assert len(set(lat_a)) > 1                       # distinct streams per step


def test_tool_executor_seeded_per_traj_step():
    """Regression: ToolExecutor used one sequential rng — outcome depended on
    global invocation order across trajectories."""
    from repro.engine.tools import TOOL_PROFILES, ToolExecutor

    x = ToolExecutor(TOOL_PROFILES["coding"], seed=3)
    y = ToolExecutor(TOOL_PROFILES["coding"], seed=3)
    first = x.invoke(traj_id=5, step=0)
    x.invoke(traj_id=6, step=0)                      # interleaved other traffic
    for _ in range(3):
        y.invoke(traj_id=9, step=2)
    assert y.invoke(traj_id=5, step=0) == first
    assert x.invoke(traj_id=5, step=1) != first      # per-step streams differ


# --------------------------------------- determinism regressions (heddle-lint)

def test_preempt_candidates_arrive_in_canonical_order(monkeypatch):
    """Regression (HDL002): the dispatch loop iterated ``lane.active`` — a set
    — when building preempt_victim's candidate list, so priority ties broke by
    hash order and CPython set internals leaked into the decision trace.  The
    orchestrator must hand the scheduler a canonically ordered (sorted by
    traj_id) candidate list at every preemption decision."""
    from repro.core.scheduler import PPSScheduler

    seen: list[list[int]] = []
    orig = PPSScheduler.preempt_victim

    def spy(self, active):
        seen.append([t.traj_id for t in active])
        return orig(self, active)

    monkeypatch.setattr(PPSScheduler, "preempt_victim", spy)
    batch, predictor = build_workbench(n_prompts=6, group_size=4, seed=SEED)
    res = run_on_sim(batch, predictor, n_workers=2,
                     config=RuntimeConfig(scheduler="pps", migration=True,
                                          max_active=2, quantum=8, seed=SEED))
    assert res.preemptions > 0 and len(seen) > 0    # the spy actually bit
    assert all(tids == sorted(tids) for tids in seen)


def test_decode_loop_defers_host_sync_past_the_loop():
    """Regression (HDL003): the worker decode loop called ``np.asarray(em)``
    on every chunk — a device→host sync per iteration.  Emitted tokens must
    stay device-resident inside the loop (one justified early-exit sync
    excepted) and be fetched once after it."""
    import ast
    import inspect
    import textwrap

    from repro.engine import worker as W

    src = textwrap.dedent(inspect.getsource(W.RolloutWorker.decode))
    tree = ast.parse(src)
    loop = next(n for n in ast.walk(tree) if isinstance(n, ast.While))
    syncs = [n.lineno for n in ast.walk(loop)
             if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
             and n.func.attr == "asarray"]
    # exactly the one noqa'd early-exit liveness check remains in-loop
    assert len(syncs) == 1
    lines = src.splitlines()
    assert "noqa HDL003" in lines[syncs[0] - 1]


def test_pinned_workload_trace_unchanged_by_lint_fixes():
    """The HDL002/HDL003 fixes must be trace-neutral: on the pinned seed-5
    smoke workload the virtual makespan and decision counters are unchanged
    (verified against pre-fix code; small dense int ids already iterated in
    ascending set order — the sorted() fix removes the hazard, not current
    behavior).  Any future change to these numbers is a decision-trace
    change and needs the BENCH_* artifacts regenerated.

    Makespan re-pinned when the paged KV pool landed: migration transfer time
    is priced from resident-page bytes instead of full-lane bytes, which moves
    the virtual clock without touching a single decision — the preemption /
    migration / event counters below are byte-for-byte the pre-paging trace."""
    batch, predictor = build_workbench(n_prompts=6, group_size=4, seed=SEED)
    res = run_on_sim(batch, predictor, n_workers=2,
                     config=RuntimeConfig(scheduler="pps", migration=True,
                                          max_active=2, quantum=8, seed=SEED,
                                          sanitize=True))
    assert res.makespan == 2.976646631992511
    assert res.preemptions == 12 and res.migrations == 28
    assert res.events == 604
    assert res.sanitizer["violations"] == 0


def test_replays_do_not_consume_the_global_id_counter():
    """Regression: predictor.harvest and workload.replay_finished built
    throwaway replay Trajectories with default (global-counter) ids, so every
    harvest shifted the ids of all later batches — and ids seed per-(traj,
    step) tool outcomes, making rollout behavior depend on unrelated prior
    runs in the same process (the trainer test failed or passed depending on
    which tests ran before it)."""
    from repro.core.predictor import harvest
    from repro.core.trajectory import StepRecord, Trajectory

    src = Trajectory(prompt_id=0, sample_id=0, prompt_tokens=4,
                     context_tokens=4)
    src.record_step(StepRecord(0, 8, 0.1, tool_output_tokens=2))
    src.record_tool_output(2)
    src.finished = True
    src.true_total_tokens = 8
    before = Trajectory(prompt_id=9, sample_id=0, prompt_tokens=1,
                        context_tokens=1)
    harvest([src])
    harvest([src], first_step_only=True)
    after = Trajectory(prompt_id=9, sample_id=1, prompt_tokens=1,
                       context_tokens=1)
    assert after.traj_id == before.traj_id + 1


def test_trainer_ids_are_instance_local():
    """The trainer's trajectory ids must come from an instance-local base (0,
    1, 2, ...), not the process-global counter."""
    import repro.rl.data as D
    from repro.rl.loop import HeddleTrainer, TrainerConfig
    from repro.configs import get_config

    cfg = get_config("smollm_135m").reduced(n_periods=1)
    tr = HeddleTrainer(cfg, TrainerConfig(group_size=2, n_workers=1, seed=0,
                                          max_steps_per_traj=1))
    tr.rollout(D.sample_tasks(2, seed=1))
    ids = sorted(t.traj_id for t in tr.last_rollout.trajectories)
    assert ids == [0, 1, 2, 3]
    tr.rollout(D.sample_tasks(2, seed=2))
    ids = sorted(t.traj_id for t in tr.last_rollout.trajectories)
    assert ids == [4, 5, 6, 7]


# ------------------------------------------------- RL training on the stack

def test_trainer_rollout_runs_through_the_orchestrator(setup):
    """HeddleTrainer.rollout() is no longer a static side-car loop: its
    trajectories flow through real scheduler queues (nonzero queue delay) and,
    once the predictor has history, preemptive execution engages."""
    import repro.rl.data as D
    from repro.rl.loop import HeddleTrainer, TrainerConfig

    cfg, _ = setup
    tr = HeddleTrainer(cfg, TrainerConfig(group_size=4, n_workers=2, seed=0))
    total_preempt = 0
    for it in range(2):
        records = tr.rollout(D.sample_tasks(4, seed=1_000 + it))
        assert len(records) == 16
        ro = tr.last_rollout
        assert ro is not None
        assert ro.queue_delay_mean > 0.0             # real queueing happened
        assert all(t.finished for t in ro.trajectories)
        assert all(t.worker_id is not None for t in ro.trajectories)
        total_preempt += ro.preemptions
        tr.update(records)
    # after the first refit the progressive predictions differentiate the
    # batch and Algorithm 1's preemptive execution engages
    assert total_preempt > 0
