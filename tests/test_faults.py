"""Failure realism: seeded fault injection, tool retry discipline, trajectory
checkpoint/restore after worker death, and chaos parity across both backends."""

import copy
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.faults import FaultPlan, RetryPolicy, resolve_tool_call
from repro.engine.runtime import (RuntimeConfig, ToolEnvironment, build_workbench,
                                  make_runtime, run_on_sim)
from repro.models import model as M

SEED = 5


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm_135m").reduced(n_periods=1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _rcfg(**kw):
    base = dict(scheduler="pps", migration=True, max_active=2, quantum=8,
                link_bandwidth=math.inf, seed=SEED)
    base.update(kw)
    return RuntimeConfig(**base)


def _chaos(horizon: float) -> FaultPlan:
    plan = FaultPlan.chaos(seed=SEED, n_workers=2, horizon=horizon)
    assert plan.deaths and plan.tool_timeout_rate >= 0.10
    return plan


# ------------------------------------------------------------ fault plan units

def test_retry_policy_backoff_capped():
    r = RetryPolicy(max_attempts=5, backoff_base=0.05, backoff_factor=2.0,
                    backoff_cap=0.15)
    assert r.backoff(0) == 0.05
    assert r.backoff(1) == 0.10
    assert r.backoff(2) == 0.15          # capped
    assert r.backoff(9) == 0.15
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)


def test_retry_policy_backoff_full_jitter_deterministic():
    """Seeded full jitter: bit-reproducible per (traj, step, attempt), bounded
    by the un-jittered ceiling, decorrelated across the seed tuple — and the
    no-seed path stays the exact ceiling (the pre-jitter contract)."""
    r = RetryPolicy(max_attempts=5, backoff_base=0.05, backoff_factor=2.0,
                    backoff_cap=0.15)
    kw = dict(seed=7, traj_id=3, step=1)
    waits = [r.backoff(k, **kw) for k in range(4)]
    assert waits == [r.backoff(k, **kw) for k in range(4)]   # deterministic
    for k, w in enumerate(waits):
        assert 0.0 <= w <= r.backoff(k)          # jitter never exceeds ceiling
    # the draw is domain-separated: any coordinate change moves the wait
    assert r.backoff(2, **kw) != r.backoff(2, seed=8, traj_id=3, step=1)
    assert r.backoff(2, **kw) != r.backoff(2, seed=7, traj_id=4, step=1)
    assert r.backoff(2, **kw) != r.backoff(2, seed=7, traj_id=3, step=2)
    # capped attempts share a ceiling but still jitter independently
    assert r.backoff(2, **kw) != r.backoff(9, **kw)


def test_fault_plan_rates_must_leave_room_for_success():
    with pytest.raises(ValueError):
        FaultPlan(tool_timeout_rate=0.6, tool_error_rate=0.5)


def test_tool_fault_seeded_per_traj_step_attempt():
    """Fault rolls depend only on (seed, traj, step, attempt) — never on call
    order — so sim and engine observe identical injected outcomes."""
    a = FaultPlan(seed=3, tool_timeout_rate=0.3, tool_error_rate=0.2)
    b = FaultPlan(seed=3, tool_timeout_rate=0.3, tool_error_rate=0.2)
    rolls_a = [a.tool_fault(7, s, k) for s in range(6) for k in range(3)]
    for other in (11, 12):               # interleave unrelated traffic on b
        b.tool_fault(other, 0, 0)
    rolls_b = [b.tool_fault(7, s, k) for s in range(6) for k in range(3)]
    assert rolls_a == rolls_b
    assert len(set(rolls_a)) > 1         # rates actually produce mixed outcomes
    # retries see a fresh (but reproducible) roll per attempt
    assert FaultPlan(seed=3, tool_timeout_rate=0.5).tool_fault(1, 0, 0) == \
        FaultPlan(seed=3, tool_timeout_rate=0.5).tool_fault(1, 0, 0)


def test_resolve_tool_call_bounds_delay_never_outcome():
    """The final allowed attempt always succeeds: chaos perturbs timing, not
    task results — injected delay is capped by the retry policy."""
    faults = FaultPlan(seed=0, tool_timeout_rate=0.55, tool_error_rate=0.35,
                       tool_timeout_s=1.0)
    retry = RetryPolicy(max_attempts=3, backoff_base=0.1, backoff_cap=0.2)
    for tid in range(40):
        tr = resolve_tool_call(faults, retry, tid, 0, base_latency=0.25)
        assert 1 <= tr.attempts <= retry.max_attempts
        assert tr.injected_faults == tr.attempts - 1
        assert tr.latency >= 0.25        # the successful attempt always runs
        # worst case: 2 faulted attempts (timeout 1.0 each) + backoffs + success
        assert tr.latency <= 2 * 1.0 + 0.1 + 0.2 + 0.25 + 1e-9
    clean = resolve_tool_call(None, retry, 0, 0, base_latency=0.25)
    assert clean.latency == 0.25 and clean.attempts == 1


def test_tool_executor_faults_stretch_latency_not_outcome():
    """ToolExecutor under a FaultPlan: identical plan-driven (failed, tokens),
    only latency and retry telemetry change."""
    from repro.engine.tools import TOOL_PROFILES, ToolExecutor

    faults = FaultPlan(seed=9, tool_timeout_rate=0.5, tool_error_rate=0.2)
    clean = ToolExecutor(TOOL_PROFILES["coding"], seed=3)
    chaos = ToolExecutor(TOOL_PROFILES["coding"], seed=3, faults=faults)
    stretched = 0
    for tid in range(20):
        lat_c, failed_c, out_c = clean.invoke(tid, 0)
        lat_f, failed_f, out_f = chaos.invoke(tid, 0)
        assert (failed_f, out_f) == (failed_c, out_c)
        assert lat_f >= lat_c - 1e-12
        stretched += lat_f > lat_c
    assert stretched > 0 and chaos.injected_faults > 0
    assert chaos.retries == chaos.injected_faults


def test_tool_environment_faults_preserve_plan_outcomes():
    """ToolEnvironment: injected faults never touch failed/output tokens, and
    the terminal step injects nothing (no tool runs on either backend)."""
    batch, _ = build_workbench(n_prompts=2, group_size=2, seed=SEED)
    traj = max(batch, key=lambda t: t.payload.num_steps)
    faults = FaultPlan(seed=SEED, tool_timeout_rate=0.6, tool_error_rate=0.3)
    clean = ToolEnvironment(seed=SEED)
    chaos = ToolEnvironment(seed=SEED, faults=faults)
    for s in range(traj.payload.num_steps - 1):
        a, b = clean.invoke(traj, s), chaos.invoke(traj, s)
        assert (a.failed, a.output_tokens) == (b.failed, b.output_tokens)
        assert b.latency >= a.latency
        assert b.injected_faults == b.attempts - 1
    last = traj.payload.num_steps - 1
    term = chaos.step_outcome(traj, last, [], [])
    assert term.terminal and term.attempts == 1 and term.injected_faults == 0


# ------------------------------------------------------------ chaos end to end

def _chaos_pair(cfg, params):
    batch, predictor = build_workbench(n_prompts=6, group_size=4, seed=SEED)
    rcfg = _rcfg()
    base = run_on_sim(copy.deepcopy(batch), predictor, n_workers=2, config=rcfg)
    faults = _chaos(base.makespan)
    eng = make_runtime(cfg, params, copy.deepcopy(batch), predictor, n_workers=2,
                       config=rcfg, faults=faults).run()
    sim = run_on_sim(copy.deepcopy(batch), predictor, n_workers=2, config=rcfg,
                     faults=faults)
    return base, eng, sim


def test_chaos_all_trajectories_finish_on_both_backends(setup):
    """The tentpole acceptance: a seeded schedule with one mid-run worker death
    and >=10% tool timeouts still drains every trajectory to FINISHED on both
    backends, recovering residents from their tool-boundary checkpoints."""
    cfg, params = setup
    base, eng, sim = _chaos_pair(cfg, params)
    for res in (eng, sim):
        assert all(t.finished for t in res.trajectories)
        assert res.worker_deaths == 1
        assert res.recoveries > 0
        assert res.injected_tool_faults > 0
        assert res.makespan > base.makespan      # chaos is not free
    # no token loss past the last tool boundary: every recorded step survived
    for t in eng.trajectories:
        assert t.tokens_generated == sum(s.gen_tokens for s in t.steps)
        assert t.tokens_generated == t.payload.total_tokens


def test_chaos_decision_parity_sim_vs_engine(setup):
    """Under an infinite link both backends make identical fault decisions:
    same deaths, same recoveries, same injected faults, same virtual makespan —
    chaos is scheduled state, not substrate behavior."""
    cfg, params = setup
    _, eng, sim = _chaos_pair(cfg, params)
    assert eng.worker_deaths == sim.worker_deaths
    assert eng.recoveries == sim.recoveries
    assert eng.injected_tool_faults == sim.injected_tool_faults
    assert eng.tool_retries == sim.tool_retries
    assert eng.makespan == sim.makespan


def test_no_fault_path_untouched(setup):
    """faults=None must be byte-for-byte the PR-5 behavior: zero chaos
    telemetry and the decision-trace parity invariant intact."""
    cfg, params = setup
    batch, predictor = build_workbench(n_prompts=6, group_size=4, seed=SEED)
    rcfg = _rcfg(trace=True)
    eng = make_runtime(cfg, params, copy.deepcopy(batch), predictor,
                       n_workers=2, config=rcfg).run()
    sim = run_on_sim(copy.deepcopy(batch), predictor, n_workers=2, config=rcfg)
    assert eng.worker_deaths == sim.worker_deaths == 0
    assert eng.recoveries == sim.recoveries == 0
    assert eng.injected_tool_faults == sim.injected_tool_faults == 0
    assert eng.trace == sim.trace and eng.makespan == sim.makespan


def test_injected_faults_disentangled_from_plan_failures(setup):
    """The rectification signal (plan-driven tool failures) is identical with
    and without chaos — predictor features never see injected faults."""
    cfg, params = setup
    base, eng, sim = _chaos_pair(cfg, params)
    plan_failures = {t.traj_id: t.failed_tool_calls for t in base.trajectories}
    for res in (eng, sim):
        for t in res.trajectories:
            assert t.failed_tool_calls == plan_failures[t.traj_id]
            assert t.injected_tool_faults == t.tool_retries
    # and the feature vector itself carries no chaos channel
    from repro.core.trajectory import FEATURE_DIM
    t = eng.trajectories[0]
    assert len(t.features()) == FEATURE_DIM


# ------------------------------------------------------------ data plane units

def test_checkpoint_out_keeps_lane_resident_and_restores_elsewhere(setup):
    """checkpoint_out host-gathers without evicting; migrate_in of the package
    on another worker reproduces the exact context tokens."""
    from repro.engine.worker import RolloutWorker

    cfg, params = setup
    a = RolloutWorker(cfg, params, capacity=64, max_slots=2, worker_id=0)
    b = RolloutWorker(cfg, params, capacity=64, max_slots=2, worker_id=1)
    a.prefill(7, [5, 6, 7, 8])
    a.decode([7], 4)
    pkg = a.checkpoint_out(7)
    assert 7 in a.store                          # still resident at the source
    before = list(a.store[7].tokens)
    a.decode([7], 2)                             # source keeps decoding
    b.migrate_in(pkg)
    assert list(b.store[7].tokens) == before     # boundary state, bit-exact
    assert b.store[7].generated == pkg["generated"]
    assert not b.store[7].preempted and not b.store[7].finished


def test_orchestrator_all_workers_dead_raises():
    """Killing the whole fleet is unrecoverable and must fail loudly."""
    batch, predictor = build_workbench(n_prompts=2, group_size=2, seed=SEED)
    faults = FaultPlan(seed=0, deaths=((0.01, 0), (0.02, 1)))
    with pytest.raises(RuntimeError, match="dead"):
        run_on_sim(copy.deepcopy(batch), predictor, n_workers=2,
                   config=_rcfg(), faults=faults)


# ------------------------------------------------------------ elastic fleets

def test_elastic_reconfigure_shrink_and_grow(setup):
    """The dynamic case of Algorithm 2: a death shrinks the budget and the
    fleet re-partitions onto survivors (residents redistribute, worker_id
    re-pointed); recovery grows it back."""
    from repro.engine.fleet import FleetSpec

    cfg, params = setup
    batch, predictor = build_workbench(n_prompts=4, group_size=2, seed=SEED)
    rt = make_runtime(cfg, params, batch, predictor, n_workers=3,
                      config=_rcfg(migration=False))
    res = rt.run()
    assert all(t.finished for t in res.trajectories)
    report = rt.reconfigure(FleetSpec.homogeneous(2), calibrate=False)
    assert report["to"] == [1, 1]
    assert len(rt.workers) == 2
    assert all(t.worker_id is None or t.worker_id < 2 for t in rt.trajs)
    report = rt.reconfigure(FleetSpec.homogeneous(3), calibrate=False)
    assert report["to"] == [1, 1, 1]
    assert len(rt.workers) == 3


def test_reconfigure_budget_override(setup):
    """reconfigure(budget=...) provisions Algorithm 2 under the shrunken
    budget without permanently mutating the controller."""
    cfg, params = setup
    batch, predictor = build_workbench(n_prompts=4, group_size=2, seed=SEED)
    rt = make_runtime(cfg, params, batch, predictor, n_workers=2,
                      config=_rcfg(migration=False))
    rt.run()
    before = rt.controller.gpu_budget
    report = rt.reconfigure(budget=1, calibrate=False)
    assert sum(report["to"]) <= 1
    assert rt.controller.gpu_budget == before    # override did not stick
