"""noqa fixture: every violation here carries a justification suppression."""
import time


def stamp():
    return time.time()  # heddle: noqa HDL001 -- fixture: telemetry only


def drain(active: set):
    return [t for t in active]  # heddle: noqa -- fixture: order-insensitive sum


def half_suppressed(active: set):
    # wrong id: HDL001 noqa does NOT silence the HDL002 hit on line 15
    return [t for t in active]  # heddle: noqa HDL001
