"""HDL005 fixture: host-gather of KV buffers in migration/checkpoint paths.

Line numbers are pinned by tests/test_analysis.py — keep edits append-only.
"""
import jax
import jax.numpy as jnp
import numpy as np


def migrate_out(seq, pool):
    pkg = {"tokens": list(seq.tokens)}
    pkg["cache"] = jax.tree.map(np.asarray, pool)   # line 12: tree-mapped gather
    pkg["key"] = np.asarray(seq.key)                # fine: metadata, not KV
    return pkg


def checkpoint_lane(lane, blocks):
    host = jax.device_get(lane)                     # line 18: device_get of a lane
    resident = np.asarray(blocks)                   # line 19: block-stack gather
    return host, resident


def restore_cache(package):
    return jax.tree.map(jnp.asarray, package["cache"])  # fine: host -> device


def gather_stats(pool):
    # not a migration-family function: host gathers are legal here
    return np.asarray(pool["cache"])


def migrate_with_noqa(seq, pool):
    return jax.tree.map(np.asarray, pool)  # heddle: noqa HDL005 -- durability copy must outlive the device
