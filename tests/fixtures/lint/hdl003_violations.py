"""HDL003 fixture: jit static-argname hygiene + host syncs in hot loops.

Line numbers are pinned by tests/test_analysis.py — keep edits append-only.
"""
from functools import partial

import jax
import numpy as np


@jax.jit                                    # line 11: mesh not pinned static
def shard_step(params, batch, mesh):
    return params, batch, mesh


def decode_loop(tokens, emitted):
    parts = []
    for tok in tokens:
        parts.append(np.asarray(tok))       # line 19: host sync per token
        done = emitted.item()               # line 20: host sync per token
        if done:
            break
    return parts


@partial(jax.jit, static_argnames=("mesh",))
def shard_step_ok(params, batch, mesh):     # fine: mesh is static
    return params, batch, mesh


def cold_path(xs):
    # not a decode/prefill/extend function: syncs here are legal
    return [np.asarray(x) for x in xs]
