"""Negative fixture: control-plane-legal code — zero violations expected."""
import numpy as np


def advance(active: set, rng: np.random.Generator):
    order = sorted(active)
    weights = rng.random(len(order))
    return [tid for tid, _ in zip(order, weights)]


def virtual_clock(now: float, dt: float) -> float:
    return now + dt
