"""HDL002 fixture: hash-order iteration in decision paths (linted as CONTROL).

Line numbers are pinned by tests/test_analysis.py — keep edits append-only.
"""


def drain(active: set, table: dict):
    out = []
    for tid in active:                      # line 9: set iteration
        out.append(tid)
    for key in table.keys():                # line 11: dict.keys() iteration
        out.append(key)
    return out


def union_walk(a: set, b: set):
    return [x for x in a | b]               # line 17: set-union comprehension


def sorted_ok(active: set, table: dict):
    out = [tid for tid in sorted(active)]   # fine: canonical order
    out += [k for k in sorted(table)]       # fine
    return out


def local_list_ok(degrees):
    # a *different* function rebinding the name to a set must not leak here
    return [d for d in degrees]             # fine: param, not a set in scope


def _rebinds_elsewhere(degrees):
    degrees = set(degrees)
    return sorted(degrees)                  # fine: sorted
