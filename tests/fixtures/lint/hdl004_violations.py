"""HDL004 fixture: event-kind push/handle drift + unstamped tuple payloads.

Line numbers are pinned by tests/test_analysis.py — keep edits append-only.
"""


class MiniLoop:
    def __init__(self):
        self.heap = []
        self.version = 0

    def schedule(self, t, tid):
        self._push(t, "worker", (tid, self.version))        # fine: stamped
        self._push(t, "orphan", (tid, self.version))        # line 14: no handler
        self._push(t, "tool_done", (tid,))                  # line 15: unstamped

    def _push(self, t, kind, payload):
        self.heap.append((t, kind, payload))

    def run(self):
        for t, kind, payload in self.heap:
            if kind == "worker":
                pass
            elif kind == "tool_done":
                pass
            elif kind == "ghost":                           # line 26: never pushed
                pass


class ServiceLoop:
    """Async service plane: harvest/weight_sync kinds must obey the rule."""

    def __init__(self):
        self.heap = []
        self.epoch = 0
        self.sync_seq = 0

    def publish(self, t, wid):
        self._push(t, "harvest", wid)                       # fine: scalar
        self._push(t, "weight_sync", (self.epoch, wid))     # line 40: unstamped
        self.sync_seq += 1
        self._push(t, "weight_sync", (self.epoch, self.sync_seq))  # fine

    def _push(self, t, kind, payload):
        self.heap.append((t, kind, payload))

    def run(self):
        for t, kind, payload in self.heap:
            if kind == "harvest":
                pass
            elif kind == "weight_sync":
                pass
