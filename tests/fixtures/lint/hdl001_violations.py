"""HDL001 fixture: wall-clock and unseeded-RNG calls (linted as CONTROL|CORE).

Line numbers are pinned by tests/test_analysis.py — keep edits append-only.
"""
import random
import time
from datetime import datetime

import numpy as np


def stamp_event():
    return time.time()                      # line 13: wall clock


def elapsed(t0):
    return time.perf_counter() - t0         # line 17: telemetry clock (CORE)


def jitter():
    return np.random.rand()                 # line 21: unseeded global RNG


def pick(items):
    return random.choice(items)             # line 25: unseeded stdlib RNG


def created_at():
    return datetime.now()                   # line 29: wall clock


def seeded_ok(seed):
    rng = np.random.default_rng(seed)       # fine: explicit seeded generator
    local = random.Random(seed)             # fine: seeded instance
    return rng.random() + local.random()
