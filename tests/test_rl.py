"""RL substrate: GRPO math, chunked cross-entropy, optimizer, checkpointing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config
from repro.models import model as M
from repro.rl.grpo import (GRPOConfig, chunked_token_logprobs, group_advantages,
                           grpo_loss, token_logprobs)
from repro.rl.optimizer import AdamW

KEY = jax.random.PRNGKey(0)


def test_group_advantages_zero_mean_unit_std():
    rewards = jnp.asarray([1.0, 0.0, 0.5, 0.25, 3.0, 3.0, 3.0, 3.0])
    adv = group_advantages(rewards, group_size=4)
    g1 = np.asarray(adv[:4])
    assert abs(g1.mean()) < 1e-5
    assert abs(g1.std() - 1.0) < 1e-2
    # degenerate group (all equal rewards) -> zero advantage, no NaN
    g2 = np.asarray(adv[4:])
    assert np.allclose(g2, 0.0)


def test_chunked_logprobs_match_dense():
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 37), 0, cfg.vocab)
    hidden, _ = M.forward_full(cfg, params, {"tokens": tokens}, return_hidden=True)
    logits, _ = M.forward_full(cfg, params, {"tokens": tokens})
    a = chunked_token_logprobs(cfg, params, hidden, tokens, chunk=16)
    b = token_logprobs(logits, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_grpo_loss_sign_and_gradient():
    """Positive-advantage samples should be pushed up; gradient must be nonzero."""
    cfg = get_config("smollm_135m").reduced(n_periods=1)
    params = M.init_params(cfg, KEY)
    B, S = 4, 16
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 5, cfg.vocab),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "advantages": jnp.asarray([2.0, -1.0, 0.5, -1.5]),
    }
    logits, _ = M.forward_full(cfg, params, batch)
    batch["old_logprobs"] = jax.lax.stop_gradient(token_logprobs(logits, batch["tokens"]))
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: grpo_loss(cfg, GRPOConfig(), p, batch), has_aux=True)(params)
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert gnorm > 0
    assert np.isfinite(float(loss))
    # on-policy (ratio=1): pg loss = -mean(adv) over tokens
    expect = -float(np.mean(np.repeat(np.asarray(batch["advantages"]), S)))
    assert abs(float(metrics["pg_loss"]) - expect) < 1e-3


def test_adamw_descends_quadratic():
    opt = AdamW(lr=0.05, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)          # d/dp of p^2
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_grad_clip():
    opt = AdamW(lr=0.1, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    huge = {"w": jnp.full(3, 1e9)}
    params2, _ = opt.update(huge, state, params)
    assert float(jnp.abs(params2["w"]).max()) <= 0.2           # clipped step


def test_checkpoint_roundtrip():
    cfg = get_config("smollm_135m").reduced(n_periods=1)
    params = M.init_params(cfg, KEY)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "step1")
        ckpt.save(path, params, step=7)
        template = M.init_params(cfg, jax.random.PRNGKey(1))   # different values
        restored = ckpt.restore(path, template)
        assert ckpt.load_step(path) == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(os.path.join(d, "c"), {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            ckpt.restore(os.path.join(d, "c"), {"w": jnp.zeros((3, 3))})
