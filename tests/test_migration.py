"""Trajectory migration (§5.3): transmission scheduler + scaled-capacity router."""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.migration import (MigrationRequest, ScaledCapacityRouter,
                                  TransmissionScheduler, kv_cache_bytes,
                                  migration_time)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(0, 9), st.floats(1, 1e5)),
                min_size=1, max_size=40))
def test_batches_are_endpoint_exclusive(reqs):
    """No two selected (or running) migrations may share a src or dst worker."""
    ts = TransmissionScheduler()
    for i, (src, dst, length) in enumerate(reqs):
        ts.submit(MigrationRequest(i, src, dst, length))
    seen_total = 0
    for _ in range(100):
        batch = ts.next_batch()
        if not batch:
            break
        endpoints = [w for r in batch for w in (r.src, r.dst)]
        assert len(endpoints) == len(set(endpoints)), "endpoint conflict in batch"
        seen_total += len(batch)
        for r in batch:
            ts.complete(r.traj_id)
    valid = sum(1 for s, d, _ in reqs if s != d)
    assert seen_total == valid                       # everything eventually scheduled


def test_longest_first_within_batch():
    ts = TransmissionScheduler()
    ts.submit(MigrationRequest(1, 0, 1, length=10))
    ts.submit(MigrationRequest(2, 0, 2, length=100))   # conflicts with req 1 on src 0
    batch = ts.next_batch()
    assert [r.traj_id for r in batch] == [2]           # longer one wins the endpoint


def test_running_migrations_block_endpoints():
    ts = TransmissionScheduler()
    ts.submit(MigrationRequest(1, 0, 1, length=10))
    assert [r.traj_id for r in ts.next_batch()] == [1]
    ts.submit(MigrationRequest(2, 1, 2, length=99))    # dst 1 still busy
    assert ts.next_batch() == []
    ts.complete(1)
    assert [r.traj_id for r in ts.next_batch()] == [2]


def test_submit_replaces_stale_request_for_same_trajectory():
    ts = TransmissionScheduler()
    ts.submit(MigrationRequest(7, 0, 1, length=10))
    ts.submit(MigrationRequest(7, 0, 3, length=12))    # newer prediction, new target
    batch = ts.next_batch()
    assert len(batch) == 1 and batch[0].dst == 3


def test_scaled_capacity_router_rank_mapping():
    r = ScaledCapacityRouter([2, 3, 5])                # 10 trajectories originally
    # full population: ranks fall into original group extents
    assert r.worker_for_rank(0, 10) == 0
    assert r.worker_for_rank(1, 10) == 0
    assert r.worker_for_rank(2, 10) == 1
    assert r.worker_for_rank(9, 10) == 2
    # half the trajectories remain: capacities scale to 1, 1.5, 2.5
    assert r.worker_for_rank(0, 5) == 0
    assert r.worker_for_rank(4, 5) == 2


def test_kv_bytes_and_migration_time_scale():
    small = kv_cache_bytes(1_000, 40, 8, 128)
    big = kv_cache_bytes(10_000, 40, 8, 128)
    assert big == 10 * small
    assert migration_time(big, 50e9) > migration_time(small, 50e9)
