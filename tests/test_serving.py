"""Overload robustness: open-loop ingress, tenant SLOs, admission control and
the degradation ladder — unit properties plus sim-vs-engine decision parity."""

import copy
import math

import jax
import pytest

from repro.configs import get_config
from repro.core.faults import FaultPlan
from repro.core.tenancy import (DEFAULT_TENANTS, ServingConfig, TenantClass,
                                assign_tenants, parse_tenants)
from repro.engine.runtime import (RuntimeConfig, build_workbench, make_runtime,
                                  run_on_sim)
from repro.engine.workload import assign_arrivals, make_arrivals
from repro.models import model as M

SEED = 5


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm_135m").reduced(n_periods=1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------------------ arrival policies

def test_arrival_policies_deterministic_and_monotone():
    for kind in ("poisson", "bursty", "diurnal"):
        a = make_arrivals(kind, rate=4.0, seed=3).times(32)
        b = make_arrivals(kind, rate=4.0, seed=3).times(32)
        assert a == b, kind                          # seeded => reproducible
        assert len(a) == 32
        assert all(t >= 0.0 for t in a), kind
        assert all(y >= x for x, y in zip(a, a[1:])), kind   # non-decreasing
        c = make_arrivals(kind, rate=4.0, seed=4).times(32)
        assert a != c, kind                          # the seed matters


def test_arrival_rate_scales_horizon():
    slow = make_arrivals("poisson", rate=1.0, seed=0).times(64)
    fast = make_arrivals("poisson", rate=8.0, seed=0).times(64)
    assert slow[-1] > fast[-1] * 3                  # ~8x compression


def test_arrival_validation():
    with pytest.raises(ValueError):
        make_arrivals("poisson", rate=0.0)
    with pytest.raises(ValueError):
        make_arrivals("uniform", rate=1.0)


def test_assign_arrivals_stamps_submit_times():
    batch, _ = build_workbench(n_prompts=2, group_size=2, seed=SEED)
    assign_arrivals(batch, make_arrivals("poisson", rate=5.0, seed=SEED))
    times = [t.submit_time for t in batch]
    assert times == sorted(times) and times[-1] > 0.0


# ------------------------------------------------------------ tenant classes

def test_parse_tenants_spec():
    classes = parse_tenants("gold:0.25:30,silver:0.35:60,best:0.4")
    assert [c.name for c in classes] == ["gold", "silver", "best"]
    assert [c.tier for c in classes] == [0, 1, 2]
    assert classes[0].deadline_s == 30.0 and classes[2].deadline_s == math.inf
    assert abs(sum(c.share for c in classes) - 1.0) < 1e-12
    assert [c.sheddable for c in classes] == [False, False, True]
    # gold outranks everyone in the PPS blend
    assert classes[0].weight > classes[1].weight > classes[2].weight
    for bad in ("", "gold", "gold:0", "gold:0.5:-2", "gold:x:3"):
        with pytest.raises(ValueError):
            parse_tenants(bad)


def test_assign_tenants_deterministic_per_traj_id():
    batch, _ = build_workbench(n_prompts=4, group_size=4, seed=SEED)
    assign_arrivals(batch, make_arrivals("poisson", rate=5.0, seed=SEED))
    twin = copy.deepcopy(batch)
    assign_tenants(batch, DEFAULT_TENANTS, seed=7)
    assign_tenants(twin[4:], DEFAULT_TENANTS, seed=7)   # sliced batch, same ids
    for a, b in zip(batch[4:], twin[4:]):
        assert (a.tenant, a.tenant_tier, a.sheddable) == \
            (b.tenant, b.tenant_tier, b.sheddable)
    assert len({t.tenant for t in batch}) > 1           # the mix is a mix
    # deadlines are absolute: arrival + class deadline
    cls = {c.name: c for c in DEFAULT_TENANTS}
    for t in batch:
        d = cls[t.tenant].deadline_s
        expect = t.submit_time + d if math.isfinite(d) else math.inf
        assert t.slo_deadline == expect


# ------------------------------------------------------- open-loop properties

TENANTS = (
    TenantClass("gold", tier=0, deadline_s=30.0, weight=2.0,
                sheddable=False, share=0.3),
    TenantClass("best", tier=1, deadline_s=1.0, weight=0.5,
                sheddable=True, share=0.7),
)


def _open_loop_sim(rate=80.0, serving=None, faults=None, n_prompts=8,
                   workbench=None):
    batch, predictor = workbench if workbench is not None else \
        build_workbench(n_prompts=n_prompts, group_size=4, seed=SEED)
    batch = copy.deepcopy(batch)
    assign_arrivals(batch, make_arrivals("poisson", rate=rate, seed=SEED))
    assign_tenants(batch, TENANTS, seed=SEED)
    rcfg = RuntimeConfig(scheduler="pps", migration=True, max_active=2,
                         quantum=8, link_bandwidth=math.inf, trace=True,
                         seed=SEED, open_loop=True)
    res = run_on_sim(batch, predictor, n_workers=2, config=rcfg,
                     serving=serving, faults=faults)
    return res, batch


OVERLOAD = ServingConfig(admission_control=True, queue_bound_per_worker=3,
                         queue_bound_global=5, shed_pressure=1.2,
                         degrade_pressure=1.6, defer_seconds=0.5)


def test_queue_bounds_never_exceeded():
    res, _ = _open_loop_sim(serving=OVERLOAD)
    assert res.shed > 0                              # the bound actually bit
    assert res.peak_live_worker <= OVERLOAD.queue_bound_per_worker
    assert res.peak_live_global <= OVERLOAD.queue_bound_global


def test_gold_tier_never_shed():
    res, batch = _open_loop_sim(serving=OVERLOAD)
    assert res.shed > 0
    assert not any(t.shed for t in batch if t.tenant == "gold")
    assert all(t.finished for t in batch if t.tenant == "gold")
    # everything drains: FINISHED or SHED, nothing stuck
    assert all(t.finished or t.shed for t in batch)


def test_shed_decisions_deterministic():
    wb = build_workbench(n_prompts=8, group_size=4, seed=SEED)
    a, batch_a = _open_loop_sim(serving=OVERLOAD, workbench=wb)
    b, batch_b = _open_loop_sim(serving=OVERLOAD, workbench=wb)
    assert a.trace == b.trace
    assert a.makespan == b.makespan
    assert [(t.traj_id, t.shed, t.shed_reason) for t in batch_a] == \
        [(t.traj_id, t.shed, t.shed_reason) for t in batch_b]


def test_serving_defaults_do_not_shed():
    """ServingConfig() = gate off, unbounded queues: open loop still admits
    everything (the closed-loop contract, spread over arrival times)."""
    res, batch = _open_loop_sim(serving=None)
    assert res.shed == res.deferred == res.degraded == 0
    assert res.admitted == res.arrivals == len(batch)
    assert all(t.finished for t in batch)


def test_degradation_ladder_tightens_step_budgets():
    serving = ServingConfig(queue_bound_per_worker=8, queue_bound_global=14,
                            shed_pressure=2.5, degrade_pressure=1.2)
    res, batch = _open_loop_sim(rate=50.0, serving=serving)
    assert res.degraded > 0
    assert not any(t.degraded for t in batch if t.tenant == "gold")
    assert all(t.finished or t.shed for t in batch)


# ------------------------------------------- sim/engine decision-trace parity

def _parity_pair(cfg, params, serving, rate=60.0, faults_seed=None):
    batch, predictor = build_workbench(n_prompts=6, group_size=4, seed=SEED)
    assign_arrivals(batch, make_arrivals("bursty", rate=rate, seed=SEED))
    assign_tenants(batch, TENANTS, seed=SEED)
    twin = copy.deepcopy(batch)
    rcfg = RuntimeConfig(scheduler="pps", migration=True, max_active=2,
                         quantum=8, link_bandwidth=math.inf, trace=True,
                         seed=SEED, open_loop=True)
    faults = twin_faults = None
    if faults_seed is not None:
        faults = FaultPlan.chaos(seed=faults_seed, n_workers=2, horizon=60.0)
        twin_faults = copy.deepcopy(faults)
    eng = make_runtime(cfg, params, batch, predictor, n_workers=2, config=rcfg,
                       serving=serving, faults=faults).run()
    sim = run_on_sim(twin, predictor, n_workers=2, config=rcfg,
                     serving=serving, faults=twin_faults)
    return eng, sim


def test_open_loop_decision_trace_parity(setup):
    """Arrival/admit/shed events are policy decisions: under overload the
    SimBackend and EngineBackend must produce the IDENTICAL (event, traj,
    worker) sequence, including who got shed, and bit-identical makespans."""
    cfg, params = setup
    serving = ServingConfig(admission_control=True, queue_bound_per_worker=5,
                            queue_bound_global=9, shed_pressure=1.5,
                            degrade_pressure=2.0)
    eng, sim = _parity_pair(cfg, params, serving)
    assert eng.shed > 0                              # the test must bite
    kinds = {k for k, _, _ in eng.trace}
    assert {"arrival", "admit", "shed"} <= kinds
    assert eng.trace == sim.trace
    assert eng.makespan == sim.makespan
    assert (eng.arrivals, eng.admitted, eng.shed, eng.deferred) == \
        (sim.arrivals, sim.admitted, sim.shed, sim.deferred)


def test_open_loop_parity_under_chaos(setup):
    """Open-loop ingress + admission control + a seeded worker death: the
    decision trace stays bit-identical across backends."""
    cfg, params = setup
    serving = ServingConfig(admission_control=True, queue_bound_per_worker=6,
                            queue_bound_global=10, shed_pressure=2.0,
                            degrade_pressure=3.0)
    eng, sim = _parity_pair(cfg, params, serving, rate=30.0, faults_seed=SEED)
    assert eng.worker_deaths == sim.worker_deaths == 1
    assert eng.trace == sim.trace
    assert eng.makespan == sim.makespan
