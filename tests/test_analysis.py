"""heddle-lint: rule precision on pinned fixtures, noqa suppression, backend
protocol conformance, and TraceSanitizer invariant enforcement."""

from pathlib import Path
from types import SimpleNamespace

import pytest

from repro.analysis.lint import lint_paths, lint_source, main as lint_main, \
    scope_for_path
from repro.analysis.protocol import check_backend
from repro.analysis.rules.base import Scope
from repro.analysis.sanitize import TraceSanitizer, TraceViolationError

FIXTURES = Path(__file__).parent / "fixtures" / "lint"
SRC = Path(__file__).parent.parent / "src" / "repro"
FULL = Scope.CONTROL | Scope.CORE


def _lint(name: str, scope: Scope = FULL):
    return lint_source((FIXTURES / name).read_text(), path=name, scope=scope)


def _hits(name: str, scope: Scope = FULL):
    return [(v.rule, v.line) for v in _lint(name, scope)]


# ------------------------------------------------------------- rule precision

def test_hdl001_wall_clock_and_rng_exact_lines():
    assert _hits("hdl001_violations.py") == [
        ("HDL001", 13),   # time.time()
        ("HDL001", 17),   # time.perf_counter() (CORE only)
        ("HDL001", 21),   # np.random.rand()
        ("HDL001", 25),   # random.choice()
        ("HDL001", 29),   # datetime.now()
    ]


def test_hdl001_perf_counter_is_core_only():
    """Wall telemetry is legal in the engine (CONTROL without CORE)."""
    lines = [line for _, line in _hits("hdl001_violations.py", Scope.CONTROL)]
    assert 17 not in lines
    assert lines == [13, 21, 25, 29]


def test_hdl002_set_iteration_exact_lines():
    assert _hits("hdl002_violations.py") == [
        ("HDL002", 9),    # for tid in active (set-annotated param)
        ("HDL002", 11),   # table.keys()
        ("HDL002", 17),   # comprehension over a | b
    ]


def test_hdl002_does_not_pool_names_across_functions():
    """`degrees` is a set in one function and a Sequence param in another;
    the latter must not be flagged (the resource_manager false positive)."""
    assert all(l not in (28, 33) for _, l in _hits("hdl002_violations.py"))


def test_hdl003_jit_and_hot_loop_sync_exact_lines():
    assert _hits("hdl003_violations.py") == [
        ("HDL003", 11),   # @jax.jit with traced mesh
        ("HDL003", 19),   # np.asarray in decode loop
        ("HDL003", 20),   # .item() in decode loop
    ]


def test_hdl004_event_kind_drift_exact_lines():
    assert _hits("hdl004_violations.py") == [
        ("HDL004", 14),   # pushed kind with no handler
        ("HDL004", 15),   # tuple payload without version stamp
        ("HDL004", 26),   # handler branch for a never-pushed kind
        ("HDL004", 40),   # weight_sync tuple without an epoch/seq stamp
    ]


def test_hdl005_kv_host_gather_exact_lines():
    assert _hits("hdl005_violations.py") == [
        ("HDL005", 12),   # jax.tree.map(np.asarray, pool) in migrate_out
        ("HDL005", 18),   # jax.device_get of a lane in checkpoint_lane
        ("HDL005", 19),   # np.asarray of the block stack
    ]


def test_hdl005_binds_in_every_scope():
    """KV transfer discipline is not a control-plane-only concern."""
    assert _hits("hdl005_violations.py", Scope.NONE) == \
        _hits("hdl005_violations.py")


def test_clean_fixture_has_zero_violations():
    assert _lint("clean.py") == []


# ---------------------------------------------------------------- suppression

def test_noqa_suppresses_by_id_and_bare():
    """Lines 6 (HDL001 noqa) and 10 (bare noqa) are silenced; the HDL001
    noqa on line 15 does NOT silence that line's HDL002 hit."""
    assert _hits("noqa_suppressed.py") == [("HDL002", 15)]


# -------------------------------------------------------------------- scoping

def test_scope_for_path():
    assert scope_for_path("src/repro/core/orchestrator.py") == FULL
    assert scope_for_path("src/repro/engine/worker.py") == Scope.CONTROL
    assert scope_for_path("src/repro/rl/loop.py") == Scope.CONTROL
    assert scope_for_path("src/repro/analysis/lint.py") == Scope.NONE
    assert scope_for_path("benchmarks/common.py") == Scope.NONE


def test_determinism_rules_gated_outside_control_plane():
    """HDL001/HDL002 only bind in core/engine/rl; HDL003/HDL004 everywhere."""
    assert _hits("hdl001_violations.py", Scope.NONE) == []
    assert _hits("hdl002_violations.py", Scope.NONE) == []
    assert _hits("hdl003_violations.py", Scope.NONE) != []
    assert _hits("hdl004_violations.py", Scope.NONE) != []


def test_cli_exit_status_counts_violations(capsys):
    """The CLI derives scope from the path: fixtures outside src/repro get
    only the unscoped rules, and the exit code is the violation count."""
    rc = lint_main([str(FIXTURES / "hdl003_violations.py")])
    assert rc == 3
    out = capsys.readouterr().out
    assert "HDL003" in out and "hdl003_violations.py" in out


def test_source_tree_is_lint_clean():
    """The enforced gate: the shipped tree carries zero unsuppressed
    violations (CI runs the same command)."""
    assert lint_paths([str(SRC)]) == []


def test_syntax_error_reported_not_raised():
    vs = lint_source("def broken(:\n", path="bad.py")
    assert [v.rule for v in vs] == ["HDL000"]


# ------------------------------------------------------ protocol conformance

def test_shipped_backends_conform():
    from repro.engine.backends import EngineBackend, SimBackend
    assert check_backend(SimBackend) == []
    assert check_backend(EngineBackend) == []


def test_protocol_checker_rejects_drifted_backend():
    """A fake backend with the classic drift modes: renamed positional
    parameter, dropped protocol default, missing method, extra required
    parameter, missing attribute."""

    class DriftedBackend:
        @property
        def n_workers(self) -> int:
            return 1

        def admit(self, trajs, now=0.0) -> None:        # renamed param
            """..."""

        def ready_time(self, wid: int, now: float) -> float:
            """..."""

        def dispatch(self, wid: int, traj) -> float:    # dropped `fresh`
            """..."""

        def preempt(self, wid: int, traj, hard) -> None:  # extra required
            """..."""

        def advance(self, wid: int, now: float) -> "list[int]":
            """..."""

        def next_completion(self, wid: int, now: float) -> "float | None":
            """..."""

        def tool_submit(self, traj):
            """..."""

        def tool_absorb(self, traj) -> None:
            """..."""

        def can_migrate(self, traj) -> bool:
            """..."""

        def migrate_out(self, traj, dst: int) -> float:
            """..."""

        def migrate_in(self, traj, dst: int) -> None:
            """..."""
        # release() missing entirely; `interruptible` never assigned

    findings = "\n".join(check_backend(DriftedBackend))
    assert "missing attribute `interruptible`" in findings
    assert "`trajs`, protocol says `trajectories`" in findings
    assert "missing parameter `fresh`" in findings
    assert "extra required parameter `hard`" in findings
    assert "missing method `release`" in findings


# ------------------------------------------------------------ TraceSanitizer

def _traj(tid, sheddable=True, tier=1):
    return SimpleNamespace(traj_id=tid, sheddable=sheddable, tenant_tier=tier)


def _san(n=4, workers=2, max_active=2):
    return TraceSanitizer([_traj(i) for i in range(n)], n_workers=workers,
                          max_active=max_active)


def test_sanitizer_clean_lifecycle_reports_zero():
    s = _san()
    s.on_clock(0.0)
    s.observe("start", 0, 0)
    s.on_clock(1.0)
    s.observe("step", 0, 0)
    s.observe("finish", 0, 0)
    rep = s.finalize()
    assert rep["violations"] == 0 and rep["events"] == 2
    assert rep["wall_s"] >= 0.0


def test_sanitizer_rejects_backwards_virtual_time():
    s = _san()
    s.on_clock(2.0)
    s.on_clock(1.0)
    with pytest.raises(TraceViolationError, match="backwards"):
        s.finalize()


def test_sanitizer_rejects_double_dispatch():
    s = _san()
    s.observe("start", 0, 0)
    s.observe("start", 0, 1)        # still active on worker 0
    with pytest.raises(TraceViolationError, match="slot conservation"):
        s.finalize()


def test_sanitizer_enforces_max_active():
    s = _san(max_active=1)
    s.observe("start", 0, 0)
    s.observe("start", 1, 0)
    with pytest.raises(TraceViolationError, match="max_active"):
        s.finalize()


def test_sanitizer_rejects_dispatch_onto_dead_worker():
    s = _san()
    s.observe("worker_death", -1, 0)
    s.observe("start", 0, 0)
    with pytest.raises(TraceViolationError, match="dead worker"):
        s.finalize()


def test_sanitizer_stale_guard():
    s = _san()
    s.on_worker_event(0, applied=False, lane_alive=False)   # dropped: legal
    assert s.stale_worker_events == 1
    s.observe("start", 0, 0)
    s.observe("step", 0, 0)
    s.finalize()                                            # no violation
    s2 = _san()
    s2.on_worker_event(0, applied=True, lane_alive=False)   # guard breach
    with pytest.raises(TraceViolationError, match="stale-guard"):
        s2.finalize()


def test_sanitizer_migration_commit_abort_balance():
    s = _san()
    s.observe("migrate", 0, 1)
    with pytest.raises(TraceViolationError, match="on the wire"):
        s.finalize()
    s = _san()
    s.observe("migrate", 0, 1)
    s.observe("migrate_done", 0, 1)
    assert s.finalize()["migrations"] == {"launched": 1, "committed": 1,
                                          "aborted": 0}
    s = _san()                       # dst dies mid-flight: recovery aborts
    s.observe("migrate", 0, 1)
    s.observe("worker_death", -1, 1)
    s.observe("recover", 0, 0)
    s.observe("restore_done", 0, 0)
    assert s.finalize()["migrations"]["aborted"] == 1


def test_sanitizer_tenancy_gold_never_shed():
    gold = _traj(0, sheddable=False, tier=0)
    s = TraceSanitizer([gold, _traj(1)], n_workers=1, max_active=2)
    s.observe("shed", 1, -1)         # sheddable tier-1: legal
    s.finalize()
    s = TraceSanitizer([gold, _traj(1)], n_workers=1, max_active=2)
    s.observe("shed", 0, -1)
    with pytest.raises(TraceViolationError, match="gold"):
        s.finalize()


def test_sanitizer_rejects_activity_after_finish():
    s = _san()
    s.observe("start", 0, 0)
    s.observe("step", 0, 0)
    s.observe("finish", 0, 0)
    s.observe("start", 0, 1)
    with pytest.raises(TraceViolationError, match="after it finished"):
        s.finalize()
