"""Slot-pool engine invariants: token-exact parity with the legacy concat/slice
worker, preemption self-healing, migration round-trips, pool growth, and the
chunked/prefix-reuse prefill plane (fixed-shape admission, radix KV implants)."""

import functools

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.engine.legacy import LegacyRolloutWorker
from repro.engine.sampler import SamplerConfig
from repro.engine.worker import RolloutWorker
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = get_config("qwen3_1_7b").reduced(n_periods=1)
    params = M.init_params(cfg, KEY)
    return cfg, params


@pytest.fixture(scope="module")
def setup():
    return _setup()


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_parity_interleaved_lifecycle(setup, temperature):
    """The slot-pool engine reproduces the legacy engine's tokens exactly through an
    interleaved admit / decode / extend / finish schedule (same seed, same prompts).

    This is the contract that lets the pool replace the per-sequence store: each
    lane's math (and, at temperature > 0, its per-sequence RNG stream) is independent
    of what else is resident.
    """
    cfg, params = setup
    sampler = SamplerConfig(temperature=temperature, top_p=0.9)
    pool = RolloutWorker(cfg, params, capacity=64, max_slots=4, sampler=sampler)
    legacy = LegacyRolloutWorker(cfg, params, capacity=64, sampler=sampler)

    for w in (pool, legacy):
        w.prefill(1, [5, 7, 9, 11])
        w.prefill(2, [5, 7, 9])
    assert pool.decode([1, 2], 4) == legacy.decode([1, 2], 4)

    for w in (pool, legacy):                      # admission mid-flight
        w.prefill(3, [2, 4, 6, 8, 10])
    assert pool.decode([1, 2, 3], 3) == legacy.decode([1, 2, 3], 3)

    for w in (pool, legacy):                      # tool absorption, one lane only
        w.extend(2, [101, 102, 103])
    assert pool.decode([2, 3], 3) == legacy.decode([2, 3], 3)

    for w in (pool, legacy):                      # finish one, keep decoding the rest
        w.release(1)
    assert pool.decode([2], 2) == legacy.decode([2], 2)
    assert pool.store[2].tokens == legacy.store[2].tokens


def test_preempt_then_resume_self_heals(setup):
    """A preempted lane rides along masked-out while others decode, then resumes with
    exactly the tokens it would have produced had nothing else run (frozen pos +
    self-healing KV writes)."""
    cfg, params = setup
    sampler = SamplerConfig(temperature=1.0, top_p=0.9)
    w = RolloutWorker(cfg, params, capacity=64, max_slots=4, sampler=sampler)
    ref = RolloutWorker(cfg, params, capacity=64, max_slots=4, sampler=sampler)
    for e in (w, ref):
        e.prefill(1, [5, 7, 9, 11])
        e.prefill(2, [3, 5, 8])
    assert w.decode([1, 2], 3) == ref.decode([1, 2], 3)
    w.preempt(1)
    w.decode([2], 5)                              # lane 1 is masked but co-resident
    out = w.decode([1], 4)                        # implicit resume (mask flip back)
    want = ref.decode([1], 4)                     # reference never preempted
    assert out == want


def test_migrate_round_trip_across_workers(setup):
    """migrate_out -> migrate_in -> back again: the trajectory's tokens are identical
    to an unmigrated run, and co-resident lanes on both workers are undisturbed."""
    cfg, params = setup
    sampler = SamplerConfig(temperature=1.0, top_p=0.9)
    w0 = RolloutWorker(cfg, params, capacity=64, max_slots=4, worker_id=0,
                       sampler=sampler)
    w1 = RolloutWorker(cfg, params, capacity=64, max_slots=4, worker_id=1,
                       sampler=sampler)
    ref = RolloutWorker(cfg, params, capacity=64, max_slots=4, worker_id=0,
                        sampler=sampler)
    for e in (w0, ref):
        e.prefill(1, [5, 7, 9, 11])               # the migrating trajectory
        e.prefill(2, [2, 4, 6])                   # co-resident on the source
    w1.prefill(3, [8, 8, 8])                      # co-resident on the destination
    bystander = w1.decode([3], 2)

    assert w0.decode([1, 2], 3) == ref.decode([1, 2], 3)
    pkg = w0.migrate_out(1)
    assert 1 not in w0.store
    w1.migrate_in(pkg)
    assert w1.decode([1], 4)[1] == ref.decode([1], 4)[1]

    pkg = w1.migrate_out(1)                       # and back again
    w0.migrate_in(pkg)
    assert w0.decode([1], 3)[1] == ref.decode([1], 3)[1]
    # bystanders on both workers keep decoding their own streams
    assert w0.decode([2], 2) == ref.decode([2], 2)
    assert len(w1.decode([3], 2)[3]) == 2 and len(bystander[3]) == 2


def test_chunked_parity_straddles_chunk_boundaries(setup):
    """Prompt lengths below / at / above multiples of the chunk size all admit
    through the one fixed-shape chunk kernel and reproduce legacy full-prefill
    tokens exactly, interleaved with tool absorption, preemption, and migration."""
    cfg, params = setup
    sampler = SamplerConfig(temperature=1.0, top_p=0.9)
    pool = RolloutWorker(cfg, params, capacity=64, max_slots=6, sampler=sampler,
                         chunk_size=4)
    legacy = LegacyRolloutWorker(cfg, params, capacity=64, sampler=sampler)
    assert pool._chunked
    prompts = {1: [5, 7, 9], 2: [5, 7, 9, 11], 3: [2, 4, 6, 8, 10],
               4: [1, 2, 3, 4, 5, 6, 7, 8], 5: [9, 8, 7, 6, 5, 4, 3, 2, 1]}
    for w in (pool, legacy):
        for sid, p in prompts.items():
            w.prefill(sid, p)
    ids = list(prompts)
    assert pool.decode(ids, 3) == legacy.decode(ids, 3)

    for w in (pool, legacy):                  # 5-token tool output straddles chunk 4
        w.extend(3, [101, 102, 103, 104, 105])
    assert pool.decode([3], 3) == legacy.decode([3], 3)

    pool.preempt(1)                           # masked lane rides along
    assert pool.decode([2], 2) == legacy.decode([2], 2)
    assert pool.decode([1], 2) == legacy.decode([1], 2)   # implicit resume

    dst = RolloutWorker(cfg, params, capacity=64, max_slots=2, sampler=sampler,
                        chunk_size=4)
    dst.migrate_in(pool.migrate_out(4))       # chunk-admitted lane migrates intact
    assert dst.decode([4], 3) == legacy.decode([4], 3)


def test_prefix_reuse_admission_parity_and_accounting(setup):
    """GRPO siblings and released-lane re-entries implant the shared prefix from
    the radix cache (O(suffix) prefill) with token-exact parity, and the engine
    reports the implanted token counts."""
    cfg, params = setup
    sampler = SamplerConfig(temperature=1.0, top_p=0.9)
    w = RolloutWorker(cfg, params, capacity=64, max_slots=4, sampler=sampler,
                      chunk_size=4)
    legacy = LegacyRolloutWorker(cfg, params, capacity=64, sampler=sampler)
    assert w._reuse
    P = [5, 7, 9, 11, 13]
    for e in (w, legacy):
        e.prefill(1, P)
    assert w.decode([1], 3) == legacy.decode([1], 3)

    for e in (w, legacy):                     # sibling: full-prompt implant
        e.prefill(2, P)
    assert w.reused_tokens >= len(P)
    assert w.decode([1, 2], 3) == legacy.decode([1, 2], 3)

    for e in (w, legacy):                     # released lane retires, stays reusable
        e.release(1)
    assert len(w.retired) == 1
    before = w.reused_tokens
    for e in (w, legacy):
        e.prefill(3, P + [40, 41, 42])
    assert w.reused_tokens >= before + len(P)
    assert w.decode([2, 3], 3) == legacy.decode([2, 3], 3)


def test_retired_lane_byte_budget_evicts_lru(setup):
    """The retired set honours its byte budget (LRU eviction) and an evicted
    lane's refs go stale — later admissions fall back to a full, correct prefill."""
    cfg, params = setup
    sampler = SamplerConfig(temperature=1.0, top_p=0.9)
    probe = RolloutWorker(cfg, params, capacity=64, max_slots=4, sampler=sampler)
    one_lane = probe._lane_bytes
    w = RolloutWorker(cfg, params, capacity=64, max_slots=4, sampler=sampler,
                      chunk_size=4, retired_kv_bytes=one_lane)   # budget: 1 lane
    legacy = LegacyRolloutWorker(cfg, params, capacity=64, sampler=sampler)
    A, B = [5, 7, 9, 11], [2, 4, 6, 8]
    for e in (w, legacy):
        e.prefill(1, A)
        e.prefill(2, B)
        e.release(1)
        e.release(2)
    assert len(w.retired) == 1               # A's lane evicted, B's retained (LRU)
    for e in (w, legacy):                    # A's refs are stale -> full prefill
        e.prefill(3, A + [90])
    assert w.decode([3], 3) == legacy.decode([3], 3)


def test_reset_cache_drops_retired_prefixes(setup):
    """Weight sync must clear retired KV: after reset_cache() nothing implants."""
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=64, max_slots=4,
                      sampler=SamplerConfig(temperature=0.0), chunk_size=4)
    w.prefill(1, [5, 7, 9, 11])
    w.release(1)
    w.reset_cache()
    assert not w.store and not w.retired
    w.prefill(2, [5, 7, 9, 11])
    assert w.reused_tokens == 0              # no stale implant after reset


@settings(max_examples=5, deadline=None)
@given(st.integers(2, 5), st.integers(4, 12), st.integers(0, 10), st.integers(0, 9999))
def test_chunked_reuse_parity_random_split_points(chunk, plen, raw_split, seed):
    """Property: for random prompts, chunk sizes, and shared-prefix split points,
    chunked + prefix-reuse admission is token-exact with legacy full prefill."""
    cfg, params = _setup()
    rng = np.random.default_rng(seed)
    prompt = [5 + int(t) for t in rng.integers(0, 100, plen)]
    split = min(raw_split, plen)
    sibling = prompt[:split] + [5 + int(t) for t in rng.integers(100, 200, plen - split)]
    sampler = SamplerConfig(temperature=1.0, top_p=0.9)
    w = RolloutWorker(cfg, params, capacity=64, max_slots=4, sampler=sampler,
                      chunk_size=chunk)
    legacy = LegacyRolloutWorker(cfg, params, capacity=64, sampler=sampler)
    for e in (w, legacy):
        e.prefill(1, prompt)
        e.prefill(2, sibling)                # implants the shared split prefix
    assert w.decode([1, 2], 2) == legacy.decode([1, 2], 2)


def test_chunk_window_past_capacity_edge_stays_exact(setup):
    """A fixed-shape chunk whose window hangs past the capacity edge
    (off + chunk_size > capacity while off + length <= capacity) must scatter each
    key to its absolute slot — a clamping slice-write would smear the tail chunk
    over resident positions.  Pins the dense (``paged=False``) lane layout the
    raw-KV comparison below assumes; the paged twin of this edge lives in
    tests/test_paging.py (page-boundary straddling)."""
    cfg, params = setup
    sampler = SamplerConfig(temperature=1.0, top_p=0.9)
    w = RolloutWorker(cfg, params, capacity=16, max_slots=2, sampler=sampler,
                      chunk_size=8, paged=False)
    legacy = LegacyRolloutWorker(cfg, params, capacity=16, sampler=sampler)
    for e in (w, legacy):
        e.prefill(1, [5, 7, 9, 11, 13])
        e.extend(1, [21, 22, 23, 24, 25, 26])   # off=5..10
        e.extend(1, [31, 32, 33, 34])           # off=11: window 11..19 > cap 16
    lane = M.gather_slots(w.pool, np.asarray([w.store[1].slot]))
    for name, blk in lane["blocks"].items():
        for key in ("k", "v"):
            got = np.asarray(blk[key])
            want = np.asarray(legacy.store[1].cache["blocks"][name][key])
            np.testing.assert_array_equal(got, want)
    assert w.decode([1], 1) == legacy.decode([1], 1)


def test_pool_grows_on_overflow_and_reuses_freed_lanes(setup):
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=32, max_slots=2,
                      sampler=SamplerConfig(temperature=0.0))
    w.prefill(1, [5, 7])
    w.prefill(2, [5, 9])
    slot1 = w.store[1].slot
    w.release(1)
    w.prefill(3, [5, 11])
    assert w.store[3].slot == slot1               # freed lane is reused first
    assert w.max_slots == 2 and w.pool_grows == 0
    w.prefill(4, [5, 13])                         # overflow: pool doubles
    assert w.max_slots == 4 and w.pool_grows == 1
    out = w.decode([2, 3, 4], 3)
    assert all(len(v) == 3 for v in out.values())
