"""Slot-pool engine invariants: token-exact parity with the legacy concat/slice
worker, preemption self-healing, migration round-trips, pool growth."""

import jax
import pytest

from repro.configs import get_config
from repro.engine.legacy import LegacyRolloutWorker
from repro.engine.sampler import SamplerConfig
from repro.engine.worker import RolloutWorker
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3_1_7b").reduced(n_periods=1)
    params = M.init_params(cfg, KEY)
    return cfg, params


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_parity_interleaved_lifecycle(setup, temperature):
    """The slot-pool engine reproduces the legacy engine's tokens exactly through an
    interleaved admit / decode / extend / finish schedule (same seed, same prompts).

    This is the contract that lets the pool replace the per-sequence store: each
    lane's math (and, at temperature > 0, its per-sequence RNG stream) is independent
    of what else is resident.
    """
    cfg, params = setup
    sampler = SamplerConfig(temperature=temperature, top_p=0.9)
    pool = RolloutWorker(cfg, params, capacity=64, max_slots=4, sampler=sampler)
    legacy = LegacyRolloutWorker(cfg, params, capacity=64, sampler=sampler)

    for w in (pool, legacy):
        w.prefill(1, [5, 7, 9, 11])
        w.prefill(2, [5, 7, 9])
    assert pool.decode([1, 2], 4) == legacy.decode([1, 2], 4)

    for w in (pool, legacy):                      # admission mid-flight
        w.prefill(3, [2, 4, 6, 8, 10])
    assert pool.decode([1, 2, 3], 3) == legacy.decode([1, 2, 3], 3)

    for w in (pool, legacy):                      # tool absorption, one lane only
        w.extend(2, [101, 102, 103])
    assert pool.decode([2, 3], 3) == legacy.decode([2, 3], 3)

    for w in (pool, legacy):                      # finish one, keep decoding the rest
        w.release(1)
    assert pool.decode([2], 2) == legacy.decode([2], 2)
    assert pool.store[2].tokens == legacy.store[2].tokens


def test_preempt_then_resume_self_heals(setup):
    """A preempted lane rides along masked-out while others decode, then resumes with
    exactly the tokens it would have produced had nothing else run (frozen pos +
    self-healing KV writes)."""
    cfg, params = setup
    sampler = SamplerConfig(temperature=1.0, top_p=0.9)
    w = RolloutWorker(cfg, params, capacity=64, max_slots=4, sampler=sampler)
    ref = RolloutWorker(cfg, params, capacity=64, max_slots=4, sampler=sampler)
    for e in (w, ref):
        e.prefill(1, [5, 7, 9, 11])
        e.prefill(2, [3, 5, 8])
    assert w.decode([1, 2], 3) == ref.decode([1, 2], 3)
    w.preempt(1)
    w.decode([2], 5)                              # lane 1 is masked but co-resident
    out = w.decode([1], 4)                        # implicit resume (mask flip back)
    want = ref.decode([1], 4)                     # reference never preempted
    assert out == want


def test_migrate_round_trip_across_workers(setup):
    """migrate_out -> migrate_in -> back again: the trajectory's tokens are identical
    to an unmigrated run, and co-resident lanes on both workers are undisturbed."""
    cfg, params = setup
    sampler = SamplerConfig(temperature=1.0, top_p=0.9)
    w0 = RolloutWorker(cfg, params, capacity=64, max_slots=4, worker_id=0,
                       sampler=sampler)
    w1 = RolloutWorker(cfg, params, capacity=64, max_slots=4, worker_id=1,
                       sampler=sampler)
    ref = RolloutWorker(cfg, params, capacity=64, max_slots=4, worker_id=0,
                        sampler=sampler)
    for e in (w0, ref):
        e.prefill(1, [5, 7, 9, 11])               # the migrating trajectory
        e.prefill(2, [2, 4, 6])                   # co-resident on the source
    w1.prefill(3, [8, 8, 8])                      # co-resident on the destination
    bystander = w1.decode([3], 2)

    assert w0.decode([1, 2], 3) == ref.decode([1, 2], 3)
    pkg = w0.migrate_out(1)
    assert 1 not in w0.store
    w1.migrate_in(pkg)
    assert w1.decode([1], 4)[1] == ref.decode([1], 4)[1]

    pkg = w1.migrate_out(1)                       # and back again
    w0.migrate_in(pkg)
    assert w0.decode([1], 3)[1] == ref.decode([1], 3)[1]
    # bystanders on both workers keep decoding their own streams
    assert w0.decode([2], 2) == ref.decode([2], 2)
    assert len(w1.decode([3], 2)[3]) == 2 and len(bystander[3]) == 2


def test_pool_grows_on_overflow_and_reuses_freed_lanes(setup):
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=32, max_slots=2,
                      sampler=SamplerConfig(temperature=0.0))
    w.prefill(1, [5, 7])
    w.prefill(2, [5, 9])
    slot1 = w.store[1].slot
    w.release(1)
    w.prefill(3, [5, 11])
    assert w.store[3].slot == slot1               # freed lane is reused first
    assert w.max_slots == 2 and w.pool_grows == 0
    w.prefill(4, [5, 13])                         # overflow: pool doubles
    assert w.max_slots == 4 and w.pool_grows == 1
    out = w.decode([2, 3, 4], 3)
    assert all(len(v) == 3 for v in out.values())
