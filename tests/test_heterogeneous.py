"""Heterogeneous-MP fleets: spec authority, meshed parity, cross-degree migration,
and live split/merge reconfiguration.

Runs in two CI environments: the plain tier-1 suite (one device — every worker
falls back un-meshed, the control plane still prices declared degrees) and a
dedicated job under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
where mp>1 workers are physically sharded on carved sub-meshes and the parity
tests exercise real cross-shard numerics.
"""

import jax
import pytest

from repro.configs import get_config
from repro.engine.fleet import FleetSpec, RolloutFleet
from repro.engine.runtime import RuntimeConfig, build_workbench, make_runtime
from repro.engine.sampler import SamplerConfig
from repro.engine.worker import RolloutWorker
from repro.launch.mesh import carve_worker_meshes
from repro.models import model as M

GREEDY = SamplerConfig(temperature=0.0)
PROMPT = [5, 6, 7, 8, 9, 10]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3_1_7b").reduced(n_periods=1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mesh(mp: int):
    """A real sub-mesh when the host has the devices, else None (fallback)."""
    if mp > 1 and jax.device_count() >= mp:
        return carve_worker_meshes([mp], jax.devices()[:mp])[0]
    return None


# ---------------------------------------------------------------- FleetSpec

def test_fleet_spec_validates_order_and_degrees():
    spec = FleetSpec((4, 2, 1, 1))
    assert spec.n_workers == 4
    assert spec.budget == 8
    with pytest.raises(ValueError):
        FleetSpec((1, 2, 4))                  # ascending: breaks sort-and-zip
    with pytest.raises(ValueError):
        FleetSpec((2, 0))
    with pytest.raises(ValueError):
        FleetSpec(())
    assert FleetSpec.from_degrees([1, 4, 2]).degrees == (4, 2, 1)
    assert FleetSpec.homogeneous(3).degrees == (1, 1, 1)


def test_fleet_spec_from_allocation():
    from repro.core.placement import InterferenceModel
    from repro.core.resource_manager import sort_initialized_sa
    res = sort_initialized_sa([400.0, 90.0, 40.0, 10.0], budget=8,
                              interference=InterferenceModel.analytic(0.05),
                              seed=0)
    spec = FleetSpec.from_allocation(res)
    assert spec.budget == 8
    assert list(spec.degrees) == sorted(spec.degrees, reverse=True)


def test_carve_worker_meshes_falls_back_without_devices():
    # a device list too small for the budget degrades every worker to un-meshed
    meshes = carve_worker_meshes([4, 2, 1, 1], jax.devices()[:1])
    assert meshes == [None] * 4
    # an all-mp1 fleet never builds meshes (nothing to shard)
    assert carve_worker_meshes([1, 1], jax.devices()) == [None, None]


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 host devices")
def test_carve_worker_meshes_disjoint_blocks():
    meshes = carve_worker_meshes([4, 2, 1, 1], jax.devices())
    assert [m.devices.shape for m in meshes] == [(1, 4), (1, 2), (1, 1), (1, 1)]
    blocks = [{d.id for d in m.devices.flat} for m in meshes]
    assert len(set().union(*blocks)) == 8     # disjoint: all 8 chips, no overlap


# ------------------------------------------------- cross-degree data plane

@pytest.mark.skipif(jax.device_count() < 2, reason="needs >=2 host devices")
def test_meshed_decode_matches_unmeshed(setup):
    """MP sharding must not change the sampled token stream (same worker_id)."""
    cfg, params = setup
    meshed = RolloutWorker(cfg, params, capacity=32, max_slots=2,
                           mesh=_mesh(2), mp=2)
    plain = RolloutWorker(cfg, params, capacity=32, max_slots=2)
    assert meshed.mesh is not None
    for w in (meshed, plain):
        w.prefill(0, PROMPT)
    assert meshed.decode([0], 16)[0] == plain.decode([0], 16)[0]


def test_cross_degree_migration_parity(setup):
    """Preempt on mp=2 -> migrate -> resume on mp=1 decodes the tokens an
    unmigrated run would have (§5.3 + §6: migration crosses MP degrees)."""
    cfg, params = setup
    src = RolloutWorker(cfg, params, capacity=32, max_slots=2, worker_id=0,
                        sampler=GREEDY, mesh=_mesh(2), mp=2)
    ref = RolloutWorker(cfg, params, capacity=32, max_slots=2, worker_id=0,
                        sampler=GREEDY, mesh=_mesh(2), mp=2)
    dst = RolloutWorker(cfg, params, capacity=32, max_slots=2, worker_id=1,
                        sampler=GREEDY, mp=1)
    src.prefill(7, PROMPT)
    ref.prefill(7, PROMPT)
    straight = ref.decode([7], 12)[7]
    first = src.decode([7], 6)[7]
    src.preempt(7)
    pkg = src.migrate_out(7)
    assert pkg["preempted"]                   # lifecycle flag travels
    dst.migrate_in(pkg)
    resumed = dst.decode([7], 6)[7]           # implicit resume on the mp=1 pool
    assert first + resumed == straight
    assert dst.store[7].tokens == ref.store[7].tokens


def test_cross_degree_migration_roundtrip_low_to_high(setup):
    """mp=1 -> mp=4 implant also holds (re-shard on ingress, not egress)."""
    cfg, params = setup
    src = RolloutWorker(cfg, params, capacity=32, max_slots=2, worker_id=0,
                        sampler=GREEDY, mp=1)
    ref = RolloutWorker(cfg, params, capacity=32, max_slots=2, worker_id=0,
                        sampler=GREEDY, mp=1)
    dst = RolloutWorker(cfg, params, capacity=32, max_slots=2, worker_id=1,
                        sampler=GREEDY, mesh=_mesh(4), mp=4)
    src.prefill(3, PROMPT)
    ref.prefill(3, PROMPT)
    straight = ref.decode([3], 10)[3]
    first = src.decode([3], 5)[3]
    dst.migrate_in(src.migrate_out(3))
    assert first + dst.decode([3], 5)[3] == straight


# ---------------------------------------------------- fleet spec authority

def _tiny_runtime(cfg, params, fleet=None, n_workers=2, seed=11):
    batch, predictor = build_workbench(n_prompts=2, group_size=2, seed=seed,
                                       max_steps=1, base_steps=1.0)
    rcfg = RuntimeConfig(scheduler="pps", migration=False, max_active=1,
                         quantum=8, seed=seed)
    return make_runtime(cfg, params, batch, predictor, n_workers=n_workers,
                        config=rcfg, fleet=fleet)


def test_fleet_spec_is_single_source_of_truth(setup):
    """Regression: runtime used to lazily re-stub controller.degrees = [1]*n in
    two places; a stale stub could silently mask a real allocation.  Now the
    fleet spec is authoritative and any drift fails loudly."""
    cfg, params = setup
    rt = _tiny_runtime(cfg, params, fleet=FleetSpec((2, 1, 1)))
    assert rt.controller.degrees == [2, 1, 1]     # synced at construction
    rt.controller.degrees = [1, 1, 1]             # the old stub, now poison
    with pytest.raises(ValueError, match="drifted"):
        rt.run()


def test_runtime_rejects_conflicting_preset_degrees(setup):
    cfg, params = setup
    rt = _tiny_runtime(cfg, params, fleet=FleetSpec((2, 1)))
    from repro.engine.runtime import RolloutRuntime, ToolEnvironment
    ctrl = rt.controller
    ctrl.degrees = [1, 1]                         # stale stub pre-set by caller
    with pytest.raises(ValueError, match="single source of truth"):
        RolloutRuntime(rt.fleet, ctrl, rt.trajs, ToolEnvironment(), rt.cfg)


def test_heterogeneous_degrees_change_virtual_pricing(setup):
    """The stub's disappearance is observable: per-worker token times now come
    from the latency model, so a het fleet prices decode differently."""
    cfg, params = setup
    rt = _tiny_runtime(cfg, params, fleet=FleetSpec((4, 1)))
    times = [ws.token_time for ws in rt.workers]
    assert times[0] < times[1]                    # mp=4 decodes faster
    assert times[1] == pytest.approx(rt.cfg.token_time)


# ------------------------------------------------------------- reconfigure

def test_fleet_reconfigure_migrates_residents_across_degrees(setup):
    cfg, params = setup
    fleet = RolloutFleet(cfg, params, FleetSpec((2, 2)), capacity=32,
                         max_slots=2, sampler=GREEDY)
    ref = RolloutWorker(cfg, params, capacity=32, max_slots=2, worker_id=1,
                        sampler=GREEDY, mesh=_mesh(2), mp=2)
    fleet.workers[1].prefill(5, PROMPT)
    ref.prefill(5, PROMPT)
    first = fleet.workers[1].decode([5], 6)[5]
    keep = fleet.workers[0]
    report = fleet.reconfigure(FleetSpec((2, 1, 1)))  # split: slot 1 becomes 2x mp1
    assert report["to"] == [2, 1, 1]
    assert report["migrated_residents"] == 1
    assert 0 in report["reused"] and 1 in report["rebuilt"]
    assert fleet.workers[0] is keep               # unchanged slot is reused
    assert fleet.workers[1].mp == 1
    assert 5 in fleet.workers[1].store            # resident landed on slot 1
    resumed = fleet.workers[1].decode([5], 6)[5]
    assert first + resumed == ref.decode([5], 12)[5]


def test_fleet_reconfigure_rebuilds_on_mesh_presence_change(setup):
    """A fleet crossing in or out of the meshed regime must re-place every
    worker — reusing an un-meshed engine under a newly carved mesh would
    silently ignore the new sharding (and vice versa)."""
    cfg, params = setup
    fleet = RolloutFleet(cfg, params, FleetSpec((2, 1)), capacity=32,
                         max_slots=2, sampler=GREEDY)
    report = fleet.reconfigure(FleetSpec((1, 1)))   # meshed fleet -> all-mp1
    if any(w.mesh is not None for w in fleet.workers):
        pytest.fail("all-mp1 fleet must be un-meshed")
    had_meshes = jax.device_count() >= 3            # (2,1) was physically meshed
    if had_meshes:
        assert report["rebuilt"] == [0, 1]          # both crossed out of meshes
    else:
        assert report["reused"] == [1]              # fallback: degree-only reuse


def test_runtime_reconfigure_keeps_controller_in_sync(setup):
    cfg, params = setup
    rt = _tiny_runtime(cfg, params, fleet=FleetSpec((2, 1, 1)))
    rt.run()
    report = rt.reconfigure()                     # calibrate + Algorithm 2
    assert sum(report["to"]) == 4                 # budget conserved
    assert rt.controller.degrees == list(rt.spec.degrees)
    assert rt.spec.degrees == rt.fleet.spec.degrees
    assert [w.mp for w in rt.fleet.workers] == list(rt.spec.degrees)


def test_reconfigure_requires_fleet_and_drained_queue(setup):
    cfg, params = setup
    rt = _tiny_runtime(cfg, params, n_workers=2)
    rt.fleet = None
    with pytest.raises(ValueError, match="RolloutFleet"):
        rt.reconfigure()


# ------------------------------------------------------------- calibration

def test_calibration_observations_flow_from_dispatch_stats(setup):
    cfg, params = setup
    rt = _tiny_runtime(cfg, params, fleet=FleetSpec((2, 1)))
    rt.run()
    obs = rt.controller.calibration_observations()
    assert len(obs) == 2                          # both workers reported timing
    assert {mp for mp, _, _ in obs} == {1, 2}
    assert all(t > 0.0 for _, _, t in obs)
    fitted = rt.calibrate()
    assert fitted is not None and fitted.t1 > 0.0
    assert rt.controller.latency is fitted        # next provision uses it
