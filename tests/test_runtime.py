"""Event-driven rollout runtime: end-to-end lifecycle, scheduling, migration,
and the controller-seam idempotency fixes."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.controller import HeddleConfig, HeddleController
from repro.core.placement import InterferenceModel
from repro.core.resource_manager import WorkerLatencyModel
from repro.core.trajectory import Trajectory, TrajectoryPhase
from repro.engine.runtime import (RuntimeConfig, ToolEnvironment,
                                  build_workbench, make_runtime, miniaturize,
                                  required_capacity)
from repro.engine.workload import WorkloadConfig, generate
from repro.models import model as M

SEED = 5          # the seeded long-tail workload bench_rollout pins (PPS < FCFS)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm_135m").reduced(n_periods=1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, scheduler, migration):
    batch, predictor = build_workbench(n_prompts=6, group_size=4, seed=SEED)
    # default preemption hysteresis: the unified orchestrator drains co-timed
    # tool returns before dispatching a quantum boundary, so dispatch picks the
    # true priority winner up front and preemption only corrects genuine
    # mid-step rank inversions (the old loop's 16-token floor was tuned for its
    # dispatch-ahead-of-arrivals ordering)
    rcfg = RuntimeConfig(scheduler=scheduler, migration=migration, max_active=2,
                         quantum=8, seed=SEED)
    return make_runtime(cfg, params, batch, predictor, n_workers=2,
                        config=rcfg).run()


@pytest.fixture(scope="module")
def pps_result(setup):
    cfg, params = setup
    return _run(cfg, params, "pps", True)


def test_every_trajectory_finishes_with_full_lifecycle(pps_result):
    res = pps_result
    assert all(t.finished for t in res.trajectories)
    assert all(t.phase is TrajectoryPhase.FINISHED for t in res.trajectories)
    # plans executed exactly: every agentic step ran on the real data plane
    for t in res.trajectories:
        assert t.num_steps == t.true_num_steps
        assert t.tokens_generated == t.true_total_tokens
    assert res.total_tokens == sum(t.true_total_tokens for t in res.trajectories)


def test_per_step_queue_delays_recorded(pps_result):
    res = pps_result
    delays = [s.queue_delay for t in res.trajectories for s in t.steps]
    assert len(delays) == sum(t.num_steps for t in res.trajectories)
    assert max(delays) > 0.0                   # oversubscription => real queueing
    for t in res.trajectories:                 # StepRecords aggregate onto the traj
        assert t.total_queue_delay == pytest.approx(
            sum(s.queue_delay for s in t.steps))


def test_preemption_and_migration_engage(pps_result):
    res = pps_result
    assert res.preemptions > 0
    assert res.migrations > 0
    assert sum(t.migrations for t in res.trajectories) == res.migrations
    assert sum(t.preemptions for t in res.trajectories) == res.preemptions


def test_migration_off_never_migrates(setup):
    cfg, params = setup
    res = _run(cfg, params, "pps", False)
    assert res.migrations == 0
    assert all(t.migrations == 0 for t in res.trajectories)
    assert all(t.finished for t in res.trajectories)


def test_pps_beats_fcfs_on_long_tail_and_is_deterministic(setup, pps_result):
    cfg, params = setup
    fcfs = _run(cfg, params, "fcfs", False)
    assert all(t.finished for t in fcfs.trajectories)
    assert fcfs.migrations == 0
    assert pps_result.makespan <= fcfs.makespan
    # virtual time is a pure function of the seeded plans: re-running the same
    # configuration reproduces the makespan exactly
    again = _run(cfg, params, "pps", True)
    assert again.makespan == pps_result.makespan
    assert again.preemptions == pps_result.preemptions
    assert again.migrations == pps_result.migrations


def test_telemetry_flows_to_controller(pps_result):
    stats = pps_result.worker_stats
    assert set(stats) == {0, 1}
    for s in stats.values():
        assert s["decode_steps"] > 0
    # GRPO siblings share prompts => the radix cache implanted admission tokens
    assert sum(s["reused_tokens"] for s in stats.values()) > 0


# ---------------------------------------------------------------- miniaturize

def test_miniaturize_preserves_tail_shape_and_ratios():
    batch = generate(WorkloadConfig(task="coding", n_prompts=8, group_size=4,
                                    seed=3))
    orig = {t.traj_id: (t.payload.total_tokens, t.payload.tool_latency[0])
            for t in batch}
    mini = miniaturize(batch, max_total_tokens=96, max_prompt=12,
                       max_tool_tokens=6, min_step_tokens=1)
    totals = [t.payload.total_tokens for t in mini]
    assert max(totals) <= 96 + len(max((t.payload.gen_tokens for t in mini),
                                       key=len))       # rounding slack only
    assert min(totals) >= 1
    # rank order of trajectory lengths survives the shrink (long tail intact)
    orig_rank = sorted(orig, key=lambda k: orig[k][0])
    mini_rank = sorted(mini, key=lambda t: t.payload.total_tokens)
    top = {t.traj_id for t in mini_rank[-4:]}
    assert len(top & set(orig_rank[-8:])) >= 3
    # tool latencies shrank by the same factor as generation tokens
    t0 = mini[0]
    g_scale = 96 / max(v[0] for v in orig.values())
    assert t0.payload.tool_latency[0] == pytest.approx(
        orig[t0.traj_id][1] * g_scale)
    assert required_capacity(mini) <= 96 + 12 + 64 * 6


def test_tool_environment_is_deterministic():
    batch = miniaturize(generate(WorkloadConfig(task="coding", n_prompts=2,
                                                group_size=2, seed=0)))
    t = batch[0]
    a, b = ToolEnvironment(seed=7), ToolEnvironment(seed=7)
    ra, rb = a.invoke(t, 0), b.invoke(t, 0)
    assert ra.output_tokens == rb.output_tokens
    assert ra.latency == rb.latency
    assert len(ra.output_tokens) == t.payload.tool_output_tokens[0]
    # different step -> different stream
    if t.payload.num_steps > 1:
        assert a.invoke(t, 1).output_tokens != ra.output_tokens or \
            t.payload.tool_output_tokens[1] != t.payload.tool_output_tokens[0]


# ------------------------------------------------- controller seam (bugfixes)

class _ConstPredictor:
    def predict(self, traj):
        return 10.0


def _controller(n=16, workers=2, **kw):
    ctrl = HeddleController(
        _ConstPredictor(), InterferenceModel.analytic(0.02),
        WorkerLatencyModel(), gpu_budget=workers,
        config=HeddleConfig(adaptive_resources=False, migration=True,
                            rank_hysteresis=0.0, migration_cooldown_steps=0,
                            migration_load_gap=2, **kw),
        max_workers=workers)
    ctrl.degrees = [1] * workers
    trajs = [Trajectory(prompt_id=i, sample_id=0, prompt_tokens=8)
             for i in range(n)]
    ctrl.initial_placement(trajs)
    return ctrl, trajs


def test_on_finish_is_idempotent():
    """Regression: double on_finish used to double-decrement worker counts."""
    ctrl, trajs = _controller()
    t = trajs[0]
    before = ctrl._worker_count.copy()
    t.finished = True
    ctrl.on_finish(t)
    after_first = ctrl._worker_count.copy()
    assert after_first[t.worker_id] == before[t.worker_id] - 1
    ctrl.on_finish(t)                          # second call: no-op
    assert np.array_equal(ctrl._worker_count, after_first)


def test_migration_commits_on_execution_not_on_emission():
    """Regression: on_step_complete used to move worker counts when *emitting*
    a migration request; a dropped request then leaked the counts forever."""
    ctrl, trajs = _controller()
    # force a visible load skew so the material-benefit gate opens
    ctrl._worker_count[:] = [12, 4]
    t = next(x for x in trajs if x.worker_id == 0)
    t.predicted_remaining = 50.0               # material prediction change
    req = ctrl.on_step_complete(t, ())
    assert req is not None and req.src == 0
    assert ctrl._worker_count.tolist() == [12, 4]   # emission moved nothing
    # a second emission while one is in flight is suppressed (idempotent)
    assert ctrl.on_step_complete(t, ()) is None
    ctrl.commit_migration(t.traj_id)           # the transfer actually launches
    assert ctrl._worker_count.tolist() == [11, 5]
    ctrl.commit_migration(t.traj_id)           # double-commit: no-op
    assert ctrl._worker_count.tolist() == [11, 5]


def test_migration_gate_is_speed_aware_on_heterogeneous_fleets():
    """Regression: the load-feedback gate compared raw live COUNTS, so on a
    heterogeneous fleet it happily parked long tails on an 'idle' mp=1 worker
    that a busier mp=4 worker would still drain sooner.  Loads are now counts
    in fast-worker equivalents (count * relative token time)."""
    ctrl, trajs = _controller(workers=2)
    ctrl.degrees = [4, 1]                      # fast worker 0, slow worker 1
    ctrl.initial_placement(trajs)
    tts = ctrl.latency.token_times([4, 1])
    assert ctrl._load_weight[1] / ctrl._load_weight[0] == pytest.approx(
        tts[1] / tts[0])
    # a raw-count gap of 8 vs 4: the count gate would migrate 0 -> 1, but in
    # fast-equivalents the slow worker already carries the heavier load
    ctrl._worker_count[:] = [8, 4]
    loads = ctrl._worker_count * ctrl._load_weight
    assert loads[1] > loads[0]
    t = next(x for x in trajs if x.worker_id == 0)
    t.predicted_remaining = 50.0
    assert ctrl.on_step_complete(t, ()) is None   # slow target: gated
    # homogeneous degrees reduce to the old pure-count behavior
    ctrl2, trajs2 = _controller(workers=2)
    ctrl2._worker_count[:] = [8, 4]
    t2 = next(x for x in trajs2 if x.worker_id == 0)
    t2.predicted_remaining = 50.0
    assert ctrl2.on_step_complete(t2, ()) is not None


def test_aborted_migration_leaks_nothing():
    ctrl, trajs = _controller()
    ctrl._worker_count[:] = [12, 4]
    t = next(x for x in trajs if x.worker_id == 0)
    t.predicted_remaining = 50.0
    req = ctrl.on_step_complete(t, ())
    assert req is not None
    ctrl.abort_migration(t.traj_id)            # trajectory resumed: drop it
    assert ctrl._worker_count.tolist() == [12, 4]
    assert len(ctrl.transmission) == 0         # pending request cancelled too
    # after an abort the trajectory may emit again
    t.predicted_remaining = 120.0
    assert ctrl.on_step_complete(t, ()) is not None
