"""The docs front door stays navigable: links resolve, anchors exist.

Runs ``tools/check_doc_links.py`` against the real repo (the gate CI
enforces) and against synthetic fixtures that pin what the checker catches —
a checker that passes everything would let the docs rot silently.
"""

import importlib.util
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

_spec = importlib.util.spec_from_file_location(
    "check_doc_links", REPO / "tools" / "check_doc_links.py"
)
_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_mod)


def test_repo_docs_have_no_broken_links_or_anchors(capsys):
    assert _mod.main(["check_doc_links", str(REPO)]) == 0


def test_readme_and_training_doc_exist_and_are_linked():
    readme = (REPO / "README.md").read_text()
    assert "docs/training.md" in readme
    assert "BENCH_async.json" in readme  # bench table covers the async artifact
    for doc in sorted((REPO / "docs").glob("*.md")):
        assert f"docs/{doc.name}" in readme, f"README must map {doc.name}"


def _run(root: Path) -> int:
    return _mod.main(["check_doc_links", str(root)])


def _mkrepo(tmp_path: Path, readme: str, docs: dict[str, str]) -> Path:
    (tmp_path / "README.md").write_text(readme)
    (tmp_path / "docs").mkdir()
    for name, text in docs.items():
        (tmp_path / "docs" / name).write_text(text)
    return tmp_path


def test_checker_flags_missing_file_and_bad_anchor(tmp_path, capsys):
    _mkrepo(
        tmp_path,
        "[gone](docs/nope.md) and [bad](docs/a.md#no-such-heading)\n",
        {"a.md": "# Real Heading\n"},
    )
    assert _run(tmp_path) == 1
    err = capsys.readouterr().err
    assert "broken link" in err and "broken anchor" in err


def test_checker_accepts_github_style_anchors(tmp_path):
    _mkrepo(
        tmp_path,
        "[ok](docs/a.md#two-clocks-one-data-plane-enginebackend)\n"
        "[dup](docs/a.md#setup-1)\n",
        {"a.md": "# Two clocks, one data plane (EngineBackend)\n\n## Setup\n\n## Setup\n"},
    )
    assert _run(tmp_path) == 0


def test_checker_ignores_code_blocks_and_external_links(tmp_path):
    _mkrepo(
        tmp_path,
        "[x](https://example.com) `[y](docs/fake.md)`\n\n"
        "```\n[z](docs/also_fake.md)\n```\n",
        {"a.md": "# A\n"},
    )
    assert _run(tmp_path) == 0


def test_checker_rejects_links_escaping_the_repo(tmp_path, capsys):
    _mkrepo(tmp_path, "[out](../../etc/passwd)\n", {"a.md": "# A\n"})
    assert _run(tmp_path) == 1
    assert "escapes the repo" in capsys.readouterr().err


def test_checker_runs_as_a_script():
    import subprocess

    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_doc_links.py"), str(REPO)],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stderr
    assert "0 problems" in proc.stdout
