"""Paged-KV data plane: PagePool accounting, paged kernel parity, bitwise
token parity against the dense lane pool (dense / MoE / recurrent configs,
page-boundary straddles), zero-copy prefix sharing, D2D migration + resume,
and resident-pages-only byte accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitize import check_block_conservation
from repro.configs import get_config
from repro.engine.paging import PagePool, PagePoolExhausted
from repro.engine.sampler import SamplerConfig
from repro.engine.worker import RolloutWorker
from repro.kernels import ops
from repro.kernels.ref import paged_decode_attention_ref
from repro.models import model as M

KEY = jax.random.PRNGKey(0)
GREEDY = SamplerConfig(temperature=0.0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, KEY)
    return cfg, params


def _pair(cfg, params, **kw):
    """(paged, dense) twin workers: identical params, greedy sampling."""
    kw.setdefault("capacity", 64)
    paged = RolloutWorker(cfg, params, worker_id=0, sampler=GREEDY,
                          paged=True, **kw)
    dense = RolloutWorker(cfg, params, worker_id=0, sampler=GREEDY,
                          paged=False, **kw)
    assert paged._paged and not dense._paged
    return paged, dense


# ------------------------------------------------------------------ PagePool

def test_pagepool_scratch_reserved_and_lowest_first():
    p = PagePool(8)
    assert p.alloc(3) == [1, 2, 3]                 # block 0 never handed out
    p.free([2])
    assert p.alloc(2) == [2, 4]                    # min-heap: lowest id first


def test_pagepool_share_and_free_refcounts():
    p = PagePool(8)
    blocks = p.alloc(2)
    p.share(blocks)
    assert p.refcount(blocks[0]) == 2 and p.shared_refs == 2
    assert p.free(blocks) == []                    # still referenced
    assert p.free(blocks) == blocks                # last ref: back on the heap
    assert p.resident_blocks == 0 and p.free_blocks == 7


def test_pagepool_exhaustion_and_grow():
    p = PagePool(4)
    p.alloc(3)
    with pytest.raises(PagePoolExhausted):
        p.alloc(1)
    p.grow(6)
    assert p.alloc(2) == [4, 5]
    with pytest.raises(ValueError):
        p.grow(2)                                  # cannot shrink


def test_pagepool_misuse_raises():
    p = PagePool(4)
    with pytest.raises(ValueError):
        p.free([1])                                # never allocated
    with pytest.raises(ValueError):
        p.share([2])
    with pytest.raises(ValueError):
        PagePool(1)                                # scratch needs a companion


def test_pagepool_conservation_stats():
    p = PagePool(16)
    a = p.alloc(4)
    p.share(a[:2])
    p.free(a[3:])
    s = p.stats()
    assert s["allocated_total"] - s["freed_total"] == s["resident"] + s["shared"]
    assert s["total"] == s["free"] + s["resident"]
    assert s["used_high_watermark"] == 4


# ------------------------------------------------------------------ the gate

def test_supports_paged_kv_gate():
    assert M.supports_paged_kv(get_config("qwen3_1_7b"))
    assert M.supports_paged_kv(get_config("qwen2_moe_a2_7b"))
    assert M.supports_paged_kv(get_config("xlstm_350m"))
    assert M.supports_paged_kv(get_config("jamba_v0_1_52b"))
    assert not M.supports_paged_kv(get_config("whisper_medium"))       # audio
    assert not M.supports_paged_kv(get_config("llama_3_2_vision_11b"))  # vlm
    ring = dataclasses.replace(get_config("qwen3_1_7b"), sliding_window=64)
    assert not M.supports_paged_kv(ring)           # ring writes wrap pages


def test_unsupported_config_falls_back_to_dense(setup):
    cfg, params = setup
    ring = dataclasses.replace(cfg, sliding_window=32)
    w = RolloutWorker(ring, params, capacity=64, worker_id=0)  # paged=None
    assert not w._paged
    assert "blocks_total" not in w.dispatch_stats()


# ------------------------------------------------------------- kernel parity

@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", [
    # (B, KV, G, hd, page_size, num_pages)
    (2, 2, 2, 64, 16, 4),
    (1, 1, 4, 64, 8, 7),       # odd page count
    (3, 4, 1, 128, 32, 2),
])
def test_paged_kernel_matches_ref(shape, dtype):
    B, KV, G, hd, ps, num_pages = shape
    NB = B * num_pages + 1                         # + scratch
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), dtype)
    k_pool = jax.random.normal(ks[1], (NB, ps, KV, hd), dtype)
    v_pool = jax.random.normal(ks[2], (NB, ps, KV, hd), dtype)
    rng = np.random.default_rng(0)
    pt = np.zeros((B, num_pages), np.int32)        # unmapped -> scratch
    vl = rng.integers(1, num_pages * ps + 1, B)
    for b in range(B):
        used = -(-int(vl[b]) // ps)
        pt[b, :used] = rng.choice(np.arange(1, NB), used, replace=False)
    pt, vl = jnp.asarray(pt), jnp.asarray(vl, jnp.int32)
    out_p = ops.paged_decode_attention(q, k_pool, v_pool, pt, vl,
                                       force_pallas=True)
    out_r = paged_decode_attention_ref(q, k_pool, v_pool, pt, vl)
    tol = 1e-5 if dtype == "float32" else 2.5e-2
    err = float(jnp.abs(out_p.astype(jnp.float32)
                        - out_r.astype(jnp.float32)).max())
    assert err < tol, (shape, dtype, err)


def test_paged_kernel_ignores_unmapped_and_invalid_blocks():
    """Scratch garbage and blocks past valid_len must not leak into the output."""
    B, KV, G, hd, ps, num_pages = 1, 2, 2, 64, 8, 4
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    k_pool = jax.random.normal(ks[1], (6, ps, KV, hd))
    v_pool = jax.random.normal(ks[2], (6, ps, KV, hd))
    pt = jnp.asarray([[1, 2, 0, 0]], jnp.int32)
    vl = jnp.asarray([11], jnp.int32)              # mid-page-2 valid boundary
    base = ops.paged_decode_attention(q, k_pool, v_pool, pt, vl,
                                      force_pallas=True)
    k2 = k_pool.at[0].set(99.0).at[3:].set(99.0)   # poison scratch + unused
    v2 = v_pool.at[0].set(-99.0).at[3:].set(-99.0)
    k2 = k2.at[2, 3:].set(77.0)                    # poison past valid_len
    v2 = v2.at[2, 3:].set(-77.0)
    out = ops.paged_decode_attention(q, k2, v2, pt, vl, force_pallas=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out), atol=1e-5)


# ------------------------------------------------------- bitwise token parity

def test_paged_decode_bitwise_matches_dense(setup):
    cfg, params = setup
    paged, dense = _pair(cfg, params)
    prompt = [5, 7, 9, 11, 13, 17, 19, 23]
    paged.prefill(1, prompt)
    dense.prefill(1, prompt)
    assert paged.decode([1], 8)[1] == dense.decode([1], 8)[1]


def test_page_boundary_straddling_sequence(setup):
    """Prompt, tool extension, and decode all straddle page boundaries
    (page_size=4): writes land split across blocks, reads gather across the
    page table — tokens must still match the dense lane bitwise."""
    cfg, params = setup
    paged, dense = _pair(cfg, params, page_size=4, chunk_size=8)
    prompt = [3 + i for i in range(6)]             # 6 = 1.5 pages
    paged.prefill(1, prompt)
    dense.prefill(1, prompt)
    assert paged.decode([1], 5)[1] == dense.decode([1], 5)[1]  # 11 = 2.75 pages
    ext = [101, 102, 103, 104, 105]                # -> 16: exact page edge
    paged.extend(1, ext)
    dense.extend(1, ext)
    assert paged.decode([1], 6)[1] == dense.decode([1], 6)[1]
    assert paged.store[1].tokens == dense.store[1].tokens


def test_paged_chunk_window_past_capacity_edge(setup):
    """Paged twin of test_slot_pool's capacity-edge test: decode right up to
    the lane capacity with on-demand page allocation covering the tail."""
    cfg, params = setup
    paged, dense = _pair(cfg, params, capacity=16, page_size=4, chunk_size=8)
    prompt = list(range(3, 16))                    # 13 tokens
    paged.prefill(1, prompt)
    dense.prefill(1, prompt)
    assert paged.decode([1], 3)[1] == dense.decode([1], 3)[1]  # fills to 16
    assert len(paged.lane_pages[paged.store[1].slot]) == 4     # full coverage


def test_moe_paged_parity_non_chunked_admission():
    """qwen2_moe: chunked prefill is unsupported (capacity dispatch), so paged
    admission runs the whole-prompt ``_admit_paged`` path — tokens must match
    the dense pool bitwise through the MoE mixers."""
    full = get_config("qwen2_moe_a2_7b")
    cfg = full.reduced(n_periods=1)
    cfg = dataclasses.replace(
        cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k + 1)
    params = M.init_params(cfg, KEY)
    paged, dense = _pair(cfg, params, capacity=32, page_size=8)
    assert not paged._chunked                      # MoE: whole-prompt admit
    prompt = [5, 7, 9, 11, 13, 17]
    paged.prefill(1, prompt)
    dense.prefill(1, prompt)
    assert paged.decode([1], 4)[1] == dense.decode([1], 4)[1]


def test_recurrent_paged_parity():
    """xlstm: zero attention layers — the paged pool is pure dense state, the
    page machinery is bookkeeping-only, and decode must match exactly."""
    cfg = get_config("xlstm_350m").reduced(n_periods=1)
    params = M.init_params(cfg, KEY)
    paged, dense = _pair(cfg, params, capacity=32, page_size=8)
    assert paged._page_bytes == 0                  # no paged leaves to price
    prompt = [5, 7, 9, 11, 13, 17]
    paged.prefill(1, prompt)
    dense.prefill(1, prompt)
    assert paged.decode([1], 4)[1] == dense.decode([1], 4)[1]


# --------------------------------------------------------------- page sharing

def test_sibling_share_zero_copy_and_parity(setup):
    """A GRPO sibling's full prefix pages are refcount-shared (no KV copy);
    only the boundary partial page is D2D-copied.  The sibling's decode must
    still match the dense pool's copy-based implant bitwise."""
    cfg, params = setup
    paged, dense = _pair(cfg, params, page_size=16, chunk_size=8)
    prompt = [3 + i for i in range(20)]            # 1 full page + 4 boundary
    paged.prefill(1, prompt)
    dense.prefill(1, prompt)
    free_before = paged.pages.free_blocks
    paged.prefill(2, prompt)
    dense.prefill(2, prompt)
    s = paged.dispatch_stats()
    assert s["blocks_shared"] == 1                 # the full page, by refcount
    assert s["reused_tokens"] == 20 and s["full_hits"] == 1
    # sibling cost: 1 boundary block + pages for the suffix beyond reuse (none)
    assert free_before - paged.pages.free_blocks == 1
    assert paged.decode([1, 2], 5) == dense.decode([1, 2], 5)
    # shared page stays intact after the sibling decodes past it
    assert paged.pages.refcount(paged.lane_pages[paged.store[1].slot][0]) == 2


# ------------------------------------------------------------------ migration

def test_d2d_migration_resume_parity(setup):
    """Paged -> paged migration ships device-resident page stacks; the
    destination resumes exactly where the source stopped."""
    cfg, params = setup
    w0 = RolloutWorker(cfg, params, capacity=64, worker_id=0, sampler=GREEDY)
    w1 = RolloutWorker(cfg, params, capacity=64, worker_id=1, sampler=GREEDY)
    ref = RolloutWorker(cfg, params, capacity=64, worker_id=0, sampler=GREEDY)
    assert w0._paged and w1._paged
    w0.prefill(1, [5, 7, 9, 11])
    ref.prefill(2, [5, 7, 9, 11])
    w0.decode([1], 3)
    ref.decode([2], 3)
    pkg = w0.migrate_out(1)
    assert "pages" in pkg and "cache" not in pkg   # page stacks, not a lane
    for leaf in jax.tree.leaves(pkg["pages"]):
        assert isinstance(leaf, jax.Array)         # stayed on device (D2D)
    w1.migrate_in(pkg)
    assert w1.decode([1], 4)[1] == ref.decode([2], 4)[2]


def test_cross_layout_migration_both_directions(setup):
    cfg, params = setup
    paged, dense = _pair(cfg, params)
    ref = RolloutWorker(cfg, params, capacity=64, worker_id=0, sampler=GREEDY)
    prompt = [5, 7, 9, 11, 13]
    for w, sid in ((paged, 1), (dense, 2), (ref, 3)):
        w.prefill(sid, prompt)
        w.decode([sid], 3)
    want = ref.decode([3], 4)[3]
    # paged package flattened onto a dense pool
    dense.migrate_in(paged.migrate_out(1))
    assert dense.decode([1], 4)[1] == want
    # dense lane scattered onto a paged pool
    paged.migrate_in(dense.migrate_out(2))
    assert paged.decode([2], 4)[2] == want


def test_checkpoint_restore_parity_and_equal_logical_bytes(setup):
    """The host-gathered checkpoint and the D2D migration package of the same
    lane must price identical logical bytes (resident pages + state), and a
    restore from the checkpoint must resume bitwise."""
    cfg, params = setup
    w0 = RolloutWorker(cfg, params, capacity=64, worker_id=0, sampler=GREEDY)
    ref = RolloutWorker(cfg, params, capacity=64, worker_id=0, sampler=GREEDY)
    w0.prefill(1, [5, 7, 9, 11])
    ref.prefill(2, [5, 7, 9, 11])
    w0.decode([1], 3)
    ref.decode([2], 3)
    ck = w0.checkpoint_out(1)
    for leaf in jax.tree.leaves(ck["pages"]):
        assert isinstance(leaf, np.ndarray)        # durability: host buffers
    pkg = w0.migrate_out(1)
    assert ck["logical_bytes"] == pkg["logical_bytes"]
    w1 = RolloutWorker(cfg, params, capacity=64, worker_id=1, sampler=GREEDY)
    w1.migrate_in(ck)
    assert w1.decode([1], 4)[1] == ref.decode([2], 4)[2]


def test_migration_bytes_account_resident_pages_only(setup):
    """Regression (cost-model fix): a short lane's transfer prices its resident
    pages + dense state, not the full ``capacity`` lane the dense fallback
    ships.  The dense package still reports its true (full-lane) bytes."""
    cfg, params = setup
    paged, dense = _pair(cfg, params)              # capacity 64, page_size 16
    paged.prefill(1, [5, 7, 9, 11])
    dense.prefill(1, [5, 7, 9, 11])
    ppkg = paged.migrate_out(1)
    dpkg = dense.migrate_out(1)
    assert ppkg["logical_bytes"] == paged._page_bytes + paged._state_bytes
    assert dpkg["logical_bytes"] == sum(x.nbytes
                                        for x in jax.tree.leaves(dpkg["cache"]))
    assert ppkg["logical_bytes"] < dpkg["logical_bytes"]


# ------------------------------------------------------ accounting / telemetry

def test_paged_kv_bytes_prices_resident_pages(setup):
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=64, worker_id=0, sampler=GREEDY,
                      page_size=4)
    w.prefill(1, [5, 7, 9])                        # 3 tokens -> 1 block
    assert w.kv_bytes(1) == w._page_bytes + w._state_bytes
    w.decode([1], 4)                               # 7 tokens -> 2 blocks
    assert w.kv_bytes(1) == 2 * w._page_bytes + w._state_bytes
    assert w.kv_bytes(1) < w._lane_bytes           # the admission win


def test_dispatch_stats_block_telemetry(setup):
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=64, worker_id=0, sampler=GREEDY)
    w.prefill(1, [5, 7, 9, 11])
    s = w.dispatch_stats()
    for k in ("blocks_total", "blocks_free", "blocks_resident", "blocks_shared",
              "blocks_allocated_total", "blocks_freed_total",
              "blocks_used_high_watermark", "page_size", "block_grows"):
        assert k in s, k
    assert s["blocks_resident"] == 1 and s["page_size"] == w.page_size


def test_block_conservation_through_lifecycle(setup):
    """allocated - freed == resident + shared at every lifecycle edge, and the
    sanitizer's drain check agrees."""
    cfg, params = setup
    w = RolloutWorker(cfg, params, capacity=64, worker_id=0, sampler=GREEDY,
                      page_size=16, chunk_size=8, max_slots=2)

    def conserved():
        s = w.pages.stats()
        assert (s["allocated_total"] - s["freed_total"]
                == s["resident"] + s["shared"]), s
        assert check_block_conservation({0: w.dispatch_stats()}) == []

    prompt = [3 + i for i in range(20)]
    w.prefill(1, prompt)
    conserved()
    w.prefill(2, prompt)                           # sibling: shares a page
    conserved()
    w.decode([1, 2], 4)
    conserved()
    w.release(1)                                   # retires; pages trimmed
    conserved()
    w.migrate_out(2)                               # gathered out + retired
    conserved()
    w.reset_cache()                                # weight sync: all freed
    conserved()
    s = w.pages.stats()
    assert s["resident"] == 0 and s["shared"] == 0
    assert s["allocated_total"] == s["freed_total"] > 0


def test_block_conservation_check_flags_leak():
    stats = {"blocks_total": 8, "blocks_free": 5, "blocks_resident": 3,
             "blocks_shared": 0, "blocks_allocated_total": 6,
             "blocks_freed_total": 2}             # 4 live refs != 3 held
    assert any("leak" in v for v in check_block_conservation({0: stats}))
    stats["blocks_freed_total"] = 3
    stats["blocks_free"] = 4                       # partition broken
    assert any("partition" in v for v in check_block_conservation({0: stats}))
    assert check_block_conservation({0: {"decode_steps": 1}}) == []  # dense: skip
