"""End-to-end behaviour tests: full agentic RL iteration on the real engine, the
orchestration stack against the simulator, and the sharding/dry-run contract."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import get_config
from repro.rl import data as D
from repro.rl.loop import HeddleTrainer, TrainerConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_end_to_end_rollout_and_grpo_update():
    """Rollout on real workers (tool calls in the loop) -> GRPO update, twice."""
    cfg = get_config("smollm_135m").reduced(n_periods=2)
    tr = HeddleTrainer(cfg, TrainerConfig(group_size=4, n_workers=2, seed=0))
    history = tr.train(2, tasks_per_iter=2)
    assert len(history) == 2
    for h in history:
        assert np.isfinite(h["loss"])
        assert 0.0 <= h["mean_reward"] <= 1.0
    assert tr.step_count == 2


def test_rollout_records_are_well_formed():
    cfg = get_config("smollm_135m").reduced(n_periods=1)
    tr = HeddleTrainer(cfg, TrainerConfig(group_size=2, n_workers=2, seed=1))
    tasks = D.sample_tasks(2, seed=5)
    records = tr.rollout(tasks)
    assert len(records) == 4                     # 2 tasks x group 2
    for r in records:
        assert r.prompt_len == 4
        assert len(r.tokens) > r.prompt_len      # something was generated
        assert r.reward in (0.0, 0.25, 1.0)


def test_grpo_update_with_reward_spread_moves_policy():
    """With shaped rewards, the advantage machinery produces nonzero updates when
    any group has reward spread (sanity of the learning loop, not convergence)."""
    from repro.rl.loop import RolloutRecord
    cfg = get_config("smollm_135m").reduced(n_periods=1)
    tr = HeddleTrainer(cfg, TrainerConfig(group_size=4, n_workers=1, seed=0))
    task = D.sample_tasks(1, seed=0)[0]
    recs = [
        RolloutRecord(task.prompt_tokens() + [D.TOOL_CALL, 20, D.EOS], 4, 1.0, 1),
        RolloutRecord(task.prompt_tokens() + [7, 8, D.EOS], 4, 0.0, 1),
        RolloutRecord(task.prompt_tokens() + [D.TOOL_CALL, D.EOS], 4, 0.25, 1),
        RolloutRecord(task.prompt_tokens() + [11, D.EOS], 4, 0.0, 1),
    ]
    m = tr.update(recs)
    assert abs(m["pg_loss"]) > 1e-8


@pytest.mark.slow
def test_dryrun_single_combo_subprocess():
    """The multi-pod dry-run contract: lower+compile one (arch, shape) on the 16x16
    production mesh with 512 host devices (subprocess: device count is locked at
    first jax init, so it cannot run in-process)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-135m",
         "--shape", "decode_32k", "--out", "/tmp/dryrun_test.json"],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")), cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    with open("/tmp/dryrun_test.json") as f:
        rec = json.load(f)[0]
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["hlo_flops"] > 0
    assert rec["collective_total_bytes"] >= 0


def test_roofline_reader_on_committed_dryrun_artifacts():
    path = os.path.join(REPO, "dryrun_16x16.json")
    if not os.path.exists(path):
        pytest.skip("dry-run artifact not generated yet")
    sys.path.insert(0, REPO)
    from benchmarks.roofline import roofline_row
    with open(path) as f:
        records = json.load(f)
    rows = [r for r in (roofline_row(rec) for rec in records) if r]
    assert len(rows) >= 39
    for r in rows:
        assert r["dominant"] in ("compute", "memory", "collective")
        assert r["t_compute_s"] > 0
