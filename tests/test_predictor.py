"""Progressive trajectory prediction (§4.1): training, progressivity, metrics."""

import numpy as np

from repro.core.predictor import (HistoryPredictor, ModelPredictor,
                                  ProgressivePredictor, harvest, long_tail_recall,
                                  pearson)
from repro.core.trajectory import FEATURE_DIM, Trajectory
from repro.engine.workload import WorkloadConfig, generate, replay_finished


def _data(task="coding", n=48, g=8, seed=1):
    return replay_finished(generate(WorkloadConfig(task=task, n_prompts=n,
                                                   group_size=g, seed=seed)))


def _replay_at(t, k):
    r = Trajectory(prompt_id=t.prompt_id, sample_id=t.sample_id,
                   prompt_tokens=t.prompt_tokens, context_tokens=t.prompt_tokens)
    for st_ in t.steps[:k]:
        r.record_step(st_)
        r.record_tool_output(st_.tool_output_tokens)
    return r


def test_harvest_shapes_and_targets():
    trajs = _data(n=8)
    feats, remaining = harvest(trajs)
    assert feats.shape[1] == FEATURE_DIM
    assert len(feats) == len(remaining)
    assert (remaining >= 0).all()
    # one prompt-only tuple plus one per step
    assert len(feats) == sum(1 + t.true_num_steps for t in trajs)


def test_predictions_nonnegative_and_finite():
    p = ProgressivePredictor().fit_trajectories(_data())
    test = _data(seed=2)
    preds = [p.predict(_replay_at(t, min(2, t.true_num_steps))) for t in test[:64]]
    assert all(np.isfinite(v) and v >= 0 for v in preds)
    batch = p.predict_batch([_replay_at(t, 1) for t in test[:64]])
    assert batch.shape == (64,)
    assert np.isfinite(batch).all()


def test_progressive_beats_static_baselines_on_recall():
    """Fig 13: runtime context beats prompt-only; later steps beat earlier ones."""
    train, test = _data(n=64, seed=1), _data(n=32, g=16, seed=2)
    pp = ProgressivePredictor().fit_trajectories(train)
    hp = HistoryPredictor().fit_trajectories(train)
    mp = ModelPredictor().fit_trajectories(train)
    true = np.array([t.true_total_tokens for t in test], float)

    def recall_at(pred_fn, k):
        reps = [_replay_at(t, min(k, t.true_num_steps)) for t in test]
        preds = np.array([r.tokens_generated + pred_fn(r) for r in reps])
        return long_tail_recall(preds, true)

    r_hist = recall_at(hp.predict, 0)
    r_model = recall_at(mp.predict, 0)
    r_h1 = recall_at(pp.predict, 1)
    r_h2 = recall_at(pp.predict, 2)
    assert r_h1 > max(r_hist, r_model), (r_h1, r_hist, r_model)
    assert r_h2 >= r_h1 - 0.05                      # progressive refinement


def test_metrics_edge_cases():
    assert long_tail_recall(np.array([1.0, 2, 3, 4]), np.array([1.0, 2, 3, 4])) == 1.0
    assert pearson(np.ones(5), np.arange(5.0)) == 0.0
    assert abs(pearson(np.arange(10.0), np.arange(10.0)) - 1.0) < 1e-9


def test_history_predictor_uses_prompt_means():
    train = _data(n=16)
    hp = HistoryPredictor().fit_trajectories(train)
    t0 = train[0]
    fresh = Trajectory(prompt_id=t0.prompt_id, sample_id=99,
                       prompt_tokens=t0.prompt_tokens)
    expected = np.mean([t.true_total_tokens for t in train
                        if t.prompt_id == t0.prompt_id])
    assert abs(hp.predict(fresh) - expected) < 1e-6
