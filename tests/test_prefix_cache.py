"""Property tests for the prefix-cache token-trie (radix-cache bookkeeping)."""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.engine.worker import PrefixCacheIndex

TOKENS = st.lists(st.integers(0, 30), min_size=0, max_size=12)


@settings(max_examples=50, deadline=None)
@given(st.lists(TOKENS, min_size=0, max_size=8), TOKENS)
def test_match_len_bounded_by_query_and_corpus(corpus, query):
    idx = PrefixCacheIndex()
    for toks in corpus:
        idx.insert(toks)
    n = idx.match_len(query)
    assert 0 <= n <= len(query)
    if corpus:
        assert n <= max(len(t) for t in corpus)
    else:
        assert n == 0


@settings(max_examples=50, deadline=None)
@given(TOKENS)
def test_insert_then_match_is_a_full_hit(tokens):
    idx = PrefixCacheIndex()
    idx.insert(tokens)
    assert idx.match_len(tokens) == len(tokens)
    # every prefix of an inserted sequence is also a full hit
    assert idx.match_len(tokens[: len(tokens) // 2]) == len(tokens) // 2


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 1), TOKENS), min_size=0, max_size=20))
def test_hit_accounting_is_monotone_and_consistent(ops):
    """lookups counts every match_len; hits/hit_tokens only grow, and only on
    nonzero matches (hits <= lookups, hit_tokens >= hits)."""
    idx = PrefixCacheIndex()
    lookups = 0
    prev = (0, 0)
    for op, toks in ops:
        if op == 0:
            idx.insert(toks)
        else:
            n = idx.match_len(toks)
            lookups += 1
            assert (idx.hits, idx.hit_tokens) >= prev
            assert (idx.hits > prev[0]) == (n > 0)
        prev = (idx.hits, idx.hit_tokens)
    assert idx.lookups == lookups
    assert idx.hits <= idx.lookups
    assert idx.hit_tokens >= idx.hits


@settings(max_examples=50, deadline=None)
@given(st.lists(TOKENS, min_size=1, max_size=8), TOKENS)
def test_full_vs_partial_hits_are_separated(corpus, query):
    """A whole-query match is a full hit; a nonzero proper-prefix match is a
    partial hit — never both, and ``hits`` is their sum (affinity stats must not
    count a 2-token graze as a cache home)."""
    idx = PrefixCacheIndex()
    for toks in corpus:
        idx.insert(toks)
    n = idx.match_len(query)
    if n == len(query) and n > 0:
        assert (idx.full_hits, idx.partial_hits) == (1, 0)
    elif n > 0:
        assert (idx.full_hits, idx.partial_hits) == (0, 1)
    else:
        assert (idx.full_hits, idx.partial_hits) == (0, 0)
    assert idx.hits == idx.full_hits + idx.partial_hits


def test_node_cap_bounds_memory_and_prunes_lru():
    """Accounting mode stays bounded: inserts past ``max_nodes`` prune the
    least-recently-used subtrees, and recently-touched prefixes survive."""
    idx = PrefixCacheIndex(max_nodes=64)
    for i in range(64):
        idx.insert([i, 1000 + i, 2000 + i])          # 3 nodes per sequence
    assert idx.node_count <= 64
    hot = [63, 1063, 2063]                           # most recent insert
    assert idx.match_len(hot) == 3                   # hot path survives the cap
    idx.insert(list(range(3000, 3040)))              # one long cold-pruning insert
    assert idx.node_count <= 64


def test_lane_refs_match_and_invalidate():
    """(lane, span) refs: match_lane returns the deepest live ref; invalidate()
    makes an overwritten lane's refs unreachable without touching accounting."""
    idx = PrefixCacheIndex()
    idx.insert([1, 2, 3, 4], slot=7)
    n, slot = idx.match_lane([1, 2, 3, 4, 5])
    assert (n, slot) == (4, 7)
    idx.insert([1, 2, 9], slot=3)                    # diverging branch, other lane
    n, slot = idx.match_lane([1, 2, 9, 9])
    assert (n, slot) == (3, 3)
    idx.invalidate(7)                                # lane 7 overwritten
    n, slot = idx.match_lane([1, 2, 3, 4])
    assert slot != 7 and n <= 2                      # only the shared [1,2] via lane 3
    idx.insert([1, 2, 3, 4], slot=7)                 # re-admitted at a new epoch
    assert idx.match_lane([1, 2, 3, 4]) == (4, 7)
