"""Property tests for the prefix-cache token-trie (radix-cache bookkeeping)."""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.engine.worker import PrefixCacheIndex

TOKENS = st.lists(st.integers(0, 30), min_size=0, max_size=12)


@settings(max_examples=50, deadline=None)
@given(st.lists(TOKENS, min_size=0, max_size=8), TOKENS)
def test_match_len_bounded_by_query_and_corpus(corpus, query):
    idx = PrefixCacheIndex()
    for toks in corpus:
        idx.insert(toks)
    n = idx.match_len(query)
    assert 0 <= n <= len(query)
    if corpus:
        assert n <= max(len(t) for t in corpus)
    else:
        assert n == 0


@settings(max_examples=50, deadline=None)
@given(TOKENS)
def test_insert_then_match_is_a_full_hit(tokens):
    idx = PrefixCacheIndex()
    idx.insert(tokens)
    assert idx.match_len(tokens) == len(tokens)
    # every prefix of an inserted sequence is also a full hit
    assert idx.match_len(tokens[: len(tokens) // 2]) == len(tokens) // 2


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 1), TOKENS), min_size=0, max_size=20))
def test_hit_accounting_is_monotone_and_consistent(ops):
    """lookups counts every match_len; hits/hit_tokens only grow, and only on
    nonzero matches (hits <= lookups, hit_tokens >= hits)."""
    idx = PrefixCacheIndex()
    lookups = 0
    prev = (0, 0)
    for op, toks in ops:
        if op == 0:
            idx.insert(toks)
        else:
            n = idx.match_len(toks)
            lookups += 1
            assert (idx.hits, idx.hit_tokens) >= prev
            assert (idx.hits > prev[0]) == (n > 0)
        prev = (idx.hits, idx.hit_tokens)
    assert idx.lookups == lookups
    assert idx.hits <= idx.lookups
    assert idx.hit_tokens >= idx.hits
