"""Presorted DP (paper §5.2): optimality, Lemma 5.1, aggregation, extensions."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.placement import (InterferenceModel, aggregate_short,
                                  brute_force_partition, evaluate_partition, place,
                                  presorted_dp)

F = InterferenceModel.analytic(0.2)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 500), min_size=2, max_size=7),
       st.integers(2, 3), st.floats(0.01, 0.5))
def test_dp_matches_brute_force(lengths, m, slope):
    """Formula 3 + Lemma 5.1 give the globally optimal partition (exhaustive oracle)."""
    interference = InterferenceModel.analytic(slope)
    res = presorted_dp(lengths, m, interference)
    _, best = brute_force_partition(lengths, m, interference)
    assert res.makespan <= best + 1e-9
    # the reported makespan is self-consistent with the objective
    assert abs(evaluate_partition(res.groups, lengths, interference)
               - res.makespan) < 1e-9


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(1, 1e4), min_size=3, max_size=40), st.integers(1, 8))
def test_dp_groups_are_contiguous_in_sorted_order(lengths, m):
    """Lemma 5.1: each group is a contiguous slice of the descending-sorted list."""
    res = presorted_dp(lengths, m, F)
    slen = np.asarray(lengths)
    boundaries = []
    for g in res.groups:
        if not g:
            continue
        vals = sorted((slen[i] for i in g), reverse=True)
        boundaries.append((max(vals), min(vals)))
    # consecutive groups: previous group's min >= next group's max (desc order)
    for (hi1, lo1), (hi2, lo2) in zip(boundaries, boundaries[1:]):
        assert lo1 >= hi2 - 1e-9


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(1, 1e4), min_size=2, max_size=40), st.integers(1, 6))
def test_dp_partitions_everything_once(lengths, m):
    res = presorted_dp(lengths, m, F)
    seen = sorted(i for g in res.groups for i in g)
    assert seen == list(range(len(lengths)))


def test_monotone_speedup_equals_naive():
    rng = np.random.default_rng(0)
    for _ in range(10):
        lengths = rng.pareto(1.5, 60) * 100 + 1
        a = presorted_dp(lengths, 7, F, monotone_speedup=True)
        b = presorted_dp(lengths, 7, F, monotone_speedup=False)
        assert abs(a.makespan - b.makespan) < 1e-9


def test_heterogeneous_worker_token_times():
    """Fast workers (low T) take the long groups (§6 sort-initialized mapping)."""
    lengths = [100, 90, 10, 9, 8, 7]
    res = presorted_dp(lengths, 3, F, base_token_time=[0.25, 0.5, 1.0])
    # the longest trajectory must sit on the fastest worker
    assert 0 in res.groups[0]
    assert res.makespan <= presorted_dp(lengths, 3, F,
                                        base_token_time=[1.0, 1.0, 1.0]).makespan


def test_aggregation_reduces_items_preserves_membership():
    rng = np.random.default_rng(1)
    lengths = rng.pareto(1.2, 500) * 100 + 1
    ilen, icnt, members = aggregate_short(lengths, float(np.quantile(lengths, 0.8)), 8)
    assert len(ilen) < len(lengths)
    flat = sorted(i for ms in members for i in ms)
    assert flat == list(range(len(lengths)))
    assert int(icnt.sum()) == len(lengths)


def test_place_pipeline_with_aggregation():
    rng = np.random.default_rng(2)
    lengths = rng.pareto(1.2, 300) * 100 + 1
    res = place(lengths, 8, F, agg_threshold=float(np.quantile(lengths, 0.7)))
    flat = sorted(i for g in res.groups for i in g)
    assert flat == list(range(len(lengths)))


def test_max_group_count_cap_is_respected():
    lengths = [10.0] * 50
    res = presorted_dp(lengths, 5, F, max_group_count=12)
    assert all(len(g) <= 12 for g in res.groups)


def test_work_aware_cost_upper_bounds_formula2():
    rng = np.random.default_rng(3)
    lengths = rng.pareto(1.2, 80) * 500 + 10
    plain = presorted_dp(lengths, 6, F)
    wa = presorted_dp(lengths, 6, F, work_aware=True)
    # the work-aware objective adds a lower bound, so its optimum cannot be cheaper
    assert wa.makespan >= plain.makespan - 1e-9


def test_interference_model_monotone_and_normalized():
    assert F(1) == pytest.approx(1.0)
    xs = [F(b) for b in (1, 2, 8, 64, 256)]
    assert xs == sorted(xs)
    with pytest.raises(ValueError):
        InterferenceModel([1, 2, 3], [3.0, 2.0, 1.0])
