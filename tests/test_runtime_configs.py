"""Runtime e2e over non-vanilla architectures: one MoE and one recurrent config.

The orchestrator/backend fault machinery host-gathers and re-implants whatever
cache pytree the model family uses, so checkpoint/restore after a worker death
must work for MoE KV lanes and recurrent (xLSTM) state exactly as for dense
attention — these runs exercise that, not just the happy path.
"""

import copy
import dataclasses
import math

import jax
import pytest

from repro.configs import get_config
from repro.core.faults import FaultPlan
from repro.engine.runtime import RuntimeConfig, build_workbench, make_runtime
from repro.models import model as M


def reduced(name):
    full = get_config(name)
    periods = 2 if len(full.block_pattern) == 1 else 1
    cfg = full.reduced(n_periods=periods)
    if cfg.n_experts:   # no-drop capacity so decode == full forward exactly
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k + 1)
    return cfg


@pytest.mark.parametrize("name", ["qwen2_moe_a2_7b", "xlstm_350m"])
def test_runtime_end_to_end(name):
    cfg = reduced(name)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch, predictor = build_workbench(n_prompts=2, group_size=2, seed=11,
                                       max_total_tokens=24, max_steps=3)
    rcfg = RuntimeConfig(scheduler="pps", migration=True, max_active=2,
                         quantum=8, link_bandwidth=math.inf, seed=11)
    res = make_runtime(cfg, params, copy.deepcopy(batch), predictor,
                       n_workers=2, config=rcfg).run()
    assert all(t.finished for t in res.trajectories)
    assert res.total_tokens == sum(t.tokens_generated for t in res.trajectories)
    assert res.worker_deaths == 0 and res.recoveries == 0

    # same workload under chaos: the death forces checkpoint_out/migrate_in of
    # this family's cache pytree onto the survivor
    faults = FaultPlan.chaos(seed=11, n_workers=2, horizon=res.makespan)
    chaos = make_runtime(cfg, params, copy.deepcopy(batch), predictor,
                         n_workers=2, config=rcfg, faults=faults).run()
    assert all(t.finished for t in chaos.trajectories)
    assert chaos.worker_deaths == 1 and chaos.recoveries > 0
    for t in chaos.trajectories:
        assert t.tokens_generated == sum(s.gen_tokens for s in t.steps)
