"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (deliverable c)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.ref import decode_attention_ref
from repro.models.flash import flash_attention

KEY = jax.random.PRNGKey(0)

DECODE_SHAPES = [
    # (B, KV, G, hd, C)
    (1, 1, 1, 64, 64),
    (2, 2, 4, 64, 128),
    (1, 8, 6, 128, 1024),
    (4, 1, 1, 64, 300),       # ragged: C not a multiple of block
    (2, 3, 2, 128, 512),
    (1, 16, 1, 64, 700),
    (3, 4, 7, 128, 257),
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_decode_attention_kernel_matches_oracle(shape, dtype):
    B, KV, G, hd, C = shape
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, C, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, C, KV, hd), dtype)
    vl = jnp.asarray(np.random.default_rng(0).integers(1, C + 1, B), jnp.int32)
    out_p = decode_attention_pallas(q, k, v, vl, block_c=128, interpret=True)
    out_r = decode_attention_ref(q, k, v, vl)
    tol = 1e-5 if dtype == "float32" else 2.5e-2
    err = float(jnp.abs(out_p.astype(jnp.float32) - out_r.astype(jnp.float32)).max())
    assert err < tol, (shape, dtype, err)


def test_decode_attention_block_size_invariance():
    B, KV, G, hd, C = 2, 2, 2, 64, 512
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    k = jax.random.normal(ks[1], (B, C, KV, hd))
    v = jax.random.normal(ks[2], (B, C, KV, hd))
    vl = jnp.asarray([512, 300], jnp.int32)
    outs = [decode_attention_pallas(q, k, v, vl, block_c=bc, interpret=True)
            for bc in (64, 128, 512)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-5)


def test_decode_attention_respects_valid_len():
    """Slots beyond valid_len must not influence the output."""
    B, KV, G, hd, C = 1, 1, 2, 64, 256
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    k = jax.random.normal(ks[1], (B, C, KV, hd))
    v = jax.random.normal(ks[2], (B, C, KV, hd))
    vl = jnp.asarray([100], jnp.int32)
    out1 = decode_attention_pallas(q, k, v, vl, interpret=True)
    k2 = k.at[:, 100:].set(99.0)            # poison the invalid region
    v2 = v.at[:, 100:].set(-99.0)
    out2 = decode_attention_pallas(q, k2, v2, vl, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


# ----------------------------------------------------------------- flash attention

def _flash_ref(q, k, v, qp, kp, scale, causal, window):
    s = jnp.einsum("bkgsd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    m = (qp[:, None] >= 0) & (kp[None, :] >= 0)
    if causal:
        m &= qp[:, None] >= kp[None, :]
    if window:
        m &= qp[:, None] - kp[None, :] < window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgst,btkd->bkgsd", p, v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("S,T,window", [(64, 64, 0), (100, 100, 0), (100, 100, 17),
                                        (33, 70, 0), (128, 128, 32)])
def test_flash_attention_forward_and_grads(S, T, window):
    B, KV, G, hd = 2, 2, 3, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, KV, G, S, hd))
    k = jax.random.normal(ks[1], (B, T, KV, hd))
    v = jax.random.normal(ks[2], (B, T, KV, hd))
    qp, kp = jnp.arange(S), jnp.arange(T)
    scale = 1 / math.sqrt(hd)
    out = flash_attention(q, k, v, qp, kp, scale, True, window, 32, 48)
    ref = _flash_ref(q, k, v, qp, kp, scale, True, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    gf = jax.grad(lambda *a: flash_attention(*a, qp, kp, scale, True, window, 32, 48)
                  .sum(), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: _flash_ref(*a, qp, kp, scale, True, window).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


# ----------------------------------------------------------------- mamba scan kernel

from repro.kernels.mamba_scan import mamba_scan_pallas, mamba_scan_ref

MAMBA_SHAPES = [
    # (B, S, di, N, chunk, di_block)
    (2, 37, 64, 8, 16, 32),
    (1, 128, 128, 16, 64, 128),
    (3, 50, 96, 4, 25, 48),
    (2, 33, 64, 16, 64, 64),     # chunk > S, ragged
]


@pytest.mark.parametrize("shape", MAMBA_SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_mamba_scan_kernel_matches_oracle(shape, dtype):
    B, S, di, N, chunk, dib = shape
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di))).astype(dtype)
    b_in = (jax.random.normal(ks[1], (B, S, N)) * 0.5).astype(dtype)
    c_in = (jax.random.normal(ks[2], (B, S, N)) * 0.5).astype(dtype)
    x = (jax.random.normal(ks[3], (B, S, di)) * 0.5).astype(dtype)
    a_log = jax.random.normal(ks[4], (di, N)) * 0.3
    out = mamba_scan_pallas(dt, b_in, c_in, x, a_log, chunk=chunk, di_block=dib,
                            interpret=True)
    ref = mamba_scan_ref(dt, b_in, c_in, x, a_log)
    tol = 1e-4 if dtype == "float32" else 5e-2
    assert float(jnp.abs(out - ref).max()) < tol


def test_mamba_scan_kernel_chunk_invariance():
    B, S, di, N = 2, 64, 64, 8
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di)))
    b_in = jax.random.normal(ks[1], (B, S, N)) * 0.5
    c_in = jax.random.normal(ks[2], (B, S, N)) * 0.5
    x = jax.random.normal(ks[3], (B, S, di)) * 0.5
    a_log = jax.random.normal(ks[4], (di, N)) * 0.3
    outs = [mamba_scan_pallas(dt, b_in, c_in, x, a_log, chunk=c, di_block=64,
                              interpret=True) for c in (8, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-5)


def test_use_pallas_decode_flag_matches_reference_engine_path():
    """ModelConfig.use_pallas_decode routes layers.attention_decode through the
    Pallas flash-decode kernel (interpret mode off-TPU); decode logits must match
    the jnp-oracle path the engine uses by default."""
    from dataclasses import replace

    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config("qwen3_1_7b").reduced(n_periods=1)
    params = M.init_params(cfg, KEY)
    prompt = jnp.asarray([[5, 7, 9, 11]], jnp.int32)
    _, _, cache_ref = M.forward_full(cfg, params, {"tokens": prompt}, capacity=32)
    cfg_p = replace(cfg, use_pallas_decode=True)
    _, _, cache_p = M.forward_full(cfg_p, params, {"tokens": prompt}, capacity=32)

    tok = jnp.asarray([[13]], jnp.int32)
    logits_ref, cache_ref = M.decode_step(cfg, params, cache_ref, tok)
    logits_p, cache_p = M.decode_step(cfg_p, params, cache_p, tok)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_ref),
                               atol=2e-5, rtol=2e-5)
    # and a second step (the caches written by both paths agree too)
    tok2 = jnp.argmax(logits_ref, -1)[:, None].astype(jnp.int32)
    logits_ref2, _ = M.decode_step(cfg, params, cache_ref, tok2)
    logits_p2, _ = M.decode_step(cfg_p, params, cache_p, tok2)
    np.testing.assert_allclose(np.asarray(logits_p2), np.asarray(logits_ref2),
                               atol=2e-5, rtol=2e-5)
