"""Crash-atomic checkpoint save and strict dtype validation on restore."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint


def _tree(val: float, dtype=jnp.float32):
    return {"w": jnp.full((3, 2), val, dtype=dtype), "b": jnp.zeros((2,), dtype)}


def test_save_is_atomic_overwrite(tmp_path):
    """Re-saving over an existing checkpoint swaps the whole directory in one
    commit: content updates, and no .tmp/.old staging dirs survive."""
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, _tree(1.0), step=1)
    checkpoint.save(path, _tree(2.0), step=2)
    restored = checkpoint.restore(path, _tree(0.0))
    assert float(restored["w"][0, 0]) == 2.0
    assert checkpoint.load_step(path) == 2
    leftovers = [d for d in os.listdir(tmp_path) if d != "ckpt"]
    assert leftovers == []


def test_save_recovers_from_stale_tmp(tmp_path):
    """A .tmp dir abandoned by a crashed earlier save (possibly half-written)
    must not poison the next save."""
    path = str(tmp_path / "ckpt")
    stale = path + ".tmp"
    os.makedirs(stale)
    with open(os.path.join(stale, "leaves.npz"), "w") as f:
        f.write("garbage from a crashed save")
    checkpoint.save(path, _tree(3.0), step=3)
    restored = checkpoint.restore(path, _tree(0.0))
    assert float(restored["w"][0, 0]) == 3.0
    assert not os.path.exists(stale)


def test_interrupted_save_leaves_previous_checkpoint_loadable(tmp_path, monkeypatch):
    """Simulated crash mid-stage (before the commit rename): the target still
    holds the previous complete checkpoint."""
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, _tree(1.0), step=1)

    def crash(*a, **kw):
        raise RuntimeError("simulated crash mid-save")

    monkeypatch.setattr(checkpoint.np, "savez", crash)
    with pytest.raises(RuntimeError, match="simulated crash"):
        checkpoint.save(path, _tree(2.0), step=2)
    monkeypatch.undo()
    restored = checkpoint.restore(path, _tree(0.0))
    assert float(restored["w"][0, 0]) == 1.0
    assert checkpoint.load_step(path) == 1


def test_restore_dtype_mismatch_raises(tmp_path):
    """Regression: restore must refuse to silently cast — loading f32 bytes
    into a bf16 (or int) template is state corruption, not a convenience."""
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, _tree(1.5, dtype=jnp.float32))
    with pytest.raises(ValueError, match="dtype"):
        checkpoint.restore(path, _tree(0.0, dtype=jnp.bfloat16))
    with pytest.raises(ValueError, match="refusing to cast"):
        checkpoint.restore(path, {"w": jnp.zeros((3, 2), jnp.int32),
                                  "b": jnp.zeros((2,), jnp.int32)})
    # matching template still round-trips exactly
    ok = checkpoint.restore(path, _tree(0.0))
    np.testing.assert_array_equal(np.asarray(ok["w"]),
                                  np.full((3, 2), 1.5, np.float32))


def test_restore_shape_mismatch_still_raises(tmp_path):
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, _tree(1.0))
    with pytest.raises(ValueError, match="template"):
        checkpoint.restore(path, {"w": jnp.zeros((4, 2)), "b": jnp.zeros((2,))})
