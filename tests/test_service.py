"""Async rollout-as-a-service plane: replay-buffer discipline, the staleness
bound as a hard property, weight-epoch stamping parity across backends (under
chaos), sanitizer invariants for the new event kinds, and the async trainer."""

import copy
import math

import jax
import pytest

from repro.configs import get_config
from repro.core.trajectory import Trajectory
from repro.engine.runtime import (RuntimeConfig, build_workbench, make_runtime,
                                  make_sim_components, synth_prompts)
from repro.models import model as M
from repro.rl.service import ReplayBuffer, RolloutService

SEED = 5


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm_135m").reduced(n_periods=1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _traj(pid: int, sid: int, epoch: int = 0) -> Trajectory:
    t = Trajectory(prompt_id=pid, sample_id=sid, prompt_tokens=4,
                   context_tokens=4)
    t.weight_epoch = epoch
    return t


# ------------------------------------------------------------- replay buffer

def test_replay_buffer_group_ready_only_when_complete():
    """GRPO advantages normalize within a group — a partial group must never
    be consumable."""
    buf = ReplayBuffer(capacity=64, group_size=2)
    buf.add(_traj(0, 0))
    assert buf.ready_groups == 0
    assert buf.take(1, epoch=0, max_staleness=0) == []
    buf.add(_traj(0, 1))
    assert buf.ready_groups == 1
    (group,) = buf.take(1, epoch=0, max_staleness=0)
    assert [t.prompt_id for t in group] == [0, 0]
    assert len(buf) == 0 and buf.ready_groups == 0


def test_replay_buffer_takes_groups_in_completion_order():
    buf = ReplayBuffer(capacity=64, group_size=2)
    buf.add(_traj(0, 0))
    buf.add(_traj(1, 0))
    buf.add(_traj(1, 1))           # group 1 completes first
    buf.add(_traj(0, 1))
    (first,) = buf.take(1, epoch=0, max_staleness=0)
    assert first[0].prompt_id == 1
    (second,) = buf.take(1, epoch=0, max_staleness=0)
    assert second[0].prompt_id == 0


def test_replay_buffer_staleness_discards_the_whole_group():
    """Freshness is per trajectory: one over-age sibling poisons the group
    (its advantages would mix policies beyond the bound), so the whole group
    is discarded and counted — never partially consumed."""
    buf = ReplayBuffer(capacity=64, group_size=2)
    buf.add(_traj(0, 0, epoch=0))  # 3 epochs old at take time
    buf.add(_traj(0, 1, epoch=2))  # fresh
    buf.add(_traj(1, 0, epoch=2))
    buf.add(_traj(1, 1, epoch=3))
    taken = buf.take(2, epoch=3, max_staleness=1)
    assert len(taken) == 1 and taken[0][0].prompt_id == 1
    assert buf.stale_discards == 2
    assert len(buf) == 0


def test_replay_buffer_capacity_evicts_oldest_ready_never_partial():
    """Overflow drops the oldest *complete* group; partial groups survive —
    their siblings are still streaming in."""
    buf = ReplayBuffer(capacity=3, group_size=2)
    buf.add(_traj(0, 0))
    buf.add(_traj(0, 1))           # ready group 0
    buf.add(_traj(1, 0))           # partial, len == capacity
    buf.add(_traj(2, 0))           # overflow -> evict ready group 0
    assert buf.evicted == 2
    assert buf.ready_groups == 0
    assert len(buf) == 2           # both partials intact
    buf.add(_traj(1, 1))           # partial completes after the eviction
    assert buf.ready_groups == 1


# ----------------------------------------------- service consumption harness

def _consume(backend_kind, cfg, params, seed, *, n_updates=3, gpu=2, gsz=4,
             max_staleness=2, train_s=1.0, sanitize=True):
    """Drive a RolloutService the way the async trainer does: seed waves of
    groups, consume complete groups FIFO, publish a weight epoch per update,
    inject a replacement wave.  Returns (per-consumed-traj staleness,
    stamps-by-batch-position, buffer, service, result)."""
    pool = n_updates * gpu
    batch, predictor = build_workbench(n_prompts=pool, group_size=gsz,
                                       seed=seed)
    by_pid = {}
    for t in batch:
        by_pid.setdefault(t.prompt_id, []).append(t)
    groups = list(by_pid.values())
    order = {t.traj_id: i for i, t in enumerate(batch)}
    rcfg = RuntimeConfig(scheduler="pps", migration=True, max_active=2,
                         quantum=8, seed=seed, link_bandwidth=math.inf,
                         trace=True, sanitize=sanitize)
    if backend_kind == "sim":
        lens = {tid: len(p)
                for tid, p in synth_prompts(batch, seed=seed).items()}
        backend, controller = make_sim_components(predictor, 2, rcfg,
                                                  prompt_lens=lens)
        svc = RolloutService(backend, controller, rcfg)
    else:
        runtime = make_runtime(cfg, params, batch, predictor, n_workers=2,
                               config=rcfg)
        svc = RolloutService(runtime.backend, runtime.controller, rcfg)
    svc.submit([t for g in groups[:gpu] for t in g])
    next_wave = gpu
    buf = ReplayBuffer(capacity=256, group_size=gsz)
    staleness, stamps = [], {}
    updates = 0
    free = 0.0
    for traj in svc.stream():
        stamps[order[traj.traj_id]] = traj.weight_epoch
        buf.add(traj)
        while updates < n_updates and buf.ready_groups >= gpu:
            taken = buf.take(gpu, epoch=svc.epoch, max_staleness=max_staleness)
            if not taken:
                break
            free = max(svc.now, free) + train_s
            updates += 1
            staleness.extend(svc.epoch - t.weight_epoch
                             for g in taken for t in g)
            if updates < n_updates:
                svc.sync_weights(at=free)
                wave = groups[next_wave:next_wave + len(taken)]
                next_wave += len(taken)
                if wave:
                    svc.submit([t for g in wave for t in g])
        if updates >= n_updates:
            break
    res = svc.close()
    for t in res.trajectories:
        stamps.setdefault(order[t.traj_id], t.weight_epoch)
    # traj_ids come from the process-global counter (differ run to run), so
    # cross-run trace comparison rewrites them to batch positions
    norm_trace = [(k, order.get(tid, tid), wid) for k, tid, wid in res.trace]
    return staleness, stamps, buf, svc, res, norm_trace


def test_no_consumed_trajectory_exceeds_max_staleness():
    """The tentpole property, multi-seed: over every consumed trajectory,
    published_epoch - weight_epoch <= max_staleness, enforced by the buffer
    (discards counted, never trained on) — and the property must bite: epochs
    actually advance and nonzero staleness is actually observed."""
    saw_nonzero = False
    for seed in (3, 5, 9):
        staleness, _, buf, svc, _, _ = _consume("sim", None, None, seed,
                                                max_staleness=2)
        assert staleness, f"seed {seed}: nothing consumed"
        assert max(staleness) <= 2, \
            f"seed {seed}: staleness bound violated ({max(staleness)})"
        assert svc.epoch >= 2, f"seed {seed}: no epoch churn — test is vacuous"
        saw_nonzero |= any(s > 0 for s in staleness)
    assert saw_nonzero, "every consumed trajectory was perfectly fresh"


def test_tight_bound_forces_discards_not_violations():
    """With max_staleness=0 and in-flight syncs, some groups MUST be refused
    (stamps inevitably lag the published epoch mid-run) — refused means
    discarded and counted, never consumed past the bound."""
    staleness, _, buf, svc, _, _ = _consume("sim", None, None, SEED,
                                            max_staleness=0)
    assert all(s == 0 for s in staleness)
    assert buf.stale_discards > 0


def test_weight_epoch_stamps_bit_identical_across_backends(setup):
    """Async-plane parity: same workload + same sync schedule => the engine
    and the analytic twin stamp every trajectory with the same weight epoch
    and make the same decisions (trace equality), extending the PR-5 parity
    guarantee to harvest/weight-sync events."""
    cfg, params = setup
    s_stale, s_stamps, _, s_svc, s_res, s_trace = _consume("sim", cfg,
                                                           params, SEED)
    e_stale, e_stamps, _, e_svc, e_res, e_trace = _consume("engine", cfg,
                                                           params, SEED)
    assert e_stamps == s_stamps
    assert e_stale == s_stale
    assert e_svc.applied_epochs == s_svc.applied_epochs
    assert e_trace == s_trace
    assert e_res.makespan == s_res.makespan
    assert e_res.sanitizer["violations"] == s_res.sanitizer["violations"] == 0
    assert e_res.sanitizer["weight_syncs"] > 0          # the fence engaged


def test_stamping_parity_survives_chaos(setup):
    """Weight-epoch discipline under failure realism: a seeded mid-run worker
    death + revival (with recoveries rebinding residency) must leave the
    per-trajectory stamps bit-identical across backends."""
    from repro.core.faults import FaultPlan

    cfg, params = setup
    # ONE batch for both backends (deepcopied): fault injection hashes the
    # runtime traj_id, so rebuilding the workbench per run would inject a
    # different chaos schedule and parity would be vacuous-false
    master, predictor = build_workbench(n_prompts=4, group_size=4, seed=SEED)

    def run(kind):
        batch = copy.deepcopy(master)
        order = {t.traj_id: i for i, t in enumerate(batch)}
        rcfg = RuntimeConfig(scheduler="pps", migration=True, max_active=2,
                             quantum=8, seed=SEED, link_bandwidth=math.inf,
                             trace=True, sanitize=True)
        faults = FaultPlan.chaos(seed=SEED, n_workers=2, horizon=2.0)
        if kind == "sim":
            lens = {tid: len(p)
                    for tid, p in synth_prompts(batch, seed=SEED).items()}
            backend, controller = make_sim_components(
                predictor, 2, rcfg, prompt_lens=lens, faults=faults)
            svc = RolloutService(backend, controller, rcfg, faults=faults)
        else:
            runtime = make_runtime(cfg, params, batch, predictor, n_workers=2,
                                   config=rcfg, faults=faults)
            svc = RolloutService(runtime.backend, runtime.controller, rcfg,
                                 faults=faults)
        svc.submit(batch)
        stamps = {}
        for k, traj in enumerate(svc.stream()):
            stamps[order[traj.traj_id]] = traj.weight_epoch
            if k == 2:                       # one in-flight sync mid-chaos
                svc.sync_weights()
        res = svc.close()
        trace = [(k, order.get(tid, tid), wid) for k, tid, wid in res.trace]
        return stamps, res, trace

    s_stamps, s_res, s_trace = run("sim")
    e_stamps, e_res, e_trace = run("engine")
    assert s_res.worker_deaths == e_res.worker_deaths == 1
    assert e_stamps == s_stamps
    assert e_res.recoveries == s_res.recoveries
    assert e_trace == s_trace
    assert e_res.makespan == s_res.makespan
    assert e_res.sanitizer["violations"] == s_res.sanitizer["violations"] == 0


# ------------------------------------------------------ sanitizer invariants

def _sanitizer(n_workers=2, max_active=2, trajs=()):
    from repro.analysis.sanitize import TraceSanitizer
    return TraceSanitizer(list(trajs), n_workers, max_active)


def test_sanitizer_flags_harvest_before_finish():
    san = _sanitizer()
    san.observe("harvest", 7, 0)
    assert san.report()["violations"] == 1


def test_sanitizer_flags_double_harvest():
    san = _sanitizer()
    san.observe("start", 7, 0)
    san.observe("finish", 7, 0)
    san.observe("harvest", 7, 0)
    san.observe("harvest", 7, 0)
    assert san.report()["violations"] == 1
    assert san.report()["harvests"] == 1


def test_sanitizer_flags_sync_with_active_steps():
    """The drain fence's contract: a weight sync may only land on a worker
    with no step in progress and no resident trajectories."""
    san = _sanitizer()
    san.observe("start", 7, 0)
    san.observe("weight_sync", 1, 0)
    assert san.report()["violations"] == 1


def test_sanitizer_flags_sync_with_residents_held():
    san = _sanitizer()
    san.observe("admit", 7, 0)
    san.observe("weight_sync", 1, 0)
    assert san.report()["violations"] == 1


def test_sanitizer_flags_nonmonotone_applied_epoch():
    san = _sanitizer()
    san.observe("weight_sync", 2, 0)
    san.observe("weight_sync", 1, 0)          # goes backwards
    assert san.report()["violations"] == 1
    san2 = _sanitizer()
    san2.observe("weight_sync", 1, 1)
    san2.observe("weight_sync", 1, 1)         # repeats (not strictly monotone)
    assert san2.report()["violations"] == 1


def test_sanitizer_flags_midflight_stamp_change():
    """Stamp immutability: a lane's weight_epoch must not change between
    dispatches — residents finish on the policy that admitted them."""
    t = _traj(0, 0, epoch=0)
    san = _sanitizer(trajs=[t])
    san.observe("start", t.traj_id, 0)
    san.observe("step", t.traj_id, 0)
    t.weight_epoch = 3                         # illegal in-flight restamp
    san.observe("start", t.traj_id, 0)
    assert san.report()["violations"] >= 1


def test_sanitizer_accepts_legal_sync_sequence():
    san = _sanitizer()
    san.observe("admit", 7, 0)
    san.observe("start", 7, 0)
    san.observe("step", 7, 0)      # step completion frees the slot
    san.observe("finish", 7, 0)
    san.observe("harvest", 7, 0)
    san.observe("weight_sync", 1, 0)
    san.observe("weight_sync", 2, 0)
    rep = san.report()
    assert rep["violations"] == 0
    assert rep["harvests"] == 1 and rep["weight_syncs"] == 2


# ----------------------------------------------------------- async trainer

def test_train_async_staleness_bounded_partial_batches(setup):
    """train_async consumes partial batches (complete groups only) with the
    staleness bound enforced, publishes in-flight weight epochs, and keeps
    the fleet resident for the whole run."""
    import repro.rl.data as D
    from repro.rl.loop import HeddleTrainer, TrainerConfig

    cfg, _ = setup
    tr = HeddleTrainer(cfg, TrainerConfig(group_size=2, n_workers=2, seed=0,
                                          max_steps_per_traj=2))
    history = tr.train_async(n_updates=3, groups_per_update=2,
                             max_staleness=2, backlog_groups=4, seed=0)
    assert len(history) == 3
    for m in history:
        assert m["groups_consumed"] >= 1          # partial batches allowed
        assert m["staleness"] <= 2                # the bound held
    assert any(m["staleness"] > 0 for m in history)   # ...and it actually bit
    # in-flight epochs were published after every non-final update
    assert [m["weight_epoch"] for m in history[:-1]] == [1.0, 2.0]
