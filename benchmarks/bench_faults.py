"""Failure-realism benchmark: seeded chaos on the unified orchestrator.

Runs the same long-tail agentic workload as ``bench_rollout`` under a
deterministic :class:`repro.core.faults.FaultPlan` — one mid-run worker death
(every resident lane lost, trajectories re-admitted on survivors from their
tool-boundary checkpoints), a later revival, and injected tool timeouts /
transient errors absorbed by the capped-backoff retry discipline — and
measures what failure handling actually costs:

  * **recovery overhead** — chaos vs no-fault makespan for the same policy on
    the same substrate (the price of a death + ≥10% tool fault injection);
  * **goodput vs fault rate** — tokens per virtual second as the injected
    tool-fault rate sweeps up (analytic backend: the sweep is decision-level);
  * **PPS+migration vs FCFS under chaos** — the paper's headline comparison
    must survive failure realism, not just the happy path.

Both execution backends run the same seeded fault schedule through the one
orchestrator, so a chaos run makes identical fault decisions on either
substrate.  ``--smoke`` (CI) asserts every trajectory still reaches FINISHED
under chaos on BOTH backends with the expected death/recovery/injection
telemetry.  Emits ``name,us_per_call,derived`` CSV rows and writes
``BENCH_faults.json``.
"""

from __future__ import annotations

import argparse
import copy
import sys

from benchmarks.common import emit, sanitizer_summary, write_json_atomic

SEED = 5

# (n_prompts, group_size, max_active): same shapes as bench_rollout
FULL = (12, 4, 2)
SMOKE = (6, 4, 2)

# injected tool-fault sweep for the goodput curve: (timeout_rate, error_rate)
RATE_SWEEP = [(0.0, 0.0), (0.05, 0.025), (0.10, 0.05), (0.20, 0.10),
              (0.40, 0.20)]


def _runtime_config(scheduler: str, migration: bool, max_active: int, seed: int,
                    sanitize: bool = False):
    from repro.engine.runtime import RuntimeConfig
    return RuntimeConfig(scheduler=scheduler, migration=migration,
                         max_active=max_active, quantum=8, seed=seed,
                         sanitize=sanitize)


def run_case(cfg, params, scheduler: str, migration: bool, shape, seed: int,
             backend: str = "engine", faults=None, sanitize: bool = False) -> dict:
    """One (policy, backend, fault-plan) rollout; returns flat metrics."""
    from repro.engine.runtime import build_workbench, make_runtime, run_on_sim
    n_prompts, group, max_active = shape
    batch, predictor = build_workbench(n_prompts=n_prompts, group_size=group,
                                       seed=seed)
    rcfg = _runtime_config(scheduler, migration, max_active, seed, sanitize)
    if backend == "sim":
        res = run_on_sim(batch, predictor, n_workers=2, config=rcfg,
                         faults=faults)
    else:
        res = make_runtime(cfg, params, batch, predictor, n_workers=2,
                           config=rcfg, faults=faults).run()
    tokens = sum(t.tokens_generated for t in res.trajectories)
    return {
        "makespan_s": res.makespan,
        "goodput_tok_s": tokens / res.makespan if res.makespan else 0.0,
        "total_tokens": tokens,
        "queue_delay_p99_s": res.queue_delay_p99,
        "preemptions": res.preemptions,
        "migrations": res.migrations,
        "worker_deaths": res.worker_deaths,
        "recoveries": res.recoveries,
        "tool_retries": res.tool_retries,
        "injected_tool_faults": res.injected_tool_faults,
        "finished": sum(t.finished for t in res.trajectories),
        "trajectories": len(res.trajectories),
        "sanitizer": res.sanitizer,
    }


def chaos_plan(seed: int, horizon: float):
    from repro.core.faults import FaultPlan
    return FaultPlan.chaos(seed=seed, n_workers=2, horizon=horizon)


def run(smoke: bool = False, seed: int = SEED,
        json_path: str = "BENCH_faults.json") -> dict:
    shape = SMOKE if smoke else FULL
    import jax
    from repro.configs import get_config
    from repro.core.faults import FaultPlan
    from repro.models import model as M
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # ---- recovery overhead + policy comparison under chaos, both backends.
    # The no-fault PPS run doubles as the horizon estimate the death is
    # scheduled against (kill at 40% of the clean makespan).
    per_backend: dict[str, dict] = {}
    # smoke validates the decision stream as it runs (TraceSanitizer) —
    # chaos runs are exactly where causality bugs (stale events, dispatch to
    # the dead, unbalanced transfers) would surface
    for backend in ("engine", "sim"):
        clean = run_case(cfg, params, "pps", True, shape, seed, backend,
                         sanitize=smoke)
        faults = chaos_plan(seed, clean["makespan_s"])
        chaos = run_case(cfg, params, "pps", True, shape, seed, backend,
                         faults=copy.deepcopy(faults), sanitize=smoke)
        fcfs_chaos = run_case(cfg, params, "fcfs", False, shape, seed, backend,
                              faults=copy.deepcopy(faults), sanitize=smoke)
        per_backend[backend] = {
            "no_fault_pps": clean,
            "chaos_pps_migration": chaos,
            "chaos_fcfs_baseline": fcfs_chaos,
            "recovery_overhead": chaos["makespan_s"] / clean["makespan_s"],
            "chaos_speedup_pps_vs_fcfs": (fcfs_chaos["makespan_s"]
                                          / chaos["makespan_s"]),
            "fault_plan": {
                "deaths": list(faults.deaths), "revivals": list(faults.revivals),
                "tool_timeout_rate": faults.tool_timeout_rate,
                "tool_error_rate": faults.tool_error_rate,
            },
        }

    results: dict = {
        "workload": {
            "task": "coding", "seed": seed, "n_prompts": shape[0],
            "group_size": shape[1], "trajectories": shape[0] * shape[1],
            "workers": 2, "max_active_per_worker": shape[2],
        },
        "backends": per_backend,
    }
    if smoke:
        results["sanitizer"] = sanitizer_summary(
            [r[k]["sanitizer"] for r in per_backend.values()
             for k in ("no_fault_pps", "chaos_pps_migration",
                       "chaos_fcfs_baseline")])

    if not smoke:
        # ---- goodput vs injected tool-fault rate (analytic backend: the
        # curve is a decision-level property, and the sweep stays cheap)
        base = per_backend["sim"]["no_fault_pps"]["makespan_s"]
        sweep = []
        for timeout_rate, error_rate in RATE_SWEEP:
            plan = FaultPlan(seed=seed, tool_timeout_rate=timeout_rate,
                             tool_error_rate=error_rate)
            r = run_case(cfg, params, "pps", True, shape, seed, "sim",
                         faults=plan if plan.injects_tool_faults else None)
            sweep.append({"tool_timeout_rate": timeout_rate,
                          "tool_error_rate": error_rate,
                          "makespan_s": r["makespan_s"],
                          "goodput_tok_s": r["goodput_tok_s"],
                          "injected_tool_faults": r["injected_tool_faults"],
                          "slowdown_vs_clean": r["makespan_s"] / base})
        results["goodput_vs_fault_rate"] = sweep

    write_json_atomic(json_path, results)

    eng = per_backend["engine"]
    emit([
        ("faults_makespan_no_fault", eng["no_fault_pps"]["makespan_s"] * 1e6,
         f"{eng['no_fault_pps']['goodput_tok_s']:.1f} tok/s"),
        ("faults_makespan_chaos", eng["chaos_pps_migration"]["makespan_s"] * 1e6,
         f"{eng['chaos_pps_migration']['goodput_tok_s']:.1f} tok/s"),
        ("faults_recovery_overhead", 0.0, f"{eng['recovery_overhead']:.3f}x"),
        ("faults_chaos_speedup_pps_vs_fcfs", 0.0,
         f"{eng['chaos_speedup_pps_vs_fcfs']:.3f}x"),
        ("faults_recoveries", 0.0, eng["chaos_pps_migration"]["recoveries"]),
        ("faults_injected_tool_faults", 0.0,
         eng["chaos_pps_migration"]["injected_tool_faults"]),
    ] + ([("faults_goodput_at_max_rate", 0.0,
           f"{results['goodput_vs_fault_rate'][-1]['goodput_tok_s']:.1f} tok/s")]
         if "goodput_vs_fault_rate" in results else []))

    if smoke:
        # enforced invariants: under a seeded worker death + >=10% injected
        # tool timeouts, every trajectory still drains to FINISHED on both
        # backends, recovery actually happened, and faults were really injected
        for backend, r in per_backend.items():
            chaos = r["chaos_pps_migration"]
            assert chaos["finished"] == chaos["trajectories"], \
                f"{backend}: chaos left live trajectories"
            assert chaos["worker_deaths"] == 1, f"{backend}: no death injected"
            assert chaos["recoveries"] > 0, f"{backend}: nothing recovered"
            assert chaos["injected_tool_faults"] > 0, \
                f"{backend}: no tool faults injected"
            assert chaos["makespan_s"] > r["no_fault_pps"]["makespan_s"], \
                f"{backend}: chaos was free — injection not engaged"
            fcfs = r["chaos_fcfs_baseline"]
            assert fcfs["finished"] == fcfs["trajectories"], \
                f"{backend}: FCFS chaos left live trajectories"
        san = results["sanitizer"]
        assert san["runs"] == 6 and san["violations"] == 0, \
            f"trace sanitizer reported violations under chaos: {san}"
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shape + assert all trajectories finish under "
                         "seeded chaos on both backends (CI)")
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--json", default="BENCH_faults.json")
    args = ap.parse_args(argv)
    emit([], header=True)
    run(smoke=args.smoke, seed=args.seed, json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
