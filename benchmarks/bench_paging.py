"""Paged-KV data plane benchmark: admission capacity, decode, migration.

Three headline numbers on the real JAX engine (reduced model, CPU-friendly):

  * admission capacity at equal HBM budget — the dense pool pins one
    ``capacity``-token lane per resident sequence, so a fixed KV budget admits
    ``max_slots`` sequences no matter how short they are; the paged pool maps
    the same bytes as fixed-size blocks and admits until *resident tokens*
    exhaust the budget (short sequences pack many-to-a-lane's-worth),
  * decode tokens/s — the paged decode attends through the page table
    (block-gather) instead of a contiguous lane; this row prices that gather,
  * migration µs/trajectory — paged engines move a lane as device-to-device
    copies of its *resident page stacks*; the dense path host-gathers the full
    ``capacity`` lane (``np.asarray`` round trip) regardless of occupancy.
    The measured ``logical_bytes`` of both packages are recorded — the same
    figures ``EngineBackend``/``SimBackend`` now price migration with.

Emits ``name,us_per_call,derived`` CSV rows and writes ``BENCH_paging.json``.
``--smoke`` (CI) asserts paged admission capacity >= 2x the dense pool at
equal budget, D2D migration >= 5x cheaper than the host-gather path at the
smoke shape, and a sanitized engine-backed runtime (paged pools on) drains
with zero violations and conserved block accounting.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax

from benchmarks.common import emit, sanitizer_summary, timed, write_json_atomic
from repro.configs import get_config
from repro.engine.sampler import SamplerConfig
from repro.engine.worker import RolloutWorker
from repro.models import model as M

CAPACITY = 256
PAGE = 16
PROMPT_LEN = 24                 # 2 pages resident vs a 256-slot dense lane


def _block(w):
    jax.block_until_ready(w.pool["pos"])


def _prompt(i: int) -> list[int]:
    return [5 + ((i * 31 + j) % 97) for j in range(PROMPT_LEN)]


def _make(cfg, params, paged: bool, **kw):
    kw.setdefault("capacity", CAPACITY)
    kw.setdefault("page_size", PAGE)
    return RolloutWorker(cfg, params, sampler=SamplerConfig(temperature=0.0),
                         prefix_reuse=False, paged=paged, **kw)


# ------------------------------------------------- admission capacity (equal HBM)

def admission_capacity(cfg, params, budget_slots: int) -> dict:
    """Sequences admitted before the KV budget forces pool growth.

    Both pools start from the same KV byte budget: ``budget_slots`` dense
    lanes == ``budget_slots * (capacity / page_size)`` paged blocks.  Dense
    stops at its ``pool_grows`` (lane overflow); paged at ``block_grows``
    (block-pool overflow).  Paged lanes' dense-state rows are pre-sized so
    lane growth (cheap, no KV) never muddies the count.
    """
    pages_per_lane = CAPACITY // PAGE
    budget_blocks = budget_slots * pages_per_lane
    dense = _make(cfg, params, paged=False, max_slots=budget_slots)
    paged = _make(cfg, params, paged=True, max_slots=4 * budget_blocks,
                  num_blocks=budget_blocks + 1)        # +1: reserved scratch

    def count(w, grew) -> int:
        n = 0
        while n < 4 * budget_blocks and not grew(w):
            w.prefill(1000 + n, _prompt(n))
            n += 1
        _block(w)
        return n - 1 if grew(w) else n

    dense_cap = count(dense, lambda w: w.pool_grows > 0)
    paged_cap = count(paged, lambda w: w.block_grows > 0)
    return {
        "kv_budget_blocks": budget_blocks,
        "prompt_tokens": PROMPT_LEN,
        "page_size": PAGE,
        "lane_capacity": CAPACITY,
        "dense_admitted": dense_cap,
        "paged_admitted": paged_cap,
        "capacity_gain": paged_cap / max(dense_cap, 1),
    }


# ----------------------------------------------------------------- decode tok/s

def decode_throughput(cfg, params, n_seqs: int, gen: int) -> dict:
    out = {}
    for name, paged in (("dense", False), ("paged", True)):
        w = _make(cfg, params, paged=paged, max_slots=n_seqs)
        for i in range(n_seqs):
            w.prefill(i, _prompt(i))
        w.decode(list(range(n_seqs)), gen)             # compile + warm
        _, dt = timed(lambda: w.decode(list(range(n_seqs)), gen), repeat=3)
        out[name] = {"s_per_call": dt, "tok_s": n_seqs * gen / dt}
    out["paged_over_dense"] = out["paged"]["tok_s"] / out["dense"]["tok_s"]
    return out


# --------------------------------------------------------------- migration cost

def migration_cost(cfg, params, decoded: int, capacity: int = 8 * CAPACITY) -> dict:
    """µs/trajectory for one full migration (package + implant), D2D vs host.

    Same logical content on both pools: a ``PROMPT_LEN``-token prompt plus
    ``decoded`` generated tokens.  Each timed iteration bounces the lane
    worker0 -> worker1 -> worker0 (two migrations), so the per-trajectory
    figure is dt/2 and both directions' implant costs are averaged in.

    The lane ``capacity`` is the long-context agentic shape (2k tokens) with
    only a couple of pages resident — exactly where the dense path hurts: it
    host-gathers the whole lane regardless of occupancy, while the D2D path
    copies resident page stacks only.
    """
    out: dict = {}
    for name, paged in (("host_gather", False), ("d2d", True)):
        w0 = _make(cfg, params, paged=paged, worker_id=0, capacity=capacity)
        w1 = _make(cfg, params, paged=paged, worker_id=1, capacity=capacity)
        w0.prefill(1, _prompt(0))
        w0.decode([1], decoded)

        def bounce(a=w0, b=w1):
            pkg = a.migrate_out(1)
            b.migrate_in(pkg)
            _block(b)
            pkg = b.migrate_out(1)
            a.migrate_in(pkg)
            _block(a)
            return pkg

        pkg = bounce()                                 # compile + warm
        _, dt = timed(bounce, repeat=3)
        out[name] = {"s_per_traj": dt / 2,
                     "logical_bytes": int(pkg["logical_bytes"])}
    out["lane_capacity"] = capacity
    out["resident_tokens"] = PROMPT_LEN + decoded
    out["d2d_speedup"] = (out["host_gather"]["s_per_traj"]
                          / out["d2d"]["s_per_traj"])
    out["bytes_ratio"] = (out["host_gather"]["logical_bytes"]
                          / out["d2d"]["logical_bytes"])
    return out


# ------------------------------------------------------------------------- run

def run(smoke: bool = False, json_path: str = "BENCH_paging.json") -> dict:
    budget_slots, n_seqs, gen, decoded = (4, 4, 16, 8) if smoke \
        else (8, 8, 32, 16)
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    t0 = time.perf_counter()
    results: dict = {
        "admission": admission_capacity(cfg, params, budget_slots),
        "decode": decode_throughput(cfg, params, n_seqs, gen),
        "migration": migration_cost(cfg, params, decoded),
    }

    # sanitized engine-backed runtime: paged pools are default-on, so this
    # drains a real workload through paged admission/decode/migration and runs
    # the block-conservation drain check end to end
    from repro.engine.runtime import RuntimeConfig, build_workbench, make_runtime
    batch, predictor = build_workbench(n_prompts=4, group_size=4, seed=0)
    runtime = make_runtime(cfg, params, batch, predictor, n_workers=2,
                           config=RuntimeConfig(scheduler="pps", migration=True,
                                                max_active=2, quantum=8,
                                                seed=0, sanitize=True))
    res = runtime.run()
    assert all(w.engine._paged for w in runtime.workers)
    results["sanitizer"] = sanitizer_summary([res.sanitizer])
    results["sanitizer"]["block_conservation"] = \
        res.sanitizer.get("block_conservation")
    results["wall_s"] = time.perf_counter() - t0

    write_json_atomic(json_path, results)

    adm, dec, mig = results["admission"], results["decode"], results["migration"]
    emit([
        ("paging_admission_dense", 0.0,
         f"{adm['dense_admitted']} seqs @ {adm['kv_budget_blocks']} blocks"),
        ("paging_admission_paged", 0.0,
         f"{adm['paged_admitted']} seqs @ {adm['kv_budget_blocks']} blocks"),
        ("paging_admission_gain", 0.0, f"{adm['capacity_gain']:.1f}x"),
        ("paging_decode_dense", dec["dense"]["s_per_call"] * 1e6,
         f"{dec['dense']['tok_s']:.1f} tok/s"),
        ("paging_decode_paged", dec["paged"]["s_per_call"] * 1e6,
         f"{dec['paged']['tok_s']:.1f} tok/s"),
        ("paging_migrate_host_gather", mig["host_gather"]["s_per_traj"] * 1e6,
         f"{mig['host_gather']['logical_bytes']} B"),
        ("paging_migrate_d2d", mig["d2d"]["s_per_traj"] * 1e6,
         f"{mig['d2d']['logical_bytes']} B"),
        ("paging_migrate_d2d_speedup", 0.0,
         f"{mig['d2d_speedup']:.1f}x ({mig['bytes_ratio']:.1f}x fewer bytes)"),
    ])

    if smoke:
        assert adm["paged_admitted"] >= 2 * adm["dense_admitted"], (
            f"paged pool admitted {adm['paged_admitted']} vs dense "
            f"{adm['dense_admitted']} at equal HBM budget — expected >= 2x")
        assert mig["d2d_speedup"] >= 5.0, (
            f"D2D migration only {mig['d2d_speedup']:.1f}x cheaper than "
            f"host-gather at the smoke shape — expected >= 5x")
        san = results["sanitizer"]
        assert san["runs"] == 1 and san["violations"] == 0, \
            f"trace sanitizer reported violations: {san}"
        assert san["block_conservation"] == "ok", \
            "paged block accounting did not pass the drain check"
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shape + assert paged admission >= 2x dense "
                         "and D2D migration >= 5x cheaper than host-gather (CI)")
    ap.add_argument("--json", default="BENCH_paging.json")
    args = ap.parse_args(argv)
    emit([], header=True)
    run(smoke=args.smoke, json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
