"""Shared benchmark harness: workloads, predictor training, simulator sweeps.

Every benchmark maps to one paper table/figure and emits ``name,us_per_call,derived``
CSV rows (us_per_call = simulated rollout makespan in microseconds where applicable;
derived = the figure's headline metric, e.g. throughput or speedup).
"""

from __future__ import annotations

import copy
import json
import os
import time
from dataclasses import dataclass

from repro.core.predictor import ProgressivePredictor
from repro.engine.simulator import SimConfig, SimResult, RolloutSimulator
from repro.engine.workload import WorkloadConfig, generate, replay_finished

TASKS = ("coding", "search", "math")

# paper model scales -> bs=1 per-token seconds at MP=1 (Hopper-class, §7.1 setup)
MODEL_SCALES = {"qwen3-8b": 0.012, "qwen3-14b": 0.020, "qwen3-32b": 0.045}


@dataclass
class Workbench:
    task: str
    trajectories: list
    predictor: ProgressivePredictor

    @classmethod
    def make(cls, task: str, n_prompts: int = 48, group_size: int = 16, seed: int = 0):
        hist = replay_finished(generate(WorkloadConfig(
            task=task, n_prompts=32, group_size=8, seed=seed + 10_000)))
        predictor = ProgressivePredictor().fit_trajectories(hist)
        batch = generate(WorkloadConfig(task=task, n_prompts=n_prompts,
                                        group_size=group_size, seed=seed))
        return cls(task, batch, predictor)

    def run(self, **kw) -> SimResult:
        batch = copy.deepcopy(self.trajectories)
        cfg = SimConfig(**kw)
        return RolloutSimulator(batch, self.predictor, cfg).run()


# the four §7.1 systems as simulator configs (baselines: RR scheduling + homogeneous MP)
def system_configs(gpu_budget: int = 64, max_batch: int = 100, mp_base: int = 1):
    homog = tuple([mp_base] * (gpu_budget // mp_base))
    return {
        "heddle": dict(scheduler="pps", placement="heddle", degrees=(),
                       gpu_budget=gpu_budget, max_batch=max_batch),
        "verl": dict(scheduler="rr", placement="cache_aware", degrees=homog,
                     gpu_budget=gpu_budget, max_batch=max_batch),
        "verl_star": dict(scheduler="rr", placement="hybrid", degrees=homog,
                          gpu_budget=gpu_budget, max_batch=max_batch),
        "slime": dict(scheduler="rr", placement="least_load", degrees=homog,
                      gpu_budget=gpu_budget, max_batch=max_batch),
    }


def write_json_atomic(path: str, obj) -> None:
    """Serialize ``obj`` to ``path`` crash-atomically.

    Writes to a sibling temp file and swaps with ``os.replace`` (same idiom as
    ``repro.checkpoint``), so a benchmark killed mid-dump never leaves a
    truncated BENCH_*.json behind — readers see the old file or the new one.
    """
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def emit(rows: list[tuple], header: bool = False) -> None:
    if header:
        print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def timed(fn, *args, repeat: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def sanitizer_summary(reports: list) -> dict:
    """Aggregate TraceSanitizer reports (``res.sanitizer``) for a bench JSON.

    Smoke benches run with ``RuntimeConfig(sanitize=True)`` and publish the
    combined event count, violation count (asserted zero) and the sanitizer's
    own wall cost, so the overhead of validating the decision stream is a
    recorded quantity rather than folklore.  Empty reports ({} = sanitizer
    off) are skipped.
    """
    reps = [r for r in reports if r]
    return {
        "runs": len(reps),
        "events": sum(r["events"] for r in reps),
        "violations": sum(r["violations"] for r in reps),
        "stale_worker_events": sum(r["stale_worker_events"] for r in reps),
        "wall_s": sum(r["wall_s"] for r in reps),
    }
