"""Benchmark suite entry point — one module per paper table/figure.

``python -m benchmarks.run``          fast mode (CI-friendly subset)
``python -m benchmarks.run --full``   every task x model scale
``python -m benchmarks.run --only fig12``

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_beyond, bench_overall, bench_overhead, bench_placement,
                        bench_predictor, bench_prefill, bench_resources,
                        bench_scheduler, bench_worker)

SUITES = {
    "fig12_overall": bench_overall,
    "fig13_predictor": bench_predictor,
    "fig14_scheduler": bench_scheduler,
    "fig15_placement": bench_placement,
    "fig16_resources": bench_resources,
    "tab12_overhead": bench_overhead,
    "beyond_ctx": bench_beyond,
    "engine_worker": bench_worker,
    "engine_prefill": bench_prefill,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, mod in SUITES.items():
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ({mod.__doc__.strip().splitlines()[0]})", file=sys.stderr)
        mod.run(fast=not args.full)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
