"""Beyond-paper analysis: context-weighted interference robustness (EXPERIMENTS §Beyond).

The paper's Formula 2 premise says interference depends only on group SIZE.  Real
batched decode also pays for every resident sequence's KV bytes, so co-locating
long-context tails is costly even in small groups.  This bench runs the same systems
under the context-weighted data plane and quantifies how each placement degrades —
motivating the work-aware DP cost and migration load-feedback gates we add.
"""

from __future__ import annotations

from benchmarks.common import Workbench, emit


def run(fast: bool = True):
    rows = []
    n_prompts, workers = (150, 24) if fast else (400, 64)
    wb = Workbench.make("coding", n_prompts=n_prompts, group_size=16)
    for tag, ctx, kvr in (("premise_true", 0.0, 0.01), ("ctx_weighted", 1.0e-6, 0.008)):
        results = {}
        for placement in ("heddle", "least_load", "cache_aware"):
            r = wb.run(scheduler="pps", placement=placement, degrees=(1,) * workers,
                       gpu_budget=workers, max_batch=100, seed=0,
                       ctx_interference=ctx, kv_weight_ratio=kvr)
            results[placement] = r
            rows.append((f"beyond/{tag}/{placement}", r.makespan * 1e6,
                         f"{r.throughput:.0f}tok/s"))
        for base in ("least_load", "cache_aware"):
            sp = results[base].makespan / results["heddle"].makespan
            rows.append((f"beyond/{tag}/speedup_vs_{base}", 0.0, f"{sp:.2f}x"))
    emit(rows)
    return rows


if __name__ == "__main__":
    emit([], header=True)
    run(fast=False)
