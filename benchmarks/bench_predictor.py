"""Figure 13: progressive trajectory prediction precision (recall of long-tail set,
Pearson r) vs model-based and history-based prompt-only baselines.

Paper claim: Heddle > baselines on both metrics; Heddle-2 (after step 2) > Heddle-1.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import TASKS, emit
from repro.core.predictor import (HistoryPredictor, ModelPredictor,
                                  ProgressivePredictor, long_tail_recall, pearson)
from repro.core.trajectory import Trajectory
from repro.engine.workload import WorkloadConfig, generate, replay_finished


def _replay_at(t: Trajectory, k: int) -> Trajectory:
    r = Trajectory(prompt_id=t.prompt_id, sample_id=t.sample_id,
                   prompt_tokens=t.prompt_tokens, context_tokens=t.prompt_tokens)
    for st in t.steps[:k]:
        r.record_step(st)
        r.record_tool_output(st.tool_output_tokens)
    return r


def run(fast: bool = True):
    rows = []
    tasks = ("coding",) if fast else TASKS
    for task in tasks:
        train = replay_finished(generate(WorkloadConfig(task=task, n_prompts=48,
                                                        group_size=8, seed=1)))
        test = replay_finished(generate(WorkloadConfig(task=task, n_prompts=32,
                                                       group_size=16, seed=2)))
        pp = ProgressivePredictor().fit_trajectories(train)
        hp = HistoryPredictor().fit_trajectories(train)
        mp = ModelPredictor().fit_trajectories(train)
        true = np.array([t.true_total_tokens for t in test], float)

        preds = {
            "history": np.array([hp.predict(_replay_at(t, 0)) for t in test]),
            "model": np.array([mp.predict(_replay_at(t, 0)) for t in test]),
        }
        for k in (1, 2):
            reps = [_replay_at(t, min(k, t.true_num_steps)) for t in test]
            preds[f"heddle-{k}"] = np.array(
                [r.tokens_generated + pp.predict(r) for r in reps])
        for name, p in preds.items():
            rows.append((f"fig13/{task}/{name}/recall", 0.0,
                         f"{long_tail_recall(p, true):.3f}"))
            rows.append((f"fig13/{task}/{name}/pearson", 0.0,
                         f"{pearson(p, true):.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    emit([], header=True)
    run(fast=False)
