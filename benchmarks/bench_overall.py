"""Figure 12: end-to-end rollout throughput — Heddle vs Verl / Verl* / Slime.

Paper claim: 1.4x-2.3x over Verl, 1.1x-2.4x over Verl*, 1.2x-2.5x over Slime, gains
amplifying with model scale (larger models -> heavier interference factor).
"""

from __future__ import annotations

from benchmarks.common import MODEL_SCALES, TASKS, Workbench, emit, system_configs


def run(fast: bool = True):
    rows = []
    tasks = ("coding",) if fast else TASKS
    scales = {"qwen3-14b": MODEL_SCALES["qwen3-14b"]} if fast else MODEL_SCALES
    for task in tasks:
        wb = Workbench.make(task, n_prompts=32 if fast else 64)
        for model, t1 in scales.items():
            # interference slope scales with model KV footprint (paper Fig. 6: larger
            # models -> heavier contention); base = the calibrated 14B slope
            kvr = 0.01 * (t1 / 0.02)
            results = {}
            for name, cfg in system_configs().items():
                r = wb.run(base_token_time=t1, kv_weight_ratio=kvr, seed=0, **cfg)
                results[name] = r
                rows.append((f"fig12/{task}/{model}/{name}", r.makespan * 1e6,
                             f"{r.throughput:.0f}tok/s"))
            for base in ("verl", "verl_star", "slime"):
                sp = results[base].makespan / results["heddle"].makespan
                rows.append((f"fig12/{task}/{model}/speedup_vs_{base}", 0.0,
                             f"{sp:.2f}x"))
    emit(rows)
    return rows


if __name__ == "__main__":
    emit([], header=True)
    run(fast=False)
