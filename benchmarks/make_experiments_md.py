"""Assemble EXPERIMENTS.md from the dry-run JSON artifacts + benchmark CSV output.

Usage: PYTHONPATH=src:. python benchmarks/make_experiments_md.py \
          [--bench /tmp/bench_final_check.txt]
"""

from __future__ import annotations

import argparse
import json

from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, improvement_hint, roofline_row

HEADER = """# EXPERIMENTS — Heddle reproduction + TPU substrate

All numbers are reproducible on this machine:
```
PYTHONPATH=src pytest tests/
PYTHONPATH=src python -m benchmarks.run            # paper tables/figures
PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_16x16.json
PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out dryrun_2x16x16.json
PYTHONPATH=src:. python -m benchmarks.roofline
```

## §Repro — validation against the paper's claims

The cluster simulator runs the *paper-faithful configuration*: the data plane honors
the paper's §5.1 interference premise (F = f(group size), calibrated slope 0.01,
per-chip TP comm scaling calibrated to the Fig 7 latency/throughput trade-off), the
control plane runs Formula 2 / Algorithm 1 / Algorithm 2 exactly as published.
Workload statistics are calibrated to Fig 2/4/5 (40K-token cap, long-tail ratio ~8x,
GRPO group 16, per-task tool latencies from Table 1).

| claim (paper) | paper | this repro (3 tasks x 3 scales, `--full`) | benchmark |
|---|---|---|---|
| overall throughput vs Verl | 1.4-2.3x | **1.01-2.09x** | fig12 |
| overall throughput vs Verl* | 1.1-2.4x | **1.01-2.09x** | fig12 |
| overall throughput vs Slime | 1.2-2.5x | **1.19-2.15x** | fig12 |
| predictor: Heddle-2 > Heddle-1 > model > history (recall) | yes | **0.73 > 0.67 > 0.37 > 0.00** | fig13 |
| PPS rollout-time gain vs FCFS/RR/Autellix | 1.1-1.26x | **1.09 / 1.09 / 1.13x** | fig14 |
| PPS removes the straggler's queueing delay | yes | **0s vs 169-253s** | fig14 |
| placement vs least-load / cache-aware | 1.2-1.5x | **1.17x / 1.08x** | fig15 |
| adaptive resources vs Fix-1 / Fix-8 | 1.1-1.3x | **1.46x / 1.15x** (search) | fig16 |
| placement DP wall time (n=6400, m=16) | ~42 ms | **~6.9 s naive / 1.7 s monotone / 0.14 s aggregated** (CPU python vs their Rust) | tab2 |
| prediction masked by tool execution | yes | **3 us/traj << 51-1420 ms tool** | tab1 |
| migration masked by tool execution | yes | **~21 ms << 460-1420 ms (coding/search)** | tab1 |

Notes:
* Fig 12's "gains amplify with model scale" reproduces on math (2.02 -> 2.09x) but not
  uniformly (search decreases with scale in our simulator): the paper's amplification
  comes from real-system contention effects beyond the calibrated count-based F; the
  per-task workload structure dominates in our model.  All 9 (task x scale) cells
  still favor Heddle (>= 1.0x vs every baseline).
* Fig 16 reproduces on the paper's own Fig 16 workload (search agent); on our coding
  workload Fix-1 edges adaptive by ~6% (bulk-throughput-bound; SA's separable cost
  model underprices mp1 bulk capacity — documented model-reality gap).
* Verl* == Verl in our runs: the load-skew trigger (max/min > 32) never fires at these
  batch sizes, so the hybrid stays cache-affine — consistent with the paper's
  description of Verl* as interpolating between the two.
* Beyond-paper robustness (bench `beyond_ctx`): when the data plane violates the
  group-size premise (context-weighted KV interference), Heddle still wins
  1.14x / 1.27x thanks to our work-aware DP cost + migration gates (see §Beyond).

"""

DRYRUN_SECTION = """## §Dry-run — 10 architectures x 4 shapes x 2 meshes

`jax.jit(step).lower(...).compile()` succeeds for EVERY assigned combination on both
production meshes (XLA host-device dry-run, ShapeDtypeStruct inputs, no allocation):

* **16x16** (one 256-chip pod, axes `("data","model")`): {ok1} ok + {skip1} documented skip
* **2x16x16** (two pods / 512 chips, axes `("pod","data","model")`): {ok2} ok + {skip2} documented skip

The single skip is `whisper-medium x long_500k` (encoder-decoder: bounded decoder
context is intrinsic to the family — DESIGN.md §5).  `long_500k` lowers `serve_step`
with SSM state (xlstm, jamba) or a sliding-window ring cache (dense/MoE/VLM, window
8192); decode shapes lower `serve_step` (1 token vs a seq_len cache); `train_4k`
lowers the full GRPO `train_step` (loss + backward + AdamW).

Sharding: params use TP ("model") x FSDP ("data") logical rules with per-dim
divisibility fallback (smollm's 9 heads -> replicated attention, 60 qwen2-moe experts
-> replicated experts, arctic's 128 experts -> 8/chip expert-parallel); decode KV
caches shard (batch -> data, kv_seq -> model); MoE dispatch is grouped per data shard.
Per-device memory (args+temp) from `memory_analysis()` is in the table below; the one
genuinely tight case is arctic-480b train (params+moments alone are 11.3 GB/chip on a
256-chip pod; the 2-pod mesh halves it).
"""


def fmt_dryrun_table(records):
    lines = ["| arch | shape | mode | lower(s) | compile(s) | args GiB | temp GiB | collectives |",
             "|---|---|---|---|---|---|---|---|"]
    for r in records:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | SKIP: {r['reason'][:40]} |")
            continue
        if r.get("status") != "ok":
            continue
        cc = r.get("collective_counts", {})
        cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in cc.items() if v)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('mode','')} | {r.get('lower_s',0):.1f} "
            f"| {r.get('compile_s',0):.1f} | {r.get('argument_size_in_bytes',0)/2**30:.2f} "
            f"| {r.get('temp_size_in_bytes',0)/2**30:.2f} | {cstr} |")
    return "\n".join(lines)


ROOFLINE_SECTION = """
## §Roofline — per (arch x shape), single-pod 16x16 mesh

Hardware constants: {peak:.0f} TFLOP/s bf16/chip, {hbm:.0f} GB/s HBM/chip, {ici:.0f} GB/s ICI.
Terms are seconds-per-step **per device**: compute = analytic_FLOPs/chip / peak;
memory = HLO bytes-accessed / HBM bw; collective = post-SPMD wire bytes / ICI bw.
`useful` = MODEL_FLOPS (6*N_active*D train, 2*N_active*D decode) / analytic FLOPs.

**Measurement caveats (documented):** XLA's HloCostAnalysis counts a while-loop body
once, so raw HLO FLOPs/bytes undercount scan-over-periods stacks by ~n_periods — the
compute term therefore uses our analytic per-device FLOPs (validated against HLO on
single-period models), while memory/collective terms use the HLO/post-SPMD numbers,
which are exact *per scan body* and comparable across optimization iterations of the
same architecture (the use §Perf makes of them).

| arch | shape | compute(s) | memory(s) | collective(s) | dominant | useful | next lever |
|---|---|---|---|---|---|---|---|
{rows}

Bottleneck summary: training and prefill of the large dense/MoE models are
compute-dominant (the healthy regime); every decode shape is memory- or
collective-dominant (KV-cache streaming — exactly the per-token time `T` that
Heddle's high-MP workers attack); jamba/qwen2-moe decode and xlstm train are
collective-dominant (SSM state + expert/grouped dispatch resharding).
"""


def fmt_roofline_rows(rows):
    out = []
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {improvement_hint(r)} |")
    return "\n".join(out)


PERF_SECTION = """
## §Perf — hillclimbing log (baseline all 40, hillclimb 3)

All 40 combos were baselined (tables above + `dryrun_16x16_baseline.json`, kept
verbatim).  Three pairs were hillclimbed per the hypothesis -> change -> measure ->
validate loop; each iteration is recorded with its verdict.

### Pair (a): jamba-v0.1-52b x train_4k — worst memory term (8.6 s, 166 GiB temp)

1. **Hypothesis:** the full-sequence `associative_scan` for the Mamba recurrence
   materializes O(log S) copies of the (B,S,d_inner,N) f32 state (napkin: 2 GiB x ~12
   levels x fwd+bwd x 7 mamba layers/period ~ 10^2 GiB).
   **Change:** chunked scan — outer sequential `lax.scan` over 512-token chunks
   (checkpointed) with the associative scan inside.
   **Result:** HLO bytes 7.04e12 -> 4.58e12 (**0.65x**), temp 166 -> 149 GiB. CONFIRMED.
2. **Hypothesis:** nested per-layer `jax.checkpoint` inside the period body serializes
   the backward working set (8 layers -> 1).
   **Result:** temp 149 -> 157 GiB (CPU buffer assignment does not reuse across the
   serialized segments). REFUTED — reverted.
3. **Hypothesis:** the (B,S,d_inner,N) tensors need never exist in HBM at all — fuse
   discretization (a = exp(dt A), b = dt x B) and the C-contraction into each chunk, so
   the scan's HBM-resident tensors are (B,S,d_inner).
   **Change:** `_mamba_scan_fused` (discretize + scan + contract per chunk).
   **Result (cumulative):** HLO bytes 7.04e12 -> 3.66e12 (**0.52x**), temp 166 -> **67 GiB**
   (0.40x), memory term 8.6 s -> 4.5 s; FLOPs unchanged; collective +20% (chunk-local
   resharding) — dominant term nearly halved. CONFIRMED.

### Pair (b): llama-3.2-vision-11b x train_4k — most collective-bound (2.02 s)

1. **Hypothesis:** the Megatron-SP residual resharding lands on f32 tensors (observed
   38 GiB of f32[16,4096,4096] all-gathers per scan body); gathering at the bf16
   post-norm point halves the wire bytes.
   **Change:** explicit bf16 SP boundary after each pre-norm.
   **Result:** collective 1.01e11 -> 9.46e10 (**0.93x only** — the f32 traffic is
   backward cotangents, not the forward gather). PARTIALLY REFUTED (kept: strictly
   better).
2. **Hypothesis:** for this cross-attention-heavy arch the SP memory saving does not
   pay for its collectives; A/B `act_seq` off.
   **Result:** collective 9.46e10 -> 5.81e10 (**0.61x**) at memory 8.5e11 -> 1.20e12
   (1.41x), temp 27 -> 44 GiB; dominant term (collective 1.89 s) -> (memory 1.47 s):
   max-term down **22%** and balanced. CONFIRMED — `sequence_parallel=False` is now a
   per-arch config knob (vision sets it; deep dense stacks keep SP).

### Pair (c): nemotron-4-15b x decode_32k — representative of the paper's technique
(memory-bound decode, 17.4 GB/step/device vs ~6.2 GB napkin minimum)

1. **Hypothesis:** without input-output aliasing XLA copies the whole 2.15 GB KV cache
   every step; `donate_argnums` on the cache removes it.
   **Result:** static bytes-accessed 1.74e10 -> 2.17e10 (**1.25x — worse**) on the CPU
   backend; the metric does not register aliasing. REFUTED under this proxy (donation
   remains the right call on real TPUs; reverted for metric comparability).
2. **Hypothesis:** `k.astype(f32)` in the decode-attention oracle materializes f32
   copies of the full cache (~8.6 GB/step).
   **Change:** `preferred_element_type=f32` accumulation, no materialized upcast.
   **Result:** 1.74e10 -> 1.73e10 (0.99x) — XLA had already fused the convert.
   REFUTED (change kept: it is the correct expression of intent).
3. **Analysis (the honest residual):** the remaining traffic decomposes as cache
   read-for-attention (2.15 GB) + cache read+write for the functional update (4.3 GB)
   + FSDP weight gather (1.9 GB) + partition/reshard copies.  The identified next
   lever is the fused update+attend Pallas kernel (the attend half ships in
   `kernels/decode_attention.py`); on TPU with donation it reads the cache once
   (~3x reduction), but neither effect registers in the CPU static metric, so we stop
   here rather than claim unmeasurable wins.

### Beyond-paper system optimizations (recorded deltas, simulator benchmarks)

These keep the paper's mechanisms but harden them; each is switchable so the
paper-faithful baseline stays runnable (`work_aware_dp=False`, etc.):

* **Monotone DP speedup** — Formula 3's argmin is locatable by binary search (cost
  non-increasing, dp non-decreasing): O(n^2 m) -> O(n m log n): 6.9 s -> 1.7 s at
  n=6400 (4x; with the paper's own aggregation: 0.14 s).
* **Work-aware DP cost** — Formula 2's longest-member bound is joined by a
  work-conserving bound; prevents unbounded work piling behind a short maxlen.
* **Batch-capacity cap** in the DP (groups beyond slot capacity silently degrade to
  queueing otherwise).
* **Migration hygiene** — newest-prediction-wins request replacement, hysteresis,
  per-trajectory cooldown + budget, and least-populated-in-window target selection:
  turned migration from a net -8% (thrash) into **+8% makespan** on fig15.
* **Historical-distribution provisioning** — Algorithm 2 plans on the (stable)
  historical length distribution rather than intra-group-variance-blind prompt-time
  point predictions (this is how the paper's "periodic, amortized" provisioning is
  actually coherent).
* **Two-pass SA pricing** — re-price each worker's token time at its DP group size
  (search fig16: adaptive 395 s -> 349 s, overtaking Fix-8).
* **Fused chunked cross-entropy** — logits never materialize (train temp on
  qwen3-1.7b: 10.8 -> 4.6 GiB); **flash attention with custom VJP** (arctic train:
  180 -> 40 GiB); **additive mask bias** (removes a 14 GiB hoisted pred broadcast).

### Known multi-pod inefficiency (recorded)

On the 2x16x16 mesh the fused Mamba chunk scan triggers XLA SPMD "involuntary full
rematerialization" warnings (resharding f32[8,512,512,16] chunk states between the
model-sharded einsum and the pod-replicated carry).  It compiles and the collective
term stays sub-dominant, but this is the next §Perf candidate for the multi-pod mesh
(fix: constrain the chunk carry to the same ("batch", None, "d_inner", None) spec as
the chunk body so no cross-axis reshard is needed).

## §Beyond — premise-violation robustness (bench `beyond_ctx`)

The paper assumes interference = f(group size).  We also simulate a harsher data plane
where batched decode pays per resident KV byte (co-locating two 40K-context tails is
then expensive even at batch 2).  The published mechanisms alone degrade there
(Formula 2 co-locates tails by design); with the work-aware cost + migration gates,
Heddle still leads least-load 1.14x and cache-aware 1.27x.
"""

TAIL = """
## Reproduction inventory

* paper-faithful: Algorithms 1 & 2 line-by-line (see docstrings), Formula 2/3 DP with
  exhaustive-oracle optimality tests, Lemma 5.1 contiguity property-tested, §5.3
  endpoint-exclusive transmission scheduler property-tested, §4.1 harvest contract.
* baselines implemented: Verl (group-pinned cache affinity), Verl* (skew-triggered
  hybrid), Slime (least-load), FCFS/RR/Autellix-SJF schedulers, Fix-1/Fix-8.
* substrate: 10-arch model zoo, real rollout workers (prefill / batched decode /
  tool absorption / preemption persistence / KV migration), GRPO + AdamW + checkpoint,
  two Pallas kernels (flash-decode GQA attention; fused Mamba selective scan — the
  TPU-native endpoint of §Perf pair (a)) validated vs oracles over shape x dtype
  sweeps, launchers (`repro.launch.train`, `repro.launch.serve`, `repro.launch.dryrun`).
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", default="dryrun_16x16.json")
    ap.add_argument("--multi", default="dryrun_2x16x16.json")
    ap.add_argument("--out", default="EXPERIMENTS.md")
    args = ap.parse_args()

    with open(args.single) as f:
        single = json.load(f)
    with open(args.multi) as f:
        multi = json.load(f)

    ok1 = sum(1 for r in single if r["status"] == "ok")
    sk1 = sum(1 for r in single if r["status"] == "skipped")
    ok2 = sum(1 for r in multi if r["status"] == "ok")
    sk2 = sum(1 for r in multi if r["status"] == "skipped")

    rows = [r for r in (roofline_row(rec) for rec in single) if r]

    parts = [
        HEADER,
        DRYRUN_SECTION.format(ok1=ok1, skip1=sk1, ok2=ok2, skip2=sk2),
        "### 16x16 single-pod dry-run\n\n" + fmt_dryrun_table(single),
        "\n\n### 2x16x16 multi-pod dry-run\n\n" + fmt_dryrun_table(multi),
        ROOFLINE_SECTION.format(peak=PEAK_FLOPS / 1e12, hbm=HBM_BW / 1e9,
                                ici=ICI_BW / 1e9, rows=fmt_roofline_rows(rows)),
        PERF_SECTION,
        TAIL,
    ]
    with open(args.out, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {args.out} ({ok1}+{sk1} single-pod, {ok2}+{sk2} multi-pod records, "
          f"{len(rows)} roofline rows)")


if __name__ == "__main__":
    main()
