"""Figure 14: trajectory-level scheduling — PPS vs FCFS / RR / Autellix(SJF).

Measures end-to-end rollout time and the cumulative queueing delay of the longest
trajectory (paper: 1.1x-1.26x rollout-time reduction, driven by queueing delay).
Scheduler is isolated: placement fixed to Heddle's DP, homogeneous MP, and worker slots
scarce enough that queueing actually occurs (trajectories/worker > max_batch).
"""

from __future__ import annotations

from benchmarks.common import Workbench, emit


def run(fast: bool = True):
    rows = []
    wb = Workbench.make("coding", n_prompts=48, group_size=16)
    results = {}
    for sched in ("pps", "fcfs", "rr", "sjf"):
        r = wb.run(scheduler=sched, placement="heddle", migration=False,
                   degrees=(1,) * 16, gpu_budget=16, max_batch=24, seed=0)
        results[sched] = r
        rows.append((f"fig14/{sched}/rollout_time", r.makespan * 1e6,
                     f"qd_longest={r.queue_delay_p100:.1f}s"))
    for sched in ("fcfs", "rr", "sjf"):
        sp = results[sched].makespan / results["pps"].makespan
        rows.append((f"fig14/speedup_vs_{sched}", 0.0, f"{sp:.2f}x"))
    emit(rows)
    return rows


if __name__ == "__main__":
    emit([], header=True)
    run(fast=False)
