"""Async rollout-as-a-service benchmark: streaming harvest vs the sync barrier.

The synchronous trainer pays the long tail once per iteration: every GRPO
update waits for the slowest trajectory of its batch, so training-step
utilization collapses exactly on the tail-dominated workloads the paper
targets.  The async plane (``repro.rl.service``) removes the barrier —
FINISHED trajectories stream into a bounded :class:`ReplayBuffer` the moment
they harvest, the consumer trains on the first ``groups_per_update`` complete
groups while stragglers keep decoding, and each update publishes an in-flight
weight sync that workers adopt as their resident lanes drain.

Measured on the same seeded long-tail workload, same total work (``n_updates
x groups_per_update`` GRPO groups), same virtual-time train cost per update:

  * **time-to-N-updates** — sync = sum of per-chunk makespans + train time;
    async = one streaming run with updates overlapping the rollout tail;
  * **training-step utilization** — fraction of the virtual timeline the
    trainer is busy (``n x train_s / time_to_n``);
  * **staleness discipline** — max observed ``published_epoch -
    weight_epoch`` over every consumed trajectory, with zero stale discards
    (the bound is enforced, not merely hoped for).

Both execution backends run the async plane through the one orchestrator;
``--smoke`` (CI) asserts async strictly beats sync on BOTH backends, the
staleness bound holds with zero discards, the per-trajectory weight-epoch
stamps are bit-identical across backends, and the TraceSanitizer reports
zero violations.  Emits ``name,us_per_call,derived`` CSV rows and writes
``BENCH_async.json``.
"""

from __future__ import annotations

import argparse
import math
import sys

from benchmarks.common import emit, sanitizer_summary, write_json_atomic

SEED = 5

# (n_updates, groups_per_update, group_size, max_active)
FULL = (6, 2, 4, 2)
SMOKE = (3, 2, 4, 2)

TRAIN_S = 1.0  # virtual seconds one GRPO update occupies the trainer
MAX_STALENESS = 2  # consumed trajectories may lag the published epoch this far
# groups resident in the service before the first update; kept equal to
# groups_per_update so each wave fully drains between updates — workers cut
# over (drain fence) every epoch and admission stamps track the published
# epoch instead of stalling at 0
BACKLOG_GROUPS = 2

# full mode: trainer-cost sweep for the speedup curve (sim backend).  Beyond
# ~2x the wave rollout time the consumer outpaces what the drain fence can
# restamp and the staleness bound starts forcing discards — the sweep stops
# at the edge of the zero-discard regime.
TRAIN_SWEEP = (0.25, 0.5, 1.0, 2.0)


def _runtime_config(max_active: int, seed: int, sanitize: bool):
    from repro.engine.runtime import RuntimeConfig
    # link_bandwidth=inf keeps migration decision-level (zero-cost transfers),
    # the regime where the sim/engine decision traces are bit-identical — the
    # async numbers below are then backend-independent by construction.
    return RuntimeConfig(scheduler="pps", migration=True, max_active=max_active,
                         quantum=8, seed=seed, link_bandwidth=math.inf,
                         sanitize=sanitize)


def _group_list(batch):
    """Groups in generation order, keyed by prompt_id (GRPO siblings)."""
    by_pid: dict[int, list] = {}
    for t in batch:
        by_pid.setdefault(t.prompt_id, []).append(t)
    return list(by_pid.values())


def run_sync_case(cfg, params, backend: str, shape, seed: int,
                  sanitize: bool = False, train_s: float = TRAIN_S) -> dict:
    """The barrier baseline: one closed-loop rollout per update, serialized.

    Chunk k's makespan is gated by its slowest trajectory (the tail); the
    trainer then runs for ``TRAIN_S`` while the fleet idles.  Weight sync is
    free here — everything between iterations is torn down anyway.
    """
    from repro.engine.runtime import (build_workbench, make_runtime,
                                     run_on_sim, synth_prompts)
    n_updates, gpu, gsz, max_active = shape
    batch, predictor = build_workbench(n_prompts=n_updates * gpu,
                                       group_size=gsz, seed=seed)
    groups = _group_list(batch)
    rcfg = _runtime_config(max_active, seed, sanitize)
    clock = 0.0
    times: list[float] = []
    reports = []
    for k in range(n_updates):
        chunk = [t for g in groups[k * gpu:(k + 1) * gpu] for t in g]
        if backend == "sim":
            lens = {tid: len(p)
                    for tid, p in synth_prompts(chunk, seed=seed).items()}
            res = run_on_sim(chunk, predictor, n_workers=2, config=rcfg,
                             prompt_lens=lens)
        else:
            res = make_runtime(cfg, params, chunk, predictor, n_workers=2,
                               config=rcfg).run()
        clock += res.makespan + train_s
        times.append(clock)
        reports.append(res.sanitizer)
    return {
        "time_to_updates_s": times,
        "time_to_n_s": times[-1],
        "rollout_s": times[-1] - n_updates * train_s,
        "train_utilization": n_updates * train_s / times[-1],
        "sanitizer_reports": reports,
    }


def run_async_case(cfg, params, backend: str, shape, seed: int,
                   sanitize: bool = False, train_s: float = TRAIN_S) -> dict:
    """The streaming plane: one resident fleet, updates overlap the tail.

    Submits ``BACKLOG_GROUPS`` up front and re-injects one wave per update
    (the ``train_async`` pattern), so admission stamps advance with the
    published epoch and the staleness bound binds for real.  Each update
    consumes exactly ``groups_per_update`` complete groups FIFO from the
    replay buffer and publishes its weights at the virtual instant the
    trainer frees up (``sync_weights(at=...)``).
    """
    from repro.engine.runtime import (build_workbench, make_runtime,
                                     make_sim_components, synth_prompts)
    from repro.rl.service import ReplayBuffer, RolloutService
    n_updates, gpu, gsz, max_active = shape
    pool = n_updates * gpu
    batch, predictor = build_workbench(n_prompts=pool, group_size=gsz,
                                       seed=seed)
    groups = _group_list(batch)
    rcfg = _runtime_config(max_active, seed, sanitize)
    if backend == "sim":
        lens = {tid: len(p)
                for tid, p in synth_prompts(batch, seed=seed).items()}
        sim_backend, controller = make_sim_components(
            predictor, 2, rcfg, prompt_lens=lens)
        svc = RolloutService(sim_backend, controller, rcfg)
    else:
        runtime = make_runtime(cfg, params, batch, predictor, n_workers=2,
                               config=rcfg)
        svc = RolloutService(runtime.backend, runtime.controller, rcfg)

    # traj_ids are globally allocated (each build_workbench call gets a fresh
    # range), so cross-run stamp comparison keys on batch position instead
    order = {t.traj_id: i for i, t in enumerate(batch)}
    backlog = min(BACKLOG_GROUPS, pool)
    svc.submit([t for g in groups[:backlog] for t in g])
    next_wave = backlog
    buffer = ReplayBuffer(capacity=pool * gsz, group_size=gsz)
    times: list[float] = []
    staleness: list[int] = []
    stamps: dict[int, int] = {}
    update_free = 0.0
    for traj in svc.stream():
        stamps[order[traj.traj_id]] = traj.weight_epoch
        buffer.add(traj)
        while (len(times) < n_updates
               and buffer.ready_groups >= gpu):
            taken = buffer.take(gpu, epoch=svc.epoch,
                                max_staleness=MAX_STALENESS)
            if not taken:
                break
            start = max(svc.now, update_free)
            update_free = start + train_s
            times.append(update_free)
            staleness.extend(svc.epoch - t.weight_epoch
                             for g in taken for t in g)
            if len(times) < n_updates:
                svc.sync_weights(at=update_free)
                wave = groups[next_wave:next_wave + len(taken)]
                next_wave += len(taken)
                if wave:
                    svc.submit([t for g in wave for t in g])
        if len(times) >= n_updates:
            break
    res = svc.close()
    for t in res.trajectories:  # drained stragglers after the Nth update
        stamps.setdefault(order[t.traj_id], t.weight_epoch)
    return {
        "time_to_updates_s": times,
        "time_to_n_s": times[-1],
        "train_utilization": n_updates * train_s / times[-1],
        "staleness_max": max(staleness),
        "staleness_mean": sum(staleness) / len(staleness),
        "consumed": len(staleness),
        "stale_discards": buffer.stale_discards,
        "evicted": buffer.evicted,
        "weight_epochs_published": svc.epoch,
        "applied_epochs": svc.applied_epochs,
        "drain_makespan_s": res.makespan,
        "preemptions": res.preemptions,
        "migrations": res.migrations,
        "stamps": stamps,
        "sanitizer_reports": [res.sanitizer],
    }


def run(smoke: bool = False, seed: int = SEED,
        json_path: str = "BENCH_async.json") -> dict:
    shape = SMOKE if smoke else FULL
    n_updates, gpu, gsz, _ = shape
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    per_backend: dict[str, dict] = {}
    reports = []
    for backend in ("engine", "sim"):
        sync = run_sync_case(cfg, params, backend, shape, seed, sanitize=smoke)
        async_ = run_async_case(cfg, params, backend, shape, seed,
                                sanitize=smoke)
        reports += sync.pop("sanitizer_reports") + async_.pop("sanitizer_reports")
        stamps = async_.pop("stamps")
        per_backend[backend] = {
            "sync": sync,
            "async": async_,
            "speedup_time_to_n": sync["time_to_n_s"] / async_["time_to_n_s"],
            "_stamps": stamps,
        }

    results: dict = {
        "workload": {
            "task": "coding", "seed": seed, "groups": n_updates * gpu,
            "group_size": gsz, "trajectories": n_updates * gpu * gsz,
            "workers": 2, "max_active_per_worker": shape[3],
            "tail": "long-tail agentic plans (build_workbench base_steps=3)",
        },
        "consumer": {"n_updates": n_updates, "groups_per_update": gpu,
                     "train_s": TRAIN_S, "max_staleness": MAX_STALENESS,
                     "backlog_groups": BACKLOG_GROUPS},
        "backends": {b: {k: v for k, v in r.items() if k != "_stamps"}
                     for b, r in per_backend.items()},
    }
    if smoke:
        results["sanitizer"] = sanitizer_summary(reports)

    if not smoke:
        # ---- speedup vs trainer cost (analytic backend: the curve is a
        # decision-level property and the sweep stays cheap).  The barrier
        # baseline pays train_s per chunk serially, so async's edge widens
        # as updates get more expensive — until the consumer outruns the
        # drain fence and the staleness bound would start discarding.
        sweep = []
        for train_s in TRAIN_SWEEP:
            a = run_async_case(cfg, params, "sim", shape, seed,
                               train_s=train_s)
            a.pop("stamps"), a.pop("sanitizer_reports")
            s = run_sync_case(cfg, params, "sim", shape, seed,
                              train_s=train_s)
            sweep.append({"train_s": train_s,
                          "sync_time_to_n_s": s["time_to_n_s"],
                          "async_time_to_n_s": a["time_to_n_s"],
                          "speedup": s["time_to_n_s"] / a["time_to_n_s"],
                          "staleness_max": a["staleness_max"],
                          "stale_discards": a["stale_discards"],
                          "async_train_utilization": a["train_utilization"]})
        results["speedup_vs_train_cost"] = sweep

    write_json_atomic(json_path, results)

    eng = per_backend["engine"]
    emit([
        ("async_time_to_n_sync_baseline", eng["sync"]["time_to_n_s"] * 1e6,
         f"util {eng['sync']['train_utilization']:.2f}"),
        ("async_time_to_n_streaming", eng["async"]["time_to_n_s"] * 1e6,
         f"util {eng['async']['train_utilization']:.2f}"),
        ("async_speedup_time_to_n", 0.0,
         f"{eng['speedup_time_to_n']:.3f}x"),
        ("async_staleness_max", 0.0,
         f"{eng['async']['staleness_max']} (bound {MAX_STALENESS})"),
        ("async_stale_discards", 0.0, eng["async"]["stale_discards"]),
        ("async_weight_epochs", 0.0, eng["async"]["weight_epochs_published"]),
    ])

    if smoke:
        for backend, r in per_backend.items():
            a, s = r["async"], r["sync"]
            assert a["time_to_n_s"] < s["time_to_n_s"], \
                f"{backend}: async did not beat the sync barrier " \
                f"({a['time_to_n_s']} vs {s['time_to_n_s']})"
            assert a["staleness_max"] <= MAX_STALENESS, \
                f"{backend}: staleness bound violated ({a['staleness_max']})"
            assert a["stale_discards"] == 0, \
                f"{backend}: staleness bound forced discards"
            assert a["consumed"] == n_updates * gpu * gsz, \
                f"{backend}: consumed {a['consumed']} trajectories, " \
                f"expected {n_updates * gpu * gsz}"
            assert a["weight_epochs_published"] == n_updates - 1, \
                f"{backend}: expected {n_updates - 1} in-flight syncs"
        # decision parity: the async plane is backend-independent — identical
        # update timeline and identical per-trajectory weight-epoch stamps
        assert (per_backend["engine"]["async"]["time_to_updates_s"]
                == per_backend["sim"]["async"]["time_to_updates_s"]), \
            "sim/engine async update timelines diverged"
        assert per_backend["engine"]["_stamps"] == per_backend["sim"]["_stamps"], \
            "sim/engine weight-epoch stamps diverged"
        san = results["sanitizer"]
        assert san["runs"] == 2 * (n_updates + 1) and san["violations"] == 0, \
            f"trace sanitizer reported violations on the async plane: {san}"
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shape + assert async strictly beats sync, "
                         "staleness bound holds with zero discards, and the "
                         "sim/engine stamp maps are bit-identical (CI)")
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--json", default="BENCH_async.json")
    args = ap.parse_args(argv)
    emit([], header=True)
    run(smoke=args.smoke, seed=args.seed, json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
