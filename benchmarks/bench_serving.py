"""Serving benchmark: open-loop ingress, tenant SLOs, overload degradation.

Sweeps offered load (Poisson arrivals at multiples of the fleet's measured
closed-loop capacity) over the unified orchestrator in open-loop mode and
measures, per tenant class and per backend:

  * **p50/p99 completion latency vs offered QPS** — the serving knee: latency
    is flat below capacity and explodes past it;
  * **goodput + deadline attainment** — tokens of finished work per virtual
    second, and the fraction of arrivals that met their SLO;
  * **admission control on vs off** — with the gate on, sheddable work that
    cannot meet its deadline is dropped at the door and queued sheddable work
    is shed under pressure, so live-queue depth stays bounded under any
    offered load and gold-tier attainment never dips; with it off, every
    arrival queues and the backlog (peak live trajectories) grows with the
    overload factor.

Both execution backends (real engine, analytic sim) run the identical arrival
sequence through the one orchestrator, so admission/shed decisions are
decision-trace comparable.  ``--smoke`` (CI) asserts on BOTH backends that at
the overloaded point (a) gold-tier deadline attainment with admission control
is >= without it, (b) gold-tier work is NEVER shed, (c) the gate actually shed
sheddable work, and (d) every arrival drains to FINISHED or SHED.  Emits
``name,us_per_call,derived`` CSV rows and writes ``BENCH_serving.json``.
"""

from __future__ import annotations

import argparse
import copy
import sys

import numpy as np

from benchmarks.common import emit, sanitizer_summary, write_json_atomic

SEED = 5

# (n_prompts, group_size, max_active): same workload family as bench_rollout
FULL = (12, 4, 2)
SMOKE = (6, 4, 2)

# offered load as multiples of measured closed-loop capacity
FULL_LOADS = (0.5, 0.8, 1.1, 1.4, 1.8)
SMOKE_LOADS = (0.7, 1.8)

ATTAINMENT_KNEE = 0.9   # knee = last offered load with overall attainment >= this


def _tenants(deadlines: dict[str, float]):
    from repro.core.tenancy import TenantClass
    return (
        TenantClass("gold", tier=0, deadline_s=deadlines["gold"], weight=2.0,
                    sheddable=False, share=0.25),
        TenantClass("silver", tier=1, deadline_s=deadlines["silver"], weight=1.0,
                    sheddable=True, share=0.35),
        TenantClass("best_effort", tier=2, deadline_s=deadlines["best_effort"],
                    weight=0.5, sheddable=True, share=0.40),
    )


def _serving_config(admission: bool, max_active: int, n_workers: int = 2):
    from repro.core.tenancy import ServingConfig
    if not admission:
        return ServingConfig()          # gate off, unbounded queues, no ladder
    per_worker = 4.0 * max_active
    return ServingConfig(admission_control=True,
                         queue_bound_per_worker=per_worker,
                         queue_bound_global=per_worker * n_workers,
                         shed_pressure=2.0, degrade_pressure=3.0)


def _capacity(shape, seed: int) -> dict:
    """Closed-loop clean run on the sim: offered-load scale for the sweep.

    Capacity = trajectories per virtual second when the whole batch is offered
    at t=0 (the fleet fully utilised)."""
    from repro.engine.runtime import RuntimeConfig, build_workbench, run_on_sim
    n_prompts, group, max_active = shape
    batch, predictor = build_workbench(n_prompts=n_prompts, group_size=group,
                                       seed=seed)
    rcfg = RuntimeConfig(scheduler="pps", migration=True, max_active=max_active,
                         quantum=8, seed=seed)
    res = run_on_sim(batch, predictor, n_workers=2, config=rcfg)
    return {
        "capacity_qps": len(res.trajectories) / res.makespan,
        "clean_makespan_s": res.makespan,
    }


def _calibrate_deadlines(cfg, params, shape, seed: int, qps: float,
                         backend: str) -> dict:
    """Unloaded open-loop run (no tenants, no gate): per-backend SLO scale.

    Deadlines must be multiples of the latency the *open-loop* system delivers
    when offered load is comfortably below capacity, so attainment is ~1.0
    below the knee and the SLO actually bites past it — the two substrates
    have different absolute cost models, hence per-backend calibration.
    """
    from repro.core.tenancy import TenantClass
    from repro.engine.runtime import (RuntimeConfig, build_workbench,
                                      make_runtime, run_on_sim)
    from repro.engine.workload import assign_arrivals, make_arrivals
    n_prompts, group, max_active = shape
    batch, predictor = build_workbench(n_prompts=n_prompts, group_size=group,
                                       seed=seed)
    assign_arrivals(batch, make_arrivals("poisson", rate=qps, seed=seed))
    rcfg = RuntimeConfig(scheduler="pps", migration=True, max_active=max_active,
                         quantum=8, seed=seed, open_loop=True)
    if backend == "sim":
        res = run_on_sim(batch, predictor, n_workers=2, config=rcfg)
    else:
        res = make_runtime(cfg, params, batch, predictor, n_workers=2,
                           config=rcfg).run()
    lat = np.sort([t.completion_time() for t in res.trajectories])
    p90 = float(lat[int(0.9 * (len(lat) - 1))])
    return {
        "unloaded_latency_p90_s": p90,
        "deadlines": {"gold": 8.0 * p90, "silver": 4.0 * p90,
                      "best_effort": 2.5 * p90},
    }


def run_point(cfg, params, shape, seed: int, qps: float, tenants, serving,
              backend: str, sanitize: bool = False) -> dict:
    """One (offered load, admission policy, backend) open-loop run."""
    from repro.core.tenancy import assign_tenants
    from repro.engine.runtime import (RuntimeConfig, build_workbench,
                                      make_runtime, run_on_sim)
    from repro.engine.workload import assign_arrivals, make_arrivals
    n_prompts, group, max_active = shape
    batch, predictor = build_workbench(n_prompts=n_prompts, group_size=group,
                                       seed=seed)
    assign_arrivals(batch, make_arrivals("poisson", rate=qps, seed=seed))
    assign_tenants(batch, tenants, seed=seed)
    rcfg = RuntimeConfig(scheduler="pps", migration=True, max_active=max_active,
                         quantum=8, seed=seed, open_loop=True,
                         sanitize=sanitize)
    if backend == "sim":
        res = run_on_sim(batch, predictor, n_workers=2, config=rcfg,
                         serving=serving)
    else:
        res = make_runtime(cfg, params, batch, predictor, n_workers=2,
                           config=rcfg, serving=serving).run()
    finished = [t for t in res.trajectories if t.finished and not t.shed]
    tokens = sum(t.tokens_generated for t in finished)
    met = sum(t.deadline_met for t in res.trajectories)
    return {
        "offered_qps": qps,
        "makespan_s": res.makespan,
        "goodput_tok_s": tokens / res.makespan if res.makespan else 0.0,
        "arrivals": res.arrivals,
        "admitted": res.admitted,
        "shed": res.shed,
        "deferred": res.deferred,
        "degraded": res.degraded,
        "attainment": met / len(res.trajectories) if res.trajectories else 0.0,
        "shed_rate": res.shed / len(res.trajectories) if res.trajectories else 0.0,
        "gold_shed": sum(1 for t in res.trajectories
                         if t.shed and t.tenant == "gold"),
        "drained": all(t.finished or t.shed for t in res.trajectories),
        "peak_live_global": res.peak_live_global,
        "peak_live_worker": res.peak_live_worker,
        "tenants": res.tenant_report,
        "sanitizer": res.sanitizer,
    }


def run(smoke: bool = False, seed: int = SEED,
        json_path: str = "BENCH_serving.json") -> dict:
    shape = SMOKE if smoke else FULL
    loads = SMOKE_LOADS if smoke else FULL_LOADS
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    calib = _capacity(shape, seed)
    capacity = calib["capacity_qps"]

    per_backend: dict[str, dict] = {}
    for backend in ("engine", "sim"):
        slo = _calibrate_deadlines(cfg, params, shape, seed, 0.5 * capacity,
                                   backend)
        tenants = _tenants(slo["deadlines"])
        curve = []
        for mult in loads:
            qps = mult * capacity
            point = {"load_multiplier": mult, "offered_qps": qps}
            for label, admission in (("admission_on", True),
                                     ("admission_off", False)):
                serving = _serving_config(admission, shape[2])
                # smoke validates the decision stream (TraceSanitizer) on every
                # point of the sweep; full runs stay uninstrumented
                point[label] = run_point(cfg, params, shape, seed, qps,
                                         copy.deepcopy(tenants), serving,
                                         backend, sanitize=smoke)
            curve.append(point)
        knee = 0.0
        for point in curve:
            if point["admission_on"]["attainment"] >= ATTAINMENT_KNEE:
                knee = point["load_multiplier"]
        per_backend[backend] = {
            "calibration": slo,
            "tenants": [{"name": t.name, "tier": t.tier,
                         "deadline_s": t.deadline_s, "weight": t.weight,
                         "sheddable": t.sheddable, "share": t.share}
                        for t in tenants],
            "curve": curve,
            "knee_load_multiplier": knee,
        }

    results: dict = {
        "workload": {
            "task": "coding", "seed": seed, "n_prompts": shape[0],
            "group_size": shape[1], "trajectories": shape[0] * shape[1],
            "workers": 2, "max_active_per_worker": shape[2],
            "arrival": "poisson", "load_multipliers": list(loads),
        },
        "calibration": calib,
        "backends": per_backend,
    }
    if smoke:
        results["sanitizer"] = sanitizer_summary(
            [point[label]["sanitizer"]
             for r in per_backend.values() for point in r["curve"]
             for label in ("admission_on", "admission_off")])
    write_json_atomic(json_path, results)

    eng = per_backend["engine"]
    hot = eng["curve"][-1]          # the overloaded point
    rows = [
        ("serving_capacity_qps", 0.0, f"{capacity:.2f} traj/s"),
        ("serving_knee_load", 0.0, f"{eng['knee_load_multiplier']:g}x"),
        ("serving_gold_attainment_ac_on", 0.0,
         f"{hot['admission_on']['tenants']['gold']['attainment']:.2f}"),
        ("serving_gold_attainment_ac_off", 0.0,
         f"{hot['admission_off']['tenants']['gold']['attainment']:.2f}"),
        ("serving_shed_rate_overload", 0.0,
         f"{hot['admission_on']['shed_rate']:.2f}"),
        ("serving_peak_queue_ac_on", 0.0,
         f"{hot['admission_on']['peak_live_global']} live"),
        ("serving_peak_queue_ac_off", 0.0,
         f"{hot['admission_off']['peak_live_global']} live"),
        ("serving_goodput_overload",
         hot["admission_on"]["makespan_s"] * 1e6,
         f"{hot['admission_on']['goodput_tok_s']:.1f} tok/s"),
    ]
    emit(rows)

    if smoke:
        for backend, r in per_backend.items():
            hot = r["curve"][-1]
            on, off = hot["admission_on"], hot["admission_off"]
            gold_on = on["tenants"]["gold"]["attainment"]
            gold_off = off["tenants"]["gold"]["attainment"]
            assert gold_on >= gold_off, (
                f"{backend}: admission control hurt gold attainment at "
                f"overload ({gold_on:.2f} < {gold_off:.2f})")
            assert on["shed"] > 0, \
                f"{backend}: overload shed nothing — the gate never engaged"
            assert on["peak_live_global"] <= off["peak_live_global"], (
                f"{backend}: admission control did not bound the live queue "
                f"({on['peak_live_global']} > {off['peak_live_global']})")
            for point in r["curve"]:
                for label in ("admission_on", "admission_off"):
                    run_ = point[label]
                    assert run_["gold_shed"] == 0, (
                        f"{backend}/{label}@{point['load_multiplier']}x: "
                        f"shed gold-tier work")
                    assert run_["drained"], (
                        f"{backend}/{label}@{point['load_multiplier']}x: "
                        f"arrivals left neither FINISHED nor SHED")
        san = results["sanitizer"]
        expect = 2 * len(loads) * 2     # backends x loads x admission on/off
        assert san["runs"] == expect and san["violations"] == 0, \
            f"trace sanitizer reported violations under overload: {san}"
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + assert gold-tier SLO protection "
                         "under overload on both backends (CI)")
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--json", default="BENCH_serving.json")
    args = ap.parse_args(argv)
    emit([], header=True)
    run(smoke=args.smoke, seed=args.seed, json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
