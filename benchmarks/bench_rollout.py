"""End-to-end rollout runtime benchmark: PPS+migration vs FCFS on real workers.

Drives the event-driven runtime (``repro.engine.runtime``) over a seeded
long-tail agentic workload — full trajectories with tool calls, preemptive
per-worker queues, tool-interval KV migration — on the real slot-pool data
plane, and compares Heddle's scheduling stack (PPS + progressive refresh +
migration) against the FCFS/no-migration baseline on identical substrate:

  * end-to-end virtual makespan (the §7.2 headline: long-tail neutralization),
  * p99 per-step queue delay,
  * preemption / migration / telemetry counters.

The workload is ``engine.workload`` plans miniaturized onto the reduced model
(``runtime.miniaturize``: one multiplicative shrink for tokens AND tool
latencies, preserving the lognormal tail and the paper's tool/generation time
ratio), heavily oversubscribed (trajectories >> decode slots) so trajectory-
level scheduling has something to do.  Virtual makespans depend only on the
seeded plans — not on sampled token ids — so results are stable across
platforms and JAX versions.

Emits ``name,us_per_call,derived`` CSV rows and writes ``BENCH_rollout.json``.
``--smoke`` (CI) runs the reduced shape and *asserts* the runtime completes the
workload with preemptions + migrations and that PPS does not regress vs FCFS.
"""

from __future__ import annotations

import argparse
import json
import sys

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.engine.runtime import RuntimeConfig, build_workbench, make_runtime
from repro.models import model as M

SEED = 5                       # seeded long-tail workload the comparison is on

# (n_prompts, group_size, max_active): full = 48 trajectories on 2x2 decode
# slots (12x oversubscription), smoke = 24 trajectories on 2x1
FULL = (12, 4, 2)
SMOKE = (6, 4, 1)


def run_policy(cfg, params, scheduler: str, migration: bool, shape, seed: int):
    n_prompts, group, max_active = shape
    batch, predictor = build_workbench(n_prompts=n_prompts, group_size=group,
                                       seed=seed)
    rcfg = RuntimeConfig(scheduler=scheduler, migration=migration,
                         max_active=max_active, quantum=8,
                         preemption_margin=1.5, preemption_floor=16.0,
                         seed=seed)
    runtime = make_runtime(cfg, params, batch, predictor, n_workers=2,
                           config=rcfg)
    res = runtime.run()
    rate = runtime.controller.measured_reuse_rate
    return {
        "makespan_s": res.makespan,
        "throughput_tok_s": res.throughput,
        "total_tokens": res.total_tokens,
        "queue_delay_mean_s": res.queue_delay_mean,
        "queue_delay_p99_s": res.queue_delay_p99,
        "preemptions": res.preemptions,
        "migrations": res.migrations,
        "finished": sum(t.finished for t in res.trajectories),
        "trajectories": len(res.trajectories),
        "agentic_steps": sum(t.num_steps for t in res.trajectories),
        "measured_reuse_rate": rate,
        "wall_s": res.wall_time,
        "events": res.events,
    }


def run(smoke: bool = False, seed: int = SEED,
        json_path: str = "BENCH_rollout.json") -> dict:
    shape = SMOKE if smoke else FULL
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    pps = run_policy(cfg, params, "pps", True, shape, seed)
    fcfs = run_policy(cfg, params, "fcfs", False, shape, seed)
    speedup = fcfs["makespan_s"] / pps["makespan_s"]
    results = {
        "workload": {
            "task": "coding", "seed": seed, "n_prompts": shape[0],
            "group_size": shape[1], "trajectories": shape[0] * shape[1],
            "workers": 2, "max_active_per_worker": shape[2],
        },
        "pps_migration": pps,
        "fcfs_baseline": fcfs,
        "makespan_speedup": speedup,
        "queue_delay_p99_ratio": (fcfs["queue_delay_p99_s"]
                                  / max(pps["queue_delay_p99_s"], 1e-9)),
    }
    with open(json_path, "w") as f:
        json.dump(results, f, indent=2)

    emit([
        ("rollout_makespan_pps_migration", pps["makespan_s"] * 1e6,
         f"{pps['throughput_tok_s']:.1f} tok/s"),
        ("rollout_makespan_fcfs", fcfs["makespan_s"] * 1e6,
         f"{fcfs['throughput_tok_s']:.1f} tok/s"),
        ("rollout_makespan_speedup", 0.0, f"{speedup:.3f}x"),
        ("rollout_queue_delay_p99_pps", pps["queue_delay_p99_s"] * 1e6, "s"),
        ("rollout_queue_delay_p99_fcfs", fcfs["queue_delay_p99_s"] * 1e6, "s"),
        ("rollout_preemptions_pps", 0.0, pps["preemptions"]),
        ("rollout_migrations_pps", 0.0, pps["migrations"]),
    ])

    if smoke:
        # enforced invariants: the runtime drains the workload end to end, the
        # control plane actually engaged, and PPS+migration does not regress
        assert pps["finished"] == pps["trajectories"], "pps left live trajectories"
        assert fcfs["finished"] == fcfs["trajectories"], "fcfs left live trajectories"
        assert pps["preemptions"] > 0, "no preemptive execution happened"
        assert pps["migrations"] > 0, "no tool-interval migration happened"
        assert fcfs["migrations"] == 0, "baseline unexpectedly migrated"
        assert pps["makespan_s"] < fcfs["makespan_s"], \
            (f"PPS+migration regressed vs FCFS: "
             f"{pps['makespan_s']:.3f} vs {fcfs['makespan_s']:.3f}")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shape + assert completion and no PPS "
                         "regression vs FCFS (CI)")
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--json", default="BENCH_rollout.json")
    args = ap.parse_args(argv)
    emit([], header=True)
    run(smoke=args.smoke, seed=args.seed, json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
