"""End-to-end rollout benchmark on the unified orchestrator: PPS vs FCFS,
engine vs analytic twin.

Drives the one orchestration core (``repro.core.orchestrator``) over a seeded
long-tail agentic workload — full trajectories with tool calls, preemptive
per-worker queues, tool-interval KV migration — on either execution backend:

  * ``--backend engine`` (default): the real slot-pool data plane
    (``engine.backends.EngineBackend``) on its deterministic virtual clock;
  * ``--backend sim``: the analytic twin (``SimBackend`` in engine-parity
    mode via ``runtime.run_on_sim``) — no model, no decode, same decisions.

and compares Heddle's scheduling stack (PPS + progressive refresh + migration)
against the FCFS/no-migration baseline on identical substrate: end-to-end
virtual makespan (the §7.2 headline), p99 per-step queue delay, and
preemption / migration / telemetry counters.

Because both backends share the orchestrator, the twin is a *predictive* model
of the engine: the full run sweeps every scheduler policy on both and asserts
the sim-vs-engine **makespan rank correlation** — the property that makes
model-free policy sweeps on the twin trustworthy.  ``--smoke`` (CI) runs the
reduced shape and asserts the runtime completes the workload with preemptions
+ migrations, that PPS does not regress vs FCFS, and that the twin ranks the
two policies the same way.

The workload is ``engine.workload`` plans miniaturized onto the reduced model
(``runtime.miniaturize``), heavily oversubscribed (trajectories >> decode
slots) so trajectory-level scheduling has something to do.  Virtual makespans
depend only on the seeded plans — not on sampled token ids — so results are
stable across platforms and JAX versions.  Emits ``name,us_per_call,derived``
CSV rows and writes ``BENCH_rollout.json``.
"""

from __future__ import annotations

import argparse
import sys

from benchmarks.common import emit, sanitizer_summary, write_json_atomic

SEED = 5                       # seeded long-tail workload the comparison is on

# (n_prompts, group_size, max_active): full = 48 trajectories on 2x2 decode
# slots (12x oversubscription), smoke = 24 trajectories on 2x2
FULL = (12, 4, 2)
SMOKE = (6, 4, 2)

# the policy matrix the sim-vs-engine rank correlation is computed over
POLICIES = [("pps", True), ("pps", False), ("sjf", False),
            ("fcfs", False), ("rr", False)]


def _runtime_config(scheduler: str, migration: bool, max_active: int, seed: int,
                    sanitize: bool = False):
    from repro.engine.runtime import RuntimeConfig
    return RuntimeConfig(scheduler=scheduler, migration=migration,
                         max_active=max_active, quantum=8, seed=seed,
                         sanitize=sanitize)


def run_policy(cfg, params, scheduler: str, migration: bool, shape, seed: int,
               backend: str = "engine", sanitize: bool = False) -> dict:
    from repro.engine.runtime import build_workbench, make_runtime, run_on_sim
    n_prompts, group, max_active = shape
    batch, predictor = build_workbench(n_prompts=n_prompts, group_size=group,
                                       seed=seed)
    rcfg = _runtime_config(scheduler, migration, max_active, seed, sanitize)
    if backend == "sim":
        res = run_on_sim(batch, predictor, n_workers=2, config=rcfg)
        reuse, tokens, wall = None, sum(t.tokens_generated for t in batch), 0.0
    else:
        runtime = make_runtime(cfg, params, batch, predictor, n_workers=2,
                               config=rcfg)
        res = runtime.run()
        reuse = runtime.controller.measured_reuse_rate
        tokens, wall = res.total_tokens, res.wall_time
    return {
        "makespan_s": res.makespan,
        "throughput_tok_s": tokens / res.makespan if res.makespan else 0.0,
        "total_tokens": tokens,
        "queue_delay_mean_s": res.queue_delay_mean,
        "queue_delay_p99_s": res.queue_delay_p99,
        "preemptions": res.preemptions,
        "migrations": res.migrations,
        "finished": sum(t.finished for t in res.trajectories),
        "trajectories": len(res.trajectories),
        "agentic_steps": sum(t.num_steps for t in res.trajectories),
        "measured_reuse_rate": reuse,
        "wall_s": wall,
        "events": res.events,
        "sanitizer": res.sanitizer,
    }


def rank_corr(xs: list[float], ys: list[float]) -> float:
    """Spearman rank correlation (no scipy; ties broken by input order)."""
    def ranks(v):
        order = sorted(range(len(v)), key=lambda i: v[i])
        r = [0] * len(v)
        for rank, i in enumerate(order):
            r[i] = rank
        return r
    rx, ry = ranks(xs), ranks(ys)
    n = len(xs)
    if n < 2:
        return 1.0
    d2 = sum((a - b) ** 2 for a, b in zip(rx, ry))
    return 1.0 - 6.0 * d2 / (n * (n * n - 1))


def run(smoke: bool = False, seed: int = SEED, backend: str = "engine",
        json_path: str = "BENCH_rollout.json") -> dict:
    shape = SMOKE if smoke else FULL
    # the model is always needed: even a sim-backend headline run crosses to
    # the engine for the twin check (smoke) / the parity sweep (full)
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # smoke validates the decision stream as it runs (TraceSanitizer); full
    # runs keep the headline timings free of instrumentation
    pps = run_policy(cfg, params, "pps", True, shape, seed, backend,
                     sanitize=smoke)
    fcfs = run_policy(cfg, params, "fcfs", False, shape, seed, backend,
                      sanitize=smoke)
    speedup = fcfs["makespan_s"] / pps["makespan_s"]
    results = {
        "workload": {
            "task": "coding", "seed": seed, "n_prompts": shape[0],
            "group_size": shape[1], "trajectories": shape[0] * shape[1],
            "workers": 2, "max_active_per_worker": shape[2],
            "backend": backend,
        },
        "pps_migration": pps,
        "fcfs_baseline": fcfs,
        "makespan_speedup": speedup,
        "queue_delay_p99_ratio": (fcfs["queue_delay_p99_s"]
                                  / max(pps["queue_delay_p99_s"], 1e-9)),
    }

    if smoke:
        # cheap twin check: the analytic backend must rank the two policies
        # the way the measured backend does (the full run sweeps all policies)
        twin = "sim" if backend == "engine" else "engine"
        t_pps = run_policy(cfg, params, "pps", True, shape, seed, twin,
                           sanitize=True)
        t_fcfs = run_policy(cfg, params, "fcfs", False, shape, seed, twin,
                            sanitize=True)
        results["twin_agrees"] = ((t_pps["makespan_s"] < t_fcfs["makespan_s"])
                                  == (pps["makespan_s"] < fcfs["makespan_s"]))
        results["sanitizer"] = sanitizer_summary(
            [r["sanitizer"] for r in (pps, fcfs, t_pps, t_fcfs)])
    else:
        # sim-vs-engine makespan rank correlation across scheduler policies:
        # the property that makes model-free policy sweeps on the twin sound.
        # The sweep runs at the reduced shape — rank agreement is a property of
        # the shared orchestrator + pricing, not of workload size.
        eng_ms, sim_ms, names = [], [], []
        for sched, mig in POLICIES:
            names.append(f"{sched}{'+mig' if mig else ''}")
            eng_ms.append(run_policy(cfg, params, sched, mig, SMOKE, seed,
                                     "engine")["makespan_s"])
            sim_ms.append(run_policy(cfg, params, sched, mig, SMOKE, seed,
                                     "sim")["makespan_s"])
        corr = rank_corr(eng_ms, sim_ms)
        results["parity"] = {
            "policies": names,
            "engine_makespans_s": eng_ms,
            "sim_makespans_s": sim_ms,
            "makespan_rank_correlation": corr,
        }
        assert corr >= 0.8, (
            f"sim-vs-engine makespan rank correlation {corr:.2f} < 0.8: the "
            f"analytic twin no longer predicts engine policy ordering "
            f"(engine {eng_ms}, sim {sim_ms})")

    write_json_atomic(json_path, results)

    emit([
        ("rollout_makespan_pps_migration", pps["makespan_s"] * 1e6,
         f"{pps['throughput_tok_s']:.1f} tok/s"),
        ("rollout_makespan_fcfs", fcfs["makespan_s"] * 1e6,
         f"{fcfs['throughput_tok_s']:.1f} tok/s"),
        ("rollout_makespan_speedup", 0.0, f"{speedup:.3f}x"),
        ("rollout_queue_delay_p99_pps", pps["queue_delay_p99_s"] * 1e6, "s"),
        ("rollout_queue_delay_p99_fcfs", fcfs["queue_delay_p99_s"] * 1e6, "s"),
        ("rollout_preemptions_pps", 0.0, pps["preemptions"]),
        ("rollout_migrations_pps", 0.0, pps["migrations"]),
    ] + ([("rollout_sim_engine_rank_corr", 0.0,
           f"{results['parity']['makespan_rank_correlation']:.3f}")]
         if "parity" in results else []))

    if smoke:
        # enforced invariants: the runtime drains the workload end to end, the
        # control plane actually engaged, and PPS+migration does not regress
        assert pps["finished"] == pps["trajectories"], "pps left live trajectories"
        assert fcfs["finished"] == fcfs["trajectories"], "fcfs left live trajectories"
        assert pps["preemptions"] > 0, "no preemptive execution happened"
        assert pps["migrations"] > 0, "no tool-interval migration happened"
        assert fcfs["migrations"] == 0, "baseline unexpectedly migrated"
        assert pps["makespan_s"] < fcfs["makespan_s"], \
            (f"PPS+migration regressed vs FCFS: "
             f"{pps['makespan_s']:.3f} vs {fcfs['makespan_s']:.3f}")
        assert results["twin_agrees"], "analytic twin ranks pps/fcfs differently"
        san = results["sanitizer"]
        assert san["runs"] == 4 and san["violations"] == 0, \
            f"trace sanitizer reported violations: {san}"
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shape + assert completion, no PPS regression "
                         "vs FCFS, and twin rank agreement (CI)")
    ap.add_argument("--backend", choices=["engine", "sim"], default="engine",
                    help="execution backend for the headline comparison "
                         "(sim = model-free analytic twin)")
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--json", default="BENCH_rollout.json")
    args = ap.parse_args(argv)
    emit([], header=True)
    run(smoke=args.smoke, seed=args.seed, backend=args.backend,
        json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
