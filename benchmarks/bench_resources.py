"""Figure 16: trajectory-adaptive resource management — Algorithm 2 vs Fix-1 / Fix-8
homogeneous MP.  Paper claim: 1.1x-1.3x; Fix-1 has peak initial throughput but slow
long-tail per-token time, Fix-8 the reverse (16b: active-trajectory timeline).
"""

from __future__ import annotations

from benchmarks.common import Workbench, emit


def run(fast: bool = True):
    rows = []
    n_prompts = 150 if fast else 400
    wb = Workbench.make("search", n_prompts=n_prompts, group_size=16)
    results = {}
    variants = {
        "adaptive": dict(degrees=()),                    # Algorithm 2
        "fix1": dict(degrees=(1,) * 64),
        "fix8": dict(degrees=(8,) * 8),
    }
    for name, extra in variants.items():
        r = wb.run(scheduler="pps", placement="heddle", gpu_budget=64,
                   max_batch=100, seed=0, **extra)
        results[name] = r
        rows.append((f"fig16/{name}", r.makespan * 1e6, f"{r.throughput:.0f}tok/s"))
        # Fig 16(b): active-trajectory count over time (head/mid/tail of the timeline)
        if r.timeline:
            for frac in (0.25, 0.5, 0.9):
                idx = min(int(len(r.timeline) * frac), len(r.timeline) - 1)
                t, n = r.timeline[idx]
                rows.append((f"fig16b/{name}/t{int(frac*100)}", t * 1e6,
                             f"{n}active"))
    for base in ("fix1", "fix8"):
        sp = results[base].makespan / results["adaptive"].makespan
        rows.append((f"fig16/speedup_vs_{base}", 0.0, f"{sp:.2f}x"))
    emit(rows)
    return rows


if __name__ == "__main__":
    emit([], header=True)
    run(fast=False)
