"""Trajectory-adaptive resource management end to end (paper §6, Figs. 7 & 16).

Two layers:

* **End-to-end fleet comparison** (default, and what ``--smoke`` asserts): a
  heterogeneous {4, 2, 1, 1} fleet vs a homogeneous {2, 2, 2, 2} fleet — the
  same 8-accelerator budget — drives REAL ``RolloutWorker``s through a
  miniaturized long-tail agentic workload on the event-driven runtime.  Under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (CI) every worker is
  physically built on its carved sub-mesh with params/KV sharded by the
  MaxText-style rules; on a single device the fleet falls back un-meshed while
  the declared degrees still drive placement and the virtual decode clock.
  The §6.1 sort-and-zip placement lands the long-tail partitions on the high-MP
  workers, whose per-token time is lower (Fig. 7 trade-off), so the
  heterogeneous fleet should complete the batch with a smaller makespan.
  Measured per-worker decode timing is then fitted back into a
  ``WorkerLatencyModel`` (t1/overlap from observations, §6 calibration) and
  Algorithm 2 is re-run on the observed trajectories to show the feedback loop.

* **Control-plane study** (``--full``): the original Fig. 16 simulator sweep —
  Algorithm 2 vs Fix-1 / Fix-8 homogeneous MP at paper scale (64 GPUs, 2400
  trajectories).  Paper claim: 1.1x–1.3x.

Emits ``name,us_per_call,derived`` CSV rows and writes ``BENCH_resources.json``
with both fleet makespans, the speedup, the fitted latency-model parameters,
and the reprovisioned degree vector.  ``--smoke`` (CI) asserts the workload
drains on both fleets and the heterogeneous makespan does not regress.
"""

from __future__ import annotations

import argparse
import sys

import jax

from benchmarks.common import (Workbench, emit, sanitizer_summary,
                               write_json_atomic)
from repro.configs import get_config
from repro.engine.fleet import FleetSpec
from repro.engine.runtime import RuntimeConfig, build_workbench, make_runtime
from repro.models import model as M

SEED = 7                       # seeded long-tail workload the comparison is on

HET = FleetSpec((4, 2, 1, 1))  # Algorithm-2-shaped fleet (budget 8)
HOM = FleetSpec((2, 2, 2, 2))  # Fix-2 baseline on the same budget

# (task, n_prompts, group_size, max_active).  Both are tail-dominated regimes —
# the paper's §6 setting, where the critical path is the longest trajectory's
# decode time and a fast high-MP worker shortens it.  (At heavier
# oversubscription the bulk's aggregate throughput dominates and homogeneous
# wins — the other arm of the Fig. 7 trade-off; the smoke pins the regime the
# mechanism exists for.)
FULL_SHAPE = ("search", 6, 4, 2)
SMOKE_SHAPE = ("coding", 3, 4, 2)


def run_fleet(cfg, params, fleet: FleetSpec, shape, seed: int,
              sanitize: bool = False) -> dict:
    task, n_prompts, group, max_active = shape
    batch, predictor = build_workbench(task=task, n_prompts=n_prompts,
                                       group_size=group, seed=seed)
    # default preemption hysteresis: tuned for the unified orchestrator's
    # causal event ordering (see docs/runtime.md "Event flow").  Load gap 2:
    # the controller weighs migration loads in fast-worker equivalents, so on
    # a heterogeneous fleet a 1-equivalent imbalance is within rounding of a
    # single resident — both fleets run the same (fair) gate.
    rcfg = RuntimeConfig(scheduler="pps", migration=True, max_active=max_active,
                         quantum=8, seed=seed, sanitize=sanitize)
    runtime = make_runtime(cfg, params, batch, predictor, config=rcfg,
                           fleet=fleet, migration_load_gap=2)
    res = runtime.run()
    return {
        "runtime": runtime,
        "degrees": res.degrees,
        "makespan_s": res.makespan,
        "throughput_tok_s": res.throughput,
        "total_tokens": res.total_tokens,
        "queue_delay_p99_s": res.queue_delay_p99,
        "preemptions": res.preemptions,
        "migrations": res.migrations,
        "finished": sum(t.finished for t in res.trajectories),
        "trajectories": len(res.trajectories),
        "meshed_workers": sum(1 for w in runtime.fleet.workers
                              if w.mesh is not None),
        "wall_s": res.wall_time,
        "sanitizer": res.sanitizer,
    }


def run_control_plane(fast: bool = True) -> list[tuple]:
    """Fig. 16 simulator study: Algorithm 2 vs Fix-1 / Fix-8 homogeneous MP."""
    rows = []
    n_prompts = 150 if fast else 400
    wb = Workbench.make("search", n_prompts=n_prompts, group_size=16)
    results = {}
    variants = {
        "adaptive": dict(degrees=()),                    # Algorithm 2
        "fix1": dict(degrees=(1,) * 64),
        "fix8": dict(degrees=(8,) * 8),
    }
    for name, extra in variants.items():
        r = wb.run(scheduler="pps", placement="heddle", gpu_budget=64,
                   max_batch=100, seed=0, **extra)
        results[name] = r
        rows.append((f"fig16/{name}", r.makespan * 1e6, f"{r.throughput:.0f}tok/s"))
        # Fig 16(b): active-trajectory count over time (head/mid/tail of the timeline)
        if r.timeline:
            for frac in (0.25, 0.5, 0.9):
                idx = min(int(len(r.timeline) * frac), len(r.timeline) - 1)
                t, n = r.timeline[idx]
                rows.append((f"fig16b/{name}/t{int(frac*100)}", t * 1e6,
                             f"{n}active"))
    for base in ("fix1", "fix8"):
        sp = results[base].makespan / results["adaptive"].makespan
        rows.append((f"fig16/speedup_vs_{base}", 0.0, f"{sp:.2f}x"))
    return rows


def run(fast: bool | None = None, smoke: bool = False, full: bool = False,
        seed: int = SEED, json_path: str = "BENCH_resources.json") -> dict:
    # ``benchmarks.run`` suite compatibility: fast=True is the smoke shape
    # without assertions, fast=False is the full end-to-end + Fig. 16 study
    if fast is not None:
        full = full or not fast
    shape = SMOKE_SHAPE if (smoke or fast) else FULL_SHAPE
    cfg = get_config("qwen3_1_7b").reduced(n_periods=1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # smoke validates the decision stream (TraceSanitizer) on both fleets;
    # full runs keep headline timings free of instrumentation
    het = run_fleet(cfg, params, HET, shape, seed, sanitize=smoke)
    hom = run_fleet(cfg, params, HOM, shape, seed, sanitize=smoke)
    speedup = hom["makespan_s"] / het["makespan_s"]

    # §6 calibration: fit t1/overlap from the het run's measured decode timing,
    # then let Algorithm 2 reprovision from the observed trajectory lengths
    runtime = het.pop("runtime")
    observations = runtime.controller.calibration_observations()
    fitted = runtime.calibrate()
    report = runtime.reconfigure(calibrate=False)
    hom.pop("runtime")

    results = {
        "workload": {
            "task": shape[0], "seed": seed, "n_prompts": shape[1],
            "group_size": shape[2], "trajectories": shape[1] * shape[2],
            "max_active_per_worker": shape[3], "budget": HET.budget,
            "devices": jax.device_count(),
        },
        "heterogeneous": het,
        "homogeneous": hom,
        "makespan_speedup": speedup,
        "latency_model": {
            "observations": [list(o) for o in observations],
            "fitted_t1_s": None if fitted is None else fitted.t1,
            "fitted_overlap": None if fitted is None else fitted.overlap,
        },
        "reprovision": report,
    }
    if smoke:
        results["sanitizer"] = sanitizer_summary([het["sanitizer"],
                                                  hom["sanitizer"]])
    if full:
        results["control_plane_rows"] = [list(r) for r in run_control_plane(False)]
    write_json_atomic(json_path, results)

    emit([
        ("resources_makespan_het_4211", het["makespan_s"] * 1e6,
         f"{het['throughput_tok_s']:.1f} tok/s"),
        ("resources_makespan_hom_2222", hom["makespan_s"] * 1e6,
         f"{hom['throughput_tok_s']:.1f} tok/s"),
        ("resources_makespan_speedup", 0.0, f"{speedup:.3f}x"),
        ("resources_meshed_workers_het", 0.0, het["meshed_workers"]),
        ("resources_fitted_t1_us", 0.0 if fitted is None else fitted.t1 * 1e6,
         "" if fitted is None else f"overlap={fitted.overlap:.2f}"),
        ("resources_reprovisioned", 0.0,
         "|".join(str(d) for d in report["to"])),
    ])
    if full:
        emit(results["control_plane_rows"])

    if smoke:
        # enforced invariants: both fleets drain the workload, the heterogeneous
        # allocation does not regress vs the homogeneous split on the same
        # budget, and calibration produced a usable model
        assert het["finished"] == het["trajectories"], "het left live trajectories"
        assert hom["finished"] == hom["trajectories"], "hom left live trajectories"
        assert het["makespan_s"] <= hom["makespan_s"], \
            (f"heterogeneous {HET.degrees} regressed vs homogeneous "
             f"{HOM.degrees}: {het['makespan_s']:.3f} vs {hom['makespan_s']:.3f}")
        assert fitted is not None and fitted.t1 > 0.0, "calibration produced no model"
        if jax.device_count() >= HET.budget:
            assert het["meshed_workers"] == HET.n_workers, \
                "every worker should own its carved sub-mesh on an 8-device host"
        san = results["sanitizer"]
        assert san["runs"] == 2 and san["violations"] == 0, \
            f"trace sanitizer reported violations on the fleet runs: {san}"
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shape + assert het<=hom and calibration (CI)")
    ap.add_argument("--full", action="store_true",
                    help="also run the Fig. 16 control-plane simulator study")
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--json", default="BENCH_resources.json")
    args = ap.parse_args(argv)
    emit([], header=True)
    run(smoke=args.smoke, full=args.full, seed=args.seed, json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
