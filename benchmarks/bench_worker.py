"""Data-plane engine benchmark: slot-pool vs legacy concat/slice worker.

Two headline numbers on the real JAX engine (reduced model, CPU-friendly):

  * batched decode tokens/s — the legacy engine pays a ``_concat_caches`` /
    ``_slice_cache`` round-trip per ``decode()`` call plus one host round-trip per
    token; the slot-pool engine runs one fused jitted loop over the resident batch,
  * admission latency — time for a new request to join a running batch and produce
    its first token (legacy: re-concat every co-resident cache; slot-pool: one
    ``dynamic_update_slice`` into a free lane).

Rows: worker_decode_{legacy,slotpool} (us_per_call, tokens/s),
      worker_admit_{legacy,slotpool} (us_per_call, seconds),
      worker_decode_speedup (derived = slotpool/legacy throughput ratio).
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit, timed
from repro.configs import get_config
from repro.engine.legacy import LegacyRolloutWorker
from repro.engine.sampler import SamplerConfig
from repro.engine.worker import RolloutWorker
from repro.models import model as M

PROMPT = [5, 7, 9, 11, 13, 17, 19, 23]


def _bench_engine(make_worker, n_seqs: int, gen_tokens: int):
    """Returns (decode_s, tokens/s, admit_s) for one engine."""
    w = make_worker()
    for i in range(n_seqs):
        w.prefill(i, PROMPT)
    w.decode(list(range(n_seqs)), gen_tokens)           # compile + warm caches
    _, dt = timed(lambda: w.decode(list(range(n_seqs)), gen_tokens), repeat=3)
    tok_s = n_seqs * gen_tokens / dt

    # admission: a fresh request joins the running batch and decodes one token
    def admit(sid):
        w.prefill(sid, PROMPT)
        w.decode(list(range(n_seqs)) + [sid], 1)

    admit(900)                                          # compile the n_seqs+1 shapes
    w.release(900)
    admit_s = float("inf")
    for sid in (901, 902, 903):
        t0 = time.perf_counter()
        admit(sid)
        admit_s = min(admit_s, time.perf_counter() - t0)
        w.release(sid)
    return dt, tok_s, admit_s


def run(fast: bool = True) -> None:
    n_seqs, gen_tokens = (4, 16) if fast else (8, 32)
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    greedy = SamplerConfig(temperature=0.0)             # keep RNG out of the timing

    leg_dt, leg_tok, leg_admit = _bench_engine(
        lambda: LegacyRolloutWorker(cfg, params, capacity=256, sampler=greedy),
        n_seqs, gen_tokens)
    # prefix_reuse off: every admission here repeats one prompt, and radix implants
    # would measure the reuse path instead of raw admission (bench_prefill covers
    # reuse separately)
    sp_dt, sp_tok, sp_admit = _bench_engine(
        lambda: RolloutWorker(cfg, params, capacity=256, max_slots=n_seqs + 1,
                              sampler=greedy, prefix_reuse=False),
        n_seqs, gen_tokens)

    emit([
        ("worker_decode_legacy", leg_dt * 1e6, f"{leg_tok:.1f} tok/s"),
        ("worker_decode_slotpool", sp_dt * 1e6, f"{sp_tok:.1f} tok/s"),
        ("worker_decode_speedup", 0.0, f"{sp_tok / leg_tok:.2f}x"),
        ("worker_admit_legacy", leg_admit * 1e6, f"{leg_admit:.4f} s"),
        ("worker_admit_slotpool", sp_admit * 1e6, f"{sp_admit:.4f} s"),
    ])


if __name__ == "__main__":
    emit([], header=True)
    run(fast=True)
