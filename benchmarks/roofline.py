"""Roofline analysis (deliverable g): three terms per (arch x input-shape x mesh).

Sources:
  * ``dryrun_16x16.json`` / ``dryrun_2x16x16.json`` — per-device HLO FLOPs / bytes from
    ``compiled.cost_analysis()`` and per-collective wire bytes parsed from the compiled
    (post-SPMD) HLO by ``repro.launch.dryrun``.
  * analytic per-device FLOPs from the model configs (this module).

Caveat (documented): XLA's HloCostAnalysis counts a while-loop body ONCE, so the HLO
FLOPs/bytes of scan-over-periods models undercount by ~n_periods on the layer stack.
We therefore compute the roofline terms from BOTH the raw HLO numbers (as specified)
and the analytic FLOPs (authoritative for the compute term); the dominant-bottleneck
call uses the analytic compute term and the HLO-parsed collective/memory terms.

Hardware constants (v5e-class target): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--json dryrun_16x16.json]
"""

from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.models.config import INPUT_SHAPES, LONG_CONTEXT_WINDOW, ModelConfig

PEAK_FLOPS = 197e12           # bf16 per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (aggregate per-chip estimate)


# ------------------------------------------------------------------ analytic FLOPs

def _attn_flops_token(cfg: ModelConfig, ctx: int) -> float:
    """Per-token attention flops at context length ctx (QK^T + PV, full blocks)."""
    H, hd, KV, d = cfg.n_heads, cfg.hd, cfg.n_kv_heads, cfg.d_model
    proj = 2 * d * (H + 2 * KV) * hd + 2 * H * hd * d
    qk_pv = 4 * H * hd * ctx
    return proj + qk_pv


def _mlp_flops_token(cfg: ModelConfig, d_ff: int) -> float:
    mats = 3 if cfg.activation == "swiglu" else 2
    return mats * 2 * cfg.d_model * d_ff


def _moe_flops_token(cfg: ModelConfig) -> float:
    d, E = cfg.d_model, cfg.n_experts
    f = 2 * d * E                                      # router
    mats = 3 if cfg.activation == "swiglu" else 2
    f += cfg.top_k * cfg.capacity_factor * mats * 2 * d * cfg.moe_d_ff
    if cfg.shared_d_ff:
        f += 3 * 2 * d * cfg.shared_d_ff + 2 * d
    if cfg.dense_residual_ff:
        f += 3 * 2 * d * cfg.dense_residual_ff
    return f


def _mamba_flops_token(cfg: ModelConfig) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state_dim
    R = cfg.ssm_dt_rank or -(-d // 16)
    return (2 * 2 * d * di                 # in + z proj
            + 2 * cfg.ssm_conv_width * di
            + 2 * di * (R + 2 * N) + 2 * R * di
            + 10 * di * N                  # discretize + scan + reduce
            + 2 * di * d)


def _mlstm_flops_token(cfg: ModelConfig) -> float:
    d = cfg.d_model
    di = cfg.xlstm_expand * d
    H = cfg.n_heads
    hd = di // H
    cs = 256                               # chunk size (intra-chunk quadratic term)
    return (2 * 2 * d * di + 3 * 2 * di * di + 2 * 2 * di * H
            + 2 * di * cs * 2              # intra-chunk qk/pv (amortized per token)
            + 4 * H * hd * hd              # state update/read
            + 2 * di * d)


def _slstm_flops_token(cfg: ModelConfig) -> float:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    return 2 * d * 4 * d + 8 * H * hd * hd + 2 * d * d


def layer_flops_token(cfg: ModelConfig, kind: str, ctx: int) -> float:
    mixer, _, mlp_kind = kind.partition("+")
    f = 0.0
    if mixer in ("attn", "dec", "enc_attn"):
        actx = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
        f += _attn_flops_token(cfg, actx)
        if mixer == "dec":
            f += _attn_flops_token(cfg, cfg.encoder_seq)     # cross-attention
    elif mixer == "xattn":
        f += _attn_flops_token(cfg, cfg.image_seq)
    elif mixer == "mamba":
        f += _mamba_flops_token(cfg)
    elif mixer == "mlstm":
        f += _mlstm_flops_token(cfg)
    elif mixer == "slstm":
        f += _slstm_flops_token(cfg)
    if mlp_kind == "mlp":
        f += _mlp_flops_token(cfg, cfg.d_ff)
    elif mlp_kind in ("moe", "moe_dr"):
        f += _moe_flops_token(cfg)
    return f


def analytic_flops(cfg: ModelConfig, shape_name: str) -> dict:
    """Global FLOPs for one step of (cfg, shape); returns fwd / total / model_flops."""
    shape = INPUT_SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "decode":
        tokens = B                           # one new token per sequence
        ctx = S
    else:
        tokens = B * S
        ctx = S // 2                         # mean causal context
    per_tok = sum(layer_flops_token(cfg, k, ctx) for k in cfg.layer_kinds())
    per_tok += 2 * cfg.d_model * cfg.vocab   # lm head
    fwd = per_tok * tokens
    if cfg.arch_type == "audio" and shape.mode != "decode":
        # encoder runs once per sequence (at decode time its output is cached)
        enc_tok = B * cfg.encoder_seq
        fwd += enc_tok * cfg.encoder_layers * layer_flops_token(
            cfg, "enc_attn+mlp", cfg.encoder_seq)
    total = fwd * 3 if shape.mode == "train" else fwd

    # MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE), D = tokens processed
    n_active = active_params(cfg)
    model_flops = (6 if shape.mode == "train" else 2) * n_active * tokens
    return {"fwd": fwd, "total": total, "model_flops": model_flops,
            "tokens": tokens}


def active_params(cfg: ModelConfig) -> float:
    """Per-token-active parameter count (MoE counts top_k experts only)."""
    d = cfg.d_model
    n = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    for kind in cfg.layer_kinds():
        mixer, _, mlp_kind = kind.partition("+")
        if mixer in ("attn", "dec", "enc_attn"):
            n += d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd + cfg.n_heads * cfg.hd * d
            if mixer == "dec":
                n += d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd + cfg.n_heads * cfg.hd * d
        elif mixer == "xattn":
            n += d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd + cfg.n_heads * cfg.hd * d
        elif mixer == "mamba":
            di = cfg.ssm_expand * d
            R = cfg.ssm_dt_rank or -(-d // 16)
            n += 2 * d * di + di * (R + 2 * cfg.ssm_state_dim) + R * di + di * d
        elif mixer == "mlstm":
            di = cfg.xlstm_expand * d
            n += 2 * d * di + 3 * di * di + 2 * di * (di // cfg.hd if cfg.hd else 1) + di * d
        elif mixer == "slstm":
            n += 4 * d * d + 4 * d * (d // cfg.n_heads) + d * d
        if mlp_kind == "mlp":
            n += (3 if cfg.activation == "swiglu" else 2) * d * cfg.d_ff
        elif mlp_kind in ("moe", "moe_dr"):
            n += d * cfg.n_experts
            n += cfg.top_k * (3 if cfg.activation == "swiglu" else 2) * d * cfg.moe_d_ff
            if cfg.shared_d_ff:
                n += 3 * d * cfg.shared_d_ff
            if cfg.dense_residual_ff:
                n += 3 * d * cfg.dense_residual_ff
    if cfg.arch_type == "audio":
        per = d * 4 * cfg.hd * cfg.n_heads // cfg.hd + 0
        n += cfg.encoder_layers * (4 * d * d + (2 if cfg.activation != "swiglu" else 3)
                                   * d * cfg.d_ff)
    return float(n)


# ------------------------------------------------------------------ report

def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    arch, shape = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.is_subquadratic():
        cfg = cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    chips = rec.get("chips", 256)
    ana = analytic_flops(cfg, shape)
    per_dev_analytic = ana["total"] / chips
    hlo_flops = rec.get("hlo_flops", 0.0)            # per-device (SPMD module)
    hlo_bytes = rec.get("hlo_bytes", 0.0)
    coll = rec.get("collective_total_bytes", 0.0)    # per-device wire bytes

    t_compute = per_dev_analytic / PEAK_FLOPS
    t_compute_hlo = hlo_flops / PEAK_FLOPS
    t_memory = hlo_bytes / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    return {
        "arch": arch, "shape": shape, "mesh": rec.get("mesh", "?"), "chips": chips,
        "t_compute_s": t_compute, "t_compute_hlo_s": t_compute_hlo,
        "t_memory_s": t_memory, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": ana["model_flops"],
        "analytic_flops": ana["total"],
        "useful_ratio": ana["model_flops"] / max(ana["total"], 1.0),
        "hlo_flops": hlo_flops, "hlo_bytes": hlo_bytes, "collective_bytes": coll,
        "temp_gib": rec.get("temp_size_in_bytes", 0) / 2**30,
        "args_gib": rec.get("argument_size_in_bytes", 0) / 2**30,
    }


def improvement_hint(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        return "raise MFU: larger per-chip tiles / fewer recompute passes"
    if d == "memory":
        return "cut HBM traffic: fuse elementwise chains, shrink dtype, shard KV wider"
    return "cut ICI: reshard to reduce all-gathers, overlap collectives with compute"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_16x16.json")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    with open(args.json) as f:
        records = json.load(f)
    rows = [r for r in (roofline_row(rec) for rec in records) if r]
    hdr = (f"{'arch':22s} {'shape':12s} {'compute(s)':>11s} {'memory(s)':>10s} "
           f"{'coll(s)':>9s} {'dominant':>10s} {'useful':>7s} {'temp GiB':>9s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:11.3e} "
              f"{r['t_memory_s']:10.3e} {r['t_collective_s']:9.3e} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} {r['temp_gib']:9.2f}")
    return rows


if __name__ == "__main__":
    main()
