"""Tables 1 & 2: system overheads, measured for real on this machine.

Table 1 (data plane): per-step prediction latency and migration transfer time vs the
tool-execution window that masks them.
Table 2 (control plane): presorted-DP placement wall time (paper: ~42 ms at n=6400,
m=16) and sort-initialized SA wall time (paper: ~5 s), plus our aggregated variants.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import TASKS, emit, timed
from repro.core.migration import kv_cache_bytes, migration_time
from repro.core.placement import InterferenceModel, aggregate_short, presorted_dp
from repro.core.predictor import ProgressivePredictor
from repro.core.resource_manager import sort_initialized_sa
from repro.engine.tools import TOOL_PROFILES
from repro.engine.workload import WorkloadConfig, generate, replay_finished


def run(fast: bool = True):
    rows = []
    rng = np.random.default_rng(0)
    interference = InterferenceModel.analytic(0.004)

    # --- Table 1: prediction + migration vs tool execution -----------------------
    hist = replay_finished(generate(WorkloadConfig(task="coding", n_prompts=32,
                                                   group_size=8, seed=7)))
    pred = ProgressivePredictor().fit_trajectories(hist)
    sample = hist[:256]
    _, t_pred = timed(lambda: [pred.predict(t) for t in sample])
    per_pred_us = t_pred / len(sample) * 1e6
    rows.append(("tab1/prediction_latency", per_pred_us, "per-trajectory"))
    _, t_batch = timed(lambda: pred.predict_batch(sample))
    rows.append(("tab1/prediction_latency_batched", t_batch / len(sample) * 1e6,
                 "per-trajectory(batch)"))
    # migration: Qwen3-14B-class KV at a typical mid-rollout context
    kv = kv_cache_bytes(6_000, n_layers=40, n_kv_heads=8, head_dim=128)
    mig_s = migration_time(kv, link_bandwidth=50e9)
    rows.append(("tab1/migration_time", mig_s * 1e6, f"kv={kv/2**20:.0f}MiB"))
    for task in TASKS:
        rows.append((f"tab1/tool_exec_{task}", TOOL_PROFILES[task].mean_latency * 1e6,
                     f"masked={'yes' if TOOL_PROFILES[task].mean_latency > mig_s else 'partial'}"))

    # --- Table 2: placement DP + SA --------------------------------------------
    n, m = 6400, 16
    lengths = rng.pareto(1.2, n) * 800 + 100
    _, t_dp = timed(lambda: presorted_dp(lengths, m, interference,
                                         monotone_speedup=False), repeat=1)
    rows.append(("tab2/placement_dp_full_n6400", t_dp * 1e6, "paper:~42000us"))
    _, t_dpm = timed(lambda: presorted_dp(lengths, m, interference), repeat=1)
    rows.append(("tab2/placement_dp_monotone_n6400", t_dpm * 1e6,
                 f"{t_dp / max(t_dpm, 1e-9):.0f}x_faster(beyond-paper)"))
    ilen, icnt, _ = aggregate_short(lengths, float(np.quantile(lengths, 0.9)), 50)
    _, t_agg = timed(lambda: presorted_dp(ilen, m, interference, counts=icnt), repeat=1)
    rows.append(("tab2/placement_dp_aggregated", t_agg * 1e6, f"n_items={len(ilen)}"))

    if not fast:
        _, t_sa = timed(lambda: sort_initialized_sa(
            ilen, 64, interference, counts=icnt, seed=0), repeat=1)
        rows.append(("tab2/resource_manager_sa", t_sa * 1e6, "paper:~5s"))
    emit(rows)
    return rows


if __name__ == "__main__":
    emit([], header=True)
    run(fast=False)
