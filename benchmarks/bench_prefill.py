"""Chunked prefill plane benchmark: admission, compile counts, tool absorption.

Four headline numbers on the real JAX engine (reduced model, CPU-friendly):

  * jit compile count across distinct prompt lengths — the legacy full-sequence
    ``_admit`` compiles once per (1, S) shape (O(n) in distinct lengths); the
    chunked plane reuses ONE fixed-shape (1, C) kernel (O(1)),
  * admission latency at a previously-unseen prompt length — where the legacy
    path pays a fresh XLA compile and the chunked path pays ceil(S/C) dispatches,
  * tool-absorption throughput — chunked suffix prefill into one lane vs the old
    per-token masked full-pool ``extend`` (O(L) whole-pool dispatches),
  * prefix-hit admission speedup on a GRPO-group workload — siblings implant the
    shared prompt from the radix cache and prefill only the suffix.

Emits ``name,us_per_call,derived`` CSV rows and writes ``BENCH_prefill.json``.
``--smoke`` (CI) runs a reduced sweep and *asserts* the compile-count bound.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from benchmarks.common import emit, sanitizer_summary, timed, write_json_atomic
from repro.configs import get_config
from repro.engine import worker as W
from repro.engine.sampler import SamplerConfig
from repro.models import model as M


def _block(w):
    jax.block_until_ready(w.pool["pos"])


def _admit_once(w, sid, prompt):
    t0 = time.perf_counter()
    w.prefill(sid, prompt)
    _block(w)
    return time.perf_counter() - t0


def run(fast: bool = True, smoke: bool = False,
        json_path: str = "BENCH_prefill.json") -> dict:
    n_lengths, tool_len, group = (6, 32, 4) if (fast or smoke) else (12, 96, 8)
    chunk = 16
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    greedy = SamplerConfig(temperature=0.0)
    rng = np.random.default_rng(0)
    # distinct lengths straddling chunk boundaries
    lengths = sorted({chunk * (i // 2) + (3 if i % 2 else chunk - 1) + 2
                      for i in range(n_lengths)})
    prompts = [[5 + int(t) for t in rng.integers(0, 100, s)] for s in lengths]

    def make(use_chunked, reuse, slots):
        # paged=False: this bench prices the *dense* prefill planes (the compile
        # counters watch the dense _admit/_prefill_chunk jit caches); the paged
        # data plane has its own bench (bench_paging.py)
        return W.RolloutWorker(cfg, params, capacity=256, max_slots=slots,
                               sampler=greedy, chunk_size=chunk, paged=False,
                               use_chunked=use_chunked, prefix_reuse=reuse)

    # ---- compile count + new-length admission latency ------------------------
    results: dict = {"chunk_size": chunk, "prompt_lengths": lengths}
    legacy = make(False, False, len(prompts) + 1)
    chunked = make(True, False, len(prompts) + 1)
    c0_legacy = W._admit._cache_size()
    c0_chunk = W._prefill_chunk._cache_size()
    t_legacy = [_admit_once(legacy, i, p) for i, p in enumerate(prompts)]
    t_chunk = [_admit_once(chunked, i, p) for i, p in enumerate(prompts)]
    legacy_compiles = W._admit._cache_size() - c0_legacy
    chunk_compiles = W._prefill_chunk._cache_size() - c0_chunk
    results["compiles"] = {
        "distinct_lengths": len(prompts),
        "legacy_admit_compiles": legacy_compiles,
        "chunked_prefill_compiles": chunk_compiles,
    }
    # skip each path's first admission (shared warmup of _implant etc.)
    results["admission_new_length"] = {
        "legacy_mean_s": float(np.mean(t_legacy[1:])),
        "chunked_mean_s": float(np.mean(t_chunk[1:])),
        "speedup": float(np.mean(t_legacy[1:]) / np.mean(t_chunk[1:])),
    }

    # ---- tool absorption: chunked extend vs per-token extend -----------------
    wa = make(True, False, 2)
    wa.prefill(0, prompts[0])
    tool = [7 + int(t) for t in rng.integers(0, 100, tool_len)]
    wa.extend(0, tool)                                   # compile warmup
    _block(wa)
    _, dt_chunked = timed(lambda: (wa.extend(0, tool), _block(wa)), repeat=3)
    wb = make(True, False, 2)
    wb.prefill(0, prompts[0])
    wb.extend_per_token(0, tool)                         # compile warmup
    _block(wb)
    _, dt_legacy = timed(lambda: (wb.extend_per_token(0, tool), _block(wb)),
                         repeat=3)
    results["tool_absorption"] = {
        "tokens": tool_len,
        "chunked_tok_s": tool_len / dt_chunked,
        "per_token_tok_s": tool_len / dt_legacy,
        "speedup": dt_legacy / dt_chunked,
    }

    # ---- GRPO group: prefix-hit admission ------------------------------------
    wg = make(True, True, group + 1)
    prompt = [5 + int(t) for t in rng.integers(0, 100, 3 * chunk)]
    cold = _admit_once(wg, 100, prompt)
    warm = [_admit_once(wg, 101 + i, prompt) for i in range(group - 1)]
    results["grpo_group"] = {
        "group_size": group,
        "prompt_tokens": len(prompt),
        "cold_admit_s": cold,
        "warm_admit_mean_s": float(np.mean(warm)),
        "speedup": cold / float(np.mean(warm)),
        "reused_tokens": wg.reused_tokens,
    }

    if smoke:
        # this bench drives workers directly (no orchestrator of its own), so
        # give CI a small sanitized control-plane pass too: every smoke lane
        # in the suite exercises the TraceSanitizer
        from repro.engine.runtime import (RuntimeConfig, build_workbench,
                                          run_on_sim)
        batch, predictor = build_workbench(n_prompts=3, group_size=group,
                                           seed=0)
        res = run_on_sim(batch, predictor, n_workers=2,
                         config=RuntimeConfig(scheduler="pps", migration=True,
                                              max_active=2, quantum=8, seed=0,
                                              sanitize=True))
        results["sanitizer"] = sanitizer_summary([res.sanitizer])

    write_json_atomic(json_path, results)

    emit([
        ("prefill_compiles_legacy", 0.0,
         f"{legacy_compiles} compiles / {len(prompts)} lengths"),
        ("prefill_compiles_chunked", 0.0,
         f"{chunk_compiles} compiles / {len(prompts)} lengths"),
        ("prefill_admit_new_length_legacy",
         results["admission_new_length"]["legacy_mean_s"] * 1e6, "s/admit"),
        ("prefill_admit_new_length_chunked",
         results["admission_new_length"]["chunked_mean_s"] * 1e6,
         f"{results['admission_new_length']['speedup']:.2f}x"),
        ("prefill_tool_absorb_chunked", dt_chunked * 1e6,
         f"{results['tool_absorption']['chunked_tok_s']:.1f} tok/s"),
        ("prefill_tool_absorb_per_token", dt_legacy * 1e6,
         f"{results['tool_absorption']['per_token_tok_s']:.1f} tok/s"),
        ("prefill_tool_absorb_speedup", 0.0,
         f"{results['tool_absorption']['speedup']:.2f}x"),
        ("prefill_grpo_admit_speedup", 0.0,
         f"{results['grpo_group']['speedup']:.2f}x "
         f"({wg.reused_tokens} tokens implanted)"),
    ])

    if smoke:
        # the enforced invariant: chunked admission compiles are bounded by the
        # chunk/bucket count, NOT by the number of distinct prompt lengths
        assert chunk_compiles <= 2, \
            f"chunked prefill compiled {chunk_compiles}x for {len(prompts)} lengths"
        assert legacy_compiles >= len(prompts), \
            "legacy baseline unexpectedly stopped compiling per length"
        assert results["grpo_group"]["reused_tokens"] >= \
            (group - 1) * len(prompt), "GRPO siblings did not implant the prompt"
        san = results["sanitizer"]
        assert san["runs"] == 1 and san["violations"] == 0, \
            f"trace sanitizer reported violations: {san}"
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + assert the O(1) compile bound (CI)")
    ap.add_argument("--json", default="BENCH_prefill.json")
    args = ap.parse_args(argv)
    emit([], header=True)
    run(fast=not args.full, smoke=args.smoke, json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
