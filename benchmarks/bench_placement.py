"""Figure 15: trajectory-aware placement — Heddle (presorted DP + migration) vs
least-load and cache-aware routing.  Paper claim: 1.2x-1.5x throughput.

Placement is isolated: PPS scheduling and homogeneous MP for all variants.
"""

from __future__ import annotations

from benchmarks.common import Workbench, emit


def run(fast: bool = True):
    rows = []
    n_prompts, workers = (150, 24) if fast else (400, 64)
    wb = Workbench.make("coding", n_prompts=n_prompts, group_size=16)
    results = {}
    for placement in ("heddle", "least_load", "cache_aware"):
        r = wb.run(scheduler="pps", placement=placement,
                   degrees=(1,) * workers, gpu_budget=workers, max_batch=100, seed=0)
        results[placement] = r
        rows.append((f"fig15/{placement}", r.makespan * 1e6,
                     f"{r.throughput:.0f}tok/s mig={r.migrations}"))
    for base in ("least_load", "cache_aware"):
        sp = results[base].makespan / results["heddle"].makespan
        rows.append((f"fig15/speedup_vs_{base}", 0.0, f"{sp:.2f}x"))
    # migration ablation: Heddle placement without runtime migration
    r = wb.run(scheduler="pps", placement="heddle", migration=False,
               degrees=(1,) * workers, gpu_budget=workers, max_batch=100, seed=0)
    rows.append(("fig15/heddle_no_migration", r.makespan * 1e6,
                 f"{r.throughput:.0f}tok/s"))
    emit(rows)
    return rows


if __name__ == "__main__":
    emit([], header=True)
    run(fast=False)
