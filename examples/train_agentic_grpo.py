"""End-to-end driver: agentic GRPO training with Heddle-orchestrated rollout.

A real (reduced) llama-family model learns a tool-use task on CPU: the agent must call
a calculator tool (emitting TOOL_CALL) and then produce the answer token the tool
returned.  Every training step runs the paper's full cycle:

  rollout  — trajectories generated on real RolloutWorkers under the unified
             orchestrator (prefill, batched decode, tool interrupts absorbed via
             incremental cache extension; presorted-DP placement, PPS queues with
             preemptive execution, progressive prediction refresh, tool-interval
             migration — the full control plane, not a side-car loop);
  inference — old-policy logprobs (fused chunked cross-entropy);
  training  — GRPO update (group-relative advantages, clipped ratio).

Run:  PYTHONPATH=src python examples/train_agentic_grpo.py [--iters 30]
(Use --iters 300 for a longer run; reward climbs as the policy discovers the tool.)
"""

import argparse
import time

from repro.configs import get_config
from repro.rl.loop import HeddleTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--group-size", type=int, default=8)
    ap.add_argument("--tasks-per-iter", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(n_periods=2)
    print(f"model: {cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab})")
    trainer = HeddleTrainer(cfg, TrainerConfig(
        group_size=args.group_size, n_workers=2, max_steps_per_traj=3,
        gen_tokens_per_step=8, lr=8e-4, seed=0))

    window = []
    t0 = time.time()
    for it in range(args.iters):
        import repro.rl.data as D
        tasks = D.sample_tasks(args.tasks_per_iter, seed=1_000 + it)
        records = trainer.rollout(tasks)
        metrics = trainer.update(records)
        window.append(metrics["mean_reward"])
        if (it + 1) % 5 == 0 or it == 0:
            avg = sum(window[-10:]) / len(window[-10:])
            tool_rate = sum(1 for r in records
                            if any(t == D.TOOL_CALL for t in r.tokens[r.prompt_len:])) \
                / len(records)
            ro = trainer.last_rollout
            print(f"iter {it+1:4d}  reward(ma10) {avg:5.3f}  "
                  f"tool-call rate {tool_rate:4.2f}  loss {metrics['loss']:+.4f}  "
                  f"sched[preempt {ro.preemptions} migr {ro.migrations} "
                  f"qdelay {ro.queue_delay_mean:.3f}s]  ({time.time()-t0:5.1f}s)")
    print("done.")


if __name__ == "__main__":
    main()
