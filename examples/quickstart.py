"""Quickstart: Heddle's three orchestration decisions in one minute.

Generates an agentic workload with the paper's long-tail statistics, trains the
progressive predictor on historical rollouts, then shows the control plane deciding
  HOW   — Algorithm 2 simulated annealing picks heterogeneous MP degrees (64 chips),
  WHERE — the presorted DP partitions trajectories across workers,
  WHEN  — progressive-priority scheduling orders (and preempts) execution,
compares end-to-end rollout throughput against the Verl/Slime baselines in the
cluster simulator, and closes with the real data plane: a few requests served by the
slot-pool continuous-batching engine on an actual (reduced) JAX model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import copy

import numpy as np

from repro.core.placement import InterferenceModel, presorted_dp
from repro.core.predictor import ProgressivePredictor
from repro.core.resource_manager import WorkerLatencyModel, sort_initialized_sa
from repro.engine.simulator import simulate
from repro.engine.workload import WorkloadConfig, generate, replay_finished


def main():
    # 1. historical rollouts -> progressive predictor (paper §4.1)
    history = replay_finished(generate(WorkloadConfig(
        task="coding", n_prompts=48, group_size=8, seed=1)))
    predictor = ProgressivePredictor().fit_trajectories(history)
    print(f"predictor trained on {len(history)} historical trajectories "
          f"(longest: {int(predictor.hist_max_tokens)} tokens)")

    # 2. a fresh rollout batch (16 GRPO samples per prompt)
    batch = generate(WorkloadConfig(task="coding", n_prompts=48, group_size=16, seed=2))
    lengths = np.array([t.true_total_tokens for t in batch])
    print(f"batch: {len(batch)} trajectories, median {int(np.median(lengths))} tokens, "
          f"max {int(lengths.max())} (long-tail ratio {lengths.max()/np.median(lengths):.1f}x)")

    # 3. HOW — Algorithm 2: heterogeneous model-parallel degrees
    interference = InterferenceModel.analytic(0.01)
    alloc = sort_initialized_sa(lengths, budget=64, interference=interference,
                                latency=WorkerLatencyModel(t1=0.02), seed=0)
    print(f"resource manager: degrees={alloc.degrees} "
          f"(predicted makespan {alloc.makespan:.0f}s, {alloc.evaluations} SA evals)")

    # 4. WHERE — presorted dynamic programming (Lemma 5.1 + Formula 3)
    res = presorted_dp(lengths, len(alloc.degrees), interference,
                       base_token_time=WorkerLatencyModel(t1=0.02).token_times(alloc.degrees))
    sizes = [len(g) for g in res.groups]
    print(f"placement DP: group sizes {sizes} (longest trajectories get the "
          f"high-MP, low-interference workers)")

    # 5. WHEN + end-to-end: the full system vs the paper's baselines
    print("\nrollout simulation (64 chips):")
    for name, kw in [
        ("heddle", dict(scheduler="pps", placement="heddle")),
        ("verl  (cache-aware, RR)", dict(scheduler="rr", placement="cache_aware",
                                         degrees=(1,) * 64)),
        ("slime (least-load, RR)", dict(scheduler="rr", placement="least_load",
                                        degrees=(1,) * 64)),
    ]:
        r = simulate(copy.deepcopy(batch), predictor, gpu_budget=64, max_batch=100,
                     seed=0, **kw)
        print(f"  {name:26s} makespan {r.makespan:7.1f}s  "
              f"throughput {r.throughput:8.0f} tok/s  "
              f"(migrations {r.migrations}, preemptions {r.preemptions})")

    # 6. the real data plane: slot-pool continuous batching on a reduced JAX model —
    #    trajectories join and leave one resident decode batch, a tool result is
    #    absorbed in place, and a preemption is just a mask flip
    import jax
    from repro.configs import get_config
    from repro.engine.sampler import SamplerConfig
    from repro.engine.worker import RolloutWorker
    from repro.models import model as M

    cfg = get_config("qwen3_1_7b").reduced(n_periods=1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    w = RolloutWorker(cfg, params, capacity=32, max_slots=4,
                      sampler=SamplerConfig(temperature=0.8))
    for rid in range(3):
        w.prefill(rid, [5 + rid, 7, 9, 11])           # each prefill lands in a lane
    out = w.decode([0, 1, 2], 8)                       # one fused masked decode loop
    w.extend(0, [201, 202])                            # tool output, no prefix recompute
    w.preempt(1)                                       # mask flip, KV stays resident
    more = w.decode([0, 2], 4)                         # lane 1 rides along frozen
    n = sum(map(len, out.values())) + sum(map(len, more.values()))
    print(f"\nreal engine: {n} tokens across {len(w.store)} resident lanes "
          f"(pool {w.max_slots} slots, {w.kv_bytes(0) / 2**20:.1f} MiB/lane)")


if __name__ == "__main__":
    main()
