"""Serving demo: batched agentic requests on the real data plane, with Heddle's
mechanisms visible — prefix-cache prefill, batched continuous decode, a tool interval
absorbed without prefix recompute, preemption persistence and live KV migration
between two workers.

Run:  PYTHONPATH=src python examples/serve_rollout.py
"""

import time

import jax

from repro.configs import get_config
from repro.engine.sampler import SamplerConfig
from repro.engine.worker import RolloutWorker
from repro.models import model as M


def main():
    cfg = get_config("qwen3_1_7b").reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    w0 = RolloutWorker(cfg, params, capacity=128, max_slots=8, worker_id=0,
                       sampler=SamplerConfig(temperature=0.8, top_p=0.9))
    w1 = RolloutWorker(cfg, params, capacity=128, max_slots=8, worker_id=1,
                       sampler=SamplerConfig(temperature=0.8, top_p=0.9))
    print(f"2 workers serving {cfg.name} (reduced), "
          f"slot pools of {w0.max_slots} lanes x 128 KV slots")

    # batched request admission (prefill)
    requests = {i: [5 + i, 7, 9, 11 + i] for i in range(6)}
    t0 = time.time()
    for rid, prompt in requests.items():
        w0.prefill(rid, prompt)
    print(f"prefilled {len(requests)} requests on w0 in {time.time()-t0:.2f}s "
          f"(prefix-cache hits: {w0.prefix_index.hits})")

    # batched continuous decode (per-slot positions differ)
    t0 = time.time()
    out = w0.decode(list(requests), 12)
    n = sum(len(v) for v in out.values())
    print(f"decoded {n} tokens across {len(requests)} slots in {time.time()-t0:.2f}s")

    # a tool call returns for request 0: absorb output without prefix recompute
    w0.extend(0, [201, 202, 203])
    print(f"request 0: tool output absorbed (context now {len(w0.store[0].tokens)} "
          f"tokens, kv {w0.kv_bytes(0)/2**20:.1f} MiB)")

    # preemption: a mask flip — request 5 leaves the decode batch, its lane stays put
    w0.preempt(5)
    print("request 5 preempted (mask flip, KV lane persisted) — resumes without recompute")

    # opportunistic migration: request 0 moves to w1 during its tool interval
    t0 = time.time()
    pkg = w0.migrate_out(0)
    w1.migrate_in(pkg)
    print(f"request 0 migrated w0 -> w1 in {time.time()-t0:.3f}s; continuing there:")
    more = w1.decode([0], 6)
    print(f"  w1 decoded {more[0]}")
    resumed = w0.decode([5], 6)
    print(f"  w0 resumed preempted request 5: {resumed[5]}")


if __name__ == "__main__":
    main()
