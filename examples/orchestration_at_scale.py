"""Paper-scale orchestration study: 64 chips, 6400 trajectories (the §7 setup).

Reproduces Figure 12 (system comparison) and Figure 16(b) (active-trajectory
timeline) in the calibrated cluster simulator, printing an ASCII timeline.

Run:  PYTHONPATH=src python examples/orchestration_at_scale.py [--small]
"""

import argparse
import copy

from repro.core.predictor import ProgressivePredictor
from repro.engine.simulator import simulate
from repro.engine.workload import WorkloadConfig, generate, replay_finished


def ascii_timeline(timeline, width=60, label=""):
    if not timeline:
        return
    tmax = timeline[-1][0]
    nmax = max(n for _, n in timeline) or 1
    buckets = [0] * width
    for t, n in timeline:
        buckets[min(int(t / tmax * (width - 1)), width - 1)] = n
    bars = "".join(" .:-=+*#%@"[min(int(b / nmax * 9), 9)] for b in buckets)
    print(f"  {label:10s} |{bars}| {tmax:6.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true", help="quarter-scale (fast)")
    args = ap.parse_args()
    n_prompts = 32 if args.small else 400

    history = replay_finished(generate(WorkloadConfig(
        task="coding", n_prompts=64, group_size=8, seed=1)))
    predictor = ProgressivePredictor().fit_trajectories(history)
    batch = generate(WorkloadConfig(task="coding", n_prompts=n_prompts,
                                    group_size=16, seed=2))
    print(f"{len(batch)} trajectories on 64 chips "
          f"({sum(t.true_total_tokens for t in batch)/1e6:.1f}M tokens to generate)\n")

    systems = {
        "heddle": dict(scheduler="pps", placement="heddle"),
        "verl": dict(scheduler="rr", placement="cache_aware", degrees=(1,) * 64),
        "verl*": dict(scheduler="rr", placement="hybrid", degrees=(1,) * 64),
        "slime": dict(scheduler="rr", placement="least_load", degrees=(1,) * 64),
    }
    results = {}
    for name, kw in systems.items():
        r = simulate(copy.deepcopy(batch), predictor, gpu_budget=64, max_batch=100,
                     seed=0, **kw)
        results[name] = r
        print(f"{name:8s} makespan {r.makespan:8.1f}s   throughput {r.throughput:9.0f} tok/s"
              f"   (x{results['heddle'].makespan and r.makespan/results['heddle'].makespan:.2f} vs heddle)")

    print("\nactive trajectories over time (Fig 16b):")
    for name in ("heddle", "verl", "slime"):
        ascii_timeline(results[name].timeline, label=name)


if __name__ == "__main__":
    main()
