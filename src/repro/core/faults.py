"""Deterministic fault injection for the unified control plane (chaos harness).

Production agentic-RL rollout runs as a long-lived service on preemptible
capacity with flaky external tools; the paper's long-tail premise makes losing
a resident trajectory to a worker death disproportionately expensive.  This
module is the *schedule* side of the failure-realism layer: a seeded,
virtual-time :class:`FaultPlan` that both execution backends (the analytic
``SimBackend`` and the real ``EngineBackend``) consume through the one
orchestrator, so a chaos run makes identical fault decisions regardless of
substrate.

Two fault families, deliberately distinct from the *plan-driven* "tool
reported failure" signal (``ToolProfile.fail_rate``, which models the task —
failing tests, empty search results — and feeds the progressive predictor's
rectification features):

* **worker faults** — death at virtual time ``t`` (every resident lane is
  lost; trajectories re-admit elsewhere from their last tool-boundary
  checkpoint) and revival (replacement capacity joins with a cold cache);
* **tool system faults** — per-``(traj, step, attempt)``-seeded timeouts and
  transient errors, absorbed by :func:`resolve_tool_call`'s capped
  exponential-backoff retry discipline.

The retry cap bounds *injected delay*, never outcome: the final attempt always
succeeds, so chaos perturbs timing and placement but cannot flip a step's
task-level result — injected-fault telemetry stays orthogonal to the
predictor's features and every trajectory still reaches FINISHED.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

# domain-separation constant for the tool-fault rng stream: keeps fault rolls
# independent of the workload/tool rngs that also seed on (seed, traj, step)
_TOOL_FAULT_STREAM = 7919
# separate stream for backoff jitter: the jitter draw must not correlate with
# the fault roll that triggered the retry
_BACKOFF_STREAM = 104729


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic full jitter.

    ``max_attempts`` bounds total tries (so injected delay is bounded);
    attempt ``k``'s failure computes a ceiling ``min(base * factor**k, cap)``
    and — when a seed context is supplied — waits a uniform draw in
    ``[0, ceiling]`` seeded per ``(traj, step, attempt)``.  Full jitter
    decorrelates retries across trajectories (no synchronized retry storms
    when a burst of calls faults together) while staying bit-reproducible on
    both backends.  Without a seed the wait is the un-jittered ceiling.
    """

    max_attempts: int = 4
    backoff_base: float = 0.05
    backoff_factor: float = 2.0
    backoff_cap: float = 1.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("RetryPolicy needs at least one attempt")

    def backoff(self, attempt: int, *, seed: Optional[int] = None,
                traj_id: int = 0, step: int = 0) -> float:
        """Seconds to wait after failed attempt ``attempt`` (0-indexed)."""
        ceiling = min(self.backoff_base * self.backoff_factor ** attempt,
                      self.backoff_cap)
        if seed is None:
            return ceiling
        rng = np.random.default_rng(
            (seed, _BACKOFF_STREAM, traj_id, step, attempt))
        return ceiling * float(rng.random())


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, deterministic chaos schedule over virtual time.

    ``deaths`` / ``revivals`` are ``(virtual_time, worker_id)`` pairs injected
    straight into the orchestrator's versioned event heap.  Tool faults are
    rolled per ``(traj_id, step, attempt)`` from ``seed`` — never from call
    order — so sim and engine observe identical outcomes and a retry sees a
    fresh (but reproducible) roll.
    """

    seed: int = 0
    deaths: tuple[tuple[float, int], ...] = ()
    revivals: tuple[tuple[float, int], ...] = ()
    tool_timeout_rate: float = 0.0   # P(attempt times out)
    tool_error_rate: float = 0.0     # P(attempt hits a transient system error)
    tool_timeout_s: float = 1.0      # wall the caller burns before declaring timeout

    def __post_init__(self):
        if self.tool_timeout_rate + self.tool_error_rate >= 1.0:
            raise ValueError(
                "tool_timeout_rate + tool_error_rate must be < 1 (an attempt "
                "must be able to succeed, or retries never converge)")

    @property
    def injects_tool_faults(self) -> bool:
        return self.tool_timeout_rate > 0.0 or self.tool_error_rate > 0.0

    def tool_fault(self, traj_id: int, step: int, attempt: int) -> Optional[str]:
        """Roll attempt ``attempt`` of (traj, step): None | 'timeout' | 'error'."""
        if not self.injects_tool_faults:
            return None
        rng = np.random.default_rng(
            (self.seed, _TOOL_FAULT_STREAM, traj_id, step, attempt))
        u = float(rng.random())
        if u < self.tool_timeout_rate:
            return "timeout"
        if u < self.tool_timeout_rate + self.tool_error_rate:
            return "error"
        return None

    @classmethod
    def chaos(cls, seed: int, n_workers: int, horizon: float, *,
              tool_timeout_rate: float = 0.10, tool_error_rate: float = 0.05,
              tool_timeout_s: float = 1.0, kill_frac: float = 0.4,
              revive_frac: float = 0.75) -> "FaultPlan":
        """A canonical chaos schedule: one mid-run death + later revival.

        ``horizon`` is the caller's makespan estimate (e.g. the no-fault run's
        makespan, or a work/throughput bound); the victim dies at
        ``kill_frac * horizon`` and replacement capacity arrives at
        ``revive_frac * horizon``.  With a single worker no death is scheduled
        (there would be no survivor to recover onto).
        """
        rng = np.random.default_rng((seed, _TOOL_FAULT_STREAM, 1))
        deaths: tuple[tuple[float, int], ...] = ()
        revivals: tuple[tuple[float, int], ...] = ()
        if n_workers > 1 and horizon > 0:
            victim = int(rng.integers(n_workers))
            deaths = ((kill_frac * horizon, victim),)
            revivals = ((revive_frac * horizon, victim),)
        return cls(seed=seed, deaths=deaths, revivals=revivals,
                   tool_timeout_rate=tool_timeout_rate,
                   tool_error_rate=tool_error_rate,
                   tool_timeout_s=tool_timeout_s)


@dataclass(frozen=True)
class ToolCallTrace:
    """What one tool call cost after injection + retries settled."""

    latency: float       # total seconds incl. timeouts, errors, and backoff
    attempts: int        # >= 1; 1 means no injected fault
    timeouts: int
    errors: int

    @property
    def injected_faults(self) -> int:
        return self.timeouts + self.errors

    @property
    def retries(self) -> int:
        return self.attempts - 1


def resolve_tool_call(faults: Optional[FaultPlan], retry: RetryPolicy,
                      traj_id: int, step: int,
                      base_latency: float) -> ToolCallTrace:
    """Apply the fault plan's injection + the retry discipline to one tool call.

    Each faulted attempt burns its cost (``tool_timeout_s`` for a timeout, the
    call's own ``base_latency`` for a transient error — the call ran, then the
    result was lost) plus the attempt's backoff.  The last allowed attempt
    always succeeds (see module docstring), so the returned latency is the
    *effective* tool interval the orchestrator masks migration behind.
    """
    if faults is None or not faults.injects_tool_faults:
        return ToolCallTrace(base_latency, 1, 0, 0)
    total = 0.0
    timeouts = errors = 0
    for attempt in range(retry.max_attempts - 1):
        kind = faults.tool_fault(traj_id, step, attempt)
        if kind is None:
            break
        if kind == "timeout":
            total += faults.tool_timeout_s
            timeouts += 1
        else:
            total += base_latency
            errors += 1
        total += retry.backoff(attempt, seed=faults.seed,
                               traj_id=traj_id, step=step)
    total += base_latency
    return ToolCallTrace(total, timeouts + errors + 1, timeouts, errors)
