"""Trajectory migration (paper §5.3).

Two pieces:

1. **Rank-based re-placement** — when the progressive predictor updates a trajectory's
   length, we avoid re-running the full DP: the original partition sizes {s_1..s_m} are
   scaled by the fraction of still-active trajectories (s_i * n_active / n), and the
   trajectory's *new rank* in the sorted order is mapped through the scaled cumulative
   capacities to a target worker.  Migrate iff target != current host.

2. **Transmission scheduler** — migrations transfer KV caches between workers; to prevent
   endpoint contention the router builds batches of strictly parallel, *endpoint-exclusive*
   requests, greedily picking the longest trajectory first and skipping any request whose
   source or destination worker is already busy (selected in this epoch or still running).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np


@dataclass
class MigrationRequest:
    traj_id: int
    src: int
    dst: int
    length: float            # predicted trajectory length (priority key)
    bytes: float = 0.0       # KV cache size to move
    submitted: float = 0.0


class ScaledCapacityRouter:
    """Maps (new rank, active count) -> worker via proportionally scaled group sizes."""

    def __init__(self, group_sizes: Sequence[int]):
        self.group_sizes = np.asarray(group_sizes, dtype=np.float64)
        self.n_total = float(self.group_sizes.sum())

    def worker_for_rank(self, rank: int, n_active: int) -> int:
        """Worker index whose scaled capacity interval contains ``rank`` (0-based).

        Effective capacity of group i is s_i * n_active / n_total (paper §5.3); ranks are
        assigned to workers in order of the (descending-length-sorted) original partition.
        """
        if self.n_total == 0:
            return 0
        scale = n_active / self.n_total
        cum = 0.0
        for i, s in enumerate(self.group_sizes):
            cum += s * scale
            if rank < cum - 1e-9 or cum >= n_active - 1e-9:
                return i
        return len(self.group_sizes) - 1

    def target_worker(self, predicted_lengths: dict[int, float], traj_id: int) -> int:
        """Rank ``traj_id`` among active trajectories by descending predicted length."""
        items = sorted(predicted_lengths.items(), key=lambda kv: (-kv[1], kv[0]))
        rank = next(i for i, (tid, _) in enumerate(items) if tid == traj_id)
        return self.worker_for_rank(rank, len(items))


@dataclass
class TransmissionScheduler:
    """Endpoint-exclusive, longest-first migration batching (paper §5.3)."""

    pending: list[MigrationRequest] = field(default_factory=list)
    running: list[MigrationRequest] = field(default_factory=list)

    def submit(self, req: MigrationRequest) -> None:
        if req.src == req.dst:
            return
        # replace any stale pending request for the same trajectory: the newest
        # prediction owns the target (prevents outdated requests firing later and
        # ping-ponging the trajectory between old targets)
        self.pending = [r for r in self.pending if r.traj_id != req.traj_id]
        self.pending.append(req)

    def cancel(self, traj_id: int) -> None:
        self.pending = [r for r in self.pending if r.traj_id != traj_id]

    def next_batch(self) -> list[MigrationRequest]:
        """One scheduling epoch: greedily select non-conflicting requests, longest first.

        A request conflicts if its src or dst worker appears as an endpoint of any
        already-selected or still-running request (strict endpoint exclusivity).
        """
        busy: set[int] = set()
        for r in self.running:
            busy.add(r.src)
            busy.add(r.dst)
        batch: list[MigrationRequest] = []
        remaining: list[MigrationRequest] = []
        for req in sorted(self.pending, key=lambda r: -r.length):
            if req.src in busy or req.dst in busy:
                remaining.append(req)
            else:
                batch.append(req)
                busy.add(req.src)
                busy.add(req.dst)
        self.pending = remaining
        self.running.extend(batch)
        return batch

    def complete(self, traj_id: int) -> None:
        self.running = [r for r in self.running if r.traj_id != traj_id]

    def __len__(self) -> int:
        return len(self.pending)


def migration_time(kv_bytes: float, link_bandwidth: float, base_latency: float = 1e-3) -> float:
    """Transfer time model for a KV-cache migration over one interconnect link."""
    return base_latency + kv_bytes / link_bandwidth


def kv_cache_bytes(context_tokens: int, n_layers: int, n_kv_heads: int, head_dim: int,
                   bytes_per_el: int = 2) -> float:
    """KV cache footprint of a trajectory: 2 (K and V) * L * kv * hd * ctx * dtype."""
    return 2.0 * n_layers * n_kv_heads * head_dim * context_tokens * bytes_per_el
