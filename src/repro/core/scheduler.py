"""Trajectory-level scheduling (paper §4.2, Algorithm 1).

Progressive Priority Scheduling (PPS) is an adaptive approximation of
longest-processing-time-first (LPT): the pending queue is ordered by *predicted remaining
length* (refreshed by the progressive predictor every time a trajectory returns from a
tool call), and preemptive execution lets a pending request that outranks the
lowest-priority active request evict it (persisting its KV cache).

Baseline disciplines from §7.2 (FCFS, round-robin, Autellix-style shortest-job-first)
share the same interface so the simulator and the real engine can swap them.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional, Protocol

from repro.core.trajectory import Trajectory, TrajectoryPhase


class Scheduler(Protocol):
    """Per-worker scheduling discipline over pending LLM generation requests."""

    def submit(self, traj: Trajectory, now: float) -> None: ...
    def pop(self, now: float) -> Optional[Trajectory]: ...
    def peek_priority(self) -> Optional[float]: ...
    def __len__(self) -> int: ...
    # Preemption hook: return the active trajectory to evict for `incoming`, or None.
    def preempt_victim(self, active: list[Trajectory]) -> Optional[Trajectory]: ...


@dataclass(order=True)
class _Entry:
    sort_key: tuple
    traj: Trajectory = field(compare=False)
    dead: bool = field(default=False, compare=False)


class _HeapScheduler:
    """Heap-based scheduler with lazy deletion; subclasses define the sort key."""

    preemptive = False

    def __init__(self) -> None:
        self._heap: list[_Entry] = []
        self._entries: dict[int, _Entry] = {}
        self._tie = itertools.count()

    def _key(self, traj: Trajectory, now: float) -> tuple:
        raise NotImplementedError

    def submit(self, traj: Trajectory, now: float) -> None:
        old = self._entries.get(traj.traj_id)
        if old is not None:
            old.dead = True
        entry = _Entry((*self._key(traj, now), next(self._tie)), traj)
        self._entries[traj.traj_id] = entry
        heapq.heappush(self._heap, entry)
        traj.phase = TrajectoryPhase.PENDING

    def pop(self, now: float) -> Optional[Trajectory]:
        while self._heap:
            entry = heapq.heappop(self._heap)
            if entry.dead:
                continue
            del self._entries[entry.traj.traj_id]
            return entry.traj
        return None

    def remove(self, traj: Trajectory) -> None:
        entry = self._entries.pop(traj.traj_id, None)
        if entry is not None:
            entry.dead = True

    def peek_priority(self) -> Optional[float]:
        while self._heap and self._heap[0].dead:
            heapq.heappop(self._heap)
        if not self._heap:
            return None
        return self._peek_value(self._heap[0].traj)

    def _peek_value(self, traj: Trajectory) -> float:
        return 0.0

    def preempt_victim(self, active: list[Trajectory]) -> Optional[Trajectory]:
        return None

    def queued(self) -> list[Trajectory]:
        """Live queued trajectories (insertion order) — degradation-ladder input."""
        return [e.traj for e in self._entries.values()]

    def __len__(self) -> int:
        return len(self._entries)


class PPSScheduler(_HeapScheduler):
    """Algorithm 1: progressive priority scheduling with preemptive execution.

    priority := predicted TOTAL trajectory length (generated + predicted remaining);
    longer => higher priority (LPT). The heap is a min-heap, so we negate.
    """

    preemptive = True

    def __init__(self, preemption_margin: float = 1.0,
                 preemption_floor: float = 1.0) -> None:
        super().__init__()
        # Hysteresis: only preempt when the pending request's priority exceeds the
        # victim's by this multiplicative margin (prevents eviction thrash).  The
        # margin alone is vacuous when the victim's priority is 0 (cold predictor:
        # anything > 0 * margin), so an additive floor guarantees a minimum
        # absolute priority gap before any eviction.
        self.preemption_margin = preemption_margin
        self.preemption_floor = preemption_floor

    def submit(self, traj: Trajectory, now: float) -> None:  # Alg.1 lines 1-4
        # Serving blend: tenant weight scales the LPT term and an EDF urgency
        # boost (computed by the controller at submit time) pulls deadline-
        # critical work forward.  Closed-loop defaults (weight 1, boost 0)
        # reduce to the paper's pure predicted-total priority.
        traj.priority = (traj.priority_weight * traj.predicted_total
                         + traj.slo_boost)
        super().submit(traj, now)

    def _key(self, traj: Trajectory, now: float) -> tuple:
        return (-traj.priority,)

    def _peek_value(self, traj: Trajectory) -> float:
        return traj.priority

    def preempt_victim(self, active: list[Trajectory]) -> Optional[Trajectory]:
        """Alg.1 lines 5-10: evict the lowest-priority active request if outranked."""
        top = self.peek_priority()
        if top is None or not active:
            return None
        victim = min(active, key=lambda t: t.priority)
        if top > victim.priority * self.preemption_margin + self.preemption_floor:
            return victim
        return None


class FCFSScheduler(_HeapScheduler):
    """First-come-first-served over *trajectory* arrival."""

    def _key(self, traj: Trajectory, now: float) -> tuple:
        return (traj.submit_time,)


class RoundRobinScheduler(_HeapScheduler):
    """Step-centric round-robin: every tool return re-queues at the tail (the de facto
    policy of existing agentic RL frameworks, §2.3)."""

    def _key(self, traj: Trajectory, now: float) -> tuple:
        return (now,)


class SJFScheduler(_HeapScheduler):
    """Autellix-style shortest-job-first (minimizes mean latency, not makespan)."""

    def submit(self, traj: Trajectory, now: float) -> None:
        traj.priority = traj.predicted_total
        super().submit(traj, now)

    def _key(self, traj: Trajectory, now: float) -> tuple:
        return (traj.predicted_total,)


SCHEDULERS: dict[str, Callable[[], _HeapScheduler]] = {
    "pps": PPSScheduler,
    "fcfs": FCFSScheduler,
    "rr": RoundRobinScheduler,
    "sjf": SJFScheduler,
}


def make_scheduler(name: str) -> _HeapScheduler:
    try:
        return SCHEDULERS[name]()
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}; options: {sorted(SCHEDULERS)}")
