"""Progressive trajectory prediction (paper §4.1).

The paper fine-tunes a lightweight regression model (Qwen-0.6B) on
``(context, remaining_length)`` tuples harvested from historical trajectories, and invokes
it after every agentic step so that estimates improve monotonically as runtime context
accumulates.

Here the regressor is a JAX ridge regression over the trajectory's runtime feature vector
(`Trajectory.features()`), trained on exactly the same data contract. The *progressive*
property — step-2 predictions beating step-1 predictions beating prompt-only predictions —
comes from the features, not the model class, and is what the paper's Figure 13 measures.

Two prompt-only baselines from §7.2 are included:
  * ``HistoryPredictor`` — per-prompt statistical heuristic over historical rollouts
    (Seer / RhymeRL style).
  * ``ModelPredictor``   — regression over *static prompt features only* (TTFT-predictor
    style), i.e. the same model class as Heddle's but blind to runtime context.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trajectory import FEATURE_DIM, Trajectory

_PROMPT_FEATURES = (0, 1)  # bias + prompt_tokens: the only static-analysis features


def _fit_ridge(x: jnp.ndarray, y: jnp.ndarray, reg: float) -> jnp.ndarray:
    """Closed-form ridge regression: (X^T X + reg I)^-1 X^T y."""
    d = x.shape[1]
    gram = x.T @ x + reg * jnp.eye(d, dtype=x.dtype)
    return jnp.linalg.solve(gram, x.T @ y)


@jax.jit
def _predict(w: jnp.ndarray, feats: jnp.ndarray) -> jnp.ndarray:
    return feats @ w


@dataclass
class ProgressivePredictor:
    """Heddle's runtime predictor: features fuse prompt + runtime context.

    Train on (features(context_at_step_k), remaining_length_after_step_k) tuples for all k,
    so a single model serves every step index; the features carry the step information.
    Regression is on log1p(remaining): trajectory lengths are multiplicative
    (lognormal difficulty x environment luck), so the log target roughly linearizes
    them and stops the bulk of short trajectories from swamping the tail fit.
    """

    reg: float = 1e-3
    weights: np.ndarray | None = None
    _scale: np.ndarray | None = None
    _resid_var: float = 0.0
    hist_max_tokens: float = 0.0          # longest trajectory seen in training data
    hist_lengths: np.ndarray | None = None  # sorted historical true lengths

    def fit(self, feats: np.ndarray, remaining: np.ndarray) -> "ProgressivePredictor":
        feats = np.asarray(feats, dtype=np.float64)
        remaining = np.asarray(remaining, dtype=np.float64)
        # Feature scaling keeps the Gram matrix well-conditioned.
        self._scale = np.maximum(np.abs(feats).max(axis=0), 1.0)
        y = np.log1p(np.maximum(remaining, 0.0))
        w = _fit_ridge(jnp.asarray(feats / self._scale), jnp.asarray(y), self.reg)
        self.weights = np.asarray(w)
        resid = y - (feats / self._scale) @ self.weights
        self._resid_var = float(np.var(resid))     # lognormal mean correction
        return self

    def fit_trajectories(self, trajectories: Sequence[Trajectory]) -> "ProgressivePredictor":
        """Harvest (context, remaining_length) tuples from finished trajectories."""
        feats, remaining = harvest(trajectories)
        self.hist_max_tokens = float(max((t.true_total_tokens for t in trajectories),
                                         default=0.0))
        self.hist_lengths = np.sort(np.asarray(
            [t.true_total_tokens for t in trajectories], dtype=np.float64))
        return self.fit(feats, remaining)

    def predict(self, traj: Trajectory) -> float:
        """Predicted *remaining* length (tokens) given the trajectory's current context."""
        assert self.weights is not None, "predictor not fitted"
        f = np.asarray(traj.features(), dtype=np.float64) / self._scale
        y = f @ self.weights + 0.5 * getattr(self, "_resid_var", 0.0)
        return float(np.expm1(np.clip(y, 0.0, 18.0)))

    def predict_batch(self, trajs: Sequence[Trajectory]) -> np.ndarray:
        assert self.weights is not None, "predictor not fitted"
        f = np.asarray([t.features() for t in trajs], dtype=np.float64) / self._scale
        y = np.asarray(_predict(jnp.asarray(self.weights), jnp.asarray(f)))
        y = y + 0.5 * getattr(self, "_resid_var", 0.0)
        return np.expm1(np.clip(y, 0.0, 18.0))


@dataclass
class ModelPredictor:
    """Prompt-only regression baseline (§7.2 'model-based prediction')."""

    reg: float = 1e-3
    weights: np.ndarray | None = None
    _scale: np.ndarray | None = None

    def fit_trajectories(self, trajectories: Sequence[Trajectory]) -> "ModelPredictor":
        feats, remaining = harvest(trajectories, first_step_only=True)
        feats = feats[:, _PROMPT_FEATURES]
        self._scale = np.maximum(np.abs(feats).max(axis=0), 1.0)
        w = _fit_ridge(jnp.asarray(feats / self._scale), jnp.asarray(remaining), self.reg)
        self.weights = np.asarray(w)
        return self

    def predict(self, traj: Trajectory) -> float:
        f = np.asarray(traj.features(), dtype=np.float64)[list(_PROMPT_FEATURES)] / self._scale
        return float(max(f @ self.weights, 0.0))


@dataclass
class HistoryPredictor:
    """Historical statistics baseline (§7.2 'history-based prediction').

    Estimates every trajectory's total length as the historical mean length for its
    prompt (falling back to the global mean) — static, so it cannot separate the
    divergent samples within a GRPO group (Fig. 5's intra-group variance).
    """

    per_prompt: dict[int, float] = field(default_factory=dict)
    global_mean: float = 0.0

    def fit_trajectories(self, trajectories: Sequence[Trajectory]) -> "HistoryPredictor":
        by_prompt: dict[int, list[int]] = {}
        totals = []
        for t in trajectories:
            by_prompt.setdefault(t.prompt_id, []).append(t.true_total_tokens)
            totals.append(t.true_total_tokens)
        self.per_prompt = {p: float(np.mean(v)) for p, v in by_prompt.items()}
        self.global_mean = float(np.mean(totals)) if totals else 0.0
        return self

    def predict(self, traj: Trajectory) -> float:
        total = self.per_prompt.get(traj.prompt_id, self.global_mean)
        return max(total - traj.tokens_generated, 0.0)


def harvest(trajectories: Sequence[Trajectory], first_step_only: bool = False
            ) -> tuple[np.ndarray, np.ndarray]:
    """Decompose finished trajectories into (context-features, remaining_length) tuples.

    Replays each trajectory's steps to reconstruct the feature vector as it would have
    looked at every step boundary — the paper's training-data harvesting.
    """
    feats: list[list[float]] = []
    remaining: list[float] = []
    for traj in trajectories:
        # reuse the source id: a feature replay IS the same trajectory, and
        # drawing a fresh id would burn the process-global counter (later
        # batches' ids — which seed per-(traj, step) tool outcomes — would
        # then depend on how many harvests ran before them)
        replay = Trajectory(traj_id=traj.traj_id, prompt_id=traj.prompt_id,
                            sample_id=traj.sample_id,
                            prompt_tokens=traj.prompt_tokens,
                            context_tokens=traj.prompt_tokens)
        # step-0 (prompt only) tuple
        feats.append(replay.features())
        remaining.append(float(traj.true_total_tokens))
        if first_step_only:
            continue
        for step in traj.steps:
            replay.record_step(step)
            replay.record_tool_output(step.tool_output_tokens or _tool_tokens(step))
            feats.append(replay.features())
            remaining.append(float(traj.true_total_tokens - replay.tokens_generated))
    if not feats:
        return np.zeros((0, FEATURE_DIM)), np.zeros((0,))
    return np.asarray(feats, dtype=np.float64), np.asarray(remaining, dtype=np.float64)


def _tool_tokens(step) -> int:
    # Tool output size proxy: failed tool calls (e.g. failing tests) emit longer output.
    return int(64 + 192 * step.tool_failed + 8 * step.tool_latency)


# ---------------------------------------------------------------- metrics (Fig. 13)

def long_tail_recall(pred_total: np.ndarray, true_total: np.ndarray, frac: float = 0.1) -> float:
    """Recall of the true top-``frac`` longest trajectories among the predicted top-frac."""
    n = len(true_total)
    k = max(1, int(round(n * frac)))
    true_top = set(np.argsort(-true_total)[:k].tolist())
    pred_top = set(np.argsort(-pred_total)[:k].tolist())
    return len(true_top & pred_top) / k


def pearson(pred: np.ndarray, true: np.ndarray) -> float:
    if len(pred) < 2 or np.std(pred) == 0 or np.std(true) == 0:
        return 0.0
    return float(np.corrcoef(pred, true)[0, 1])
