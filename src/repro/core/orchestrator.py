"""The one event-driven orchestration core (control plane, backend-agnostic).

Heddle's trajectory-centric decisions — *when* (progressive priority scheduling
with preemptive execution, Algorithm 1), *where* (placement + tool-interval
migration, §5.3), *how fast* (per-worker MP pricing, §6) — used to be executed
by two hand-rolled twin event loops: one inside the discrete-event simulator and
one inside the real-engine runtime.  Every policy change had to land twice and
the loops drifted.  ``Orchestrator`` is the single canonical lifecycle machine

    PENDING → GENERATING ⇄ PREEMPTED
                  │
                  ▼
              TOOL_CALL → MIGRATING → (PENDING …) → FINISHED

driving a pluggable :class:`ExecutionBackend` that supplies only *mechanics and
cost*: how a generation step advances, what it costs in virtual seconds, how a
lane is preempted or migrated, and what the step's tool call returns.  Two
backends ship in ``repro.engine.backends``:

* ``SimBackend`` — the analytic processor-sharing cost model (paper-scale
  studies: 64 workers, thousands of trajectories, 40K-token tails);
* ``EngineBackend`` — the real ``RolloutWorker`` slot-pool data plane on a
  deterministic virtual clock (real tokens, real KV lanes, real migrations).

Because both backends run under this one loop, the scheduling/migration
*decision sequence* is a property of the policy, not of the substrate — the
decision-trace parity harness (``tests/test_orchestrator.py``) asserts the two
backends produce identical ``(event, traj, worker)`` traces on the same
workload.  All policy hooks flow through ``HeddleController`` exactly once:
``initial_placement``, ``on_step_complete`` (progressive refresh + migration
emission), ``commit_migration``/``abort_migration``, ``on_finish``,
``record_worker_stats``.

The loop also carries the asynchronous rollout-as-a-service plane
(``repro.rl.service``, docs/training.md): with ``stream_harvest`` on,
``run_stream()`` yields each FINISHED trajectory through a ``harvest`` event
instead of barriering on the makespan, ``inject()`` admits new work mid-run,
and ``publish_weights()`` schedules an in-flight weight sync — each worker
cuts over to the new policy epoch only once its resident lanes drain, so every
trajectory finishes on the weights that admitted it (the ``weight_epoch``
stamp, enforced by the sanitizer).
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Iterable, Optional, Protocol, Sequence

import numpy as np

from repro.core.faults import FaultPlan
from repro.core.scheduler import make_scheduler
from repro.core.trajectory import StepRecord, Trajectory, TrajectoryPhase


@dataclass(frozen=True)
class StepOutcome:
    """What one completed generation step looked like, backend-reported.

    ``gen_tokens`` is the step's actual generation length (plan tokens for the
    simulator, decoded tokens for the engine), ``terminal`` ends the episode,
    and the ``tool_*`` fields describe the tool call the step triggered (for a
    terminal step they are recorded but no tool interval is waited out).
    ``tool_failed`` is the *plan-driven* task-level failure (rectification
    signal); ``tool_attempts``/``tool_injected_faults`` account the chaos
    layer's injected timeouts/errors separately — the two channels must never
    be conflated (the predictor's features consume only the former).
    """

    gen_tokens: int
    terminal: bool
    tool_latency: float
    tool_failed: bool
    tool_output_tokens: int
    gen_time: float = 0.0
    tool_attempts: int = 1
    tool_injected_faults: int = 0


class ExecutionBackend(Protocol):
    """Mechanics-and-cost contract the orchestrator drives (see docs/runtime.md).

    The orchestrator owns lifecycle, queues, preemption policy, migration
    policy and all controller traffic; the backend owns *how work advances and
    what it costs*.  A backend is either **interruptible** (``advance`` can
    settle partial progress at any instant — analytic cost models) or not
    (work is quantized; new arrivals wait for the current quantum — real
    engines).  The orchestrator adapts its event discipline accordingly.
    """

    interruptible: bool

    @property
    def n_workers(self) -> int: ...

    def admit(self, trajectories: Sequence[Trajectory], now: float = 0.0) -> None:
        """Admission (e.g. prompt prefill) charged to clocks: the whole batch
        at t=0 closed loop, or one arrival at a time (at ``now``) open loop."""
        ...

    def ready_time(self, wid: int, now: float) -> float:
        """Earliest instant worker ``wid`` can start newly queued work."""
        ...

    def dispatch(self, wid: int, traj: Trajectory, fresh: bool) -> float:
        """Start (``fresh``) or resume a step on ``wid``; returns its token-work."""
        ...

    def preempt(self, wid: int, traj: Trajectory) -> None:
        """Evict ``traj`` mid-step, persisting its remaining work and state."""
        ...

    def advance(self, wid: int, now: float) -> Iterable[int]:
        """Progress ``wid`` to ``now``; returns traj_ids whose step completed."""
        ...

    def next_completion(self, wid: int, now: float) -> Optional[float]:
        """Time of ``wid``'s next step completion (None if idle)."""
        ...

    def tool_submit(self, traj: Trajectory) -> StepOutcome:
        """Roll the completed step's tool call; returns the step's outcome."""
        ...

    def tool_absorb(self, traj: Trajectory) -> None:
        """Fold the pending tool output into the trajectory's context."""
        ...

    def can_migrate(self, traj: Trajectory) -> bool: ...

    def migrate_out(self, traj: Trajectory, dst: int) -> float:
        """Extract the trajectory's state for transfer; returns link seconds."""
        ...

    def migrate_in(self, traj: Trajectory, dst: int) -> None:
        """Land the in-flight state on worker ``dst``."""
        ...

    def release(self, traj: Trajectory) -> None:
        """The trajectory finished; free (or retire) its resources."""
        ...

    def stats(self, wid: int) -> dict:
        """Measured telemetry snapshot for ``wid`` ({} when nothing measured)."""
        ...

    # ---- failure realism (fault injection / recovery; see docs/runtime.md) ----

    def checkpoint(self, traj: Trajectory) -> None:
        """Snapshot the trajectory's state at a tool boundary (restore source)."""
        ...

    def restore(self, traj: Trajectory, dst: int) -> float:
        """Re-admit the trajectory on ``dst`` from its last tool-boundary
        checkpoint (the prompt when it never completed a step); returns the
        virtual seconds the re-admission transfer costs."""
        ...

    def kill(self, wid: int) -> None:
        """Worker ``wid`` died: drop every resident lane and all mid-step state."""
        ...

    def revive(self, wid: int) -> None:
        """Replacement capacity for slot ``wid`` joined (cold cache)."""
        ...

    # ---- in-flight weight sync (async rollout-as-a-service; docs/training.md) ----

    def stage_weights(self, params, epoch: int) -> None:
        """Publish new policy weights as ``epoch``; staged, not applied — each
        worker cuts over via ``sync_weights`` once its residents drain.
        ``params=None`` advances the epoch without new tensors (modeled runs)."""
        ...

    def sync_weights(self, wid: int, epoch: int) -> None:
        """Cut worker ``wid`` over to the staged ``epoch``: swap weights in and
        drop every cached stale-weight prefix (the orchestrator's drain fence
        guarantees the worker holds no resident lanes at this instant)."""
        ...


@dataclass(frozen=True)
class OrchestratorConfig:
    scheduler: str = "pps"  # pps | fcfs | rr | sjf (per-worker queues)
    migration: bool = True  # tool-interval migration (§5.3)
    max_active: int = 4  # concurrent generation slots per worker
    open_loop: bool = False  # serve an arrival process instead of a t=0 batch
    preemption_margin: float = 1.0  # PPS hysteresis (multiplicative)
    preemption_floor: float = 1.0  # PPS hysteresis (additive)
    max_events: int = 2_000_000  # runaway-loop guard
    timeline_every: int = 0  # sample (t, live) every N events (0 = off)
    trace: bool = False  # record the (event, traj, worker) decision trace
    sanitize: bool = False  # validate the decision stream (TraceSanitizer)
    stream_harvest: bool = False  # emit harvest events; run_stream() yields them


@dataclass
class OrchestratorResult:
    makespan: float
    preemptions: int
    migrations: int
    queue_delay_mean: float  # over per-step queue delays
    queue_delay_p99: float
    trajectories: list[Trajectory] = field(default_factory=list)
    events: int = 0
    trace: list[tuple[str, int, int]] = field(default_factory=list)
    timeline: list[tuple[float, int]] = field(default_factory=list)
    # chaos telemetry (all zero on a fault-free run)
    worker_deaths: int = 0
    recoveries: int = 0  # trajectory re-admissions from a checkpoint
    tool_retries: int = 0  # injected-fault retry attempts across the batch
    injected_tool_faults: int = 0  # injected timeouts + transient errors
    # serving telemetry (all zero/empty on a closed-loop run)
    arrivals: int = 0  # open-loop arrival events handled (deferrals excluded)
    admitted: int = 0
    shed: int = 0  # dropped by the admission gate or the ladder
    deferred: int = 0  # admissions pushed back by backpressure
    degraded: int = 0  # step budgets tightened by ladder level 2
    peak_live_global: int = 0  # high-water mark of concurrently live trajs
    peak_live_worker: int = 0  # high-water mark on any single worker
    tenant_report: dict = field(default_factory=dict)
    sanitizer: dict = field(default_factory=dict)  # TraceSanitizer report ({} = off)


class _WorkerLane:
    """One worker's control-plane view: queue + active set + event bookkeeping."""

    def __init__(self, wid: int, scheduler_name: str):
        self.wid = wid
        self.scheduler = make_scheduler(scheduler_name)
        self.active: set[int] = set()  # traj_ids with a step in progress
        self.version = 0  # event-staleness guard
        self.sleeping = True  # no worker event in flight
        self.alive = True  # dead lanes accept no work (fault injection)
        self.incoming = 0  # checkpoint restores headed here (placement spread)


class Orchestrator:
    """The canonical rollout event loop over a pluggable execution backend.

    The caller supplies the backend, the trajectory batch and exactly one
    placement/policy source: a ``HeddleController`` (full Heddle stack —
    placement DP, progressive refresh, migration) or a baseline ``routing``
    policy plus a bare ``predictor`` (§7 comparison systems).  ``run()``
    executes the batch to completion and returns substrate-independent metrics
    plus (optionally) the decision trace.
    """

    def __init__(
        self,
        backend: ExecutionBackend,
        trajectories: Sequence[Trajectory],
        config: OrchestratorConfig = OrchestratorConfig(),
        *,
        controller=None,
        routing=None,
        predictor=None,
        faults: Optional[FaultPlan] = None,
    ):
        if controller is None and predictor is None:
            raise ValueError("need a controller or a bare predictor")
        if controller is None and routing is None:
            raise ValueError("need a controller or a routing policy for placement")
        self.backend = backend
        self.cfg = config
        self.controller = controller
        self.routing = routing
        self.predictor = predictor if predictor is not None else controller.predictor
        self.trajs = list(trajectories)
        self.by_id = {t.traj_id: t for t in self.trajs}
        self.lanes = [_WorkerLane(w, config.scheduler) for w in range(backend.n_workers)]
        for lane in self.lanes:
            if hasattr(lane.scheduler, "preemption_margin"):
                lane.scheduler.preemption_margin = config.preemption_margin
                lane.scheduler.preemption_floor = config.preemption_floor
        self._mid_step: set[int] = set()  # step in progress (resume ≠ fresh)
        self.in_flight: dict[int, tuple[int, int]] = {}  # traj -> (dst, transfer token)
        self.tool_arrived: set[int] = set()  # tool done while state in flight
        self.faults = faults
        # tool-boundary checkpoints are only worth their cost when a death can
        # actually orphan a lane; fault-free runs skip them entirely (parity)
        self._checkpointing = faults is not None and bool(faults.deaths)
        self.restoring: dict[int, tuple[int, bool]] = {}  # traj -> (token, resubmit)
        self._xfer_seq = itertools.count()  # staleness tokens for transfers/restores
        # async service plane: per-worker weight epochs + residency fence
        self.now = 0.0  # virtual instant of the event being handled
        self.published_epoch = 0  # latest epoch handed to publish_weights
        self.weight_epoch = 0  # latest epoch whose sync event has popped
        self.applied_epoch = [0] * backend.n_workers  # per-worker applied epoch
        self._resident: list[set[int]] = [set() for _ in range(backend.n_workers)]
        self._started = False
        self._result: Optional[OrchestratorResult] = None
        self.preemptions = 0
        self.migrations = 0
        self.worker_deaths = 0
        self.recoveries = 0
        self.arrivals = 0
        self.admitted = 0
        self.shed_count = 0
        self.deferred = 0
        self.degraded = 0
        self.events = 0
        self.trace: list[tuple[str, int, int]] = []
        self.timeline: list[tuple[float, int]] = []
        self._evq: list[tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        self._sanitizer = None
        if config.sanitize:
            # lazy: core must not import analysis (which imports core) eagerly
            from repro.analysis.sanitize import TraceSanitizer

            self._sanitizer = TraceSanitizer(
                self.trajs, backend.n_workers, config.max_active
            )

    # ------------------------------------------------------------ event plumbing
    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._evq, (t, next(self._seq), kind, payload))

    def _note(self, kind: str, tid: int, wid: int) -> None:
        if self.cfg.trace:
            self.trace.append((kind, tid, wid))
        if self._sanitizer is not None:
            self._sanitizer.observe(kind, tid, wid)

    def _loads(self) -> np.ndarray:
        return np.asarray(
            [
                len(ln.active) + len(ln.scheduler) if ln.alive else np.inf
                for ln in self.lanes
            ],
            float,
        )

    def _plan(self, lane: _WorkerLane, now: float) -> None:
        """Re-derive the worker's next completion event; stale events die."""
        lane.version += 1
        nc = self.backend.next_completion(lane.wid, now)
        if nc is None:
            lane.sleeping = True
        else:
            lane.sleeping = False
            self._push(nc, "worker", (lane.wid, lane.version))

    def _worker_pass(self, lane: _WorkerLane, now: float) -> None:
        """Settle work, handle completed steps, refill, replan — one pass."""
        for tid in self.backend.advance(lane.wid, now):
            lane.active.discard(tid)
            self._mid_step.discard(tid)
            self._complete_step(self.by_id[tid], lane, now)
        self._dispatch(lane, now)
        self._plan(lane, now)

    def _submit(self, traj: Trajectory, now: float) -> None:
        """Queue the trajectory's next generation step on its current worker."""
        lane = self.lanes[traj.worker_id]
        traj._queued_at = now
        if self.cfg.open_loop and self.controller is not None:
            # EDF blend: refresh the urgency boost each time the trajectory
            # (re-)enters a queue, so shrinking slack steadily raises priority
            traj.slo_boost = self.controller.edf_boost(traj, now)
        lane.scheduler.submit(traj, now)
        if self.backend.interruptible:
            self._worker_pass(lane, now)
        elif lane.sleeping:
            lane.sleeping = False
            lane.version += 1
            self._push(
                self.backend.ready_time(lane.wid, now),
                "worker",
                (lane.wid, lane.version),
            )

    # ------------------------------------------------------------ dispatch / preempt
    def _start(self, lane: _WorkerLane, traj: Trajectory, now: float) -> None:
        tid = traj.traj_id
        traj._step_queue_delay = getattr(traj, "_step_queue_delay", 0.0) + max(
            0.0, now - getattr(traj, "_queued_at", now)
        )
        fresh = tid not in self._mid_step
        self._mid_step.add(tid)
        traj.phase = TrajectoryPhase.GENERATING
        lane.active.add(tid)
        self.backend.dispatch(lane.wid, traj, fresh)
        self._note("start", tid, lane.wid)

    def _preempt(self, lane: _WorkerLane, victim: Trajectory, now: float) -> None:
        """Algorithm 1 lines 5-10: evict, persist state, requeue."""
        tid = victim.traj_id
        self.backend.preempt(lane.wid, victim)
        lane.active.discard(tid)  # _mid_step persists: next start is a resume
        victim.preemptions += 1
        self.preemptions += 1
        victim.phase = TrajectoryPhase.PREEMPTED
        victim._queued_at = now
        lane.scheduler.submit(victim, now)
        self._note("preempt", tid, lane.wid)

    def _dispatch(self, lane: _WorkerLane, now: float) -> None:
        while len(lane.active) < self.cfg.max_active and len(lane.scheduler):
            traj = lane.scheduler.pop(now)
            if traj is None:
                break
            self._start(lane, traj, now)
        if lane.scheduler.preemptive and len(lane.scheduler):
            for _ in range(len(lane.active)):
                # canonical candidate order: preempt_victim breaks priority
                # ties by position, so set order would leak into the trace
                active = [self.by_id[t] for t in sorted(lane.active)]
                victim = lane.scheduler.preempt_victim(active)
                if victim is None:
                    break
                self._preempt(lane, victim, now)
                nxt = lane.scheduler.pop(now)
                if nxt is not None:
                    self._start(lane, nxt, now)

    # ------------------------------------------------------------ step lifecycle
    def _complete_step(self, traj: Trajectory, lane: _WorkerLane, now: float) -> None:
        out = self.backend.tool_submit(traj)
        rec = StepRecord(
            traj.num_steps,
            int(out.gen_tokens),
            out.tool_latency,
            tool_failed=out.tool_failed,
            tool_output_tokens=out.tool_output_tokens,
            queue_delay=getattr(traj, "_step_queue_delay", 0.0),
            gen_time=out.gen_time,
            tool_attempts=out.tool_attempts,
            tool_injected_faults=out.tool_injected_faults,
        )
        traj.record_step(rec)
        traj._step_queue_delay = 0.0
        traj.record_tool_output(out.tool_output_tokens)
        stats = self.backend.stats(lane.wid)
        if stats and self.controller is not None:
            self.controller.record_worker_stats(lane.wid, stats)
        self._note("step", traj.traj_id, lane.wid)
        if out.terminal:
            traj.finished = True
            traj.finish_time = now
            traj.phase = TrajectoryPhase.FINISHED
            if self.controller is not None:
                self.controller.on_finish(traj)
            self.backend.release(traj)
            self._note("finish", traj.traj_id, lane.wid)
            self._unbind(traj.traj_id, now)
            if self.cfg.stream_harvest:
                # no makespan barrier: the consumer sees this trajectory now
                self._push(now, "harvest", traj.traj_id)
            return
        traj.phase = TrajectoryPhase.TOOL_CALL
        if self._checkpointing:
            # tool boundary = the recovery point: a later worker death loses at
            # most the tokens decoded since this snapshot
            self.backend.checkpoint(traj)
        self._push(now + out.tool_latency, "tool_done", traj.traj_id)
        # progressive refresh + migration decision, masked by the tool interval
        if self.controller is not None:
            req = self.controller.on_step_complete(traj, ())
            if req is not None and self.cfg.migration:
                for r in self.controller.transmission.next_batch():
                    self._launch_migration(r, now)
        else:
            traj.predicted_remaining = self.predictor.predict(traj)
            traj.priority = traj.predicted_total

    # ------------------------------------------------------------ migration (§5.3)
    def _launch_migration(self, req, now: float) -> None:
        traj = self.by_id.get(req.traj_id)
        if (
            traj is None
            or traj.phase is not TrajectoryPhase.TOOL_CALL
            or req.traj_id in self.restoring
            or req.src != traj.worker_id  # moved by a checkpoint recovery
            or not self.lanes[req.dst].alive  # destination died since emission
            or self.applied_epoch[req.dst] != traj.weight_epoch  # policy mismatch
            or not self.backend.can_migrate(traj)
        ):
            # resumed, finished, or already moved: migrating now would stall the
            # critical path — drop without touching load accounting
            self.controller.transmission.complete(req.traj_id)
            self.controller.abort_migration(req.traj_id)
            return
        dur = self.backend.migrate_out(traj, req.dst)
        self.controller.commit_migration(req.traj_id)
        traj.phase = TrajectoryPhase.MIGRATING
        traj.migrations += 1
        self.migrations += 1
        token = next(self._xfer_seq)
        self.in_flight[req.traj_id] = (req.dst, token)
        # rebind residency to dst now: the destination must not cut weights
        # over while an epoch-matched lane is on the wire towards it
        self._unbind(req.traj_id, now)
        self._resident[req.dst].add(req.traj_id)
        self._push(now + dur, "migration_done", (req.traj_id, token))
        self._note("migrate", req.traj_id, req.dst)

    def _on_migration_done(self, tid: int, token: int, now: float) -> None:
        if self.in_flight.get(tid, (None, None))[1] != token:
            return  # transfer aborted (destination died mid-flight)
        dst, _ = self.in_flight.pop(tid)
        traj = self.by_id[tid]
        self.backend.migrate_in(traj, dst)
        traj.worker_id = dst
        self.controller.transmission.complete(tid)
        self._note("migrate_done", tid, dst)
        for r in self.controller.transmission.next_batch():
            self._launch_migration(r, now)
        if tid in self.tool_arrived:  # transfer outlived the tool call
            self.tool_arrived.discard(tid)
            self._resume(traj, now)
        else:  # fully masked by the tool call
            traj.phase = TrajectoryPhase.TOOL_CALL

    def _on_tool_done(self, tid: int, now: float) -> None:
        traj = self.by_id[tid]
        self._note("tool_done", tid, traj.worker_id)
        if tid in self.in_flight or tid in self.restoring:
            # state still on the wire (migration or checkpoint restore): the
            # trajectory resumes when its lane lands
            self.tool_arrived.add(tid)
            return
        self._resume(traj, now)

    # ------------------------------------------------------------ faults / recovery
    def _pick_survivor(self, epoch: int = 0) -> int:
        """Least-loaded alive lane, counting restores already headed there.

        Lanes whose applied weight epoch matches the recovering trajectory's
        stamp are preferred (the lane resumes on the policy that started it);
        when none matches, availability beats purity — the stamp is still
        never rewritten, so the staleness-bounded consumer sees the truth.
        """
        alive = [ln for ln in self.lanes if ln.alive]
        if not alive:
            raise RuntimeError("all workers dead: nothing left to recover onto")
        matching = [ln for ln in alive if self.applied_epoch[ln.wid] == epoch]
        if matching:
            alive = matching
        return min(
            alive, key=lambda ln: (len(ln.active) + len(ln.scheduler) + ln.incoming, ln.wid)
        ).wid

    def _recover(self, traj: Trajectory, now: float, resubmit: bool) -> None:
        """Re-admit ``traj`` on a survivor from its last tool-boundary checkpoint.

        ``resubmit`` distinguishes a trajectory that must re-queue a generation
        step once landed (it was generating/queued when its worker died — the
        tokens since the last tool boundary are lost and re-decoded) from one
        whose tool call is still outstanding (it resumes via ``tool_done``).
        """
        tid = traj.traj_id
        dst = self._pick_survivor(traj.weight_epoch)
        if self.controller is not None:  # reads worker_id as src: before reassign
            self.controller.on_recover(traj, dst)
        delay = self.backend.restore(traj, dst)
        self._unbind(tid, now)
        self._resident[dst].add(tid)
        traj.worker_id = dst
        traj.recoveries += 1
        self.recoveries += 1
        self.lanes[dst].incoming += 1
        token = next(self._xfer_seq)
        self.restoring[tid] = (token, resubmit)
        self._push(now + delay, "restore_done", (tid, token))
        self._note("recover", tid, dst)

    def _on_restore_done(self, tid: int, token: int, now: float) -> None:
        entry = self.restoring.get(tid)
        if entry is None or entry[0] != token:
            return  # superseded: the restore target died before the lane landed
        _, resubmit = self.restoring.pop(tid)
        traj = self.by_id[tid]
        self.lanes[traj.worker_id].incoming -= 1
        self._note("restore_done", tid, traj.worker_id)
        if resubmit:
            traj.phase = TrajectoryPhase.PENDING
            self._submit(traj, now)
        elif tid in self.tool_arrived:  # tool finished while the lane was in flight
            self.tool_arrived.discard(tid)
            self._resume(traj, now)
        else:
            traj.phase = TrajectoryPhase.TOOL_CALL

    def _on_worker_death(self, wid: int, now: float) -> None:
        lane = self.lanes[wid]
        if not lane.alive:
            return
        lane.alive = False
        lane.version += 1  # every in-flight worker event for this lane is stale
        lane.sleeping = True
        self.worker_deaths += 1
        self._note("worker_death", -1, wid)
        # queued residents: their scheduler entries die with the lane
        queued: list[Trajectory] = []
        while len(lane.scheduler):
            t = lane.scheduler.pop(now)
            if t is not None:
                queued.append(t)
        victims = [self.by_id[tid] for tid in sorted(lane.active)]
        lane.active.clear()
        self.backend.kill(wid)
        if self.controller is not None:
            self.controller.mark_worker_dead(wid)
        for traj in victims + queued:
            self._mid_step.discard(traj.traj_id)  # partial step is gone: fresh redo
            self._recover(traj, now, resubmit=True)
        for traj in self.trajs:
            if traj.finished or traj.shed:
                continue
            tid = traj.traj_id
            if tid in self.in_flight and self.in_flight[tid][0] == wid:
                # in-flight migration to a corpse: abort cleanly, recover from
                # the checkpoint (the wire copy never lands)
                self.in_flight.pop(tid)
                self.controller.transmission.complete(tid)
                self._recover(traj, now, resubmit=False)
            elif (
                tid in self.restoring
                and traj.worker_id == wid
                and traj not in victims
                and traj not in queued
            ):
                # restore was headed to the dead worker: re-route (new token
                # invalidates the stale restore_done)
                _, resubmit = self.restoring.pop(tid)
                self._recover(traj, now, resubmit=resubmit)
            elif (
                traj.phase is TrajectoryPhase.TOOL_CALL
                and traj.worker_id == wid
                and tid not in self.in_flight
                and tid not in self.restoring
            ):
                # resident parked at a tool boundary: its KV died with the worker
                self._recover(traj, now, resubmit=False)
        # losing a worker shrinks capacity: re-check the overload ladder
        self._degradation_ladder(now)

    def _on_worker_up(self, wid: int, now: float) -> None:
        lane = self.lanes[wid]
        if lane.alive:
            return
        lane.alive = True
        lane.version += 1
        lane.sleeping = True
        self.backend.revive(wid)
        if self.controller is not None:
            self.controller.mark_worker_alive(wid)
        self._note("worker_up", -1, wid)
        # a cold replacement has no residents: adopt the latest policy at once
        self._try_sync(lane, now)

    def _resume(self, traj: Trajectory, now: float) -> None:
        # resuming invalidates any emitted-but-unlaunched migration: its target
        # was chosen from now-stale load/rank data
        if self.controller is not None:
            self.controller.abort_migration(traj.traj_id)
        if self.routing is not None:
            traj.worker_id = int(self.routing.step_worker(traj, self._loads()))
        self.backend.tool_absorb(traj)
        self._submit(traj, now)

    # ------------------------------------------------------------ serving (open loop)
    def _on_arrival(self, tid: int, now: float) -> None:
        """One open-loop arrival (or a deferred retry) hits the front door."""
        traj = self.by_id[tid]
        first = traj.deferrals == 0
        if first:
            self.arrivals += 1
            self._note("arrival", tid, -1)
        if self.controller is None:
            # baseline routing has no admission policy: place and go
            traj.predicted_remaining = self.predictor.predict(traj)
            traj.priority = traj.predicted_total
            traj.worker_id = int(self.routing.initial_worker(traj, self._loads()))
            self.backend.admit([traj], now)
            self._admit_resident(traj)
            self.admitted += 1
            self._note("admit", tid, traj.worker_id)
            self._submit(traj, now)
            return
        decision = self.controller.admit_arrival(traj, now)
        if decision.action == "shed":
            self._shed(traj, now, decision.reason, admitted=False)
            return
        if decision.action == "defer":
            traj.deferrals += 1
            self.deferred += 1
            self._note("defer", tid, -1)
            self._push(now + self.controller.config.serving.defer_seconds,
                       "arrival", tid)
            return
        self.backend.admit([traj], now)
        self._admit_resident(traj)
        self.admitted += 1
        self._note("admit", tid, decision.worker)
        self._submit(traj, now)
        self._degradation_ladder(now)

    def _shed(self, traj: Trajectory, now: float, reason: str,
              admitted: bool) -> None:
        """Drop one trajectory (admission gate or ladder level 1)."""
        tid = traj.traj_id
        if admitted:
            # it only ever sheds from a queue (PENDING/PREEMPTED): pull the
            # scheduler entry and free whatever lane state the backend holds
            self.lanes[traj.worker_id].scheduler.remove(traj)
            self._mid_step.discard(tid)
            self.backend.release(traj)
            self._unbind(tid, now)
        if self.controller is not None:
            self.controller.on_shed(traj, now, reason, admitted)
        traj.shed = True
        traj.shed_reason = reason
        traj.finish_time = now
        traj.phase = TrajectoryPhase.SHED
        self.shed_count += 1
        self._note("shed", tid, traj.worker_id if admitted else -1)

    def _degradation_ladder(self, now: float) -> None:
        """Graceful degradation under sustained overload (two levels).

        Level 1 (pressure >= shed_pressure): shed queued sheddable work,
        highest tier first, until pressure returns under the threshold.
        Level 2 (pressure >= degrade_pressure): tighten the step budget of
        live non-gold trajectories (they finish at their current-or-next tool
        boundary).  Gold tier is untouchable at every level; every decision
        lands in the trace, so sim/engine parity covers the ladder too.
        """
        ctl = self.controller
        if ctl is None or not self.cfg.open_loop:
            return
        scfg = ctl.config.serving
        if ctl.pressure() >= scfg.shed_pressure:
            queued: list[Trajectory] = []
            for lane in self.lanes:
                if lane.alive:
                    queued.extend(lane.scheduler.queued())
            for victim in ctl.select_shed_victims(queued):
                self._shed(victim, now, "overload", admitted=True)
        if ctl.pressure() >= scfg.degrade_pressure:
            live = [t for t in self.trajs if not t.finished and not t.shed]
            for traj in ctl.select_degrade_victims(live):
                traj.step_cap = traj.num_steps + scfg.degrade_step_grace
                traj.degraded = True
                self.degraded += 1
                ctl.on_degrade(traj)
                self._note("degrade", traj.traj_id, traj.worker_id
                           if traj.worker_id is not None else -1)

    # ------------------------------------------------------------ async service plane
    def _admit_resident(self, traj: Trajectory) -> None:
        """Stamp the admitting worker's applied weight epoch and bind residency.

        The stamp is written exactly once, here: a resident finishes on the
        policy that admitted it (sanitizer-enforced), and the staleness-bounded
        consumer compares this stamp against the latest published epoch.
        """
        wid = traj.worker_id
        traj.weight_epoch = self.applied_epoch[wid]
        self._resident[wid].add(traj.traj_id)

    def _unbind(self, tid: int, now: float) -> None:
        """Release ``tid``'s residency; a fully drained lane may cut weights over."""
        for lane in self.lanes:
            residents = self._resident[lane.wid]
            if tid in residents:
                residents.remove(tid)
                if not residents:
                    self._try_sync(lane, now)
                return

    def _try_sync(self, lane: _WorkerLane, now: float) -> None:
        """In-flight weight-sync fence: cut worker ``lane`` over to the latest
        published epoch only when it holds zero resident lanes — never under a
        running, queued, parked-at-a-tool-boundary or inbound trajectory."""
        wid = lane.wid
        if (
            not lane.alive
            or self.applied_epoch[wid] >= self.weight_epoch
            or self._resident[wid]
        ):
            return
        self.backend.sync_weights(wid, self.weight_epoch)
        self.applied_epoch[wid] = self.weight_epoch
        self._note("weight_sync", self.weight_epoch, wid)

    def publish_weights(self, params=None, *, at: Optional[float] = None) -> int:
        """Stage new policy weights and schedule their in-flight sync.

        Returns the new epoch.  ``at`` (virtual time, >= now) models training
        latency: the epoch only starts cutting workers over once its
        ``weight_sync`` event pops.  ``params=None`` advances the epoch without
        new tensors (modeled benches).  Workers adopt the epoch individually as
        their residents drain; lanes admitted before their worker cut over keep
        their old stamp, which is exactly what the staleness bound consumes.
        """
        self.published_epoch += 1
        epoch = self.published_epoch
        self.backend.stage_weights(params, epoch)
        when = self.now if at is None else max(self.now, at)
        self._push(when, "weight_sync", (epoch, next(self._xfer_seq)))
        return epoch

    def _on_weight_sync(self, epoch: int, now: float) -> None:
        if epoch <= self.weight_epoch:
            return  # superseded by a later publish that already popped
        self.weight_epoch = epoch
        for lane in self.lanes:
            self._try_sync(lane, now)

    def inject(self, trajectories: Sequence[Trajectory]) -> None:
        """Mid-run submission (rollout-as-a-service): new work enters the
        open-loop front door at the current virtual instant."""
        if not self.cfg.open_loop:
            raise ValueError("inject() needs open_loop mode (the service plane)")
        if not self._started:
            raise RuntimeError("inject() before run(): pass initial work instead")
        for t in trajectories:
            if t.traj_id in self.by_id:
                raise ValueError(f"trajectory {t.traj_id} already submitted")
            t.submit_time = self.now
            self.trajs.append(t)
            self.by_id[t.traj_id] = t
            self._push(self.now, "arrival", t.traj_id)
        if self._sanitizer is not None:
            self._sanitizer.register(trajectories)

    # ------------------------------------------------------------ run
    def run(self) -> OrchestratorResult:
        """Execute to completion (the synchronous barrier view of run_stream)."""
        for _ in self.run_stream():
            pass
        return self._result

    def run_stream(self):
        """Drive the event loop, yielding each harvested trajectory.

        Harvest events only exist under ``cfg.stream_harvest``; without it the
        generator yields nothing and ``run()`` degenerates to the classic
        barrier.  Between yields the consumer may ``inject()`` new work and
        ``publish_weights()`` — the service plane's whole API.  When the heap
        drains, the final :class:`OrchestratorResult` lands in ``self._result``.
        """
        self._begin()
        while self._evq:
            self.events += 1
            if self.events > self.cfg.max_events:
                raise RuntimeError("orchestrator event budget exceeded")
            now, _, kind, payload = heapq.heappop(self._evq)
            self.now = now
            harvested: Optional[Trajectory] = None
            if self._sanitizer is not None:
                self._sanitizer.on_clock(now)
            if kind == "worker":
                wid, ver = payload
                lane = self.lanes[wid]
                if self._sanitizer is not None:
                    self._sanitizer.on_worker_event(
                        wid, ver == lane.version, lane.alive
                    )
                if ver != lane.version:
                    continue  # stale event superseded by a replan
                self._worker_pass(lane, now)
            elif kind == "tool_done":
                self._on_tool_done(payload, now)
            elif kind == "migration_done":
                tid, token = payload
                self._on_migration_done(tid, token, now)
            elif kind == "restore_done":
                tid, token = payload
                self._on_restore_done(tid, token, now)
            elif kind == "arrival":
                self._on_arrival(payload, now)
            elif kind == "worker_death":
                self._on_worker_death(payload, now)
            elif kind == "worker_up":
                self._on_worker_up(payload, now)
            elif kind == "harvest":
                harvested = self.by_id[payload]
                self._note("harvest", payload, harvested.worker_id)
            elif kind == "weight_sync":
                epoch, _sync_token = payload
                self._on_weight_sync(epoch, now)
            if self.cfg.timeline_every and self.events % self.cfg.timeline_every == 0:
                self.timeline.append((now, sum(1 for t in self.trajs if not t.finished)))
            if harvested is not None:
                yield harvested
        self._result = self._finalize()

    def _begin(self) -> None:
        """Seed the heap: the t=0 batch (closed loop) or the arrival process."""
        self._started = True
        if self.cfg.open_loop:
            # serving: trajectories arrive over time (submit_time stamped by an
            # ArrivalPolicy); placement and admission happen per arrival
            if self.controller is not None:
                self.controller.begin_serving(self.cfg.max_active)
            for t in self.trajs:
                self._push(t.submit_time, "arrival", t.traj_id)
        else:
            for t in self.trajs:
                t.predicted_remaining = self.predictor.predict(t)
                t.priority = t.predicted_total
                t.submit_time = 0.0
            if self.routing is not None:
                loads = np.zeros(len(self.lanes))
                for t in self.trajs:
                    t.worker_id = int(self.routing.initial_worker(t, loads))
                    loads[t.worker_id] += 1
            else:
                self.controller.initial_placement(self.trajs)
            self.backend.admit(self.trajs)
            for t in self.trajs:
                self._admit_resident(t)
                self._submit(t, 0.0)
        if self.faults is not None:
            # the chaos schedule rides the same versioned heap as everything else
            for t, wid in self.faults.deaths:
                self._push(t, "worker_death", wid)
            for t, wid in self.faults.revivals:
                self._push(t, "worker_up", wid)

    def _finalize(self) -> OrchestratorResult:
        unfinished = [t.traj_id for t in self.trajs if not t.finished and not t.shed]
        assert not unfinished, f"orchestrator drained with live trajectories {unfinished}"
        # balance checks + raise on any accumulated invariant violation
        sanitizer_report = (
            self._sanitizer.finalize() if self._sanitizer is not None else {}
        )
        delays = np.asarray([s.queue_delay for t in self.trajs for s in t.steps])
        return OrchestratorResult(
            makespan=max((t.finish_time for t in self.trajs), default=0.0),
            preemptions=self.preemptions,
            migrations=self.migrations,
            queue_delay_mean=float(delays.mean()) if len(delays) else 0.0,
            queue_delay_p99=float(np.quantile(delays, 0.99)) if len(delays) else 0.0,
            trajectories=self.trajs,
            events=self.events,
            trace=self.trace,
            timeline=self.timeline,
            worker_deaths=self.worker_deaths,
            recoveries=self.recoveries,
            tool_retries=sum(t.tool_retries for t in self.trajs),
            injected_tool_faults=sum(t.injected_tool_faults for t in self.trajs),
            arrivals=self.arrivals,
            admitted=self.admitted,
            shed=self.shed_count,
            deferred=self.deferred,
            degraded=self.degraded,
            peak_live_global=(self.controller.peak_global_count
                              if self.cfg.open_loop and self.controller
                              is not None else 0),
            peak_live_worker=(self.controller.peak_worker_count
                              if self.cfg.open_loop and self.controller
                              is not None else 0),
            tenant_report=(self.controller.tenant_report()
                           if self.cfg.open_loop and self.controller is not None
                           else {}),
            sanitizer=sanitizer_report,
        )
