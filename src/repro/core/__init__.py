"""Heddle core: the paper's control-plane contribution.

  trajectory        — trajectory-centric metadata & lifecycle
  predictor         — progressive trajectory prediction (§4.1)
  scheduler         — progressive priority scheduling, Algorithm 1 (§4.2)
  placement         — presorted dynamic programming, Lemma 5.1 + Formula 3 (§5.2)
  migration         — scaled-capacity re-placement + transmission scheduler (§5.3)
  resource_manager  — sort-initialized simulated annealing, Algorithm 2 (§6.2)
  controller        — control plane + baseline routing policies (§3, §7)
  faults            — deterministic chaos schedules + tool retry discipline
                      (worker death/revival, injected tool timeouts/errors)
  tenancy           — tenant/SLA classes + serving-time overload policy
                      (admission control, backpressure, degradation ladder)
  orchestrator      — THE event loop: one lifecycle state machine driving a
                      pluggable ExecutionBackend (engine.backends: the analytic
                      SimBackend and the real-worker EngineBackend), so every
                      scheduling/preemption/migration decision is made by
                      exactly one code path on either substrate.  Runs closed
                      loop (whole batch at t=0, barrier on makespan) or open
                      loop (arrival events, admission, shedding), and under
                      ``stream_harvest`` yields each FINISHED trajectory the
                      moment it completes — the streaming mode the async
                      training plane (rl.service.RolloutService, in-flight
                      weight syncs, docs/training.md) is built on
"""

from repro.core.faults import (FaultPlan, RetryPolicy, ToolCallTrace,
                               resolve_tool_call)
from repro.core.migration import (MigrationRequest, ScaledCapacityRouter,
                                  TransmissionScheduler, kv_cache_bytes)
from repro.core.orchestrator import (ExecutionBackend, Orchestrator,
                                     OrchestratorConfig, OrchestratorResult,
                                     StepOutcome)
from repro.core.placement import (InterferenceModel, PlacementResult,
                                  aggregate_short, brute_force_partition,
                                  evaluate_partition, place, presorted_dp)
from repro.core.predictor import (HistoryPredictor, ModelPredictor,
                                  ProgressivePredictor, harvest, long_tail_recall,
                                  pearson)
from repro.core.resource_manager import (AllocationResult, WorkerLatencyModel,
                                         homogeneous_allocation, sort_initialized_sa)
from repro.core.scheduler import (FCFSScheduler, PPSScheduler, RoundRobinScheduler,
                                  SJFScheduler, make_scheduler)
from repro.core.tenancy import (DEFAULT_TENANTS, ServingConfig, TenantClass,
                                assign_tenants, parse_tenants)
from repro.core.trajectory import StepRecord, Trajectory, TrajectoryPhase, make_group
from repro.core.controller import (AdmissionDecision, CacheAffinityRouting,
                                   HeddleConfig, HeddleController, HybridRouting,
                                   LeastLoadRouting)

__all__ = [name for name in dir() if not name.startswith("_")]
