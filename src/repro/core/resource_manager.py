"""Trajectory-adaptive resource manager (paper §6, Algorithm 2).

Breaks the rigid homogeneous-MP constraint: a total accelerator budget N is carved into m
workers with model-parallel degrees {N_1..N_m} drawn from a discrete set D.  Long-tail
partitions map to high-MP workers (low per-token time T), short partitions to low-MP
workers (high aggregate throughput).

The joint (partition, allocation) problem is decoupled (paper §6.1):
  * **mapping** — sort both the DP partitions (by length, §5.2 already does) and the
    workers (by MP degree, descending) and zip them;
  * **allocation** — *sort-initialized simulated annealing*: start from a random sorted
    allocation, perturb with redistribute / split / merge moves, evaluate each candidate
    by running the presorted DP with the candidate's per-worker token-time vector, accept
    worse states with probability exp(-delta/T), cool by alpha until T < eps.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core.placement import InterferenceModel, PlacementResult, presorted_dp


@dataclass(frozen=True)
class WorkerLatencyModel:
    """Per-token decode latency as a function of model-parallel degree.

    t(mp) = t1 * ((1 - overlap) / mp + overlap): scaling the model axis divides the
    weight/KV read time by mp but leaves a non-scalable fraction (ICI latency,
    layernorms, sampling).  This reproduces the Fig. 7 latency-throughput trade-off:
    per-token time falls with mp while per-chip throughput (1 / (t * mp)) falls too.
    """

    t1: float = 1.0              # per-token seconds at mp=1, batch=1
    overlap: float = 0.22        # non-scalable latency fraction (calibrated to the
                                 # paper's Fig 7 latency-throughput trade-off)
    comm_batch_coef: float = 0.087   # TP all-reduce volume scales with batch

    def base_token_time(self, mp: int, batch: float = 1.0) -> float:
        """Per-token time at MP degree ``mp`` and typical batch ``batch``.

        The batch-scaled comm term keeps the control-plane model consistent with the
        data plane: high MP buys latency at small batch but pays growing all-reduce
        volume at saturation (Fig 7)."""
        comm = self.overlap * (1.0 + self.comm_batch_coef * max(batch - 1.0, 0.0))             if mp > 1 else self.overlap
        return self.t1 * ((1.0 - self.overlap) / mp + comm)

    def token_times(self, degrees: Sequence[int], batch: float = 1.0) -> np.ndarray:
        return np.asarray([self.base_token_time(d, batch) for d in degrees],
                          dtype=np.float64)

    @classmethod
    def fit(cls, observations: Sequence[tuple[int, float, float]],
            comm_batch_coef: float = 0.087) -> "WorkerLatencyModel":
        """Least-squares ``(t1, overlap)`` from measured per-worker decode timing.

        ``observations`` are ``(mp, batch, per_step_seconds)`` samples — the
        engine's warm-call ``dispatch_stats`` feed (``decode_wall_s /
        decode_timed_steps`` at the worker's declared MP degree and mean live
        batch; one masked full-pool step advances every live lane one token, so
        per-step time is the per-sequence token time the control plane prices).
        The model is linear in ``u = t1`` and ``v = t1 * overlap``:

            t(mp, b) = u / mp + v * (c(mp, b) - 1 / mp),
            c(mp, b) = 1 + comm_batch_coef * max(b - 1, 0)  if mp > 1 else 1,

        so ordinary least squares recovers both, replacing the Fig. 7 constants
        with observed behavior.  With a single distinct MP degree the system is
        degenerate; ``overlap`` keeps its prior and only ``t1`` is re-scaled to
        match the observed mean.  Fitted values are clamped to the physical
        range (t1 > 0, 0 <= overlap <= 0.95).
        """
        obs = [(int(mp), float(b), float(t)) for mp, b, t in observations
               if t > 0.0 and int(mp) >= 1]
        if not obs:
            raise ValueError("WorkerLatencyModel.fit needs at least one "
                             "positive-time observation")

        def c_term(mp: int, b: float) -> float:
            return 1.0 + comm_batch_coef * max(b - 1.0, 0.0) if mp > 1 else 1.0

        prior = cls(comm_batch_coef=comm_batch_coef)
        if len({mp for mp, _, _ in obs}) == 1:
            # shape is per-observation: samples at different batches carry
            # different comm terms, so divide each out before averaging
            ratios = [t / ((1.0 - prior.overlap) / mp
                           + prior.overlap * c_term(mp, b))
                      for mp, b, t in obs]
            return cls(t1=max(float(np.mean(ratios)), 1e-12),
                       overlap=prior.overlap,
                       comm_batch_coef=comm_batch_coef)
        design = np.asarray([[1.0 / mp, c_term(mp, b) - 1.0 / mp]
                             for mp, b, _ in obs], dtype=np.float64)
        target = np.asarray([t for _, _, t in obs], dtype=np.float64)
        (u, v), *_ = np.linalg.lstsq(design, target, rcond=None)
        if u <= 0.0:                     # pathological sample: keep prior shape
            return cls(t1=max(float(target.mean()), 1e-12),
                       overlap=prior.overlap, comm_batch_coef=comm_batch_coef)
        overlap = float(np.clip(v / u, 0.0, 0.95))
        return cls(t1=float(u), overlap=overlap,
                   comm_batch_coef=comm_batch_coef)


@dataclass
class AllocationResult:
    degrees: list[int]               # {N_1..N_m}, descending
    makespan: float
    placement: PlacementResult
    history: list[float] = field(default_factory=list)   # best-so-far per iteration
    evaluations: int = 0


def _random_allocation(rng: np.random.Generator, budget: int, degrees: Sequence[int]
                       ) -> list[int]:
    """Sample N_i ~ D until the budget is exactly consumed (Alg. 2 line 1)."""
    degrees = sorted(degrees)
    alloc: list[int] = []
    remaining = budget
    while remaining > 0:
        feasible = [d for d in degrees if d <= remaining]
        d = int(rng.choice(feasible))
        alloc.append(d)
        remaining -= d
    return sorted(alloc, reverse=True)


def _perturb(rng: np.random.Generator, alloc: list[int], degrees: Sequence[int]
             ) -> list[int]:
    """One of three moves (Alg. 2 line 6): redistribute / split / merge."""
    degrees = set(degrees)
    alloc = list(alloc)
    moves = ["redistribute", "split", "merge"]
    rng.shuffle(moves)
    for move in moves:
        if move == "split":
            cands = [i for i, d in enumerate(alloc) if d // 2 in degrees and d >= 2]
            if cands:
                i = int(rng.choice(cands))
                d = alloc.pop(i)
                alloc.extend([d // 2, d // 2])
                return sorted(alloc, reverse=True)
        elif move == "merge":
            if len(alloc) >= 2:
                by_deg: dict[int, list[int]] = {}
                for i, d in enumerate(alloc):
                    by_deg.setdefault(d, []).append(i)
                cands = [d for d, idxs in by_deg.items()
                         if len(idxs) >= 2 and 2 * d in degrees]
                if cands:
                    d = int(rng.choice(cands))
                    i, j = by_deg[d][:2]
                    alloc = [x for k, x in enumerate(alloc) if k not in (i, j)]
                    alloc.append(2 * d)
                    return sorted(alloc, reverse=True)
        else:  # redistribute: halve a donor, double a same-size receiver elsewhere
            if len(alloc) >= 2:
                pairs = [(i, j) for i, di in enumerate(alloc) for j, dj in enumerate(alloc)
                         if i != j and di // 2 in degrees and di >= 2
                         and dj + di // 2 in degrees]
                if pairs:
                    i, j = pairs[int(rng.integers(len(pairs)))]
                    give = alloc[i] // 2
                    alloc[i] -= give
                    alloc[j] += give
                    return sorted(alloc, reverse=True)
    return sorted(alloc, reverse=True)   # no feasible move: return unchanged


def sort_initialized_sa(
    lengths: Sequence[float],
    budget: int,
    interference: InterferenceModel,
    latency: WorkerLatencyModel | None = None,
    degrees: Sequence[int] = (1, 2, 4, 8),
    cooling: float = 0.95,
    eps_frac: float = 1e-3,
    max_workers: int | None = None,
    counts: Sequence[int] | None = None,
    seed: int = 0,
    work_aware: bool = False,
    max_group_count: float | None = None,
) -> AllocationResult:
    """Algorithm 2: sort-initialized simulated annealing over MP allocations."""
    latency = latency or WorkerLatencyModel()
    rng = np.random.default_rng(seed)

    n_total = float(np.sum(counts)) if counts is not None else float(len(lengths))
    counts_arr = (np.asarray(counts, dtype=np.float64) if counts is not None
                  else np.ones(len(lengths)))

    def evaluate(alloc: list[int]) -> tuple[float, PlacementResult]:
        if max_workers is not None and len(alloc) > max_workers:
            return math.inf, None   # infeasible: too many workers for the slot count
        # two-pass pricing: first DP at the average batch, then re-price each worker's
        # token time at its actual group size (high-MP tail workers run small batches,
        # mp1 bulk workers big ones — a single average misprices both)
        avg_batch = n_total / max(len(alloc), 1)
        res = presorted_dp(lengths, len(alloc), interference,
                           base_token_time=latency.token_times(alloc, avg_batch),
                           counts=counts,
                           work_aware=work_aware, max_group_count=max_group_count)
        group_counts = [max(sum(counts_arr[i] for i in g), 1.0) for g in res.groups]
        tt2 = np.asarray([latency.base_token_time(mp, c)
                          for mp, c in zip(alloc, group_counts)])
        res2 = presorted_dp(lengths, len(alloc), interference, base_token_time=tt2,
                            counts=counts, work_aware=work_aware,
                            max_group_count=max_group_count)
        return res2.makespan, res2

    alloc = _random_allocation(rng, budget, degrees)           # line 1-2
    cost, placement = evaluate(alloc)                          # line 3
    while not math.isfinite(cost):                             # re-sample if infeasible
        alloc = _random_allocation(rng, budget, degrees)
        cost, placement = evaluate(alloc)
    temp = cost                                                # line 4
    best_cost, best_alloc, best_placement = cost, alloc, placement
    eps = eps_frac * cost
    history = [best_cost]
    evals = 1

    while temp > eps:                                          # line 5
        cand = _perturb(rng, alloc, degrees)                   # lines 6-7 (sorted inside)
        cand_cost, cand_placement = evaluate(cand)             # line 8
        evals += 1
        delta = cand_cost - cost                               # line 9
        if math.isfinite(cand_cost) and (
                delta < 0 or rng.random() < math.exp(-delta / max(temp, 1e-12))):
            alloc, cost = cand, cand_cost                      # line 11
            if cost < best_cost:                               # lines 12-13
                best_cost, best_alloc, best_placement = cost, alloc, cand_placement
        temp *= cooling                                        # line 14
        history.append(best_cost)

    return AllocationResult(best_alloc, best_cost, best_placement, history, evals)


def homogeneous_allocation(budget: int, mp: int) -> list[int]:
    """Fix-k baseline (§7.4): all workers share one MP degree."""
    if budget % mp:
        raise ValueError(f"budget {budget} not divisible by mp {mp}")
    return [mp] * (budget // mp)
