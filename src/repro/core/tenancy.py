"""Tenant/SLA classes and serving-time overload policy (open-loop front door).

The closed-loop reproduction treats a rollout batch as one undifferentiated
pile of work; a serving front door does not.  Requests arrive over time, belong
to tenants with different SLOs, and under overload the system must decide whose
latency to protect.  This module holds the *policy vocabulary* for that layer:

* :class:`TenantClass` — an SLA class (deadline, priority weight, sheddable
  flag, traffic share).  Tier 0 is "gold": never shed, never degraded.
* :class:`ServingConfig` — admission-control and degradation-ladder knobs
  attached to :class:`repro.core.controller.HeddleConfig`.
* :func:`assign_tenants` — seeded, deterministic tenant assignment over a
  workload batch (domain-separated per trajectory id, like the fault rngs),
  stamping absolute deadlines relative to each trajectory's arrival time.

The mechanisms that *consume* this vocabulary live in ``core/controller.py``
(admission gate, shed-victim selection, per-tenant accounting) and
``core/orchestrator.py`` (arrival events, degradation ladder).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.trajectory import Trajectory

# Domain-separation constant for the tenant-assignment rng stream (the fault
# layer uses the same idiom so independent random decisions never correlate).
_TENANT_STREAM = 6151


@dataclass(frozen=True)
class TenantClass:
    """One SLA class.  ``tier`` orders the degradation ladder: tier 0 (gold)
    is untouchable, higher tiers are shed / degraded first."""

    name: str
    tier: int = 0
    deadline_s: float = math.inf     # completion SLO relative to arrival time
    weight: float = 1.0              # multiplier on the PPS priority (higher = sooner)
    sheddable: bool = False          # admission gate / ladder may drop this work
    share: float = 1.0               # fraction of arriving traffic in this class


#: Default three-class mix used by the serving bench and the launcher when the
#: user asks for tenants without spelling out a spec.
DEFAULT_TENANTS: tuple[TenantClass, ...] = (
    TenantClass("gold", tier=0, deadline_s=math.inf, weight=2.0,
                sheddable=False, share=0.25),
    TenantClass("silver", tier=1, deadline_s=math.inf, weight=1.0,
                sheddable=False, share=0.35),
    TenantClass("best_effort", tier=2, deadline_s=math.inf, weight=0.5,
                sheddable=True, share=0.40),
)


@dataclass(frozen=True)
class ServingConfig:
    """Admission-control + graceful-degradation knobs (all off by default, so
    a default controller behaves exactly like the closed-loop reproduction)."""

    # deadline-aware admission gate: predict each arrival's completion time
    # from the progressive predictor + current fast-worker-equivalent loads and
    # shed sheddable arrivals that cannot meet their SLO.
    admission_control: bool = False
    # backpressure: bounded queues.  An arrival beyond a bound is shed (if
    # sheddable) or deferred (gold/silver are never dropped at the door).
    queue_bound_per_worker: float = math.inf    # live trajectories per worker
    queue_bound_global: float = math.inf        # live trajectories fleet-wide
    # degradation ladder, driven by pressure = live / (alive_workers * max_active):
    #   level 1 (>= shed_pressure):    shed queued sheddable work, lowest tier first
    #   level 2 (>= degrade_pressure): tighten step budgets for non-gold tenants
    shed_pressure: float = math.inf
    degrade_pressure: float = math.inf
    degrade_step_grace: int = 1       # degraded trajectories get current+grace steps
    # EDF blend: priority -= nothing, priority += edf_weight * urgency * scale.
    # 0 disables deadline-shaped preemption entirely.
    edf_weight: float = 0.5
    edf_urgency_cap: float = 4.0      # cap on service/slack so late work can't explode
    defer_seconds: float = 1.0        # re-arrival delay for deferred admissions


def parse_tenants(spec: str) -> tuple[TenantClass, ...]:
    """Parse a CLI tenant spec: ``name:share[:deadline_s]`` comma-separated,
    e.g. ``gold:0.25:40,silver:0.35:80,best:0.4``.  Tiers follow list order
    (first class = tier 0 = gold); the last class is sheddable.  Shares must be
    positive and are normalised to sum to 1."""
    fields = [f.strip() for f in spec.split(",") if f.strip()]
    if not fields:
        raise ValueError("empty tenant spec")
    raw: list[tuple[str, float, float]] = []
    for f in fields:
        parts = f.split(":")
        if len(parts) not in (2, 3):
            raise ValueError(f"tenant field {f!r}: expected name:share[:deadline_s]")
        name = parts[0]
        try:
            share = float(parts[1])
            deadline = float(parts[2]) if len(parts) == 3 else math.inf
        except ValueError as e:
            raise ValueError(f"tenant field {f!r}: {e}") from None
        if not name or share <= 0 or deadline <= 0:
            raise ValueError(f"tenant field {f!r}: name must be non-empty, "
                             "share and deadline must be > 0")
        raw.append((name, share, deadline))
    total = sum(s for _, s, _ in raw)
    n = len(raw)
    return tuple(
        # weights halve per tier (gold highest): PPS pops max priority first
        TenantClass(name, tier=i, deadline_s=deadline, weight=2.0 ** (n - 2 - i),
                    sheddable=(i == n - 1 and n > 1), share=share / total)
        for i, (name, share, deadline) in enumerate(raw)
    )


def assign_tenants(trajectories: Sequence[Trajectory],
                   tenants: Optional[Sequence[TenantClass]] = None,
                   seed: int = 0) -> None:
    """Stamp tenant/SLA fields onto a batch, deterministically per traj_id.

    Deadlines are absolute virtual times: ``submit_time + deadline_s``, so run
    :func:`repro.engine.workload.assign_arrivals` *first*.  Seeded per
    trajectory id (not per list position) so the same workload gets the same
    tenant mix regardless of batch slicing.
    """
    classes = tuple(tenants) if tenants else DEFAULT_TENANTS
    shares = np.array([c.share for c in classes], dtype=float)
    cum = np.cumsum(shares / shares.sum())
    for t in trajectories:
        u = np.random.default_rng((seed, _TENANT_STREAM, t.traj_id)).random()
        cls = classes[int(np.searchsorted(cum, u, side="right").clip(0, len(classes) - 1))]
        t.tenant = cls.name
        t.tenant_tier = cls.tier
        t.priority_weight = cls.weight
        t.sheddable = cls.sheddable
        t.slo_deadline = (t.submit_time + cls.deadline_s
                          if math.isfinite(cls.deadline_s) else math.inf)
