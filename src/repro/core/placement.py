"""Trajectory-aware placement (paper §5): presorted dynamic programming.

Problem (Formula 2): partition n trajectories across m workers minimizing
``max_i F(g_i) * max_len(g_i) * T`` where F is a monotone interference factor of group
size.  Lemma 5.1: with trajectories sorted by descending length, some optimal partition is
contiguous — so the search space drops from Stirling S(n, m) to C(n-1, m-1), and the DP in
Formula 3 resolves it exactly in O(n^2 m).

This module provides:
  * ``InterferenceModel`` — F(batch) from profiler samples or the roofline-analytic
    default (decode per-token time t(b) = t_weights + t_kv*b, so F(b) = t(b)/t(1)).
  * ``presorted_dp``      — the paper's DP (vectorized; optional monotone two-pointer
    speedup, a beyond-paper control-plane optimization recorded in EXPERIMENTS.md §Perf).
  * ``aggregate_short``   — the paper's short-trajectory aggregation heuristic.
  * ``brute_force_partition`` — exact enumeration oracle for tests.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Sequence

import numpy as np


class InterferenceModel:
    """Monotone interference factor F(group_size) (paper §5.2 'Interference Factor').

    The paper profiles per-token time across batch sizes and feeds a simulator; we keep
    the profile as a lookup table with linear interpolation.  The analytic default models
    memory-bound decode: one step reads the weights once (t_w, shared by the batch is NOT
    possible per-token-latency-wise — every decode step costs t_w regardless of batch) plus
    each sequence's KV cache (t_kv each), so step latency t(b) = t_w + t_kv * b and
    F(b) = t(b) / t(1).
    """

    def __init__(self, batch_sizes: Sequence[float], per_token_time: Sequence[float]):
        bs = np.asarray(batch_sizes, dtype=np.float64)
        tt = np.asarray(per_token_time, dtype=np.float64)
        order = np.argsort(bs)
        self._bs, self._tt = bs[order], tt[order]
        if not np.all(np.diff(self._tt) >= -1e-12):
            raise ValueError("per-token time must be monotone non-decreasing in batch size")
        self._base = self._tt[0]

    @classmethod
    def analytic(cls, kv_weight_ratio: float = 0.05, max_batch: int = 4096) -> "InterferenceModel":
        """Roofline default: t(b) = 1 + kv_weight_ratio * b (normalized to t_w = 1)."""
        bs = np.arange(1, max_batch + 1, dtype=np.float64)
        return cls(bs, 1.0 + kv_weight_ratio * bs)

    @classmethod
    def from_profile(cls, profile: dict[int, float]) -> "InterferenceModel":
        items = sorted(profile.items())
        return cls([b for b, _ in items], [t for _, t in items])

    def per_token_time(self, batch: float) -> float:
        return float(np.interp(batch, self._bs, self._tt))

    def __call__(self, group_size: float) -> float:
        if group_size <= 0:
            return 0.0
        return self.per_token_time(group_size) / self._base

    def table(self, n: int) -> np.ndarray:
        """F evaluated at group sizes 0..n (F(0) := 0 so empty groups cost nothing)."""
        sizes = np.arange(n + 1, dtype=np.float64)
        out = np.interp(sizes, self._bs, self._tt) / self._base
        out[0] = 0.0
        return out


@dataclass
class PlacementResult:
    groups: list[list[int]]        # per-worker lists of item indices (into the sorted order)
    makespan: float                # predicted makespan (Formula 2 objective)
    splits: list[int]              # DP split points (prefix sizes), len m
    order: np.ndarray              # indices sorting the original lengths descending


def sort_desc(lengths: Sequence[float]) -> np.ndarray:
    return np.argsort(-np.asarray(lengths, dtype=np.float64), kind="stable")


def presorted_dp(
    lengths: Sequence[float],
    m: int,
    interference: InterferenceModel,
    base_token_time: float | Sequence[float] = 1.0,
    counts: Sequence[int] | None = None,
    monotone_speedup: bool = True,
    max_group_count: float | None = None,
    work_aware: bool = False,
) -> PlacementResult:
    """Formula 3 DP over descending-sorted trajectories.

    ``counts`` supports aggregated items (an item standing for `count` short
    trajectories); group interference is evaluated at the summed count.

    ``base_token_time`` may be a per-worker vector (descending-MP order) — the §6
    heterogeneous extension: worker j's groups cost L * T_j * F.  Workers are consumed
    in order, matching the resource manager's sort-initialized mapping.

    dp[i][j] = optimal makespan for the first i sorted items on j workers:
        dp[i][1] = L(1) * T * F(c_1..i)
        dp[i][j] = min_k max( dp[k][j-1], L(k+1) * T * F(c_{k+1}..i) )

    With lengths descending and F monotone, cost(k+1, i) is non-increasing in k while
    dp[k][j-1] is non-decreasing, so the argmin is locatable by binary search
    (``monotone_speedup``) reducing O(n^2 m) to O(n m log n).

    ``max_group_count`` caps group count at the worker's batch-slot capacity (Formula 2
    models members as co-resident, which only holds within the batch).  ``work_aware``
    (beyond-paper, EXPERIMENTS.md §Perf) strengthens Formula 2's longest-member bound to
        cost(g) = max( F(|g|)*maxlen(g)*T,  total_len(g)*T*F(b)/b ),  b = min(|g|, cap):
    a group's completion can never beat either lower bound, and Formula 2 alone lets the
    DP pile unbounded work behind a small maxlen.  Contiguity (Lemma 5.1) still holds —
    the swap argument only needs group cost non-increasing when a member is swapped for
    a shorter one at equal count, true for both terms.
    """
    lengths = np.asarray(lengths, dtype=np.float64)
    n = len(lengths)
    if n == 0:
        return PlacementResult([[] for _ in range(m)], 0.0, [0] * m, np.array([], dtype=int))
    order = sort_desc(lengths)
    slen = lengths[order]
    scnt = (np.ones(n) if counts is None else np.asarray(counts, dtype=np.float64)[order])
    csum = np.concatenate([[0.0], np.cumsum(scnt)])          # csum[i] = count of first i items
    m_eff = min(m, n)

    if np.ndim(base_token_time) == 0:
        tvec = np.full(m_eff, float(base_token_time))
    else:
        tvec = np.asarray(base_token_time, dtype=np.float64)[:m_eff]
        if len(tvec) < m_eff:
            raise ValueError("per-worker token-time vector shorter than worker count")

    cap = float("inf") if max_group_count is None else float(max_group_count)
    if csum[-1] > cap * m_eff:          # infeasible cap: relax proportionally
        cap = csum[-1] / m_eff * 1.25

    ftab = interference.table(int(round(csum[-1])))

    def fcount(c: np.ndarray | float) -> np.ndarray | float:
        # counts are integral (trajectory counts), so F is a direct table lookup —
        # np.interp here costs ~10x more and dominated the SA loop before this.
        if isinstance(c, np.ndarray):
            return ftab[c.astype(np.int64)]
        return ftab[int(c)]

    # work-conserving term: per-count throughput divisor g(c) = F(min(c,cap)) / min(c,cap)
    wsum = np.concatenate([[0.0], np.cumsum(slen * scnt)])   # total predicted tokens
    gdiv = None
    if work_aware:
        cap_idx = len(ftab) - 1 if not np.isfinite(cap) else int(min(cap, len(ftab) - 1))
        cc = np.minimum(np.arange(len(ftab), dtype=np.float64), max(float(cap_idx), 1.0))
        cc[0] = 1.0
        gdiv = ftab.copy()
        gdiv[1:] = ftab[np.minimum(np.arange(1, len(ftab)), cap_idx)] / cc[1:]

    def gcost_scalar(k, i, T):
        c = csum[i] - csum[k]
        if c > cap:
            return np.inf
        base = slen[k] * T * fcount(c)
        if work_aware and c >= 1:
            base = max(base, (wsum[i] - wsum[k]) * T * gdiv[int(c)])
        return base

    def gcost_vec(ks, i, T):
        c = csum[i] - csum[ks]
        base = slen[ks] * T * fcount(c)
        if work_aware:
            wb = (wsum[i] - wsum[ks]) * T * gdiv[np.maximum(c.astype(np.int64), 1)]
            base = np.maximum(base, wb)
        return np.where(c <= cap, base, np.inf)

    # cost(a, i) = slen[a] * T_j * F(csum[i] - csum[a]) for group = items a..i-1 (0-based)
    dp = np.full((n + 1, m_eff + 1), np.inf)
    arg = np.zeros((n + 1, m_eff + 1), dtype=int)
    dp[0, 0] = 0.0
    # j = 1 row
    dp[1:, 1] = np.array([gcost_scalar(0, i, tvec[0]) for i in range(1, n + 1)])
    for j in range(2, m_eff + 1):
        T = tvec[j - 1]
        if monotone_speedup:
            for i in range(j, n + 1):
                lo, hi = j - 1, i - 1   # k range: previous j-1 workers need >= j-1 items
                # binary search for crossing point of dp[k][j-1] (nondecr) vs cost (nonincr)
                def cost(k):
                    return gcost_scalar(k, i, T)

                while lo < hi:
                    mid = (lo + hi) // 2
                    if dp[mid, j - 1] < cost(mid):
                        lo = mid + 1
                    else:
                        hi = mid
                best_k, best_v = lo, max(dp[lo, j - 1], cost(lo))
                if lo > j - 1:  # check the neighbor on the other side of the crossing
                    v = max(dp[lo - 1, j - 1], cost(lo - 1))
                    if v < best_v:
                        best_k, best_v = lo - 1, v
                dp[i, j], arg[i, j] = best_v, best_k
        else:
            for i in range(j, n + 1):
                ks = np.arange(j - 1, i)
                cand = np.maximum(dp[ks, j - 1], gcost_vec(ks, i, T))
                b = int(np.argmin(cand))
                dp[i, j], arg[i, j] = cand[b], ks[b]

    makespan = float(dp[n, m_eff])
    # backtrack splits
    splits_rev = []
    i = n
    for j in range(m_eff, 0, -1):
        k = int(arg[i, j]) if j > 1 else 0
        splits_rev.append(i)
        i = k
    bounds = [0] + splits_rev[::-1]
    groups: list[list[int]] = []
    for a, b in zip(bounds[:-1], bounds[1:]):
        groups.append([int(order[t]) for t in range(a, b)])
    while len(groups) < m:
        groups.append([])
    return PlacementResult(groups, makespan, bounds[1:] + [n] * (m - m_eff), order)


def aggregate_short(
    lengths: Sequence[float], threshold: float, block: int = 8
) -> tuple[np.ndarray, np.ndarray, list[list[int]]]:
    """Paper §5.2 heuristic: after sorting, coalesce sub-threshold trajectories into
    blocks of ``block`` treated as single DP items (length = block max, count = block size).

    Returns (item_lengths, item_counts, item_members) where members map items back to
    original trajectory indices.
    """
    lengths = np.asarray(lengths, dtype=np.float64)
    order = sort_desc(lengths)
    item_lengths: list[float] = []
    item_counts: list[int] = []
    members: list[list[int]] = []
    i = 0
    n = len(order)
    while i < n:
        idx = int(order[i])
        if lengths[idx] >= threshold:
            item_lengths.append(float(lengths[idx]))
            item_counts.append(1)
            members.append([idx])
            i += 1
        else:
            chunk = [int(order[t]) for t in range(i, min(i + block, n))]
            item_lengths.append(float(lengths[chunk[0]]))  # max of chunk (sorted desc)
            item_counts.append(len(chunk))
            members.append(chunk)
            i += len(chunk)
    return np.asarray(item_lengths), np.asarray(item_counts), members


def place(
    lengths: Sequence[float],
    m: int,
    interference: InterferenceModel,
    base_token_time: float = 1.0,
    agg_threshold: float | None = None,
    agg_block: int = 8,
) -> PlacementResult:
    """Full placement pipeline: optional aggregation -> presorted DP -> expand members."""
    if agg_threshold is None:
        return presorted_dp(lengths, m, interference, base_token_time)
    ilen, icnt, members = aggregate_short(lengths, agg_threshold, agg_block)
    res = presorted_dp(ilen, m, interference, base_token_time, counts=icnt)
    groups = [[orig for item in g for orig in members[item]] for g in res.groups]
    return PlacementResult(groups, res.makespan, res.splits, res.order)


def evaluate_partition(
    groups: Sequence[Sequence[int]],
    lengths: Sequence[float],
    interference: InterferenceModel,
    base_token_time: float | Sequence[float] = 1.0,
) -> float:
    """Formula 2 objective for an arbitrary partition (scalar or per-worker T)."""
    lengths = np.asarray(lengths, dtype=np.float64)
    if np.ndim(base_token_time) == 0:
        tvec = np.full(len(groups), float(base_token_time))
    else:
        tvec = np.asarray(base_token_time, dtype=np.float64)
    worst = 0.0
    for j, g in enumerate(groups):
        if len(g):
            worst = max(worst, interference(len(g)) * float(lengths[list(g)].max())
                        * tvec[j])
    return worst


def brute_force_partition(
    lengths: Sequence[float],
    m: int,
    interference: InterferenceModel,
    base_token_time: float = 1.0,
) -> tuple[list[list[int]], float]:
    """Exact enumeration over all assignments (test oracle; n small)."""
    n = len(lengths)
    best, best_groups = np.inf, None
    for assign in itertools.product(range(m), repeat=n):
        groups: list[list[int]] = [[] for _ in range(m)]
        for t, w in enumerate(assign):
            groups[w].append(t)
        v = evaluate_partition(groups, lengths, interference, base_token_time)
        if v < best:
            best, best_groups = v, groups
    return best_groups, float(best)
