"""Heddle control plane (paper §3) and baseline routing policies (§7 baselines).

The control plane maintains the global view (cluster resources + trajectory states) and
makes the three orchestration decisions:

  when  — scheduler (core/scheduler.py), refreshed by the progressive predictor;
  where — placement (core/placement.py DP) + runtime migration (core/migration.py);
  how   — resource manager (core/resource_manager.py simulated annealing).

Baseline policies reproduce the paper's comparison systems on identical substrate:
  * ``CacheAffinityRouting`` — Verl: statically pin each trajectory to a worker
    (max prefix-cache hits, no load rebalancing).
  * ``LeastLoadRouting`` — Slime: route every step to the least-loaded worker.
  * ``HybridRouting`` — Verl*: least-load when load skew (max/min) exceeds a threshold,
    else cache-affine.
  * ``HeddleRouting`` — presorted-DP partition + rank-scaled migration.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Protocol, Sequence

import numpy as np

from repro.core.migration import (MigrationRequest, ScaledCapacityRouter,
                                  TransmissionScheduler)
from repro.core.placement import InterferenceModel
from repro.core.predictor import ProgressivePredictor
from repro.core.resource_manager import (WorkerLatencyModel, homogeneous_allocation,
                                         sort_initialized_sa)
from repro.core.tenancy import ServingConfig
from repro.core.trajectory import Trajectory


class RoutingPolicy(Protocol):
    def initial_worker(self, traj: Trajectory, loads: np.ndarray) -> int: ...
    def step_worker(self, traj: Trajectory, loads: np.ndarray) -> int: ...


class CacheAffinityRouting:
    """Verl-style: pin each GRPO group (all samples of one prompt) to one worker.

    Group pinning maximizes prefix-cache hits (the 16 samples share the prompt), which
    is exactly why it suffers the paper's load-imbalance pathology: a hard prompt's
    entire group of correlated-long trajectories lands on a single worker."""

    def initial_worker(self, traj: Trajectory, loads: np.ndarray) -> int:
        return traj.prompt_id % len(loads)

    def step_worker(self, traj: Trajectory, loads: np.ndarray) -> int:
        return traj.worker_id


class LeastLoadRouting:
    """Slime-style: every step goes to the least-loaded worker (cache be damned)."""

    def initial_worker(self, traj: Trajectory, loads: np.ndarray) -> int:
        return int(np.argmin(loads))

    def step_worker(self, traj: Trajectory, loads: np.ndarray) -> int:
        return int(np.argmin(loads))


class HybridRouting:
    """Verl*-style: least-load if max/min skew > threshold else cache-affine."""

    def __init__(self, skew_threshold: float = 32.0) -> None:
        self.skew_threshold = skew_threshold

    def initial_worker(self, traj: Trajectory, loads: np.ndarray) -> int:
        return traj.prompt_id % len(loads)

    def step_worker(self, traj: Trajectory, loads: np.ndarray) -> int:
        lo = max(float(loads.min()), 1.0)
        if float(loads.max()) / lo > self.skew_threshold:
            return int(np.argmin(loads))
        return traj.worker_id


@dataclass
class HeddleConfig:
    scheduler: str = "pps"
    adaptive_resources: bool = True
    migration: bool = True
    agg_threshold_quantile: float = 0.5   # aggregate trajectories below this length quantile
    agg_block: int = 8
    mp_degrees: tuple[int, ...] = (1, 2, 4, 8)
    sa_cooling: float = 0.95
    sa_seed: int = 0
    rank_hysteresis: float = 0.50         # migrate only on a material prediction change
    migration_cooldown_steps: int = 2     # steps between migrations of one trajectory
    max_migrations_per_traj: int = 2
    migration_load_gap: int = 4           # min live-count gap before migrating (material
                                          # benefit: KV transfer + re-warm are not free)
    max_group_count: float | None = None  # worker batch-slot capacity (DP group cap)
    work_aware_dp: bool = True            # beyond-paper DP cost (EXPERIMENTS.md §Perf);
                                          # False = paper-faithful Formula 2
    # open-loop serving policy (admission control, backpressure, degradation
    # ladder); the default ServingConfig disables all of it
    serving: ServingConfig = field(default_factory=ServingConfig)


@dataclass(frozen=True)
class AdmissionDecision:
    """Admission gate verdict for one open-loop arrival."""

    action: str                 # "admit" | "shed" | "defer"
    worker: int = -1            # placement when admitted
    reason: str = ""            # gate that fired ("queue_full", "deadline", ...)
    eta: float = 0.0            # predicted completion time (deadline gate only)


class HeddleController:
    """Trajectory-centric control plane for one rollout batch."""

    def __init__(
        self,
        predictor: ProgressivePredictor,
        interference: InterferenceModel,
        latency: WorkerLatencyModel,
        gpu_budget: int,
        config: HeddleConfig | None = None,
        max_workers: int | None = None,
    ) -> None:
        self.predictor = predictor
        self.interference = interference
        self.latency = latency
        self.gpu_budget = gpu_budget
        self.config = config or HeddleConfig()
        self.max_workers = max_workers
        self.transmission = TransmissionScheduler()
        self.capacity_router: Optional[ScaledCapacityRouter] = None
        self.degrees: list[int] = []
        self.groups: list[list[int]] = []
        self._traj_index: dict[int, Trajectory] = {}
        self.worker_stats: dict[int, dict] = {}   # wid -> engine dispatch_stats()
        self._finished_ids: set[int] = set()      # on_finish idempotency guard
        # migrations emitted but not yet executed: load accounting moves only when
        # the transfer actually launches (commit_migration) — emitting a request the
        # transmission scheduler later drops must not leak worker counts
        self._pending_migration: dict[int, MigrationRequest] = {}
        self._dead_workers: set[int] = set()  # fault layer: no placements here
        # ---- open-loop serving state (inert until begin_serving) ----------
        self._serving = False
        self._max_active = 1                      # decode slots per worker
        self.tenant_stats: dict[str, dict] = {}   # per-tenant latency accounting
        self._arrived_ids: set[int] = set()       # first-arrival dedup (deferrals re-enter)
        self.shed_log: list[tuple[int, str]] = [] # (traj_id, reason), decision order
        self.peak_global_count = 0                # queue-bound property-test watermarks
        self.peak_worker_count = 0

    # ------------------------------------------------------------ telemetry (measured)
    def record_worker_stats(self, worker_id: int, stats: dict) -> None:
        """Ingest a data-plane worker's ``dispatch_stats()`` snapshot.

        The engine reports *measured* prefix reuse (tokens implanted from the radix
        cache vs tokens actually prefilled), which replaces the control plane's
        assumed hit rates in placement and simulation."""
        self.worker_stats[worker_id] = dict(stats)

    @property
    def measured_reuse_rate(self) -> Optional[float]:
        """Fraction of admission tokens served from cached prefixes, cluster-wide.

        Admission tokens only — tool absorption (``absorbed_tokens``) is excluded,
        it has its own cache-hit path.  Cold first-of-group admissions ARE in the
        denominator, so this is a conservative lower bound on the per-sibling
        implant fraction the simulator's cache model applies.  ``None`` until any
        worker has reported — callers fall back to the paper's assumed full-prompt
        reuse in that case."""
        reused = sum(s.get("reused_tokens", 0) for s in self.worker_stats.values())
        total = reused + sum(s.get("prefilled_tokens", 0)
                             for s in self.worker_stats.values())
        if total == 0:
            return None
        return reused / total

    def calibration_observations(self) -> list[tuple[int, float, float]]:
        """Measured ``(mp, mean_batch, per_step_seconds)`` per reporting worker.

        Derived from the engine's decode telemetry (warm, compile-free calls
        only): ``decode_wall_s / decode_timed_steps`` is the observed per-STEP
        decode time at the worker's declared MP degree — the quantity the
        latency model prices, since the full-pool masked kernel costs the same
        whether 1 or 8 lanes are live and one step advances every live lane one
        token.  ``decode_timed_lane_steps / decode_timed_steps`` is the mean
        live batch the model's comm/interference term regresses on.  Feeds
        ``WorkerLatencyModel.fit`` (§6 calibration — t1/overlap from
        observations instead of Fig. 7 constants).
        """
        obs: list[tuple[int, float, float]] = []
        for stats in self.worker_stats.values():
            steps = stats.get("decode_timed_steps", 0)
            lane_steps = stats.get("decode_timed_lane_steps", 0)
            wall = stats.get("decode_wall_s", 0.0)
            if steps > 0 and wall > 0.0:
                obs.append((int(stats.get("mp", 1)),
                            lane_steps / steps, wall / steps))
        return obs

    def calibrate_latency(self, observations=None) -> Optional[WorkerLatencyModel]:
        """Refit the worker latency model from measured decode timing.

        Swaps ``self.latency`` so the next provisioning / placement round prices
        MP degrees from observed behavior.  Returns the fitted model, or None
        when no worker has reported timing yet (model unchanged)."""
        obs = (observations if observations is not None
               else self.calibration_observations())
        if not obs:
            return None
        self.latency = WorkerLatencyModel.fit(
            obs, comm_batch_coef=self.latency.comm_batch_coef)
        return self.latency

    # ------------------------------------------------------------ provisioning (how)
    def provision(self, trajectories: Sequence[Trajectory]) -> list[int]:
        """Run Algorithm 2 (or homogeneous fallback) to pick worker MP degrees.

        Lengths are pre-aggregated (§5.2 short-trajectory heuristic) so every SA
        evaluation's DP runs on a few hundred items instead of thousands.
        """
        lengths = self._predicted_lengths(trajectories)
        # Provisioning runs periodically and is amortized across training steps
        # (paper §7.5), so it plans on the *historical length distribution* — which is
        # stable across steps — rather than on this batch's prompt-time point
        # predictions (which are intra-group-variance-blind, Fig. 5).  Resample the
        # historical distribution to this batch's size.
        hist = getattr(self.predictor, "hist_lengths", None)
        if hist is not None and len(hist) > 8 and len(lengths):
            q = np.linspace(0.0, 1.0, len(lengths))
            lengths = np.quantile(hist, q)
        if self.config.adaptive_resources:
            from repro.core.placement import aggregate_short
            n = len(lengths)
            if n > 192:
                # keep the long tail at full resolution, bundle the rest aggressively:
                # the SA only needs coarse makespans to rank allocations
                thresh = float(np.quantile(lengths, max(0.0, 1.0 - 64.0 / n)))
                block = max(self.config.agg_block, -(-n // 128))
                ilen, icnt, _ = aggregate_short(lengths, thresh, block)
            else:
                ilen, icnt = lengths, None
            res = sort_initialized_sa(
                ilen, self.gpu_budget, self.interference, self.latency,
                degrees=self.config.mp_degrees, cooling=self.config.sa_cooling,
                max_workers=self.max_workers, counts=icnt, seed=self.config.sa_seed,
                work_aware=self.config.work_aware_dp,
                max_group_count=self.config.max_group_count)
            self.degrees = res.degrees
        else:
            self.degrees = homogeneous_allocation(self.gpu_budget, self.config.mp_degrees[0])
        return self.degrees

    # ------------------------------------------------------------ placement (where)
    def initial_placement(self, trajectories: Sequence[Trajectory]) -> list[list[int]]:
        """Presorted DP over prompt-stage predictions; returns per-worker traj lists."""
        self._traj_index = {t.traj_id: t for t in trajectories}
        lengths = self._predicted_lengths(trajectories)
        m = len(self.degrees) if self.degrees else (self.max_workers or 1)
        thresh = float(np.quantile(lengths, self.config.agg_threshold_quantile)) \
            if len(lengths) else None
        token_times = (self.latency.token_times(self.degrees)
                       if self.degrees else 1.0)
        # heterogeneous DP via per-worker token times, aggregation for speed
        from repro.core.placement import aggregate_short, presorted_dp
        cap = self.config.max_group_count
        wa = self.config.work_aware_dp
        if thresh is not None and len(lengths) > 4 * m:
            ilen, icnt, members = aggregate_short(lengths, thresh, self.config.agg_block)
            res = presorted_dp(ilen, m, self.interference, token_times, counts=icnt,
                               max_group_count=cap, work_aware=wa)
            groups = [[orig for item in g for orig in members[item]] for g in res.groups]
        else:
            res = presorted_dp(lengths, m, self.interference, token_times,
                               max_group_count=cap, work_aware=wa)
            groups = res.groups
        self.groups = groups
        self.capacity_router = ScaledCapacityRouter([len(g) for g in groups])
        for w, group in enumerate(groups):
            for idx in group:
                trajectories[idx].worker_id = w
        # incremental rank-tracking state (see on_step_complete)
        self._slots = {t.traj_id: i for i, t in enumerate(trajectories)}
        self._n_slots = len(trajectories)
        self._pred_totals = np.asarray([t.predicted_total for t in trajectories])
        self._live = np.ones(len(trajectories), dtype=bool)
        # per-worker live-trajectory counts (migration load feedback)
        self._worker_count = np.array([len(g) for g in groups], dtype=np.int64)
        # heterogeneity-aware load weights: a resident trajectory on a slow
        # (low-MP) worker represents more drain time than one on a fast worker,
        # so the migration gate compares counts in fast-worker equivalents —
        # count * token_time / min(token_time).  Homogeneous fleets reduce to
        # the plain counts this replaces.
        if self.degrees and len(self.degrees) == m:
            tts = np.asarray(self.latency.token_times(self.degrees), dtype=float)
            self._load_weight = tts / tts.min()
        else:
            self._load_weight = np.ones(m, dtype=float)
        self._finished_ids.clear()
        self._pending_migration.clear()
        for t in trajectories:
            t._last_migration_pred = t.predicted_total    # hysteresis anchor
        return groups

    # ------------------------------------------------------------ runtime (telemetry)
    def on_step_complete(self, traj: Trajectory, active: Sequence[Trajectory]) -> Optional[MigrationRequest]:
        """Telemetry hook: refresh prediction, maybe emit a migration request (§5.3).

        Rank computation is incremental: a dense array of predicted totals (indexed by a
        per-batch slot id) is kept up to date one entry at a time, so each telemetry
        event costs O(n) vector ops instead of an O(n log n) sort.
        """
        traj.predicted_remaining = self.predictor.predict(traj)
        traj.priority = traj.predicted_total
        if not (self.config.migration and self.capacity_router is not None):
            return None
        slot = self._slots.get(traj.traj_id)
        if slot is None or traj.finished:
            return None
        # refresh the rank state even when no new request may be emitted, so
        # other trajectories never rank against a stale priority
        self._pred_totals[slot] = traj.priority
        self._live[slot] = not traj.finished
        if traj.traj_id in self._pending_migration:
            return None                   # one in-flight migration per trajectory
        live_preds = self._pred_totals[self._live]
        n_active = int(self._live.sum())
        if n_active == 0:
            return None
        rank = int((live_preds > traj.priority).sum())
        target = self.capacity_router.worker_for_rank(rank, n_active)
        # load feedback (beyond-paper, EXPERIMENTS.md §Perf): the paper's open-loop
        # scaled-capacity mapping over-concentrates late-discovered tails on the few
        # original "long" workers; pick the least-loaded worker within a
        # +/-2-group window of the capacity target instead.  Loads are in
        # fast-worker equivalents (count * relative token time): on a
        # heterogeneous fleet an "idle" mp=1 worker is NOT a good home for a
        # tail that a busy mp=4 worker would still drain sooner.
        loads = (self._worker_count * self._load_weight).astype(float)
        if self._dead_workers:
            # a dead worker must never win the window argmin (inf on the loads
            # array, NOT an inf load weight: inf * 0 residents would be nan)
            loads[list(self._dead_workers)] = np.inf
        lo, hi = max(0, target - 2), min(len(self._worker_count), target + 3)
        target = lo + int(np.argmin(loads[lo:hi]))
        # material-benefit gate: a migration must buy a real interference reduction
        # (KV transfer + re-warm are not free), so require a clear load gap
        if loads[target] + self.config.migration_load_gap \
                > loads[traj.worker_id]:
            return None
        # backpressure: a migration must not push the target over its queue
        # bound (unbounded by default, so closed-loop behavior is unchanged)
        if float(self._worker_count[target]) + 1.0 \
                > self.config.serving.queue_bound_per_worker:
            return None
        if target != traj.worker_id:
            # hysteresis: only migrate when the prediction moved materially since the
            # last migration decision — rank jitter at group boundaries otherwise
            # ping-pongs trajectories between adjacent workers
            last = getattr(traj, "_last_migration_pred", None)
            if last is not None and abs(traj.priority - last) < \
                    self.config.rank_hysteresis * max(last, 1.0) \
                    and abs(target - traj.worker_id) < 2:
                return None
            if traj.migrations >= self.config.max_migrations_per_traj:
                return None
            if traj.num_steps - getattr(traj, "_last_mig_step", -99) < \
                    self.config.migration_cooldown_steps:
                return None
            traj._last_mig_step = traj.num_steps
            traj._last_migration_pred = traj.priority
            req = MigrationRequest(traj.traj_id, traj.worker_id, target,
                                   length=traj.predicted_total)
            self._pending_migration[traj.traj_id] = req
            self.transmission.submit(req)
            return req
        return None

    def commit_migration(self, traj_id: int) -> Optional[MigrationRequest]:
        """The KV transfer for ``traj_id`` actually launched: move load accounting.

        Idempotent — a second commit (or a commit for a request that was never
        emitted) is a no-op, so runtimes can call it from completion paths without
        tracking which requests they already acknowledged."""
        req = self._pending_migration.pop(traj_id, None)
        if req is not None:
            self._worker_count[req.src] -= 1
            self._worker_count[req.dst] += 1
        return req

    def abort_migration(self, traj_id: int) -> None:
        """Drop an emitted-but-unexecuted migration (trajectory resumed/finished).

        No load accounting to undo — counts move only at commit."""
        if self._pending_migration.pop(traj_id, None) is not None:
            self.transmission.cancel(traj_id)

    def on_finish(self, traj: Trajectory) -> None:
        if traj.traj_id in self._finished_ids:
            return                        # idempotent: double-finish must not
        self._finished_ids.add(traj.traj_id)  # double-decrement worker counts
        self.abort_migration(traj.traj_id)
        slot = self._slots.get(traj.traj_id)
        if slot is not None:
            self._live[slot] = False
        if getattr(self, "_worker_count", None) is not None and traj.worker_id is not None \
                and traj.worker_id < len(self._worker_count):
            self._worker_count[traj.worker_id] -= 1
        if self._serving:
            ts = self._tstat(traj.tenant)
            ts["finished"] += 1
            ts["latencies"].append(traj.completion_time())
            if traj.finish_time <= traj.slo_deadline:
                ts["deadline_met"] += 1

    def on_degrade(self, traj: Trajectory) -> None:
        """Tenant accounting for a ladder level-2 step-budget tightening."""
        self._tstat(traj.tenant)["degraded"] += 1

    # ------------------------------------------------------------ faults (elasticity)
    def mark_worker_dead(self, worker_id: int) -> None:
        """Worker died: exclude it from every future placement decision.

        Its residents are recovered one by one via :meth:`on_recover`, which
        moves the load accounting; the count left here is whatever the
        orchestrator has not yet re-admitted."""
        self._dead_workers.add(worker_id)

    def mark_worker_alive(self, worker_id: int) -> None:
        """Replacement capacity joined for slot ``worker_id`` (cold cache)."""
        self._dead_workers.discard(worker_id)

    def on_recover(self, traj: Trajectory, dst: int) -> None:
        """A checkpoint restore re-admitted ``traj`` on ``dst``: move its load.

        Mirrors ``commit_migration``'s accounting for the recovery path; any
        pending migration for the trajectory is stale (its src is gone)."""
        self.abort_migration(traj.traj_id)
        if getattr(self, "_worker_count", None) is None:
            return
        src = traj.worker_id
        if src is not None and src < len(self._worker_count):
            self._worker_count[src] -= 1
        if dst < len(self._worker_count):
            self._worker_count[dst] += 1

    # ------------------------------------------------------- serving (open loop)
    def begin_serving(self, max_active: int) -> None:
        """Enter open-loop mode: empty rank state, arrivals admitted one by one.

        The closed-loop path sizes its rank-tracking arrays in
        :meth:`initial_placement` from the whole batch; a serving front door
        sees trajectories only as they arrive, so the dense arrays start empty
        and grow geometrically (padding slots stay ``live=False`` so every
        closed-loop vector op still works unchanged).
        """
        m = len(self.degrees) if self.degrees else (self.max_workers or 1)
        self._serving = True
        self._max_active = max(int(max_active), 1)
        self.groups = [[] for _ in range(m)]
        # equal nominal capacities: open loop has no batch presort to derive
        # group sizes from, so rank-scaled migration maps ranks uniformly
        self.capacity_router = ScaledCapacityRouter([1.0] * m)
        self._traj_index = {}
        self._slots: dict[int, int] = {}
        self._n_slots = 0
        self._pred_totals = np.zeros(0, dtype=float)
        self._live = np.zeros(0, dtype=bool)
        self._worker_count = np.zeros(m, dtype=np.int64)
        if self.degrees and len(self.degrees) == m:
            tts = np.asarray(self.latency.token_times(self.degrees), dtype=float)
            self._load_weight = tts / tts.min()
        else:
            self._load_weight = np.ones(m, dtype=float)
        self._finished_ids.clear()
        self._pending_migration.clear()
        self.tenant_stats.clear()
        self._arrived_ids.clear()
        self.shed_log.clear()
        self.peak_global_count = 0
        self.peak_worker_count = 0

    def _tstat(self, tenant: str) -> dict:
        return self.tenant_stats.setdefault(tenant, {
            "arrived": 0, "admitted": 0, "deferred": 0, "shed": 0,
            "finished": 0, "deadline_met": 0, "degraded": 0, "latencies": [],
        })

    def _abs_token_time(self, worker_id: int) -> float:
        """Absolute per-token seconds on one worker (admission-gate pricing)."""
        if self.degrees and worker_id < len(self.degrees):
            return float(self.latency.base_token_time(self.degrees[worker_id]))
        return float(self.latency.t1)

    def service_estimate(self, traj: Trajectory, worker_id: int) -> float:
        """Predicted seconds to drain ``traj`` on ``worker_id`` at current load.

        Processor-sharing approximation: predicted remaining tokens priced at
        the worker's token time, stretched by the residents it would share the
        worker with.  Deliberately deterministic and cheap — this is the
        admission gate's completion-time oracle, not a simulator.
        """
        tokens = max(float(traj.predicted_remaining), 1.0)
        sharing = 1.0 + float(self._worker_count[worker_id])
        return tokens * self._abs_token_time(worker_id) * sharing

    def edf_boost(self, traj: Trajectory, now: float) -> float:
        """EDF urgency term blended into the PPS priority at submit time.

        urgency = predicted service time / remaining slack (capped): a request
        whose slack is shrinking toward its service demand outranks peers of
        equal predicted length, so deadlines shape preemption without
        abandoning the paper's LPT core.  Scale-matched to predicted_total so
        the boost competes in the same units as the base priority.
        """
        cfg = self.config.serving
        if cfg.edf_weight <= 0.0 or not math.isfinite(traj.slo_deadline):
            return 0.0
        fastest = min((self._abs_token_time(w)
                       for w in range(len(self._worker_count))
                       if w not in self._dead_workers),
                      default=self._abs_token_time(0))
        service = max(float(traj.predicted_remaining), 1.0) * fastest
        slack = traj.slo_deadline - now
        urgency = cfg.edf_urgency_cap if slack <= 0.0 else \
            min(service / slack, cfg.edf_urgency_cap)
        return cfg.edf_weight * urgency * max(traj.predicted_total, 1.0)

    def pressure(self) -> float:
        """Live work vs decode capacity: 1.0 = every slot on every alive worker
        is spoken for; the degradation ladder triggers on this."""
        alive = len(self._worker_count) - len(self._dead_workers)
        capacity = max(alive, 1) * self._max_active
        return float(self._live.sum()) / capacity

    def admit_arrival(self, traj: Trajectory, now: float) -> AdmissionDecision:
        """Admission gate for one open-loop arrival (possibly a deferred retry).

        Order of gates: (1) backpressure — bounded global/per-worker queues;
        a full queue sheds sheddable work and defers the rest.  (2) deadline
        gate — predict completion from the progressive predictor + current
        fast-worker-equivalent loads; a sheddable arrival that cannot meet its
        SLO is rejected at the door (finishing it late helps nobody and its
        service time would push *other* tenants over).  Gold-tier work is
        never shed here, whatever the prediction says.
        """
        cfg = self.config.serving
        ts = self._tstat(traj.tenant)
        if traj.traj_id not in self._arrived_ids:
            self._arrived_ids.add(traj.traj_id)
            ts["arrived"] += 1
        traj.predicted_remaining = self.predictor.predict(traj)
        traj.priority = traj.predicted_total
        alive = [w for w in range(len(self._worker_count))
                 if w not in self._dead_workers]
        if not alive:
            ts["deferred"] += 1
            return AdmissionDecision("defer", reason="no_alive_worker")
        loads = (self._worker_count * self._load_weight).astype(float)
        if self._dead_workers:
            loads[list(self._dead_workers)] = np.inf
        worker = int(np.argmin(loads))
        full = (float(self._live.sum()) >= cfg.queue_bound_global
                or float(self._worker_count[worker]) >= cfg.queue_bound_per_worker)
        if full:
            if traj.sheddable:
                return AdmissionDecision("shed", reason="queue_full")
            ts["deferred"] += 1
            return AdmissionDecision("defer", reason="queue_full")
        if cfg.admission_control and traj.sheddable \
                and math.isfinite(traj.slo_deadline):
            eta = now + self.service_estimate(traj, worker)
            if eta > traj.slo_deadline:
                return AdmissionDecision("shed", reason="deadline", eta=eta)
        self._register_arrival(traj, worker)
        ts["admitted"] += 1
        return AdmissionDecision("admit", worker=worker)

    def _register_arrival(self, traj: Trajectory, worker: int) -> None:
        """Adopt an admitted arrival into the incremental rank/load state."""
        if self._n_slots >= len(self._pred_totals):
            grow = max(64, 2 * len(self._pred_totals))
            self._pred_totals = np.concatenate(
                [self._pred_totals, np.zeros(grow, dtype=float)])
            self._live = np.concatenate(
                [self._live, np.zeros(grow, dtype=bool)])
        slot = self._n_slots
        self._n_slots += 1
        self._slots[traj.traj_id] = slot
        self._pred_totals[slot] = traj.predicted_total
        self._live[slot] = True
        self._traj_index[traj.traj_id] = traj
        self._worker_count[worker] += 1
        traj.worker_id = worker
        traj._last_migration_pred = traj.predicted_total
        self.peak_worker_count = max(self.peak_worker_count,
                                     int(self._worker_count.max()))
        self.peak_global_count = max(self.peak_global_count,
                                     int(self._live.sum()))

    def on_shed(self, traj: Trajectory, now: float, reason: str,
                admitted: bool) -> None:
        """Load + tenant accounting for a shed decision (gate or ladder)."""
        self.shed_log.append((traj.traj_id, reason))
        self._tstat(traj.tenant)["shed"] += 1
        if not admitted:
            return
        self.abort_migration(traj.traj_id)
        slot = self._slots.get(traj.traj_id)
        if slot is not None:
            self._live[slot] = False
        if traj.worker_id is not None and traj.worker_id < len(self._worker_count):
            self._worker_count[traj.worker_id] -= 1

    def select_shed_victims(self, candidates: Sequence[Trajectory]
                            ) -> list[Trajectory]:
        """Ladder level 1: pick queued sheddable work to drop, enough to bring
        pressure back under the shed threshold.  Deterministic order — lowest
        tier last (shed highest tier first), largest predicted remaining work
        first within a tier, traj_id as the final tiebreak.  Gold (tier 0)
        and non-sheddable work are never candidates."""
        cfg = self.config.serving
        pool = sorted((t for t in candidates
                       if t.sheddable and t.tenant_tier > 0
                       and not t.finished and not t.shed),
                      key=lambda t: (-t.tenant_tier, -t.predicted_remaining,
                                     t.traj_id))
        alive = len(self._worker_count) - len(self._dead_workers)
        capacity = max(alive, 1) * self._max_active
        excess = float(self._live.sum()) - cfg.shed_pressure * capacity
        n = min(len(pool), max(int(math.ceil(excess)), 0))
        return pool[:n]

    def select_degrade_victims(self, candidates: Sequence[Trajectory]
                               ) -> list[Trajectory]:
        """Ladder level 2: live non-gold trajectories whose step budget the
        orchestrator should tighten.  Gold (tier 0) is untouchable."""
        return [t for t in candidates
                if t.tenant_tier > 0 and not t.degraded
                and not t.finished and not t.shed]

    def tenant_report(self) -> dict[str, dict]:
        """Per-tenant serving metrics: completion-latency percentiles, deadline
        attainment (a shed request counts as a missed deadline), and the
        admit/defer/shed/degrade counters."""
        report: dict[str, dict] = {}
        for tenant, ts in sorted(self.tenant_stats.items()):
            lat = np.asarray(ts["latencies"], dtype=float)
            arrived = max(ts["arrived"], 1)
            report[tenant] = {
                "arrived": ts["arrived"], "admitted": ts["admitted"],
                "deferred": ts["deferred"], "shed": ts["shed"],
                "finished": ts["finished"], "degraded": ts["degraded"],
                "deadline_met": ts["deadline_met"],
                "attainment": ts["deadline_met"] / arrived,
                "shed_rate": ts["shed"] / arrived,
                "latency_p50_s": float(np.percentile(lat, 50)) if len(lat) else 0.0,
                "latency_p99_s": float(np.percentile(lat, 99)) if len(lat) else 0.0,
                "latency_mean_s": float(lat.mean()) if len(lat) else 0.0,
            }
        return report

    def _predicted_lengths(self, trajectories: Sequence[Trajectory]) -> np.ndarray:
        for t in trajectories:
            t.predicted_remaining = self.predictor.predict(t)
            t.priority = t.predicted_total
        return np.asarray([t.predicted_total for t in trajectories])


ROUTING_POLICIES = {
    "cache_aware": CacheAffinityRouting,
    "least_load": LeastLoadRouting,
    "hybrid": HybridRouting,
}
