from repro.distributed.sharding import (DEFAULT_RULES, axis_rules, current_mesh,
                                        logical_pspec, param_pspecs, shard)

__all__ = ["DEFAULT_RULES", "axis_rules", "current_mesh", "logical_pspec",
           "param_pspecs", "shard"]
