"""Logical-axis sharding rules (MaxText-style) for every architecture family.

Model code annotates tensors with *logical* axis names; a rules table maps logical names
to physical mesh axes.  ``shard`` applies ``with_sharding_constraint`` only when a mesh is
active (so the same model code runs un-meshed on CPU tests) and silently drops a mesh axis
whose size does not divide the tensor dim — this is how e.g. smollm's 9 attention heads
degrade gracefully to replicated attention on a 16-way model axis while its MLP (d_ff
1536) still shards.

Parameter shardings are derived from a leaf-name table (``PARAM_LOGICAL_AXES``): every
parameter name used by ``repro.models`` maps to the logical axes of its dims.  Stacked
(scan-over-period) params get a leading ``None``.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": ("model",),
    "d_ff": ("model",),
    "d_inner": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "kv_seq": ("model",),     # sequence-sharded decode KV (used when heads don't divide)
    "fsdp": ("data",),        # ZeRO-3-style second param axis (arctic-class models
                              # cannot fit on a 16-way model axis alone)
    "act_seq": ("model",),    # sequence-parallel residual stream (Megatron-SP style)
    "dispatch": ("data",),    # MoE dispatch groups (per-data-shard capacity)
    "d_model": (),
    "seq": (),
    "state": (),
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: dict[str, tuple[str, ...]] = DEFAULT_RULES


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def logical_pspec(shape: Sequence[int], dims: Sequence[Optional[str]],
                  mesh: Optional[Mesh] = None, rules: Optional[dict] = None) -> P:
    """PartitionSpec for ``shape`` given per-dim logical names.

    A mesh axis is assigned to a dim only if (a) the rules map the logical name to it,
    (b) the axis exists in the mesh, (c) the dim size is divisible by the (product of)
    axis size(s), and (d) the axis is not already used by an earlier dim.
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None:
        return P(*([None] * len(shape)))
    sizes = _mesh_axis_sizes(mesh)
    used: set[str] = set()
    spec: list = []
    for dim_size, logical in zip(shape, dims):
        assigned = None
        if logical is not None:
            axes = tuple(a for a in rules.get(logical, ()) if a in sizes)
            axes = tuple(a for a in axes if a not in used)
            if axes:
                prod = 1
                for a in axes:
                    prod *= sizes[a]
                if prod > 1 and dim_size % prod == 0:
                    assigned = axes if len(axes) > 1 else axes[0]
                    used.update(axes)
                elif len(axes) == 1 and sizes[axes[0]] > 1 and dim_size % sizes[axes[0]] == 0:
                    assigned = axes[0]
                    used.add(axes[0])
                else:
                    # try each candidate axis individually (e.g. batch=("pod","data"))
                    for a in axes:
                        if sizes[a] > 1 and dim_size % sizes[a] == 0:
                            assigned = a
                            used.add(a)
                            break
        spec.append(assigned)
    return P(*spec)


def shard(x: jax.Array, dims: Sequence[Optional[str]]) -> jax.Array:
    """Constrain ``x``'s sharding by logical dims; identity when no mesh is active."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = logical_pspec(x.shape, dims, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------- parameter specs

# leaf parameter name -> logical axes of its (unstacked) dims.
# Two-axis sharding: one "tensor" dim on the model axis, the d_model (or expert-hidden)
# dim on the fsdp axis — GSPMD all-gathers the fsdp axis per layer (ZeRO-3).
PARAM_LOGICAL_AXES: dict[str, tuple[Optional[str], ...]] = {
    "tok_embed": ("vocab", "fsdp"),
    "lm_head": ("fsdp", "vocab"),
    "enc_proj": ("fsdp", "d_model"),
    # attention / cross-attention
    "wq": ("fsdp", "heads", "head_dim"),
    "wk": ("fsdp", "kv_heads", "head_dim"),
    "wv": ("fsdp", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "fsdp"),
    "q_norm": ("head_dim",),
    "k_norm": ("head_dim",),
    "xgate": (),
    # dense MLP
    "w_gate": ("fsdp", "d_ff"),
    "w_in": ("fsdp", "d_ff"),
    "w_out": ("d_ff", "fsdp"),
    # MoE
    "router": ("d_model", "experts"),
    "we_gate": ("experts", "fsdp", None),
    "we_in": ("experts", "fsdp", None),
    "we_out": ("experts", None, "fsdp"),
    "ws_gate": ("fsdp", "d_ff"),
    "ws_in": ("fsdp", "d_ff"),
    "ws_out": ("d_ff", "fsdp"),
    "shared_gate": ("d_model",),
    "wd_gate": ("fsdp", "d_ff"),
    "wd_in": ("fsdp", "d_ff"),
    "wd_out": ("d_ff", "fsdp"),
    # Mamba
    "m_in": ("fsdp", "d_inner"),
    "m_z": ("fsdp", "d_inner"),
    "m_conv": (None, "d_inner"),
    "m_xproj": ("d_inner", None),
    "m_dtproj": (None, "d_inner"),
    "m_Alog": ("d_inner", "state"),
    "m_D": ("d_inner",),
    "m_out": ("d_inner", "fsdp"),
    # mLSTM
    "l_up": ("fsdp", "d_inner"),
    "l_z": ("fsdp", "d_inner"),
    "l_q": ("d_inner", "heads", "head_dim"),
    "l_k": ("d_inner", "heads", "head_dim"),
    "l_v": ("d_inner", "heads", "head_dim"),
    "l_ig": ("d_inner", "heads"),
    "l_fg": ("d_inner", "heads"),
    "l_og": ("d_inner", "d_inner"),
    "l_down": ("d_inner", "fsdp"),
    "l_skip": ("d_inner",),
    # sLSTM
    "s_w": ("fsdp", None, "heads", "head_dim"),
    "s_r": (None, "heads", "head_dim", None),
    "s_b": (None, "heads", "head_dim"),
    "s_out": ("fsdp", "d_model"),
    # norms
    "scale": ("d_model",),
    "bias": ("d_model",),
}


def dispatch_groups(n_tokens: int) -> int:
    """MoE dispatch-group count: one group per data shard so expert capacity is
    per-shard (keeps the dispatch buffer O(local_tokens)).  1 when un-meshed."""
    mesh = _CTX.mesh
    if mesh is None:
        return 1
    sizes = _mesh_axis_sizes(mesh)
    g = 1
    for a in _CTX.rules.get("batch", ()):
        g *= sizes.get(a, 1)
    while g > 1 and n_tokens % g:
        g //= 2
    return max(g, 1)


def _spec_for_leaf(name: str, ndim: int, mesh: Mesh, shape: Sequence[int]) -> P:
    dims = PARAM_LOGICAL_AXES.get(name)
    if dims is None:
        return P(*([None] * ndim))
    dims = tuple(dims)
    if len(dims) < ndim:                       # scan-stacked: leading period dim(s)
        dims = (None,) * (ndim - len(dims)) + dims
    elif len(dims) > ndim:
        dims = dims[-ndim:]
    return logical_pspec(shape, dims, mesh)


def param_pspecs(params, mesh: Optional[Mesh] = None):
    """PartitionSpec pytree for a params pytree (leaf-name lookup)."""
    mesh = mesh or _CTX.mesh

    def walk(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        if mesh is None:
            return P(*([None] * leaf.ndim))
        return _spec_for_leaf(name, leaf.ndim, mesh, leaf.shape)

    return jax.tree_util.tree_map_with_path(walk, params)


def param_shardings(params, mesh: Optional[Mesh] = None):
    mesh = mesh or _CTX.mesh
    specs = param_pspecs(params, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------- decode-cache specs

# leaf cache name -> logical axes (right-aligned against the leaf's ndim; extra leading
# dims — period stacking — get None).  Collisions across families (mamba "h" vs sLSTM
# "h") are benign: the divisibility check replicates whichever dim doesn't divide.
CACHE_LOGICAL_AXES: dict[str, tuple[Optional[str], ...]] = {
    "pos": ("batch",),
    "page_table": ("batch", None),
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "xk": ("batch", None, "kv_heads", None),
    "xv": ("batch", None, "kv_heads", None),
    "h": ("batch", "d_inner", None),
    "conv": ("batch", None, "d_inner"),
    "C": ("batch", None, None, None),
    "n": ("batch", "d_inner", None),
    "c": ("batch", "d_inner", None),
    "m": ("batch", "d_inner"),
}


def cache_pspecs(cache, mesh: Optional[Mesh] = None):
    mesh = mesh or _CTX.mesh

    def walk(path, leaf):
        if mesh is None:
            return P(*([None] * leaf.ndim))
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        dims = CACHE_LOGICAL_AXES.get(name)
        if dims is None:
            return P(*([None] * leaf.ndim))
        dims = tuple(dims)
        if len(dims) < leaf.ndim:
            dims = (None,) * (leaf.ndim - len(dims)) + dims
        elif len(dims) > leaf.ndim:
            dims = dims[-leaf.ndim:]
        return logical_pspec(leaf.shape, dims, mesh)

    return jax.tree_util.tree_map_with_path(walk, cache)


def cache_shardings(cache, mesh: Optional[Mesh] = None):
    mesh = mesh or _CTX.mesh
    specs = cache_pspecs(cache, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
