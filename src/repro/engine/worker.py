"""Slot-pool rollout worker: the real JAX data plane with true continuous batching.

The engine owns one preallocated **slot-pool KV cache** — ``max_slots`` lanes built by
``model.init_cache`` — instead of a per-sequence cache store:

  * admission: prefill writes its cache straight into a free lane
    (``lax.dynamic_update_slice`` via ``model.write_slot``; the pool buffer is donated,
    so XLA updates the lane in place),
  * decode: one persistent jitted loop (``lax.scan``) over the whole resident batch
    with an active-slot mask — no ``concat``/``slice`` round-trips per call,
  * preemption: a mask flip — the lane stays resident, nothing moves,
  * migration: ``model.gather_slots`` lifts one lane out; the destination implants it
    into a free lane without disturbing co-resident sequences (§5.3),
  * tool absorption: masked teacher-forcing into a single lane (no prefix recompute),
  * prefix-cache hit accounting via a token-trie.

Sampling is per-slot: every sequence draws from
``fold_in(fold_in(PRNGKey(seed + worker_id), seq_id), context_len)``, making its token
stream independent of co-resident lanes and stable across preemption and migration
(the key travels in the migration package).  ``repro.engine.legacy`` keeps the old
concat/slice engine as the parity reference; see docs/engine.md for invariants.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.engine.sampler import SamplerConfig, sample_slots
from repro.models import model as M
from repro.models.config import ModelConfig


# ---------------------------------------------------------------- prefix trie

class PrefixCacheIndex:
    """Token-trie for prefix-hit accounting (radix-cache bookkeeping)."""

    def __init__(self):
        self.root: dict = {}
        self.hits = 0
        self.hit_tokens = 0
        self.lookups = 0

    def insert(self, tokens: list[int]) -> None:
        node = self.root
        for t in tokens:
            node = node.setdefault(int(t), {})

    def match_len(self, tokens: list[int]) -> int:
        self.lookups += 1
        node = self.root
        n = 0
        for t in tokens:
            node = node.get(int(t))
            if node is None:
                break
            n += 1
        if n:
            self.hits += 1
            self.hit_tokens += n
        return n


# ---------------------------------------------------------------- jitted kernels
# Module-level jits keyed on (cfg, shapes): workers sharing a config share compiles.

@partial(jax.jit, static_argnames=("cfg", "capacity"), donate_argnums=(2,))
def _admit(cfg: ModelConfig, params, pool, tokens, slot, capacity: int):
    """Prefill ``tokens`` (1, S) and write the resulting cache into lane ``slot``."""
    _, _, lane = M.forward_full(cfg, params, {"tokens": tokens}, capacity=capacity)
    return M.write_slot(pool, lane, slot)


@partial(jax.jit, donate_argnums=(0,))
def _implant(pool, lane, slot):
    """Write a migrated batch-1 cache into lane ``slot`` (migration ingress)."""
    return M.write_slot(pool, lane, slot)


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(2,))
def _extend_slot(cfg: ModelConfig, params, pool, tool_tokens, slot):
    """Teacher-force ``tool_tokens`` (L,) into lane ``slot`` only (active mask)."""
    B = pool["pos"].shape[0]
    active = jnp.arange(B) == slot

    def body(pool, tok):
        _, pool = M.decode_step(cfg, params, pool,
                                jnp.broadcast_to(tok, (B,))[:, None], active=active)
        return pool, None

    pool, _ = lax.scan(body, pool, tool_tokens)
    return pool


@partial(jax.jit, static_argnames=("cfg", "n_tokens", "stop_token", "sampler"),
         donate_argnums=(2,))
def _decode_loop(cfg: ModelConfig, params, pool, last, live, keys,
                 n_tokens: int, stop_token: int | None, sampler: SamplerConfig):
    """The persistent decode loop: ``n_tokens`` masked steps over the whole pool.

    last: (B,) int32 last context token per lane; live: (B,) bool active mask;
    keys: (B, 2) uint32 per-sequence base keys.  Returns (pool', emitted (T, B))
    where emitted is -1 for lanes that were inactive (or already stopped) at a step.
    """

    def body(carry, _):
        pool, last, live = carry
        step_keys = jax.vmap(jax.random.fold_in)(keys, pool["pos"])
        logits, pool = M.decode_step(cfg, params, pool, last[:, None], active=live)
        toks = sample_slots(step_keys, logits, sampler, active=live)
        last = jnp.where(live, toks, last)
        if stop_token is not None:
            live = live & (toks != stop_token)
        return (pool, last, live), toks

    (pool, last, live), emitted = lax.scan(body, (pool, last, live), None,
                                           length=n_tokens)
    return pool, last, live, emitted


# host-side chunk size for stop-token decodes: one device round-trip per CHUNK steps
# buys back the legacy early exit (all requested lanes stopped -> stop paying for
# masked full-pool steps) while bounding jit variants to {CHUNK, tail}
_DECODE_CHUNK = 8


# ---------------------------------------------------------------- worker

@dataclass
class Sequence:
    seq_id: int
    tokens: list[int]                    # full context (prompt + generated + tool)
    slot: int                            # lane index in the worker's slot pool
    key: np.ndarray                      # (2,) uint32 per-sequence sampling key
    generated: int = 0
    preempted: bool = False
    finished: bool = False


class RolloutWorker:
    """One rollout worker holding model params and a slot-pool KV cache."""

    def __init__(self, cfg: ModelConfig, params, capacity: int = 256,
                 max_slots: int = 8, worker_id: int = 0,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.capacity = capacity
        self.max_slots = max_slots
        self.worker_id = worker_id
        self.sampler = sampler
        self.base_key = jax.random.PRNGKey(seed + worker_id)
        self.pool = M.init_cache(cfg, params, max_slots, capacity)
        self.store: dict[int, Sequence] = {}       # resident sequences (incl. preempted)
        self.prefix_index = PrefixCacheIndex()
        self.decode_steps = 0
        self.pool_grows = 0

    # ------------------------------------------------------------ slot bookkeeping
    def _alloc_slot(self) -> int:
        """Lowest free lane; grows the pool (doubling) when every lane is resident.

        Free lanes are derived from the store, so ``store.clear()`` (weight-sync reset
        in the RL loop) releases every lane with no extra bookkeeping."""
        used = {s.slot for s in self.store.values()}
        for slot in range(self.max_slots):
            if slot not in used:
                return slot
        slot = self.max_slots
        fresh = M.init_cache(self.cfg, self.params, self.max_slots, self.capacity)
        self.pool = M.concat_pools(self.pool, fresh)
        self.max_slots *= 2
        self.pool_grows += 1
        return slot

    # ------------------------------------------------------------ lifecycle
    def prefill(self, seq_id: int, tokens: list[int]) -> None:
        """Admit a sequence: full-sequence forward writes straight into a free lane."""
        self.prefix_index.match_len(tokens)
        slot = self._alloc_slot()
        arr = jnp.asarray(tokens, jnp.int32)[None]
        self.pool = _admit(self.cfg, self.params, self.pool, arr, slot, self.capacity)
        key = np.asarray(jax.random.fold_in(self.base_key, seq_id))
        self.store[seq_id] = Sequence(seq_id, list(tokens), slot, key)
        self.prefix_index.insert(tokens)

    def extend(self, seq_id: int, tool_tokens: list[int]) -> None:
        """Absorb tool output into a resident lane (no prefix recompute)."""
        seq = self.store[seq_id]
        arr = jnp.asarray(tool_tokens, jnp.int32)
        self.pool = _extend_slot(self.cfg, self.params, self.pool, arr, seq.slot)
        seq.tokens.extend(int(t) for t in tool_tokens)

    def decode(self, seq_ids: list[int], n_tokens: int, stop_token: int | None = None
               ) -> dict[int, list[int]]:
        """Batched decode of the requested resident sequences for ``n_tokens`` steps.

        Runs one fused device loop over the whole pool; lanes not requested (free,
        preempted, or co-resident but idle) ride along masked-out at frozen ``pos``.
        Requesting a preempted sequence implicitly resumes it (mask flip back).
        """
        B = self.max_slots
        last = np.zeros((B,), np.int32)
        live = np.zeros((B,), bool)
        keys = np.zeros((B, 2), np.uint32)
        for seq in self.store.values():
            last[seq.slot] = seq.tokens[-1]
            keys[seq.slot] = seq.key
        for sid in seq_ids:
            seq = self.store[sid]
            seq.preempted = False
            live[seq.slot] = True
        last, live, keys = jnp.asarray(last), jnp.asarray(live), jnp.asarray(keys)
        # without a stop token nothing can finish early: one fused dispatch; with one,
        # chunk so the loop exits once every requested lane has stopped
        chunk = n_tokens if stop_token is None else _DECODE_CHUNK
        parts = []
        remaining = n_tokens
        while remaining > 0:
            step = min(chunk, remaining)
            self.pool, last, live, em = _decode_loop(
                self.cfg, self.params, self.pool, last, live, keys,
                step, stop_token, self.sampler)
            parts.append(np.asarray(em))                    # (step, B)
            remaining -= step
            self.decode_steps += step
            if remaining > 0 and not bool(np.asarray(live).any()):
                break
        emitted = np.concatenate(parts, axis=0)
        out: dict[int, list[int]] = {}
        for sid in seq_ids:
            seq = self.store[sid]
            toks = [int(t) for t in emitted[:, seq.slot] if t >= 0]
            out[sid] = toks
            seq.tokens.extend(toks)
            seq.generated += len(toks)
            if stop_token is not None and toks and toks[-1] == stop_token:
                seq.finished = True
            self.prefix_index.insert(seq.tokens)
        return out

    # ------------------------------------------------------------ control ops
    def preempt(self, seq_id: int) -> None:
        """Evict from the running batch but persist the KV cache (Alg. 1 line 7).

        A pure mask flip: the lane stays resident at frozen ``pos``; the next
        ``decode()`` naming this sequence flips the mask back — zero data movement."""
        self.store[seq_id].preempted = True

    def release(self, seq_id: int) -> None:
        """Finish a sequence and free its lane (next admission overwrites it)."""
        self.store.pop(seq_id, None)

    def migrate_out(self, seq_id: int) -> dict:
        """Package one lane's context + cache for transfer (§5.3 KV migration).

        Gathers a single lane — co-resident sequences are untouched."""
        seq = self.store.pop(seq_id)
        lane = M.gather_slots(self.pool, np.asarray([seq.slot]))
        return {
            "seq_id": seq.seq_id,
            "tokens": list(seq.tokens),
            "generated": seq.generated,
            "key": np.asarray(seq.key),
            "cache": jax.tree.map(np.asarray, lane),        # device -> host buffer
        }

    def migrate_in(self, package: dict) -> None:
        """Implant a migrated lane into a free slot (capacities must match)."""
        def check(dst, src):                  # fail fast on capacity/arch mismatch
            if (dst.shape[0],) + dst.shape[2:] != (src.shape[0],) + src.shape[2:]:
                raise ValueError(
                    f"migrate_in: lane shape {src.shape} does not fit pool lane "
                    f"{dst.shape} — source and destination workers must share "
                    f"capacity and architecture")

        jax.tree.map(check, self.pool["blocks"], package["cache"]["blocks"])
        slot = self._alloc_slot()
        lane = jax.tree.map(jnp.asarray, package["cache"])  # host -> this worker
        self.pool = _implant(self.pool, lane, slot)
        key = package.get("key")
        if key is None:                                     # foreign package: re-key
            key = np.asarray(jax.random.fold_in(self.base_key, package["seq_id"]))
        seq = Sequence(package["seq_id"], list(package["tokens"]), slot,
                       np.asarray(key), generated=package["generated"])
        self.store[package["seq_id"]] = seq
        self.prefix_index.insert(seq.tokens)

    def kv_bytes(self, seq_id: int) -> int:
        """Per-lane cache footprint (one slot's share of the pool)."""
        assert seq_id in self.store
        B = self.max_slots
        return sum((x.size // B) * x.dtype.itemsize
                   for x in jax.tree.leaves(self.pool))
