"""Slot-pool rollout worker: the real JAX data plane with true continuous batching.

The engine owns one preallocated **slot-pool KV cache** — ``max_slots`` lanes built by
``model.init_cache`` — instead of a per-sequence cache store:

  * admission: prefill writes its cache straight into a free lane
    (``lax.dynamic_update_slice`` via ``model.write_slot``; the pool buffer is donated,
    so XLA updates the lane in place),
  * decode: one persistent jitted loop (``lax.scan``) over the whole resident batch
    with an active-slot mask — no ``concat``/``slice`` round-trips per call,
  * preemption: a mask flip — the lane stays resident, nothing moves,
  * migration: ``model.gather_slots`` lifts one lane out; the destination implants it
    into a free lane without disturbing co-resident sequences (§5.3),
  * tool absorption: chunked prefill into the lane at its current offset
    (ceil(L/C) fixed-shape dispatches, no prefix recompute),
  * prefix reuse: a radix cache owning resident + retired lane KV — matched
    prefixes are implanted by an on-device lane-slice copy and only the unmatched
    suffix is prefilled (O(suffix) admission for GRPO siblings / tool re-entries).

Admission itself is chunked: ceil(S/C) reuses of ONE compiled (1, C) kernel replace
the legacy one-compile-per-prompt-length full forward (kept in ``_admit`` for
configs chunking can't serve — see ``model.supports_chunked_prefill``).

Sampling is per-slot: every sequence draws from
``fold_in(fold_in(PRNGKey(seed + worker_id), seq_id), context_len)``, making its token
stream independent of co-resident lanes and stable across preemption and migration
(the key travels in the migration package).  ``repro.engine.legacy`` keeps the old
concat/slice engine as the parity reference; see docs/engine.md for invariants.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.distributed.sharding import (axis_rules, cache_shardings,
                                        param_shardings)
from repro.engine.paging import PagePool, PagePoolExhausted
from repro.engine.sampler import SamplerConfig, sample_slots
from repro.models import model as M
from repro.models.config import ModelConfig


# ---------------------------------------------------------------- radix cache

class _TrieNode:
    __slots__ = ("children", "refs", "last_used")

    def __init__(self):
        self.children: dict[int, _TrieNode] = {}
        self.refs: dict[int, int] = {}       # lane slot -> epoch at insert
        self.last_used = 0


class PrefixCacheIndex:
    """Radix cache over token prefixes: accounting trie + (lane, span) KV refs.

    Accounting: every ``match_len``/``match_lane`` counts a lookup and classifies it
    as a **full** hit (the whole query matched) or a **partial** hit (a nonzero
    proper prefix matched) — ``hits`` aggregates both, so controller affinity stats
    can consume the honest split.  Node count is bounded by ``max_nodes``: inserts
    past the cap first prune the least-recently-used subtrees (a parent is always at
    least as recent as its children, so pruning by timestamp cutoff removes whole
    cold subtrees) and then truncate, keeping memory bounded even in pure
    accounting mode.

    KV ownership: ``insert(tokens, slot=...)`` tags every node on the path with a
    ``(slot, epoch)`` ref, claiming that lane ``slot`` holds valid KV for this
    prefix at positions ``[0, depth)``.  ``invalidate(slot)`` bumps the slot's epoch
    (lane overwritten / evicted); stale refs are dropped lazily during matching.
    ``match_lane`` returns the deepest live ref, which the engine implants with an
    on-device lane-slice copy so only the unmatched suffix is prefilled.
    """

    def __init__(self, max_nodes: int = 65_536):
        self.root = _TrieNode()
        self.max_nodes = max_nodes
        self.node_count = 0                  # root excluded
        self._clock = 0
        self._epochs: dict[int, int] = {}
        self.lookups = 0
        self.full_hits = 0
        self.partial_hits = 0
        self.hit_tokens = 0

    @property
    def hits(self) -> int:
        return self.full_hits + self.partial_hits

    def invalidate(self, slot: int) -> None:
        """Mark lane ``slot``'s KV refs stale (lane reassigned or evicted)."""
        self._epochs[slot] = self._epochs.get(slot, 0) + 1

    # ------------------------------------------------------------ insert / match
    def insert(self, tokens: list[int], slot: int | None = None) -> None:
        self._clock += 1
        now = self._clock
        epoch = self._epochs.setdefault(slot, 0) if slot is not None else 0
        node = self.root
        node.last_used = now
        for t in tokens:
            child = node.children.get(int(t))
            if child is None:
                if self.node_count >= self.max_nodes:
                    self._prune()
                if self.node_count >= self.max_nodes:
                    return                   # cap still binding: truncate the insert
                child = _TrieNode()
                node.children[int(t)] = child
                self.node_count += 1
            child.last_used = now
            if slot is not None:
                child.refs[slot] = epoch
            node = child

    def _walk(self, tokens: list[int]) -> tuple[int, int, int | None]:
        """Walk + account one lookup; returns (trie depth, reuse depth, lane)."""
        self._clock += 1
        now = self._clock
        node = self.root
        n = 0
        reuse_n, reuse_slot = 0, None
        for t in tokens:
            node = node.children.get(int(t))
            if node is None:
                break
            node.last_used = now
            n += 1
            if node.refs:
                stale = [s for s, e in node.refs.items()
                         if self._epochs.get(s, 0) != e]
                for s in stale:
                    del node.refs[s]
                if node.refs:
                    reuse_n, reuse_slot = n, next(iter(node.refs))
        self.lookups += 1
        if n and n == len(tokens):
            self.full_hits += 1
        elif n:
            self.partial_hits += 1
        self.hit_tokens += n
        return n, reuse_n, reuse_slot

    def match_len(self, tokens: list[int]) -> int:
        return self._walk(tokens)[0]

    def match_lane(self, tokens: list[int]) -> tuple[int, int | None]:
        """Deepest prefix of ``tokens`` backed by a live lane: (length, slot)."""
        _, reuse_n, reuse_slot = self._walk(tokens)
        return reuse_n, reuse_slot

    # ------------------------------------------------------------ LRU pruning
    def _subtree_size(self, node: _TrieNode) -> int:
        count, stack = 0, [node]
        while stack:
            n = stack.pop()
            count += 1
            stack.extend(n.children.values())
        return count

    def _prune(self) -> None:
        """Evict least-recently-used subtrees down to ~3/4 of the node cap."""
        target = max(1, self.max_nodes * 3 // 4)
        stamps: list[int] = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            for c in node.children.values():
                stamps.append(c.last_used)
                stack.append(c)
        excess = len(stamps) - target
        if excess <= 0:
            return
        # never evict the in-flight insert path (stamped with the current clock)
        cutoff = min(sorted(stamps)[excess - 1], self._clock - 1)
        removed = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            doomed = [t for t, c in node.children.items() if c.last_used <= cutoff]
            for t in doomed:
                removed += self._subtree_size(node.children.pop(t))
            stack.extend(node.children.values())
        self.node_count -= removed


# ---------------------------------------------------------------- jitted kernels
# Module-level jits keyed on (cfg, shapes): workers sharing a config share compiles.
# Kernels whose model code emits sharding constraints (``sharding.shard``) also key
# on the worker's ``mesh`` as a *static* argument: pjit caches the traced jaxpr by
# avals alone, so a constraint traced under worker A's mesh would otherwise be
# replayed — with A's device set baked in — for worker B's differently-meshed
# arguments.  ``axis_rules`` runs at trace time, once per (cfg, shapes, mesh).

@partial(jax.jit, static_argnames=("cfg", "capacity", "mesh"), donate_argnums=(2,))
def _admit(cfg: ModelConfig, params, pool, tokens, slot, capacity: int, mesh=None):
    """Full-sequence prefill fallback: one compile per distinct prompt length.

    Used only for configs ``supports_chunked_prefill`` rejects (MoE, sliding-window,
    cross-attention); everything else admits through the chunked path below."""
    with axis_rules(mesh):
        _, _, lane = M.forward_full(cfg, params, {"tokens": tokens},
                                    capacity=capacity)
        return M.write_slot(pool, lane, slot)


@partial(jax.jit, static_argnames=("cfg", "batch", "capacity"))
def _fresh_lane(cfg: ModelConfig, batch: int, capacity: int):
    """Empty batch-1 lane cache (chunked admission starts here)."""
    return M.init_cache(cfg, None, batch, capacity)


@partial(jax.jit, static_argnames=("cfg", "mesh"), donate_argnums=(2,))
def _prefill_chunk(cfg: ModelConfig, params, lane, tokens, length, mesh=None):
    """One fixed-shape (1, C) chunk into a batch-1 lane at its current ``pos``.

    ``length`` is traced, so ONE compile serves every offset and tail length —
    admission cost is bounded by chunk count, not by distinct prompt lengths."""
    with axis_rules(mesh):
        return M.prefill_chunk(cfg, params, lane, tokens, length)


@partial(jax.jit, donate_argnums=(2,))
def _copy_prefix(pool, src_slot, lane, n):
    """Implant the first ``n`` positions of pool lane ``src_slot`` into ``lane``
    (radix-cache prefix reuse: an on-device lane-slice copy, no recompute)."""
    return M.copy_prefix(pool, src_slot, lane, n)


@jax.jit
def _gather_lane(pool, slot):
    """Lift one lane out of the pool as a batch-1 cache (chunked tool absorption)."""
    return M.gather_slots(pool, slot[None])


@partial(jax.jit, donate_argnums=(0,))
def _implant(pool, lane, slot):
    """Write a migrated batch-1 cache into lane ``slot`` (migration ingress)."""
    return M.write_slot(pool, lane, slot)


@partial(jax.jit, static_argnames=("cfg", "mesh"), donate_argnums=(2,))
def _extend_slot(cfg: ModelConfig, params, pool, tool_tokens, slot, mesh=None):
    """Teacher-force ``tool_tokens`` (L,) into lane ``slot`` only (active mask)."""
    B = pool["pos"].shape[0]
    active = jnp.arange(B) == slot

    def body(pool, tok):
        _, pool = M.decode_step(cfg, params, pool,
                                jnp.broadcast_to(tok, (B,))[:, None], active=active)
        return pool, None

    with axis_rules(mesh):
        pool, _ = lax.scan(body, pool, tool_tokens)
    return pool


# ---- paged-pool kernels (model.supports_paged_kv data plane) -------------------
# The pool dict grows a ``page_table`` leaf; every kernel donates the pool so XLA
# updates blocks/rows in place.  Host-side block accounting (PagePool) never sees
# the device: the worker keeps lane -> block lists and mirrors them into the
# device page table through ``_paged_row`` / ``_paged_lane``.

@partial(jax.jit, static_argnames=("cfg", "mesh"), donate_argnums=(2,))
def _paged_chunk(cfg: ModelConfig, params, pool, slot, tokens, length, mesh=None):
    """One fixed-shape (1, C) chunk straight into lane ``slot``'s pages."""
    with axis_rules(mesh):
        return M.prefill_chunk_paged(cfg, params, pool, slot, tokens, length)


@partial(jax.jit, donate_argnums=(0,))
def _paged_lane(pool, slot, row, pos0):
    """Map a lane: page-table row + position reset (admission ingress)."""
    return M.paged_set_lane(pool, slot, row, pos0)


@partial(jax.jit, donate_argnums=(0,))
def _paged_row(pool, slot, row):
    """Rewrite one page-table row without touching ``pos`` (coverage extension,
    retire-trim: unmapped tail entries go back to scratch so a masked lane's
    self-healing write can never land in a reassigned block)."""
    return dict(pool, page_table=pool["page_table"].at[slot].set(row))


@partial(jax.jit, donate_argnums=(0,))
def _copy_block(pool, dst, src):
    """Device-to-device copy of one physical block (prefix-share boundary page)."""
    return M.paged_copy_block(pool, dst, src)


@jax.jit
def _gather_pages(pool, idx):
    """Lift resident physical blocks out of the pool (D2D migration payload)."""
    return M.paged_gather_pages(pool, idx)


@partial(jax.jit, donate_argnums=(0,))
def _paged_ingest(pool, pages, idx, state, slot, row):
    """Migration ingress: scatter page stacks into freshly allocated blocks and
    write the lane's dense state + page-table row."""
    pool = M.paged_scatter_pages(pool, pages, idx)
    return M.paged_write_state(pool, state, slot, row)


@partial(jax.jit, donate_argnums=(0,))
def _paged_implant(pool, lane, slot, row, n):
    """Scatter a dense batch-1 lane into mapped pages (cross-layout ingress)."""
    return M.paged_write_lane(pool, lane, slot, row, n)


@partial(jax.jit, static_argnames=("cfg", "capacity", "mesh"), donate_argnums=(2,))
def _admit_paged(cfg: ModelConfig, params, pool, tokens, slot, row,
                 capacity: int, mesh=None):
    """Full-sequence paged admission (non-chunkable configs: MoE, etc.) — the
    dense ``_admit`` followed by a page scatter instead of a lane write."""
    with axis_rules(mesh):
        _, _, lane = M.forward_full(cfg, params, {"tokens": tokens},
                                    capacity=capacity)
        return M.paged_write_lane(pool, lane, slot, row, tokens.shape[1])


@partial(jax.jit,
         static_argnames=("cfg", "n_tokens", "stop_token", "sampler", "mesh"),
         donate_argnums=(2,))
def _decode_loop(cfg: ModelConfig, params, pool, last, live, keys,
                 n_tokens: int, stop_token: int | None, sampler: SamplerConfig,
                 mesh=None):
    """The persistent decode loop: ``n_tokens`` masked steps over the whole pool.

    last: (B,) int32 last context token per lane; live: (B,) bool active mask;
    keys: (B, 2) uint32 per-sequence base keys.  Returns (pool', emitted (T, B))
    where emitted is -1 for lanes that were inactive (or already stopped) at a step.
    """

    def body(carry, _):
        pool, last, live = carry
        step_keys = jax.vmap(jax.random.fold_in)(keys, pool["pos"])
        logits, pool = M.decode_step(cfg, params, pool, last[:, None], active=live)
        toks = sample_slots(step_keys, logits, sampler, active=live)
        last = jnp.where(live, toks, last)
        if stop_token is not None:
            live = live & (toks != stop_token)
        return (pool, last, live), toks

    with axis_rules(mesh):
        (pool, last, live), emitted = lax.scan(body, (pool, last, live), None,
                                               length=n_tokens)
    return pool, last, live, emitted


# host-side chunk size for stop-token decodes: one device round-trip per CHUNK steps
# buys back the legacy early exit (all requested lanes stopped -> stop paying for
# masked full-pool steps) while bounding jit variants to {CHUNK, tail}
_DECODE_CHUNK = 8


# ---------------------------------------------------------------- worker

@dataclass
class Sequence:
    seq_id: int
    tokens: list[int]                    # full context (prompt + generated + tool)
    slot: int                            # lane index in the worker's slot pool
    key: np.ndarray                      # (2,) uint32 per-sequence sampling key
    generated: int = 0
    preempted: bool = False
    finished: bool = False


class RolloutWorker:
    """One rollout worker holding model params and a slot-pool KV cache.

    Admission runs the **chunked prefill plane** whenever the architecture supports
    it (``model.supports_chunked_prefill``): a prompt of any length is ceil(S/C)
    dispatches of one fixed-shape compiled chunk kernel, with the radix cache
    implanting any matched prefix from a resident or retired lane first, so GRPO
    siblings and multi-turn re-entries pay O(suffix).  Released lanes retire into an
    LRU set (bounded by ``retired_kv_bytes``) instead of being dropped, keeping
    their KV reusable until admission pressure reclaims them.
    """

    def __init__(self, cfg: ModelConfig, params, capacity: int = 256,
                 max_slots: int = 8, worker_id: int = 0,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 chunk_size: int = 32, prefix_reuse: bool = True,
                 use_chunked: bool | None = None,
                 retired_kv_bytes: int | None = None,
                 prefix_index_nodes: int = 65_536,
                 mesh=None, mp: int = 1,
                 paged: bool | None = None, page_size: int = 16,
                 num_blocks: int | None = None):
        self.cfg = cfg
        self.capacity = capacity
        self.max_slots = max_slots
        self.worker_id = worker_id
        self.sampler = sampler
        # model parallelism: `mp` is the worker's declared MP degree (drives the
        # control plane's latency model); `mesh` is its physical realization — a
        # ("data", "model") sub-mesh over `mp` devices.  When the device set can't
        # host the mesh (un-forced CPU), mesh is None and the worker runs the
        # identical un-meshed code path (sharding.shard() is the identity).
        self.mp = max(int(mp), 1)
        self.mesh = mesh
        self.base_key = jax.random.PRNGKey(seed + worker_id)
        if mesh is not None:
            self.params = jax.device_put(params, param_shardings(params, mesh))
        else:
            self.params = params
        # paged KV data plane: default ON whenever the architecture supports it —
        # admission capacity then scales with resident tokens, not max_len * slots
        self._paged = ((paged if paged is not None else True)
                       and M.supports_paged_kv(cfg))
        if self._paged:
            ps = max(int(page_size), 1)
            while capacity % ps:                   # page size must tile the lane
                ps //= 2
            self.page_size = ps
            self.num_pages = capacity // ps
            # default block budget: the dense pool's HBM footprint (+ scratch)
            self.num_blocks = (num_blocks if num_blocks is not None
                               else max_slots * self.num_pages + 1)
            self.pages = PagePool(self.num_blocks)
            self.lane_pages: dict[int, list[int]] = {}   # slot -> ordered blocks
            self.block_grows = 0
            self.pool = self._place_cache(M.init_paged_pool(
                cfg, None, max_slots, self.num_blocks, ps, self.num_pages))
        else:
            self.pool = self._place_cache(
                M.init_cache(cfg, None, max_slots, capacity))
        self.store: dict[int, Sequence] = {}       # resident sequences (incl. preempted)
        self.chunk_size = chunk_size
        self._chunked = ((use_chunked if use_chunked is not None else True)
                         and M.supports_chunked_prefill(cfg))
        self._reuse = prefix_reuse and self._chunked and M.supports_prefix_reuse(cfg)
        # stable per-lane cache footprint (shape math only — nothing is allocated),
        # independent of later pool growth
        lane = jax.eval_shape(lambda: M.init_cache(cfg, None, 1, capacity))
        self._lane_bytes = sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
                               for x in jax.tree.leaves(lane))
        if self._paged:
            # per-block bytes (k+v across every paged layer) and the per-lane
            # dense-state remainder — kv_bytes() prices *resident pages* only
            itemsize = jnp.dtype(cfg.dtype).itemsize
            n_attn = sum(1 for k in cfg.block_pattern
                         if k.partition("+")[0] == "attn")
            self._page_bytes = (2 * cfg.n_periods * n_attn * self.page_size
                                * cfg.n_kv_heads * cfg.hd * itemsize)
            state = jax.eval_shape(lambda: M.init_cache(cfg, None, 1, 0))
            self._state_bytes = sum(
                int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
                for x in jax.tree.leaves(state))
        budget = (retired_kv_bytes if retired_kv_bytes is not None
                  else self._lane_bytes * max_slots)
        self._max_retired = budget // self._lane_bytes if self._lane_bytes else 0
        self.retired: OrderedDict[int, int] = OrderedDict()   # slot -> token count
        self.prefix_index = PrefixCacheIndex(max_nodes=prefix_index_nodes)
        self.decode_steps = 0
        self.pool_grows = 0
        self.reused_tokens = 0                     # admission tokens implanted, not computed
        self.prefilled_tokens = 0                  # admission tokens actually computed
        self.absorbed_tokens = 0                   # tool tokens teacher-forced (extend)
        self.prefill_dispatches = 0                # chunk kernel launches
        # measured decode timing (feeds WorkerLatencyModel calibration, §6).
        # Only WARM calls are timed — a call that grew the jit cache spent
        # seconds compiling, and meshed workers pay per-mesh compiles that
        # un-meshed ones share, so compile-polluted samples would make mp>1
        # look slower than it is.  wall_s / timed_steps is the observed
        # per-STEP decode time (the full-pool masked kernel's cost is
        # batch-independent; one step advances every live lane one token), and
        # timed_lane_steps / timed_steps is the mean live batch the model's
        # comm/interference term regresses on.
        self.decode_wall_s = 0.0
        self.decode_timed_steps = 0
        self.decode_timed_lane_steps = 0
        self.decode_calls = 0

    def _place_cache(self, cache):
        """Place a cache pytree on this worker's sub-mesh (identity un-meshed).

        THE one path for cache placement: mixing a default-device-committed
        cache with sharded params/pool in one jit is rejected (committed arrays
        on disjoint device sets), so every cache that enters the worker —
        construction, fresh lanes, pool growth, migration ingress — funnels
        through here."""
        if self.mesh is None:
            return cache
        return jax.device_put(cache, cache_shardings(cache, self.mesh))

    def _new_lane(self):
        """Empty batch-1 lane, placed on this worker's mesh when it has one.

        The jitted ``_fresh_lane`` commits its output to the default device,
        which is only safe when the worker is un-meshed (see _place_cache)."""
        if self.mesh is None:
            return _fresh_lane(self.cfg, 1, self.capacity)
        return self._place_cache(M.init_cache(self.cfg, None, 1, self.capacity))

    # ------------------------------------------------------------ slot bookkeeping
    def _alloc_slot(self) -> int:
        """Lowest free lane, else the LRU retired lane, else pool growth (doubling).

        The returned lane is about to be overwritten, so its radix refs are
        invalidated here — one rule covers release, eviction, and external resets.
        In paged mode the reclaimed lane's pages are freed (shared blocks survive
        via their sharers' refcounts) and its page-table row reset to scratch."""
        used = {s.slot for s in self.store.values()}
        for slot in range(self.max_slots):
            if slot not in used and slot not in self.retired:
                self.prefix_index.invalidate(slot)
                if self._paged:
                    self._free_lane_pages(slot)
                return slot
        if self.retired:
            slot, _ = self.retired.popitem(last=False)
            self.prefix_index.invalidate(slot)
            if self._paged:
                self._free_lane_pages(slot)
            return slot
        slot = self.max_slots
        if self._paged:
            # lane growth only: page-table rows + dense per-lane state double,
            # the physical block pools are untouched (lanes and HBM decouple)
            self.pool = self._place_cache(
                M.grow_paged_lanes(self.cfg, self.pool, self.max_slots))
        else:
            fresh = self._place_cache(
                M.init_cache(self.cfg, None, self.max_slots, self.capacity))
            # re-pin after the eager concat, which drops the sharding
            self.pool = self._place_cache(M.concat_pools(self.pool, fresh))
        self.max_slots *= 2
        self.pool_grows += 1
        self.prefix_index.invalidate(slot)
        return slot

    def _retire_slot(self, slot: int, n_tokens: int) -> None:
        """Hand a released lane to the radix cache (LRU, byte-budgeted).

        Paged: the lane's over-allocated tail pages (decode headroom past the
        last resident token) are freed immediately — a retired lane holds
        exactly ceil(n_tokens / page_size) blocks."""
        if not (self._reuse and self._max_retired > 0 and n_tokens > 0):
            self.prefix_index.invalidate(slot)
            if self._paged:
                self._free_lane_pages(slot)
            return
        if self._paged:
            self._trim_lane_pages(slot, n_tokens)
        self.retired[slot] = n_tokens
        self.retired.move_to_end(slot)
        while len(self.retired) > self._max_retired:
            old, _ = self.retired.popitem(last=False)
            self.prefix_index.invalidate(old)
            if self._paged:
                self._free_lane_pages(old)

    # ------------------------------------------------------------ page bookkeeping
    def _row_of(self, blocks: list[int]) -> jnp.ndarray:
        """Fixed-shape (num_pages,) device row; unmapped tail -> scratch block 0."""
        row = np.zeros((self.num_pages,), np.int32)
        row[:len(blocks)] = blocks
        return jnp.asarray(row)

    def _sync_row(self, slot: int) -> None:
        """Mirror ``lane_pages[slot]`` into the device page table."""
        self.pool = _paged_row(self.pool, jnp.asarray(slot, jnp.int32),
                               self._row_of(self.lane_pages.get(slot, [])))

    def _free_lane_pages(self, slot: int) -> None:
        """Release every page a lane holds and point its row at scratch."""
        blocks = self.lane_pages.pop(slot, None)
        if blocks:
            self.pages.free(blocks)
            self._sync_row(slot)

    def _trim_lane_pages(self, slot: int, n_tokens: int) -> None:
        """Free pages past ceil(n_tokens / page_size) (retire headroom trim)."""
        blocks = self.lane_pages.get(slot, [])
        keep = -(-n_tokens // self.page_size)
        if len(blocks) > keep:
            self.pages.free(blocks[keep:])
            self.lane_pages[slot] = blocks[:keep]
            self._sync_row(slot)

    def _alloc_blocks(self, n: int) -> list[int]:
        """Allocate ``n`` physical blocks, evicting retired lanes under pressure
        and doubling the device block pool only once nothing is left to reclaim."""
        while True:
            try:
                return self.pages.alloc(n)
            except PagePoolExhausted:
                if self.retired:
                    old, _ = self.retired.popitem(last=False)
                    self.prefix_index.invalidate(old)
                    self._free_lane_pages(old)
                    continue
                self._grow_blocks(n)

    def _grow_blocks(self, min_extra: int) -> None:
        extra = max(min_extra, self.num_blocks)     # doubling growth
        self.pool = self._place_cache(M.grow_paged_blocks(self.pool, extra))
        self.pages.grow(self.num_blocks + extra)
        self.num_blocks += extra
        self.block_grows += 1

    def _ensure_coverage(self, slot: int, total_tokens: int) -> None:
        """Map enough pages on lane ``slot`` to hold ``total_tokens`` positions
        (capped at lane capacity — past it, writes self-heal into scratch)."""
        need = min(-(-total_tokens // self.page_size), self.num_pages)
        have = self.lane_pages.get(slot, [])
        if len(have) >= need:
            return
        self.lane_pages[slot] = have + self._alloc_blocks(need - len(have))
        self._sync_row(slot)

    # ------------------------------------------------------------ lifecycle
    def prefill(self, seq_id: int, tokens: list[int]) -> None:
        """Admit a sequence: implant any radix-matched prefix from a resident or
        retired lane (O(1) on-device slice copy), then chunk-prefill the suffix."""
        S = len(tokens)
        reuse_n, src = 0, None
        if self._reuse:
            reuse_n, src = self.prefix_index.match_lane(tokens)
        else:
            self.prefix_index.match_len(tokens)
        slot = self._alloc_slot()
        if self._paged:
            self._prefill_paged(slot, tokens, reuse_n, src)
        elif not self._chunked:
            arr = jnp.asarray(tokens, jnp.int32)[None]
            self.pool = _admit(self.cfg, self.params, self.pool, arr, slot,
                               self.capacity, mesh=self.mesh)
            self.prefilled_tokens += S
        else:
            lane = self._new_lane()
            if src is not None and reuse_n > 0:
                if src in self.retired:
                    self.retired.move_to_end(src)         # LRU touch
                lane = _copy_prefix(self.pool, jnp.asarray(src, jnp.int32), lane,
                                    jnp.asarray(reuse_n, jnp.int32))
                self.reused_tokens += reuse_n
            lane = self._chunk_into(lane, tokens, reuse_n)
            self.pool = _implant(self.pool, lane, slot)
            self.prefilled_tokens += S - reuse_n
        key = np.asarray(jax.random.fold_in(self.base_key, seq_id))
        self.store[seq_id] = Sequence(seq_id, list(tokens), slot, key)
        self.prefix_index.insert(tokens, slot=slot)

    def _prefill_paged(self, slot: int, tokens: list[int], reuse_n: int,
                       src: int | None) -> None:
        """Paged admission: share the matched prefix's full pages by refcount
        (zero KV copy), D2D-copy its boundary partial page, then chunk-prefill
        the suffix straight into freshly mapped pages.

        Warm GRPO siblings therefore pay page-table rows + O(suffix) compute —
        the dense path's O(reuse_n) lane-slice copy disappears entirely."""
        S, ps = len(tokens), self.page_size
        blocks: list[int] = []
        boundary: tuple[int, int] | None = None
        reuse_eff = 0
        if self._chunked and src is not None and reuse_n > 0:
            if src in self.retired:
                self.retired.move_to_end(src)             # LRU touch
            src_blocks = self.lane_pages.get(src, [])
            reuse_eff = min(reuse_n, len(src_blocks) * ps)
            n_full = reuse_eff // ps
            if n_full:
                blocks = list(src_blocks[:n_full])
                self.pages.share(blocks)
            if reuse_eff % ps:
                [b] = self._alloc_blocks(1)
                boundary = (b, src_blocks[n_full])
                blocks.append(b)
            self.reused_tokens += reuse_eff
        need = min(-(-S // ps), self.num_pages)
        if need > len(blocks):
            blocks = blocks + self._alloc_blocks(need - len(blocks))
        self.lane_pages[slot] = blocks
        self.pool = _paged_lane(self.pool, jnp.asarray(slot, jnp.int32),
                                self._row_of(blocks),
                                jnp.asarray(reuse_eff, jnp.int32))
        if boundary is not None:
            self.pool = _copy_block(self.pool, jnp.asarray(boundary[0], jnp.int32),
                                    jnp.asarray(boundary[1], jnp.int32))
        if not self._chunked:
            arr = jnp.asarray(tokens, jnp.int32)[None]
            self.pool = _admit_paged(self.cfg, self.params, self.pool, arr, slot,
                                     self._row_of(blocks), S, mesh=self.mesh)
            self.prefilled_tokens += S
            return
        self._chunk_into_paged(slot, tokens, reuse_eff)
        self.prefilled_tokens += S - reuse_eff

    def _chunk_into(self, lane, tokens: list[int], start: int):
        """Feed ``tokens[start:]`` through the fixed-shape chunk kernel."""
        C = self.chunk_size
        off, S = start, len(tokens)
        while off < S:
            step = min(C, S - off)
            buf = np.zeros((1, C), np.int32)
            buf[0, :step] = tokens[off:off + step]
            lane = _prefill_chunk(self.cfg, self.params, lane, jnp.asarray(buf),
                                  jnp.asarray(step, jnp.int32), mesh=self.mesh)
            off += step
            self.prefill_dispatches += 1
        return lane

    def _chunk_into_paged(self, slot: int, tokens: list[int], start: int) -> None:
        """Feed ``tokens[start:]`` straight into lane ``slot``'s pages — no
        lane gather/implant round trip; the pool is the chunk kernel's operand."""
        C = self.chunk_size
        off, S = start, len(tokens)
        while off < S:
            step = min(C, S - off)
            buf = np.zeros((1, C), np.int32)
            buf[0, :step] = tokens[off:off + step]
            self.pool = _paged_chunk(self.cfg, self.params, self.pool,
                                     jnp.asarray(slot, jnp.int32),
                                     jnp.asarray(buf),
                                     jnp.asarray(step, jnp.int32), mesh=self.mesh)
            off += step
            self.prefill_dispatches += 1

    def extend(self, seq_id: int, tool_tokens: list[int]) -> None:
        """Absorb tool output: chunked prefill into the lane at its current offset
        (ceil(L/C) lane-sized dispatches instead of L full-pool decode steps)."""
        seq = self.store[seq_id]
        if self._paged and self._chunked:
            ext = list(seq.tokens) + [int(t) for t in tool_tokens]
            self._ensure_coverage(seq.slot, len(ext))
            self._chunk_into_paged(seq.slot, ext, len(seq.tokens))
            self.absorbed_tokens += len(tool_tokens)
            seq.tokens = ext
        elif self._chunked:
            lane = _gather_lane(self.pool, jnp.asarray(seq.slot, jnp.int32))
            ext = list(seq.tokens) + [int(t) for t in tool_tokens]
            lane = self._chunk_into(lane, ext, len(seq.tokens))
            self.pool = _implant(self.pool, lane, seq.slot)
            self.absorbed_tokens += len(tool_tokens)
            seq.tokens = ext
        else:
            self.extend_per_token(seq_id, tool_tokens)
            return
        self.prefix_index.insert(seq.tokens, slot=seq.slot)

    def extend_per_token(self, seq_id: int, tool_tokens: list[int]) -> None:
        """Legacy tool absorption: one masked full-pool decode step per token.

        Kept as the fallback for non-chunkable configs and as the baseline
        ``benchmarks/bench_prefill.py`` measures the chunked path against."""
        seq = self.store[seq_id]
        if self._paged:
            self._ensure_coverage(seq.slot, len(seq.tokens) + len(tool_tokens))
        arr = jnp.asarray(tool_tokens, jnp.int32)
        self.pool = _extend_slot(self.cfg, self.params, self.pool, arr, seq.slot,
                                 mesh=self.mesh)
        self.absorbed_tokens += len(tool_tokens)
        seq.tokens.extend(int(t) for t in tool_tokens)
        self.prefix_index.insert(seq.tokens, slot=seq.slot)

    def decode(self, seq_ids: list[int], n_tokens: int, stop_token: int | None = None
               ) -> dict[int, list[int]]:
        """Batched decode of the requested resident sequences for ``n_tokens`` steps.

        Runs one fused device loop over the whole pool; lanes not requested (free,
        preempted, or co-resident but idle) ride along masked-out at frozen ``pos``.
        Requesting a preempted sequence implicitly resumes it (mask flip back).
        A sequence whose ``finished`` flag is set is never resumed: it stays
        masked-out at frozen ``pos`` and contributes an empty output stream, so a
        scheduler naming a stopped sequence cannot push tokens past its stop token.
        """
        requested = []
        for sid in seq_ids:
            if self.store[sid].finished:
                continue
            requested.append(sid)
        if not requested:
            return {sid: [] for sid in seq_ids}
        B = self.max_slots
        last = np.zeros((B,), np.int32)
        live = np.zeros((B,), bool)
        keys = np.zeros((B, 2), np.uint32)
        for seq in self.store.values():
            last[seq.slot] = seq.tokens[-1]
            keys[seq.slot] = seq.key
        for sid in requested:
            seq = self.store[sid]
            seq.preempted = False
            live[seq.slot] = True
            if self._paged:
                # map decode headroom up front: the loop writes positions
                # [len(tokens), len(tokens) + n_tokens) — one host-side check,
                # zero device syncs inside the loop (unused tail pages are
                # trimmed back at retire time)
                self._ensure_coverage(seq.slot, len(seq.tokens) + n_tokens)
        last, live, keys = jnp.asarray(last), jnp.asarray(live), jnp.asarray(keys)
        # without a stop token nothing can finish early: one fused dispatch; with one,
        # chunk so the loop exits once every requested lane has stopped
        chunk = n_tokens if stop_token is None else _DECODE_CHUNK
        parts = []
        remaining = n_tokens
        ran = 0
        lane_steps = 0
        cache0 = _decode_loop._cache_size()
        t0 = time.perf_counter()
        while remaining > 0:
            step = min(chunk, remaining)
            self.pool, last, live, em = _decode_loop(
                self.cfg, self.params, self.pool, last, live, keys,
                step, stop_token, self.sampler, mesh=self.mesh)
            parts.append(em)   # device-resident: D2H deferred past the loop
            remaining -= step
            ran += step
            self.decode_steps += step
            if stop_token is None:                          # nothing stops early
                lane_steps += step * len(requested)
            else:
                # live batch after the chunk: lanes stopping mid-call must not
                # keep inflating the calibration's mean-batch regressor.  The
                # sync is the point — it is the early-exit check that stops
                # decoding once every requested lane hit its stop token.
                n_live = int(np.asarray(live).sum())  # heddle: noqa HDL003 -- deliberate early-exit sync, one per chunk
                lane_steps += step * n_live
                if remaining > 0 and n_live == 0:
                    break
        wall = time.perf_counter() - t0
        if _decode_loop._cache_size() == cache0:            # warm: no compile inside
            self.decode_wall_s += wall
            self.decode_timed_steps += ran
            self.decode_timed_lane_steps += lane_steps
        self.decode_calls += 1
        emitted = (np.concatenate([np.asarray(p) for p in parts], axis=0)
                   if parts else np.zeros((0, B), np.int32))  # n_tokens == 0 edge
        out: dict[int, list[int]] = {sid: [] for sid in seq_ids}
        for sid in requested:
            seq = self.store[sid]
            toks = [int(t) for t in emitted[:, seq.slot] if t >= 0]
            out[sid] = toks
            seq.tokens.extend(toks)
            seq.generated += len(toks)
            if stop_token is not None and toks and toks[-1] == stop_token:
                seq.finished = True
            self.prefix_index.insert(seq.tokens, slot=seq.slot)
        return out

    # ------------------------------------------------------------ control ops
    def preempt(self, seq_id: int) -> None:
        """Evict from the running batch but persist the KV cache (Alg. 1 line 7).

        A pure mask flip: the lane stays resident at frozen ``pos``; the next
        ``decode()`` naming this sequence flips the mask back — zero data movement."""
        self.store[seq_id].preempted = True

    def release(self, seq_id: int) -> None:
        """Finish a sequence; its lane retires into the radix cache's LRU set
        (prefix stays implantable) until admission pressure or the byte budget
        reclaims it."""
        seq = self.store.pop(seq_id, None)
        if seq is not None:
            self._retire_slot(seq.slot, len(seq.tokens))

    def _package_meta(self, seq: Sequence, preempted: bool, finished: bool) -> dict:
        return {
            "seq_id": seq.seq_id,
            "tokens": list(seq.tokens),
            "generated": seq.generated,
            "key": np.asarray(seq.key),
            # lifecycle flags travel with the lane: a trajectory preempted before a
            # tool-interval migration must arrive preempted, not active
            "preempted": preempted,
            "finished": finished,
        }

    def _gather_resident(self, seq: Sequence) -> tuple[dict, dict, list[int], int]:
        """Pages + dense state of one paged lane, trimmed to resident tokens."""
        keep = -(-len(seq.tokens) // self.page_size)
        blocks = self.lane_pages.get(seq.slot, [])[:keep]
        pages = _gather_pages(self.pool, jnp.asarray(blocks, jnp.int32))
        state = M.paged_gather_state(self.pool, seq.slot)
        logical = len(blocks) * self._page_bytes + self._state_bytes
        return pages, state, blocks, logical

    def migrate_out(self, seq_id: int) -> dict:
        """Package one lane's context + cache for transfer (§5.3 KV migration).

        Gathers a single lane — co-resident sequences are untouched.  The local
        copy retires into the radix cache, so group siblings arriving later still
        find the shared prefix here.

        Paged workers package *device-resident* page stacks trimmed to the
        lane's resident tokens: a same-process move is block copies device to
        device, never a host bounce, and ``logical_bytes`` prices exactly the
        resident pages + dense state so the controller/simulator cost model
        stops charging full-lane bytes."""
        seq = self.store.pop(seq_id)
        if self._paged:
            pages, state, blocks, logical = self._gather_resident(seq)
            pkg = self._package_meta(seq, seq.preempted, seq.finished)
            pkg.update(pages=pages, state=state, page_size=self.page_size,
                       capacity=self.capacity, logical_bytes=logical)
            self._retire_slot(seq.slot, len(seq.tokens))
            return pkg
        lane = M.gather_slots(self.pool, np.asarray([seq.slot]))
        self._retire_slot(seq.slot, len(seq.tokens))
        pkg = self._package_meta(seq, seq.preempted, seq.finished)
        pkg["cache"] = jax.tree.map(np.asarray, lane)  # heddle: noqa HDL005 -- dense fallback pool has no page table; the host bounce is its only transport
        pkg["logical_bytes"] = sum(x.nbytes
                                   for x in jax.tree.leaves(pkg["cache"]))
        return pkg

    def checkpoint_out(self, seq_id: int) -> dict:
        """Host-gather one lane WITHOUT evicting it (tool-boundary checkpoint).

        Same package format as :meth:`migrate_out`, but the live lane keeps
        running here — the copy is a recovery source for the fault layer
        (``migrate_in`` on a survivor re-implants it after a worker death).
        Lifecycle flags are snapshotted clean: a restore always re-admits the
        trajectory parked at its tool boundary, never mid-preemption.

        The checkpoint must survive this worker's device dying, so the paged
        payload is host-gathered here — the one legitimate host bounce in the
        migration family (``logical_bytes`` still prices resident pages only,
        identical to the D2D package for the same lane)."""
        seq = self.store[seq_id]
        if self._paged:
            pages, state, blocks, logical = self._gather_resident(seq)
            pkg = self._package_meta(seq, False, False)
            pkg.update(
                pages=jax.tree.map(np.asarray, pages),  # heddle: noqa HDL005 -- checkpoint copy must outlive the source device
                state=jax.tree.map(np.asarray, state),  # heddle: noqa HDL005 -- checkpoint copy must outlive the source device
                page_size=self.page_size, capacity=self.capacity,
                logical_bytes=logical)
            return pkg
        lane = M.gather_slots(self.pool, np.asarray([seq.slot]))
        pkg = self._package_meta(seq, False, False)
        pkg["cache"] = jax.tree.map(np.asarray, lane)  # heddle: noqa HDL005 -- checkpoint copy must outlive the source device (dense fallback)
        pkg["logical_bytes"] = sum(x.nbytes
                                   for x in jax.tree.leaves(pkg["cache"]))
        return pkg

    def _ingest_pages(self, package: dict, slot: int) -> None:
        """Land a paged package: allocate blocks, D2D-scatter the page stacks."""
        pages, state = package["pages"], package["state"]
        n = next(iter(jax.tree.leaves(pages))).shape[1] if pages else 0
        blocks = self._alloc_blocks(n) if n else []
        self.lane_pages[slot] = blocks
        if self.mesh is not None:             # re-shard for THIS worker's sub-mesh
            pages = jax.device_put(pages, cache_shardings(pages, self.mesh))
            state = self._place_cache(state)
        self.pool = _paged_ingest(self.pool, pages,
                                  jnp.asarray(blocks, jnp.int32), state,
                                  jnp.asarray(slot, jnp.int32),
                                  self._row_of(blocks))

    def migrate_in(self, package: dict) -> None:
        """Implant a migrated lane into a free slot (capacities must match).

        Four ingress layouts meet here: a paged package landing on a paged
        worker with the same page size scatters its blocks device-to-device; a
        paged package on a mismatched/dense worker is flattened back to a lane
        (``model.pages_to_lane`` — the cross-degree fallback); a dense package
        on a paged worker scatters through ``model.paged_write_lane``; and the
        dense-to-dense path is the original lane implant.  Implanting re-shards
        for THIS worker's mesh, so migration crosses MP degrees — an mp=4 lane
        lands correctly on an mp=1 pool and vice versa."""
        slot = self._alloc_slot()
        n_tokens = len(package["tokens"])
        if "pages" in package:
            if (self._paged and package.get("page_size") == self.page_size
                    and package.get("capacity") == self.capacity):
                self._ingest_pages(package, slot)
                self._register_seq(package, slot)
                return
            # layout mismatch: flatten the pages back into a dense lane
            cache = M.pages_to_lane(package["pages"], package["state"],
                                    self.capacity)
        else:
            cache = package["cache"]

        def check(dst, src):                  # fail fast on capacity/arch mismatch
            if (dst.shape[0],) + dst.shape[2:] != (src.shape[0],) + src.shape[2:]:
                raise ValueError(
                    f"migrate_in: lane shape {src.shape} does not fit pool lane "
                    f"{dst.shape} — source and destination workers must share "
                    f"capacity and architecture")

        if not self._paged:
            jax.tree.map(check, self.pool["blocks"], cache["blocks"])
        if self.mesh is not None:             # host -> this worker's sub-mesh
            lane = self._place_cache(cache)
        else:
            lane = jax.tree.map(jnp.asarray, cache)
        if self._paged:
            need = min(-(-n_tokens // self.page_size), self.num_pages)
            blocks = self._alloc_blocks(need)
            self.lane_pages[slot] = blocks
            self.pool = _paged_implant(self.pool, lane,
                                       jnp.asarray(slot, jnp.int32),
                                       self._row_of(blocks),
                                       jnp.asarray(n_tokens, jnp.int32))
        else:
            self.pool = _implant(self.pool, lane, slot)
        self._register_seq(package, slot)

    def _register_seq(self, package: dict, slot: int) -> None:
        key = package.get("key")
        if key is None:                                     # foreign package: re-key
            key = np.asarray(jax.random.fold_in(self.base_key, package["seq_id"]))
        seq = Sequence(package["seq_id"], list(package["tokens"]), slot,
                       np.asarray(key), generated=package["generated"],
                       preempted=package.get("preempted", False),
                       finished=package.get("finished", False))
        self.store[package["seq_id"]] = seq
        self.prefix_index.insert(seq.tokens, slot=slot)

    # ------------------------------------------------------------ accounting
    def kv_bytes(self, seq_id: int) -> int:
        """Per-lane cache footprint.

        Dense pools report the fixed lane shape (``jax.eval_shape`` at
        construction, stable across growth).  Paged lanes report *resident*
        pages + dense state — the number that actually gates admission."""
        assert seq_id in self.store
        if self._paged:
            slot = self.store[seq_id].slot
            return (len(self.lane_pages.get(slot, [])) * self._page_bytes
                    + self._state_bytes)
        return self._lane_bytes

    def reset_cache(self) -> None:
        """Drop every resident and retired lane and all radix refs.

        Required on weight sync (RL loop): retired KV computed under old weights
        must never be implanted into post-update admissions.  Paged lanes free
        their blocks through the pool's normal accounting (conservation stats
        stay consistent); rows are reset to scratch lazily at reallocation."""
        if self._paged:
            for slot in list(self.lane_pages):
                self._free_lane_pages(slot)
        self.store.clear()
        self.retired.clear()
        self.prefix_index = PrefixCacheIndex(
            max_nodes=self.prefix_index.max_nodes)

    def dispatch_stats(self) -> dict:
        """Measured admission/reuse counters for the control plane (§3 telemetry).

        The controller aggregates these into ``measured_reuse_rate`` so placement
        and the simulator's cache model consume observed hit rates, not assumed
        ones."""
        idx = self.prefix_index
        stats = {}
        if self._paged:
            # page-pool occupancy watermarks + the block-conservation feed
            # (TraceSanitizer checks allocated == freed + resident + shared at
            # drain; serve.py surfaces the watermarks in the run report)
            stats = {"blocks_" + k: v for k, v in self.pages.stats().items()}
            stats["page_size"] = self.page_size
            stats["block_grows"] = self.block_grows
        return {
            **stats,
            "reused_tokens": self.reused_tokens,
            "prefilled_tokens": self.prefilled_tokens,
            "absorbed_tokens": self.absorbed_tokens,
            "prefill_dispatches": self.prefill_dispatches,
            "full_hits": idx.full_hits,
            "partial_hits": idx.partial_hits,
            "lookups": idx.lookups,
            "hit_tokens": idx.hit_tokens,
            "retired_lanes": len(self.retired),
            "decode_steps": self.decode_steps,
            "pool_grows": self.pool_grows,
            # §6 calibration feed: declared MP degree + measured decode timing
            # (warm calls only), consumed by calibration_observations()
            "mp": self.mp,
            "decode_wall_s": self.decode_wall_s,
            "decode_timed_steps": self.decode_timed_steps,
            "decode_timed_lane_steps": self.decode_timed_lane_steps,
            "decode_calls": self.decode_calls,
        }
