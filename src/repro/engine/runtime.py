"""Real-engine rollout runtime = orchestrator + RolloutWorker backend.

``RolloutRuntime`` runs full agentic trajectories — generate → tool call →
absorb → repeat — on the real slot-pool data plane (``engine.worker``,
``engine.fleet``), under the same canonical control loop the simulator uses:
``core.orchestrator.Orchestrator`` driving an ``engine.backends.EngineBackend``.
This module contributes the *engine-side wiring*, not an event loop of its own
(the former twin loop is gone):

  * workload helpers — ``miniaturize`` (paper-scale plans → engine scale, tail
    and tool/gen ratio preserving), ``synth_prompts``, ``build_workbench``;
  * ``ToolEnvironment`` — deterministic tool backend (paper §3 'Tool Manager'):
    plan-driven outcomes, per-``(traj, step)``-seeded token ids *and* sampled
    latencies, so results never depend on backend or invocation order;
  * ``make_runtime`` / ``run_on_sim`` — identical controller wiring for the
    real fleet and for its analytic twin (the decision-trace parity pair);
  * ``calibrate()`` / ``reconfigure()`` — the §6 feedback loop: measured decode
    timing refits the latency model, Algorithm 2 re-provisions, and the fleet
    split/merges between runs.

Time is a **virtual event clock**: decoded tokens are real (real model, real
KV lanes, real sampling keys), but each decode quantum of ``q`` tokens at batch
``b`` costs ``q * token_time * F(b)`` virtual seconds and tool calls cost their
workload-sampled latencies.  That keeps end-to-end makespans deterministic,
hardware-independent, and long-tail-faithful while the data plane does the
actual token work.  See docs/runtime.md for the orchestrator/backend contract
and the lifecycle (PENDING → GENERATING → TOOL_CALL → MIGRATING → FINISHED).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.controller import HeddleController
from repro.core.faults import FaultPlan, RetryPolicy, resolve_tool_call
from repro.core.orchestrator import Orchestrator, OrchestratorConfig, OrchestratorResult
from repro.core.tenancy import ServingConfig
from repro.core.trajectory import Trajectory
from repro.engine.backends import EngineBackend, SimBackend
from repro.engine.fleet import FleetSpec, RolloutFleet
from repro.engine.tools import TOOL_PROFILES, ToolProfile
from repro.engine.worker import RolloutWorker
from repro.engine.workload import TrajectoryPlan


# ---------------------------------------------------------------- configuration

@dataclass(frozen=True)
class RuntimeConfig:
    scheduler: str = "pps"               # pps | fcfs | rr | sjf (per-worker queues)
    migration: bool = True               # tool-interval KV migration (§5.3)
    max_active: int = 4                  # decode-concurrency slots per worker
    quantum: int = 8                     # decode tokens per scheduling quantum
    token_time: float = 0.02             # virtual s/token at batch 1 (per worker)
    kv_weight_ratio: float = 0.02        # interference F(b) = 1 + r * b
    prefill_speedup: float = 100.0       # prefill token cost vs decode token cost
    link_bandwidth: float = 2e9          # virtual migration link (bytes/s)
    tool_latency_scale: float = 1.0      # scales the workload's sampled latencies
    # preemption hysteresis applied to preemptive schedulers (PPS): progressive
    # predictions are noisy early in a trajectory, and at that stage every
    # low-margin preemption is a coin flip that only adds requeue delay — raise
    # these when the batch is heavily oversubscribed (units: predicted tokens)
    preemption_margin: float = 1.0
    preemption_floor: float = 2.0
    trace: bool = False                  # record the decision trace (parity harness)
    sanitize: bool = False               # validate the decision stream
                                         # (repro.analysis.sanitize.TraceSanitizer)
    seed: int = 0
    checkpoint_dir: str | None = None    # persist tool-boundary checkpoints here
    open_loop: bool = False              # serve arrival-stamped trajectories
                                         # (submit_time) instead of a t=0 batch
    paged: bool | None = None            # paged-KV data plane (None = auto: on
                                         # whenever model.supports_paged_kv)
    page_size: int = 16                  # KV tokens per physical block


@dataclass
class RuntimeResult:
    makespan: float                      # virtual seconds to drain the batch
    total_tokens: int                    # real tokens decoded across all workers
    throughput: float                    # tokens per virtual second
    preemptions: int
    migrations: int
    queue_delay_mean: float              # over per-step queue delays
    queue_delay_p99: float
    trajectories: list[Trajectory] = field(default_factory=list)
    worker_stats: dict[int, dict] = field(default_factory=dict)
    wall_time: float = 0.0               # real seconds spent end to end
    events: int = 0
    degrees: list[int] = field(default_factory=list)  # fleet MP degrees (§6)
    trace: list[tuple[str, int, int]] = field(default_factory=list)
    # chaos telemetry (all zero on a fault-free run)
    worker_deaths: int = 0
    recoveries: int = 0
    tool_retries: int = 0
    injected_tool_faults: int = 0
    # serving telemetry (all zero/empty on a closed-loop run)
    arrivals: int = 0
    admitted: int = 0
    shed: int = 0
    deferred: int = 0
    degraded: int = 0
    peak_live_global: int = 0
    peak_live_worker: int = 0
    tenant_report: dict = field(default_factory=dict)
    sanitizer: dict = field(default_factory=dict)  # TraceSanitizer report ({} = off)


@dataclass
class ToolResult:
    latency: float
    failed: bool                         # plan-driven task outcome (rectification)
    output_tokens: list[int]
    terminal: bool = False
    attempts: int = 1                    # chaos layer: >1 = retries absorbed faults
    injected_faults: int = 0             # chaos layer: injected timeouts + errors


class ToolEnvironment:
    """Deterministic simulated tool backend (paper §3 'Tool Manager', elastic FaaS).

    Plan-driven outcomes — latency, failure, output size — come from the
    trajectory's pre-rolled ``TrajectoryPlan`` (``engine.workload``
    distributions, Table 1 latency calibration).  Everything stochastic the
    environment produces itself — output token *ids*, and sampled latencies for
    plan-less trajectories — is drawn from an rng seeded by
    ``(seed, traj_id, step)``: the same trajectory sees the same tool behavior
    regardless of which backend runs it or in what order steps across the batch
    interleave (the per-call-sequence rng this replaced broke exactly that).
    """

    def __init__(self, seed: int = 0, latency_scale: float = 1.0,
                 vocab: tuple[int, int] = (5, 105),
                 profile: ToolProfile | None = None, *,
                 faults: FaultPlan | None = None,
                 retry: RetryPolicy = RetryPolicy()):
        self.seed = seed
        self.latency_scale = latency_scale
        self.vocab = vocab
        self.profile = profile
        self.faults = faults
        self.retry = retry
        self.invocations = 0
        self.total_latency = 0.0

    def _rng(self, traj_id: int, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, traj_id, step))

    def sample_latency(self, traj_id: int, step: int) -> float:
        """Profile-sampled latency, seeded per (traj, step) — order-independent."""
        profile = self.profile or TOOL_PROFILES["math"]
        return float(profile.sample_latency(self._rng(traj_id, step))) \
            * self.latency_scale

    def invoke(self, traj: Trajectory, step: int) -> ToolResult:
        plan: TrajectoryPlan = traj.payload
        lat = float(plan.tool_latency[step]) * self.latency_scale
        n_out = int(plan.tool_output_tokens[step])
        toks = [int(t) for t in self._rng(traj.traj_id, step).integers(
            *self.vocab, n_out)]
        # injected system faults stretch latency via the retry discipline but
        # never touch the plan-driven outcome (failed / output tokens)
        trace = resolve_tool_call(self.faults, self.retry, traj.traj_id, step, lat)
        self.invocations += 1
        self.total_latency += trace.latency
        return ToolResult(trace.latency, bool(plan.tool_failed[step]), toks,
                          attempts=trace.attempts,
                          injected_faults=trace.injected_faults)

    def step_outcome(self, traj: Trajectory, step: int, gen_tokens: list[int],
                     context: list[int]) -> ToolResult:
        """The EngineBackend environment hook: roll the step's tool + terminality.

        The terminal step's tool ends the episode: its plan outcome is recorded
        for predictor-feature parity (harvest replays it too) but the
        environment is never invoked — no tool actually runs.  A degraded
        trajectory's tightened ``step_cap`` terminates ahead of the plan; the
        check is ordered identically to ``SimBackend.tool_submit`` so fault
        injection stays bit-equal across backends."""
        plan: TrajectoryPlan = traj.payload
        if (traj.step_cap is not None and step + 1 >= traj.step_cap) \
                or step + 1 >= plan.num_steps:
            return ToolResult(float(plan.tool_latency[step]) * self.latency_scale,
                              bool(plan.tool_failed[step]),
                              [0] * int(plan.tool_output_tokens[step]),
                              terminal=True)
        return self.invoke(traj, step)


# ---------------------------------------------------------------- workload helpers

def miniaturize(trajectories: list[Trajectory], *, max_steps: int | None = None,
                max_total_tokens: int = 48, max_prompt: int = 12,
                max_tool_tokens: int = 6, min_step_tokens: int = 2
                ) -> list[Trajectory]:
    """Rescale a paper-scale workload onto the real reduced-model engine.

    ``engine.workload.generate`` rolls plans at paper magnitudes (8K-token
    medians, 40K tails) that a reduced CPU model cannot decode; this maps every
    plan's token counts into engine range *multiplicatively* — one shared scale
    factor per quantity — so the lognormal long-tail shape (the thing the
    scheduler is being evaluated on) survives the shrink.  Tool latencies shrink
    by the *same* factor as generation tokens: a step's generation time is
    ``tokens * token_time``, so scaling both keeps the paper's tool/generation
    time ratio (Table 1 latencies vs ~420-token steps, ≈0.05) — leaving
    latencies at full scale would park every trajectory in tool calls and erase
    the slot contention trajectory-level scheduling exists to manage.  Plans are
    optionally truncated to ``max_steps`` agentic steps first (note the
    truncation itself flattens the step-count tail — benchmarks that evaluate
    long-tail scheduling should leave it None).  Mutates in place.
    """
    n_steps = {t.traj_id: (len(t.payload.gen_tokens) if max_steps is None
                           else min(len(t.payload.gen_tokens), max_steps))
               for t in trajectories}
    peak_total = max(sum(t.payload.gen_tokens[:n_steps[t.traj_id]])
                     for t in trajectories)
    peak_prompt = max(t.prompt_tokens for t in trajectories)
    peak_tool = max((o for t in trajectories
                     for o in t.payload.tool_output_tokens[:n_steps[t.traj_id]]),
                    default=1)
    g_scale = max_total_tokens / max(peak_total, 1)
    p_scale = max_prompt / max(peak_prompt, 1)
    o_scale = max_tool_tokens / max(peak_tool, 1)
    for t in trajectories:
        p: TrajectoryPlan = t.payload
        n = n_steps[t.traj_id]
        gen = [max(min_step_tokens, round(g * g_scale)) for g in p.gen_tokens[:n]]
        touts = [max(1, round(o * o_scale)) for o in p.tool_output_tokens[:n]]
        fail = list(p.tool_failed[:n])
        fail[-1] = False                 # terminal step's tool ends the episode
        lat = [x * g_scale for x in p.tool_latency[:n]]
        t.payload = TrajectoryPlan(gen, lat, fail, touts)
        t.prompt_tokens = max(4, round(t.prompt_tokens * p_scale))
        t.context_tokens = t.prompt_tokens
        t.true_total_tokens = sum(gen)
        t.true_num_steps = n
    return trajectories


def synth_prompts(trajectories: list[Trajectory], seed: int = 0,
                  vocab: tuple[int, int] = (5, 105)) -> dict[int, list[int]]:
    """Deterministic prompt token ids; GRPO siblings (same prompt_id) share ids,
    so co-placed groups exercise the engine's radix-cache prefix implants."""
    prompts: dict[int, list[int]] = {}
    for t in trajectories:
        rng = np.random.default_rng((seed, t.prompt_id))
        prompts[t.traj_id] = [int(x) for x in rng.integers(*vocab, t.prompt_tokens)]
    return prompts


def required_capacity(trajectories: list[Trajectory]) -> int:
    """Max lane occupancy any trajectory can reach: prompt + all gen + all tool."""
    return max(t.prompt_tokens + t.payload.total_tokens
               + sum(t.payload.tool_output_tokens) for t in trajectories)


def build_workbench(task: str = "coding", n_prompts: int = 6, group_size: int = 4,
                    seed: int = 0, *, base_steps: float = 3.0,
                    max_steps: int | None = None, max_total_tokens: int = 96,
                    max_prompt: int = 12, max_tool_tokens: int = 6,
                    min_step_tokens: int = 1, hist_prompts: int = 24):
    """Miniaturized long-tail batch + a predictor fitted on a disjoint history.

    The predictor trains on a *replayed* history workload at the same miniature
    scale the runtime decodes at — same contract as the paper's harvesting of
    historical trajectories, so predictions land in the units the scheduler
    queues on.  Returns ``(batch, predictor)``.
    """
    from repro.core.predictor import ProgressivePredictor
    from repro.engine.workload import WorkloadConfig, generate, replay_finished
    mini = dict(max_steps=max_steps, max_total_tokens=max_total_tokens,
                max_prompt=max_prompt, max_tool_tokens=max_tool_tokens,
                min_step_tokens=min_step_tokens)
    wl = dict(task=task, group_size=group_size, base_steps=base_steps)
    hist = replay_finished(miniaturize(
        generate(WorkloadConfig(n_prompts=hist_prompts, seed=seed + 10_000, **wl)),
        **mini))
    predictor = ProgressivePredictor().fit_trajectories(hist)
    batch = miniaturize(
        generate(WorkloadConfig(n_prompts=n_prompts, seed=seed, **wl)), **mini)
    return batch, predictor


def _make_controller(predictor, config: RuntimeConfig, spec: FleetSpec, *,
                     migration_load_gap: int = 1, migration_cooldown_steps: int = 1,
                     rank_hysteresis: float = 0.2,
                     serving: "ServingConfig | None" = None) -> HeddleController:
    """One controller construction for the real fleet AND its analytic twin.

    Gates default to small-cluster values (load gap 1, short cooldown): at a
    few workers and a few dozen live trajectories, the simulator-scale defaults
    never see a gap wide enough to open.  Heterogeneous fleets usually want a
    wider gap (the controller weighs loads in fast-worker equivalents, so a
    1-equivalent imbalance is within rounding of a single resident)."""
    from repro.core.controller import HeddleConfig
    from repro.core.placement import InterferenceModel
    from repro.core.resource_manager import WorkerLatencyModel
    return HeddleController(
        predictor, InterferenceModel.analytic(config.kv_weight_ratio),
        WorkerLatencyModel(t1=config.token_time), gpu_budget=spec.budget,
        config=HeddleConfig(scheduler=config.scheduler, adaptive_resources=False,
                            migration=config.migration,
                            migration_load_gap=migration_load_gap,
                            migration_cooldown_steps=migration_cooldown_steps,
                            rank_hysteresis=rank_hysteresis,
                            serving=serving if serving is not None
                            else ServingConfig()),
        max_workers=spec.n_workers)


def make_runtime(cfg, params, batch: list[Trajectory], predictor,
                 n_workers: int = 2, config: RuntimeConfig = RuntimeConfig(), *,
                 fleet: FleetSpec | None = None, capacity: int | None = None,
                 migration_load_gap: int = 1, migration_cooldown_steps: int = 1,
                 rank_hysteresis: float = 0.2, temperature: float = 0.8,
                 devices=None, faults: FaultPlan | None = None,
                 retry: RetryPolicy = RetryPolicy(),
                 serving: ServingConfig | None = None) -> "RolloutRuntime":
    """Wire controller + real worker fleet + tool environment into a RolloutRuntime.

    ``fleet`` is the per-worker MP degree spec (§6); omitted, it defaults to a
    homogeneous mp=1 fleet of ``n_workers``.  A non-trivial spec builds each
    worker on its own carved sub-mesh (when the device set allows) and prices
    its virtual decode clock through the controller's ``WorkerLatencyModel``,
    so long-tail partitions land on — and actually decode faster on — the
    high-MP workers.
    """
    from repro.engine.sampler import SamplerConfig
    spec = fleet if fleet is not None else FleetSpec.homogeneous(n_workers)
    controller = _make_controller(predictor, config, spec,
                                  migration_load_gap=migration_load_gap,
                                  migration_cooldown_steps=migration_cooldown_steps,
                                  rank_hysteresis=rank_hysteresis,
                                  serving=serving)
    cap = max(capacity or 0, required_capacity(batch))
    if max(spec.degrees) > 1:            # KV capacity shards evenly on the model axis
        cap = -(-cap // max(spec.degrees)) * max(spec.degrees)
    fleet_obj = RolloutFleet(cfg, params, spec, capacity=cap,
                             max_slots=len(batch),
                             sampler=SamplerConfig(temperature=temperature),
                             seed=config.seed, devices=devices,
                             paged=config.paged, page_size=config.page_size)
    env = ToolEnvironment(seed=config.seed,
                          latency_scale=config.tool_latency_scale,
                          faults=faults, retry=retry)
    return RolloutRuntime(fleet_obj, controller, batch, env, config,
                          faults=faults)


def make_sim_components(predictor, n_workers: int = 2,
                        config: RuntimeConfig = RuntimeConfig(), *,
                        fleet: FleetSpec | None = None,
                        migration_load_gap: int = 1,
                        migration_cooldown_steps: int = 1,
                        rank_hysteresis: float = 0.2,
                        prompt_lens: dict[int, int] | None = None,
                        faults: FaultPlan | None = None,
                        retry: RetryPolicy = RetryPolicy(),
                        serving: ServingConfig | None = None):
    """Controller + engine-parity ``SimBackend`` pair — ``run_on_sim``'s wiring,
    reusable by anything that drives the orchestrator itself (the streaming
    service plane builds on this).  Returns ``(backend, controller)``.
    """
    spec = fleet if fleet is not None else FleetSpec.homogeneous(n_workers)
    controller = _make_controller(predictor, config, spec,
                                  migration_load_gap=migration_load_gap,
                                  migration_cooldown_steps=migration_cooldown_steps,
                                  rank_hysteresis=rank_hysteresis,
                                  serving=serving)
    controller.degrees = list(spec.degrees)
    lat = controller.latency
    token_times = [config.token_time * lat.base_token_time(mp)
                   / lat.base_token_time(1) for mp in spec.degrees]
    backend = SimBackend(
        list(spec.degrees), token_times, controller.interference,
        prefill_speedup=config.prefill_speedup,
        link_bandwidth=config.link_bandwidth,
        latency_scale=config.tool_latency_scale,
        quantum=config.quantum, prompt_lens=prompt_lens,
        faults=faults, retry=retry,
        # price migrated KV on the page grid iff the engine twin runs paged
        page_size=0 if config.paged is False else config.page_size)
    return backend, controller


def run_on_sim(batch: list[Trajectory], predictor, n_workers: int = 2,
               config: RuntimeConfig = RuntimeConfig(), *,
               fleet: FleetSpec | None = None, migration_load_gap: int = 1,
               migration_cooldown_steps: int = 1, rank_hysteresis: float = 0.2,
               prompt_lens: dict[int, int] | None = None,
               faults: FaultPlan | None = None,
               retry: RetryPolicy = RetryPolicy(),
               serving: ServingConfig | None = None) -> OrchestratorResult:
    """Run a runtime configuration on the analytic twin — no model, no engine.

    Builds the exact controller ``make_runtime`` would and a ``SimBackend`` in
    engine-parity mode (quantized decode priced with the engine's arithmetic,
    admission charged to worker clocks), then drives the shared orchestrator.
    With the same batch, predictor and config — and a latency-dominated or
    infinite migration link — the scheduling/migration decision trace is
    identical to the real engine's, which ``tests/test_orchestrator.py``
    asserts and ``benchmarks/bench_rollout.py --backend sim`` exploits for
    model-free policy sweeps.
    """
    backend, controller = make_sim_components(
        predictor, n_workers, config, fleet=fleet,
        migration_load_gap=migration_load_gap,
        migration_cooldown_steps=migration_cooldown_steps,
        rank_hysteresis=rank_hysteresis, prompt_lens=prompt_lens,
        faults=faults, retry=retry, serving=serving)
    orch = Orchestrator(
        backend, batch,
        OrchestratorConfig(scheduler=config.scheduler, migration=config.migration,
                           max_active=config.max_active,
                           open_loop=config.open_loop,
                           preemption_margin=config.preemption_margin,
                           preemption_floor=config.preemption_floor,
                           trace=config.trace, sanitize=config.sanitize),
        controller=controller, faults=faults)
    return orch.run()


# ---------------------------------------------------------------- runtime

class RolloutRuntime:
    """Drives real RolloutWorkers through full agentic trajectories, event-driven.

    The caller supplies the worker fleet — a ``RolloutFleet`` (heterogeneous MP,
    reconfigurable between steps) or a bare worker list — a ``HeddleController``
    with a fitted predictor, the trajectory batch, and an environment exposing
    ``step_outcome`` (plan-driven ``ToolEnvironment`` or a task adapter like
    ``rl.loop.TaskEnvironment``).  ``run()`` builds the EngineBackend +
    Orchestrator pair, executes the batch to completion and returns
    deterministic end-to-end metrics.

    The fleet's per-worker MP degrees are the **single source of truth**: the
    controller's ``degrees`` vector is synced from them here (a pre-set
    conflicting stub raises), and each worker's virtual decode clock is priced
    at ``controller.latency.base_token_time(mp)`` — normalized so an mp=1 worker
    costs exactly ``config.token_time`` per token.
    """

    def __init__(self,
                 workers: list[RolloutWorker] | RolloutFleet,
                 controller: HeddleController,
                 trajectories: list[Trajectory], tool_env,
                 config: RuntimeConfig = RuntimeConfig(),
                 prompts: dict[int, list[int]] | None = None, *,
                 stop_token: int | None = None,
                 step_budget=None,
                 faults: FaultPlan | None = None):
        self.cfg = config
        self.controller = controller
        self.env = tool_env
        self.faults = faults
        self.trajs = list(trajectories)
        self.prompts = prompts if prompts is not None \
            else synth_prompts(self.trajs, seed=config.seed)
        if isinstance(workers, RolloutFleet):
            self.fleet: RolloutFleet | None = workers
            engines = workers.workers
        else:
            self.fleet = None
            engines = list(workers)
        # one authority for MP degrees: the engines themselves (FleetSpec
        # validates the §6.1 descending sort-and-zip order).  A controller
        # arriving with a different pre-set vector is a stale stub — refuse to
        # let it silently mask the real allocation.
        self.spec = FleetSpec(tuple(w.mp for w in engines))
        if controller.degrees and list(controller.degrees) != list(self.spec.degrees):
            raise ValueError(
                f"controller.degrees {controller.degrees} conflicts with the "
                f"fleet's MP degrees {list(self.spec.degrees)}; the fleet spec "
                f"is the single source of truth — drop the manual assignment")
        controller.degrees = list(self.spec.degrees)
        planned = [t for t in self.trajs
                   if isinstance(t.payload, TrajectoryPlan)]
        if planned:
            cap = min(w.capacity for w in engines)
            need = required_capacity(planned)
            if need > cap:
                raise ValueError(f"worker capacity {cap} < max trajectory context "
                                 f"{need}; raise capacity or miniaturize harder")
        self.stop_token = stop_token
        self.step_budget = step_budget
        self.backend = self._make_backend(engines)
        self._orch: Orchestrator | None = None

    # ------------------------------------------------------------ fleet pricing
    def _make_backend(self, engines: list[RolloutWorker]) -> EngineBackend:
        """The ONE place engine pricing + environment are wired, so
        reconfigured fleets never drift from freshly constructed ones."""
        return EngineBackend(
            engines, self.env, self.prompts,
            interference=self.controller.interference,
            quantum=self.cfg.quantum,
            token_times=[self._token_time(w.mp) for w in engines],
            prefill_speedup=self.cfg.prefill_speedup,
            link_bandwidth=self.cfg.link_bandwidth,
            stop_token=self.stop_token, step_budget=self.step_budget,
            checkpoint_dir=self.cfg.checkpoint_dir)

    def _token_time(self, mp: int) -> float:
        """Virtual s/token at batch 1 for MP degree ``mp``.

        Scaled through the controller's latency model and normalized so mp=1
        costs exactly ``config.token_time`` — a homogeneous mp=1 fleet prices
        identically to the pre-heterogeneous runtime."""
        lat = self.controller.latency
        return self.cfg.token_time * lat.base_token_time(mp) / lat.base_token_time(1)

    @property
    def workers(self):
        """Per-worker runtime views (``.wid``, ``.engine``, ``.token_time``)."""
        return self.backend.views

    # ------------------------------------------------------------ run
    def run(self) -> RuntimeResult:
        cfg = self.cfg
        wall0 = time.perf_counter()
        # the fleet spec was synced to the controller at construction; anything
        # that mutated it since (a stale [1]*n stub, a partial reconfigure)
        # would silently misprice placement — fail loudly instead
        if list(self.controller.degrees) != list(self.spec.degrees):
            raise ValueError(
                f"controller.degrees {self.controller.degrees} drifted from the "
                f"fleet spec {list(self.spec.degrees)} between construction and "
                f"run(); reconfigure() is the only sanctioned mutation path")
        self._orch = Orchestrator(
            self.backend, self.trajs,
            OrchestratorConfig(scheduler=cfg.scheduler, migration=cfg.migration,
                               max_active=cfg.max_active,
                               open_loop=cfg.open_loop,
                               preemption_margin=cfg.preemption_margin,
                               preemption_floor=cfg.preemption_floor,
                               max_events=2_000_000, trace=cfg.trace,
                               sanitize=cfg.sanitize),
            controller=self.controller, faults=self.faults)
        res = self._orch.run()
        for view in self.backend.views:              # final telemetry snapshot
            self.controller.record_worker_stats(view.wid,
                                                view.engine.dispatch_stats())
        if cfg.sanitize:
            from repro.analysis.sanitize import (TraceViolationError,
                                                 check_block_conservation)

            leaks = check_block_conservation(self.controller.worker_stats)
            if leaks:
                raise TraceViolationError(leaks, len(leaks))
            if isinstance(res.sanitizer, dict) and res.sanitizer:
                res.sanitizer["block_conservation"] = "ok"
        makespan = res.makespan
        total = self.backend.total_tokens
        return RuntimeResult(
            makespan=makespan,
            total_tokens=total,
            throughput=total / makespan if makespan > 0 else 0.0,
            preemptions=res.preemptions,
            migrations=res.migrations,
            queue_delay_mean=res.queue_delay_mean,
            queue_delay_p99=res.queue_delay_p99,
            trajectories=self.trajs,
            worker_stats=dict(self.controller.worker_stats),
            wall_time=time.perf_counter() - wall0,
            events=res.events,
            degrees=list(self.spec.degrees),
            trace=res.trace,
            worker_deaths=res.worker_deaths,
            recoveries=res.recoveries,
            tool_retries=res.tool_retries,
            injected_tool_faults=res.injected_tool_faults,
            arrivals=res.arrivals,
            admitted=res.admitted,
            shed=res.shed,
            deferred=res.deferred,
            degraded=res.degraded,
            peak_live_global=res.peak_live_global,
            peak_live_worker=res.peak_live_worker,
            tenant_report=res.tenant_report,
            sanitizer=res.sanitizer,
        )

    # ------------------------------------------------------------ §6 feedback loop
    def calibrate(self):
        """Refit the controller's WorkerLatencyModel from measured decode timing.

        Uses the per-worker warm-call decode timing the run streamed through
        ``record_worker_stats`` (``decode_wall_s / decode_timed_steps`` per-step
        samples), so the next provisioning round prices MP degrees from
        observations instead of Fig. 7 constants.  Returns the fitted model
        (None if no timing was recorded)."""
        return self.controller.calibrate_latency()

    def reconfigure(self, spec: FleetSpec | None = None, *,
                    calibrate: bool = True, budget: int | None = None) -> dict:
        """Between-steps reconfiguration: calibrate → provision → split/merge.

        With ``spec=None`` the controller re-runs Algorithm 2 over this batch's
        trajectories (now carrying observed step histories) under the calibrated
        latency model and the fleet executes the resulting split/merge moves
        (``RolloutFleet.reconfigure``: reuse unchanged slots, re-shard changed
        ones, migrate residents across MP degrees).  ``budget`` overrides the
        accelerator budget for this provisioning round — the dynamic case of
        Algorithm 2: a dead worker shrinks the budget, recovered or scaled-up
        capacity grows it, and the fleet re-partitions onto whatever survives
        (specs of a different length than the current fleet are handled —
        retired workers' residents redistribute, new slots join cold).  Only
        legal between runs — the event queue must be drained.  Returns the
        fleet's move report; residents the fleet relocated have their
        trajectory ``worker_id`` re-pointed so the next run resumes them where
        they actually live.
        """
        if self.fleet is None:
            raise ValueError("runtime was built from a bare worker list; "
                             "construct it with a RolloutFleet to reconfigure")
        if self._orch is not None and self._orch._evq:
            raise RuntimeError("reconfigure() during a live run: drain the "
                               "event queue first (call between steps)")
        if calibrate:
            self.controller.calibrate_latency()
        if spec is None:
            was_adaptive = self.controller.config.adaptive_resources
            was_budget = self.controller.gpu_budget
            self.controller.config.adaptive_resources = True
            if budget is not None:
                self.controller.gpu_budget = int(budget)
            try:
                spec = FleetSpec.from_degrees(
                    self.controller.provision(self.trajs))
            finally:
                self.controller.config.adaptive_resources = was_adaptive
                self.controller.gpu_budget = was_budget
        report = self.fleet.reconfigure(spec)
        moves = report.get("moves", {})
        for t in self.trajs:
            if t.traj_id in moves:
                t.worker_id = moves[t.traj_id]
            elif t.worker_id is not None and t.worker_id >= spec.n_workers:
                t.worker_id = None       # stale placement beyond the new fleet
        self.spec = self.fleet.spec
        self.controller.degrees = list(self.spec.degrees)
        self.backend = self._make_backend(self.fleet.workers)
        return report
