"""Event-driven trajectory-centric rollout runtime: control plane meets data plane.

This module closes the seam the repo previously left open: the trajectory-level
mechanisms of the paper (§4 scheduling/preemption, §5.3 tool-interval migration,
§4.1 progressive prediction) only ever ran inside the discrete-event *simulator*,
while the real ``RolloutWorker`` JAX data plane was driven by a static one-shot
loop with no tool calls, no queues, and no preemption.  ``RolloutRuntime`` drives
real workers through full agentic trajectories — generate → tool call → absorb →
repeat — under the real control plane:

  * **per-worker scheduler queues** (``core.scheduler``: pps | fcfs | rr | sjf)
    gate *decode concurrency* (``max_active`` lanes decode together; the paper's
    batch-size-driven interference premise), with real preemptive execution:
    ``PPSScheduler.preempt_victim`` evicts the weakest active trajectory via
    ``worker.preempt`` — a mask flip, the KV cache persists in its lane;
  * **progressive prediction refresh** on every tool return
    (``HeddleController.on_step_complete`` → ``ProgressivePredictor.predict``),
    so queue priorities track runtime context, not prompt-time guesses;
  * **opportunistic migration during tool-call idle intervals**: controller
    emits ``MigrationRequest``s, the ``TransmissionScheduler`` batches them
    endpoint-exclusively, and the runtime executes real ``migrate_out`` /
    ``migrate_in`` lane transfers whose duration is the *measured* package bytes
    over the configured link;
  * **telemetry feedback**: each worker's ``dispatch_stats()`` flows through
    ``record_worker_stats`` so ``measured_reuse_rate`` reflects the run.

Time is a **virtual event clock**: decoded tokens are real (real model, real KV
lanes, real sampling keys), but each decode quantum of ``q`` tokens at batch
``b`` costs ``q * token_time * F(b)`` virtual seconds and tool calls cost their
workload-sampled latencies.  That keeps end-to-end makespans deterministic,
hardware-independent, and long-tail-faithful while the data plane does the
actual token work — the same methodology the paper uses to profile §5.2, now
wrapped around the real engine.  See docs/runtime.md for the lifecycle
(PENDING → GENERATING → TOOL_CALL → MIGRATING → FINISHED) and invariants.
"""

from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.controller import HeddleController
from repro.core.migration import MigrationRequest, migration_time
from repro.core.scheduler import make_scheduler
from repro.core.trajectory import StepRecord, Trajectory, TrajectoryPhase
from repro.engine.fleet import FleetSpec, RolloutFleet
from repro.engine.worker import RolloutWorker
from repro.engine.workload import TrajectoryPlan


# ---------------------------------------------------------------- configuration

@dataclass(frozen=True)
class RuntimeConfig:
    scheduler: str = "pps"               # pps | fcfs | rr | sjf (per-worker queues)
    migration: bool = True               # tool-interval KV migration (§5.3)
    max_active: int = 4                  # decode-concurrency slots per worker
    quantum: int = 8                     # decode tokens per scheduling quantum
    token_time: float = 0.02             # virtual s/token at batch 1 (per worker)
    kv_weight_ratio: float = 0.02        # interference F(b) = 1 + r * b
    prefill_speedup: float = 100.0       # prefill token cost vs decode token cost
    link_bandwidth: float = 2e9          # virtual migration link (bytes/s)
    tool_latency_scale: float = 1.0      # scales the workload's sampled latencies
    # preemption hysteresis applied to preemptive schedulers (PPS): progressive
    # predictions are noisy early in a trajectory, and at that stage every
    # low-margin preemption is a coin flip that only adds requeue delay — raise
    # these when the batch is heavily oversubscribed (units: predicted tokens)
    preemption_margin: float = 1.0
    preemption_floor: float = 2.0
    seed: int = 0


@dataclass
class RuntimeResult:
    makespan: float                      # virtual seconds to drain the batch
    total_tokens: int                    # real tokens decoded across all workers
    throughput: float                    # tokens per virtual second
    preemptions: int
    migrations: int
    queue_delay_mean: float              # over per-step queue delays
    queue_delay_p99: float
    trajectories: list[Trajectory] = field(default_factory=list)
    worker_stats: dict[int, dict] = field(default_factory=dict)
    wall_time: float = 0.0               # real seconds spent in the data plane
    events: int = 0
    degrees: list[int] = field(default_factory=list)  # fleet MP degrees (§6)


@dataclass
class ToolResult:
    latency: float
    failed: bool
    output_tokens: list[int]


class ToolEnvironment:
    """Deterministic simulated tool backend (paper §3 'Tool Manager', elastic FaaS).

    Outcomes — latency, failure, output size — come from the trajectory's
    pre-rolled ``TrajectoryPlan`` (``engine.workload`` distributions, Table 1
    latency calibration); the output token *ids* are drawn from an rng seeded by
    (seed, traj_id, step), so every run over the same workload absorbs identical
    tool tokens regardless of scheduling order.
    """

    def __init__(self, seed: int = 0, latency_scale: float = 1.0,
                 vocab: tuple[int, int] = (5, 105)):
        self.seed = seed
        self.latency_scale = latency_scale
        self.vocab = vocab
        self.invocations = 0
        self.total_latency = 0.0

    def invoke(self, traj: Trajectory, step: int) -> ToolResult:
        plan: TrajectoryPlan = traj.payload
        lat = float(plan.tool_latency[step]) * self.latency_scale
        n_out = int(plan.tool_output_tokens[step])
        rng = np.random.default_rng((self.seed, traj.traj_id, step))
        toks = [int(t) for t in rng.integers(*self.vocab, n_out)]
        self.invocations += 1
        self.total_latency += lat
        return ToolResult(lat, bool(plan.tool_failed[step]), toks)


# ---------------------------------------------------------------- workload helpers

def miniaturize(trajectories: list[Trajectory], *, max_steps: int | None = None,
                max_total_tokens: int = 48, max_prompt: int = 12,
                max_tool_tokens: int = 6, min_step_tokens: int = 2
                ) -> list[Trajectory]:
    """Rescale a paper-scale workload onto the real reduced-model engine.

    ``engine.workload.generate`` rolls plans at paper magnitudes (8K-token
    medians, 40K tails) that a reduced CPU model cannot decode; this maps every
    plan's token counts into engine range *multiplicatively* — one shared scale
    factor per quantity — so the lognormal long-tail shape (the thing the
    scheduler is being evaluated on) survives the shrink.  Tool latencies shrink
    by the *same* factor as generation tokens: a step's generation time is
    ``tokens * token_time``, so scaling both keeps the paper's tool/generation
    time ratio (Table 1 latencies vs ~420-token steps, ≈0.05) — leaving
    latencies at full scale would park every trajectory in tool calls and erase
    the slot contention trajectory-level scheduling exists to manage.  Plans are
    optionally truncated to ``max_steps`` agentic steps first (note the
    truncation itself flattens the step-count tail — benchmarks that evaluate
    long-tail scheduling should leave it None).  Mutates in place.
    """
    n_steps = {t.traj_id: (len(t.payload.gen_tokens) if max_steps is None
                           else min(len(t.payload.gen_tokens), max_steps))
               for t in trajectories}
    peak_total = max(sum(t.payload.gen_tokens[:n_steps[t.traj_id]])
                     for t in trajectories)
    peak_prompt = max(t.prompt_tokens for t in trajectories)
    peak_tool = max((o for t in trajectories
                     for o in t.payload.tool_output_tokens[:n_steps[t.traj_id]]),
                    default=1)
    g_scale = max_total_tokens / max(peak_total, 1)
    p_scale = max_prompt / max(peak_prompt, 1)
    o_scale = max_tool_tokens / max(peak_tool, 1)
    for t in trajectories:
        p: TrajectoryPlan = t.payload
        n = n_steps[t.traj_id]
        gen = [max(min_step_tokens, round(g * g_scale)) for g in p.gen_tokens[:n]]
        touts = [max(1, round(o * o_scale)) for o in p.tool_output_tokens[:n]]
        fail = list(p.tool_failed[:n])
        fail[-1] = False                 # terminal step's tool ends the episode
        lat = [x * g_scale for x in p.tool_latency[:n]]
        t.payload = TrajectoryPlan(gen, lat, fail, touts)
        t.prompt_tokens = max(4, round(t.prompt_tokens * p_scale))
        t.context_tokens = t.prompt_tokens
        t.true_total_tokens = sum(gen)
        t.true_num_steps = n
    return trajectories


def synth_prompts(trajectories: list[Trajectory], seed: int = 0,
                  vocab: tuple[int, int] = (5, 105)) -> dict[int, list[int]]:
    """Deterministic prompt token ids; GRPO siblings (same prompt_id) share ids,
    so co-placed groups exercise the engine's radix-cache prefix implants."""
    prompts: dict[int, list[int]] = {}
    for t in trajectories:
        rng = np.random.default_rng((seed, t.prompt_id))
        prompts[t.traj_id] = [int(x) for x in rng.integers(*vocab, t.prompt_tokens)]
    return prompts


def required_capacity(trajectories: list[Trajectory]) -> int:
    """Max lane occupancy any trajectory can reach: prompt + all gen + all tool."""
    return max(t.prompt_tokens + t.payload.total_tokens
               + sum(t.payload.tool_output_tokens) for t in trajectories)


def build_workbench(task: str = "coding", n_prompts: int = 6, group_size: int = 4,
                    seed: int = 0, *, base_steps: float = 3.0,
                    max_steps: int | None = None, max_total_tokens: int = 96,
                    max_prompt: int = 12, max_tool_tokens: int = 6,
                    min_step_tokens: int = 1, hist_prompts: int = 24):
    """Miniaturized long-tail batch + a predictor fitted on a disjoint history.

    The predictor trains on a *replayed* history workload at the same miniature
    scale the runtime decodes at — same contract as the paper's harvesting of
    historical trajectories, so predictions land in the units the scheduler
    queues on.  Returns ``(batch, predictor)``.
    """
    from repro.core.predictor import ProgressivePredictor
    from repro.engine.workload import WorkloadConfig, generate, replay_finished
    mini = dict(max_steps=max_steps, max_total_tokens=max_total_tokens,
                max_prompt=max_prompt, max_tool_tokens=max_tool_tokens,
                min_step_tokens=min_step_tokens)
    wl = dict(task=task, group_size=group_size, base_steps=base_steps)
    hist = replay_finished(miniaturize(
        generate(WorkloadConfig(n_prompts=hist_prompts, seed=seed + 10_000, **wl)),
        **mini))
    predictor = ProgressivePredictor().fit_trajectories(hist)
    batch = miniaturize(
        generate(WorkloadConfig(n_prompts=n_prompts, seed=seed, **wl)), **mini)
    return batch, predictor


def make_runtime(cfg, params, batch: list[Trajectory], predictor,
                 n_workers: int = 2, config: RuntimeConfig = RuntimeConfig(), *,
                 fleet: FleetSpec | None = None, capacity: int | None = None,
                 migration_load_gap: int = 1, migration_cooldown_steps: int = 1,
                 rank_hysteresis: float = 0.2, temperature: float = 0.8,
                 devices=None) -> "RolloutRuntime":
    """Wire controller + real worker fleet + tool environment into a RolloutRuntime.

    ``fleet`` is the per-worker MP degree spec (§6); omitted, it defaults to a
    homogeneous mp=1 fleet of ``n_workers`` — the pre-heterogeneous behavior.
    A non-trivial spec builds each worker on its own carved sub-mesh (when the
    device set allows) and prices its virtual decode clock through the
    controller's ``WorkerLatencyModel``, so long-tail partitions land on — and
    actually decode faster on — the high-MP workers.

    Controller gates default to small-cluster values (load gap 1, short
    cooldown): at a few workers and a few dozen live trajectories, the
    simulator-scale defaults never see a gap wide enough to open.
    """
    from repro.core.controller import HeddleConfig
    from repro.core.placement import InterferenceModel
    from repro.core.resource_manager import WorkerLatencyModel
    from repro.engine.sampler import SamplerConfig
    spec = fleet if fleet is not None else FleetSpec.homogeneous(n_workers)
    controller = HeddleController(
        predictor, InterferenceModel.analytic(config.kv_weight_ratio),
        WorkerLatencyModel(t1=config.token_time), gpu_budget=spec.budget,
        config=HeddleConfig(scheduler=config.scheduler, adaptive_resources=False,
                            migration=config.migration,
                            migration_load_gap=migration_load_gap,
                            migration_cooldown_steps=migration_cooldown_steps,
                            rank_hysteresis=rank_hysteresis),
        max_workers=spec.n_workers)
    cap = max(capacity or 0, required_capacity(batch))
    if max(spec.degrees) > 1:            # KV capacity shards evenly on the model axis
        cap = -(-cap // max(spec.degrees)) * max(spec.degrees)
    fleet_obj = RolloutFleet(cfg, params, spec, capacity=cap,
                             max_slots=len(batch),
                             sampler=SamplerConfig(temperature=temperature),
                             seed=config.seed, devices=devices)
    env = ToolEnvironment(seed=config.seed,
                          latency_scale=config.tool_latency_scale)
    return RolloutRuntime(fleet_obj, controller, batch, env, config)


# ---------------------------------------------------------------- runtime

class _WorkerState:
    """One rollout worker's runtime view: engine + queue + active decode set."""

    def __init__(self, wid: int, engine: RolloutWorker, scheduler_name: str,
                 token_time: float = 0.02):
        self.wid = wid
        self.engine = engine
        self.scheduler = make_scheduler(scheduler_name)
        self.active: set[int] = set()    # traj_ids currently decoding
        self.clock = 0.0                 # this worker's virtual time frontier
        self.sleeping = True             # no worker_ready event in flight
        self.token_time = token_time     # virtual s/token at batch 1 AT THIS MP


class RolloutRuntime:
    """Drives real RolloutWorkers through full agentic trajectories, event-driven.

    The caller supplies the worker fleet — a ``RolloutFleet`` (heterogeneous MP,
    reconfigurable between steps) or a bare worker list (uniform ``capacity`` —
    migration moves lanes between pools) — a ``HeddleController`` with a fitted
    predictor, the trajectory batch (``engine.workload`` plans, typically
    ``miniaturize``d), and a ``ToolEnvironment``.  ``run()`` executes the batch
    to completion and returns deterministic end-to-end metrics.

    The fleet's per-worker MP degrees are the **single source of truth**: the
    controller's ``degrees`` vector is synced from them here (a pre-set
    conflicting stub raises), and each worker's virtual decode clock is priced
    at ``controller.latency.base_token_time(mp)`` — normalized so an mp=1 worker
    costs exactly ``config.token_time`` per token.
    """

    def __init__(self,
                 workers: list[RolloutWorker] | RolloutFleet,
                 controller: HeddleController,
                 trajectories: list[Trajectory], tool_env: ToolEnvironment,
                 config: RuntimeConfig = RuntimeConfig(),
                 prompts: dict[int, list[int]] | None = None):
        self.cfg = config
        self.controller = controller
        self.env = tool_env
        self.trajs = list(trajectories)
        self.by_id = {t.traj_id: t for t in self.trajs}
        self.prompts = prompts if prompts is not None \
            else synth_prompts(self.trajs, seed=config.seed)
        if isinstance(workers, RolloutFleet):
            self.fleet: RolloutFleet | None = workers
            engines = workers.workers
        else:
            self.fleet = None
            engines = list(workers)
        # one authority for MP degrees: the engines themselves (FleetSpec
        # validates the §6.1 descending sort-and-zip order).  A controller
        # arriving with a different pre-set vector is a stale stub — refuse to
        # let it silently mask the real allocation.
        self.spec = FleetSpec(tuple(w.mp for w in engines))
        if controller.degrees and list(controller.degrees) != list(self.spec.degrees):
            raise ValueError(
                f"controller.degrees {controller.degrees} conflicts with the "
                f"fleet's MP degrees {list(self.spec.degrees)}; the fleet spec "
                f"is the single source of truth — drop the manual assignment")
        controller.degrees = list(self.spec.degrees)
        cap = min(w.capacity for w in engines)
        need = required_capacity(self.trajs)
        if need > cap:
            raise ValueError(f"worker capacity {cap} < max trajectory context "
                             f"{need}; raise capacity or miniaturize harder")
        self.workers = self._worker_states(engines)
        self.interference = controller.interference
        # runtime lifecycle state
        self.step_remaining: dict[int, int] = {}     # mid-step decode budget
        self.pending_tool: dict[int, list[int]] = {} # tool output awaiting absorb
        self.in_flight: dict[int, tuple[dict, int]] = {}  # migration (pkg, dst)
        self.tool_arrived: set[int] = set()          # tool done while KV in flight
        self.preemptions = 0
        self.migrations = 0
        self.total_tokens = 0
        self.wall = 0.0
        self._evq: list[tuple[float, int, str, int]] = []
        self._seq = itertools.count()

    # ------------------------------------------------------------ fleet pricing
    def _worker_states(self, engines: list[RolloutWorker]) -> list[_WorkerState]:
        """Runtime views (queue + clock + pricing) for a worker set — the ONE
        place scheduler knobs are wired, so reconfigured fleets never drift
        from freshly constructed ones."""
        states = [
            _WorkerState(w.worker_id, w, self.cfg.scheduler,
                         token_time=self._token_time(w.mp))
            for w in engines]
        for ws in states:
            if hasattr(ws.scheduler, "preemption_margin"):
                ws.scheduler.preemption_margin = self.cfg.preemption_margin
                ws.scheduler.preemption_floor = self.cfg.preemption_floor
        return states

    def _token_time(self, mp: int) -> float:
        """Virtual s/token at batch 1 for MP degree ``mp``.

        Scaled through the controller's latency model and normalized so mp=1
        costs exactly ``config.token_time`` — a homogeneous mp=1 fleet prices
        identically to the pre-heterogeneous runtime."""
        lat = self.controller.latency
        return self.cfg.token_time * lat.base_token_time(mp) / lat.base_token_time(1)

    # ------------------------------------------------------------ event plumbing
    def _push(self, t: float, kind: str, payload: int) -> None:
        heapq.heappush(self._evq, (t, next(self._seq), kind, payload))

    def _submit(self, traj: Trajectory, now: float) -> None:
        """Queue the trajectory's next generation step on its current worker."""
        ws = self.workers[traj.worker_id]
        traj._queued_at = now
        ws.scheduler.submit(traj, now)
        if ws.sleeping:
            ws.sleeping = False
            self._push(max(now, ws.clock), "worker_ready", ws.wid)

    # ------------------------------------------------------------ dispatch / preempt
    def _start(self, ws: _WorkerState, traj: Trajectory, now: float) -> None:
        tid = traj.traj_id
        traj._step_queue_delay = getattr(traj, "_step_queue_delay", 0.0) \
            + max(0.0, now - getattr(traj, "_queued_at", now))
        if tid not in self.step_remaining:           # fresh step (not a resume)
            plan: TrajectoryPlan = traj.payload
            self.step_remaining[tid] = int(plan.gen_tokens[traj.num_steps])
        traj.phase = TrajectoryPhase.GENERATING
        ws.active.add(tid)

    def _preempt(self, ws: _WorkerState, victim: Trajectory, now: float) -> None:
        """Alg. 1 lines 5-10 on the real engine: evict, persist KV, requeue."""
        tid = victim.traj_id
        ws.engine.preempt(tid)                       # mask flip; lane stays resident
        ws.active.discard(tid)                       # step_remaining persists: resume
        victim.preemptions += 1                      # continues mid-step
        self.preemptions += 1
        victim.phase = TrajectoryPhase.PREEMPTED
        victim._queued_at = now
        ws.scheduler.submit(victim, now)

    def _dispatch(self, ws: _WorkerState, now: float) -> None:
        while len(ws.active) < self.cfg.max_active and len(ws.scheduler):
            traj = ws.scheduler.pop(now)
            if traj is None:
                break
            self._start(ws, traj, now)
        if ws.scheduler.preemptive and len(ws.scheduler):
            for _ in range(len(ws.active)):
                victim = ws.scheduler.preempt_victim(
                    [self.by_id[t] for t in ws.active])
                if victim is None:
                    break
                self._preempt(ws, victim, now)
                nxt = ws.scheduler.pop(now)
                if nxt is not None:
                    self._start(ws, nxt, now)

    # ------------------------------------------------------------ decode quantum
    def _on_worker_ready(self, ws: _WorkerState, now: float) -> None:
        now = max(now, ws.clock)
        self._dispatch(ws, now)
        if not ws.active:
            ws.sleeping = True
            return
        ids = sorted(ws.active)
        q = min(self.cfg.quantum, min(self.step_remaining[t] for t in ids))
        t0 = time.perf_counter()
        out = ws.engine.decode(ids, q)               # REAL tokens into real lanes
        self.wall += time.perf_counter() - t0
        dt = q * ws.token_time * float(self.interference(len(ids)))
        end = now + dt
        ws.clock = end
        for tid in ids:
            got = len(out[tid])
            self.total_tokens += got
            self.step_remaining[tid] -= got
            traj = self.by_id[tid]
            traj._step_gen_time = getattr(traj, "_step_gen_time", 0.0) + dt
            if self.step_remaining[tid] <= 0:
                ws.active.discard(tid)
                del self.step_remaining[tid]
                self._complete_step(traj, ws, end)
        self._dispatch(ws, end)                      # refill before the next quantum
        if ws.active:
            self._push(end, "worker_ready", ws.wid)
        else:
            ws.sleeping = True

    # ------------------------------------------------------------ step lifecycle
    def _complete_step(self, traj: Trajectory, ws: _WorkerState, now: float) -> None:
        plan: TrajectoryPlan = traj.payload
        s = traj.num_steps
        terminal = s + 1 >= plan.num_steps
        if terminal:
            # the terminal step's tool ends the episode: record the plan's
            # outcome for predictor-feature parity (harvest replays it too) but
            # never invoke the environment — no tool actually runs
            tool = ToolResult(float(plan.tool_latency[s]) * self.env.latency_scale,
                              bool(plan.tool_failed[s]),
                              [0] * int(plan.tool_output_tokens[s]))
        else:
            tool = self.env.invoke(traj, s)
        traj.record_step(StepRecord(
            s, int(plan.gen_tokens[s]), tool.latency, tool_failed=tool.failed,
            tool_output_tokens=len(tool.output_tokens),
            queue_delay=getattr(traj, "_step_queue_delay", 0.0),
            gen_time=getattr(traj, "_step_gen_time", 0.0)))
        traj._step_queue_delay = 0.0
        traj._step_gen_time = 0.0
        traj.record_tool_output(len(tool.output_tokens))
        self.controller.record_worker_stats(ws.wid, ws.engine.dispatch_stats())
        if terminal:
            traj.finished = True
            traj.finish_time = now
            traj.phase = TrajectoryPhase.FINISHED
            self.controller.on_finish(traj)
            ws.engine.release(traj.traj_id)          # lane retires into radix cache
            return
        traj.phase = TrajectoryPhase.TOOL_CALL
        self.pending_tool[traj.traj_id] = tool.output_tokens
        self._push(now + tool.latency, "tool_done", traj.traj_id)
        # progressive refresh + migration decision, masked by the tool interval
        req = self.controller.on_step_complete(traj, ())
        if req is not None and self.cfg.migration:
            for r in self.controller.transmission.next_batch():
                self._launch_migration(r, now)

    # ------------------------------------------------------------ migration (§5.3)
    def _launch_migration(self, req: MigrationRequest, now: float) -> None:
        traj = self.by_id[req.traj_id]
        if traj.phase is not TrajectoryPhase.TOOL_CALL or \
                req.traj_id not in self.workers[req.src].engine.store:
            # resumed, finished, or already moved: migrating now would stall the
            # critical path — drop without touching load accounting
            self.controller.transmission.complete(req.traj_id)
            self.controller.abort_migration(req.traj_id)
            return
        pkg = self.workers[req.src].engine.migrate_out(req.traj_id)
        nbytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(pkg["cache"]))
        self.controller.commit_migration(req.traj_id)
        traj.phase = TrajectoryPhase.MIGRATING
        traj.migrations += 1
        self.migrations += 1
        self.in_flight[req.traj_id] = (pkg, req.dst)
        self._push(now + migration_time(nbytes, self.cfg.link_bandwidth),
                   "migration_done", req.traj_id)

    def _on_migration_done(self, tid: int, now: float) -> None:
        pkg, dst = self.in_flight.pop(tid)
        self.workers[dst].engine.migrate_in(pkg)     # lane lands in the new pool
        traj = self.by_id[tid]
        traj.worker_id = dst
        self.controller.transmission.complete(tid)
        for r in self.controller.transmission.next_batch():
            self._launch_migration(r, now)
        if tid in self.tool_arrived:                 # transfer outlived the tool
            self.tool_arrived.discard(tid)
            self._absorb_and_resume(traj, now)
        else:                                        # fully masked by the tool call
            traj.phase = TrajectoryPhase.TOOL_CALL

    def _on_tool_done(self, tid: int, now: float) -> None:
        if tid in self.in_flight:                    # KV still on the wire: wait
            self.tool_arrived.add(tid)
            return
        self._absorb_and_resume(self.by_id[tid], now)

    def _absorb_and_resume(self, traj: Trajectory, now: float) -> None:
        # resuming invalidates any emitted-but-unlaunched migration: its target
        # was chosen from now-stale load/rank data, and leaving it pending would
        # both fire in some later tool interval and suppress fresh decisions
        self.controller.abort_migration(traj.traj_id)
        toks = self.pending_tool.pop(traj.traj_id, [])
        if toks:                                     # chunked prefill into the lane
            self.workers[traj.worker_id].engine.extend(traj.traj_id, toks)
        self._submit(traj, now)

    # ------------------------------------------------------------ run
    def run(self) -> RuntimeResult:
        cfg = self.cfg
        wall0 = time.perf_counter()
        for t in self.trajs:
            t.predicted_remaining = self.controller.predictor.predict(t)
            t.priority = t.predicted_total
            t.submit_time = 0.0
        # the fleet spec was synced to the controller at construction; anything
        # that mutated it since (a stale [1]*n stub, a partial reconfigure)
        # would silently misprice placement — fail loudly instead
        if list(self.controller.degrees) != list(self.spec.degrees):
            raise ValueError(
                f"controller.degrees {self.controller.degrees} drifted from the "
                f"fleet spec {list(self.spec.degrees)} between construction and "
                f"run(); reconfigure() is the only sanctioned mutation path")
        self.controller.initial_placement(self.trajs)
        # admission: prefill each worker's group up front (lanes are memory; the
        # scheduler gates decode *compute*).  Sibling-adjacent order maximizes
        # radix-cache implants; admission cost lands on the worker's clock.
        for ws in self.workers:
            mine = [t for t in self.trajs if t.worker_id == ws.wid]
            mine.sort(key=lambda t: (t.prompt_id, t.sample_id))
            t0 = time.perf_counter()
            for t in mine:
                ws.engine.prefill(t.traj_id, self.prompts[t.traj_id])
                ws.clock += len(self.prompts[t.traj_id]) * ws.token_time \
                    / cfg.prefill_speedup
            self.wall += time.perf_counter() - t0
        for t in self.trajs:
            self._submit(t, 0.0)

        guard = 0
        now = 0.0
        while self._evq:
            guard += 1
            if guard > 2_000_000:
                raise RuntimeError("runtime event budget exceeded")
            now, _, kind, payload = heapq.heappop(self._evq)
            if kind == "worker_ready":
                self._on_worker_ready(self.workers[payload], now)
            elif kind == "tool_done":
                self._on_tool_done(payload, now)
            elif kind == "migration_done":
                self._on_migration_done(payload, now)

        unfinished = [t.traj_id for t in self.trajs if not t.finished]
        assert not unfinished, f"runtime drained with live trajectories {unfinished}"
        for ws in self.workers:                      # final telemetry snapshot
            self.controller.record_worker_stats(ws.wid, ws.engine.dispatch_stats())
        makespan = max(t.finish_time for t in self.trajs)
        delays = np.asarray([s.queue_delay for t in self.trajs for s in t.steps])
        return RuntimeResult(
            makespan=makespan,
            total_tokens=self.total_tokens,
            throughput=self.total_tokens / makespan if makespan > 0 else 0.0,
            preemptions=self.preemptions,
            migrations=self.migrations,
            queue_delay_mean=float(delays.mean()) if len(delays) else 0.0,
            queue_delay_p99=float(np.quantile(delays, 0.99)) if len(delays) else 0.0,
            trajectories=self.trajs,
            worker_stats=dict(self.controller.worker_stats),
            wall_time=time.perf_counter() - wall0,
            events=guard,
            degrees=list(self.spec.degrees),
        )

    # ------------------------------------------------------------ §6 feedback loop
    def calibrate(self):
        """Refit the controller's WorkerLatencyModel from measured decode timing.

        Uses the per-worker warm-call decode timing the run streamed through
        ``record_worker_stats`` (``decode_wall_s / decode_timed_steps`` per-step
        samples), so the next provisioning round prices MP degrees from
        observations instead of Fig. 7 constants.  Returns the fitted model
        (None if no timing was recorded)."""
        return self.controller.calibrate_latency()

    def reconfigure(self, spec: FleetSpec | None = None, *,
                    calibrate: bool = True) -> dict:
        """Between-steps reconfiguration: calibrate → provision → split/merge.

        With ``spec=None`` the controller re-runs Algorithm 2 over this batch's
        trajectories (now carrying observed step histories) under the calibrated
        latency model and the fleet executes the resulting split/merge moves
        (``RolloutFleet.reconfigure``: reuse unchanged slots, re-shard changed
        ones, migrate residents across MP degrees).  Only legal between runs —
        the event queue must be drained.  Returns the fleet's move report.
        """
        if self.fleet is None:
            raise ValueError("runtime was built from a bare worker list; "
                             "construct it with a RolloutFleet to reconfigure")
        if self._evq:
            raise RuntimeError("reconfigure() during a live run: drain the "
                               "event queue first (call between steps)")
        if calibrate:
            self.controller.calibrate_latency()
        if spec is None:
            was_adaptive = self.controller.config.adaptive_resources
            self.controller.config.adaptive_resources = True
            try:
                spec = FleetSpec.from_degrees(
                    self.controller.provision(self.trajs))
            finally:
                self.controller.config.adaptive_resources = was_adaptive
        report = self.fleet.reconfigure(spec)
        self.spec = self.fleet.spec
        self.controller.degrees = list(self.spec.degrees)
        self.workers = self._worker_states(self.fleet.workers)
        return report
