"""Agentic workload generators reproducing the paper's long-tail statistics (Fig. 2/5).

Each prompt has a latent difficulty; each GRPO sample of that prompt rolls its own
environment feedback (tool failures -> rectification steps), producing the *intra-group
variance* of Fig. 5 that defeats prompt-only length predictors.  The first step's
generation length correlates with difficulty (the "execution plan" semantic anchor of
§4.1), which is what the progressive predictor exploits.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.trajectory import StepRecord, Trajectory, make_group
from repro.engine.tools import TOOL_PROFILES, ToolProfile


@dataclass(frozen=True)
class WorkloadConfig:
    task: str = "coding"                  # coding | search | math
    n_prompts: int = 32
    group_size: int = 16                  # GRPO samples per prompt (paper: 16)
    max_output_tokens: int = 40_000       # paper cap
    # Calibrated against the paper's Fig 2 / Fig 4 statistics: median total ~8K tokens,
    # max ~40K (the cap), completion-time max/median ~4x.
    mean_step_tokens: float = 420.0
    difficulty_sigma: float = 0.55        # lognormal spread of latent difficulty
    base_steps: float = 3.0
    seed: int = 0

    @property
    def tool(self) -> ToolProfile:
        return TOOL_PROFILES[self.task]


@dataclass
class TrajectoryPlan:
    """Pre-rolled environment outcome for one trajectory (the simulator's oracle)."""

    gen_tokens: list[int]
    tool_latency: list[float]
    tool_failed: list[bool]
    tool_output_tokens: list[int]

    @property
    def num_steps(self) -> int:
        return len(self.gen_tokens)

    @property
    def total_tokens(self) -> int:
        return sum(self.gen_tokens)


# per-task shape knobs: (steps multiplier, step-token multiplier, step-count spread)
_TASK_SHAPE = {
    "coding": (2.5, 1.2, 1.0),     # many rectification steps, medium generations
    "search": (3.0, 0.4, 0.6),     # many short steps (multi-hop), tool-latency heavy
    "math": (1.8, 0.8, 0.8),       # fewer steps, light tools
}


def generate(config: WorkloadConfig) -> list[Trajectory]:
    """Generate a rollout batch: n_prompts x group_size trajectories with plans."""
    rng = np.random.default_rng(config.seed)
    steps_mult, tok_mult, spread = _TASK_SHAPE[config.task]
    tool = config.tool
    trajectories: list[Trajectory] = []
    for pid in range(config.n_prompts):
        difficulty = rng.lognormal(0.0, config.difficulty_sigma)
        prompt_tokens = int(np.clip(rng.normal(120 + 60 * difficulty, 40), 16, 2048))
        group = make_group(pid, prompt_tokens, config.group_size)
        for traj in group:
            # per-sample environment stochasticity (Fig. 5 intra-group variance)
            sample_luck = rng.lognormal(0.0, 0.6 * spread)
            hardness = difficulty * sample_luck          # only partially prompt-visible
            gen, lat, fail, touts = [], [], [], []
            # Step count is hardness-determined up to modest noise: a hard task *is*
            # visibly hard (its plan, tool outputs and failures reveal it) — the
            # predictability §4.1's progressive refinement relies on.  Failed tool
            # calls (hardness-driven) add rectification steps on top.
            fail_p = min(0.85, tool.fail_rate * (0.4 + 0.6 * hardness))
            base_n = config.base_steps + steps_mult * hardness
            n_steps = int(np.clip(round(rng.lognormal(np.log(base_n), 0.22)), 1, 64))
            total, s = 0, 0
            while True:
                # step 0 is the plan: its size reveals the sample's own complexity
                # (the paper's "strong semantic indicator")
                scale = (0.5 + 0.7 * hardness) if s == 0 else (0.9 + 0.1 * hardness)
                g = int(np.clip(rng.lognormal(
                    np.log(config.mean_step_tokens * tok_mult * scale), 0.35), 8, 8192))
                g = min(g, max(config.max_output_tokens - total, 8))
                total += g
                failed = bool(rng.random() < fail_p)
                if failed:
                    n_steps = min(n_steps + 1, 64)   # rectification extends the episode
                gen.append(g)
                lat.append(float(tool.sample_latency(rng)))
                # tool output size also tracks hardness (longer error logs / search
                # results for harder tasks) — observable runtime signal for §4.1
                touts.append(int(tool.sample_output_tokens(rng, failed)
                                 * (0.7 + 0.35 * hardness)))
                s += 1
                stop = (total >= config.max_output_tokens or s >= n_steps)
                fail.append(failed and not stop)  # terminal step's tool ends the episode
                if stop:
                    break
            traj.payload = TrajectoryPlan(gen, lat, fail, touts)
            traj.true_total_tokens = sum(gen)
            traj.true_num_steps = len(gen)
        trajectories.extend(group)
    return trajectories


# --------------------------------------------------------------------------
# Arrival processes (open-loop ingress).  A closed-loop batch admits
# everything at t=0; a serving front door sees an *arrival process*.  Each
# policy deterministically maps (seed, n) -> n monotone arrival times, which
# the orchestrator turns into ``arrival`` events on its versioned heap.

# Domain-separation constant for arrival rngs (same idiom as the fault layer:
# independent random decision streams must never correlate across subsystems).
_ARRIVAL_STREAM = 4099


@dataclass(frozen=True)
class PoissonArrivals:
    """Memoryless open-loop traffic: i.i.d. exponential inter-arrival gaps."""

    rate: float                       # mean arrivals per virtual second (QPS)
    seed: int = 0

    def times(self, n: int) -> list[float]:
        if self.rate <= 0:
            raise ValueError(f"arrival rate must be > 0, got {self.rate}")
        rng = np.random.default_rng((self.seed, _ARRIVAL_STREAM))
        return np.cumsum(rng.exponential(1.0 / self.rate, size=n)).tolist()


@dataclass(frozen=True)
class BurstyArrivals:
    """Markov-modulated Poisson: a 2-state chain alternates a calm rate and a
    burst rate (``burst_factor`` x), producing the clustered arrivals that
    stress admission control harder than a plain Poisson stream."""

    rate: float                       # *mean* arrivals per virtual second
    seed: int = 0
    burst_factor: float = 4.0         # burst-state rate multiplier
    burst_prob: float = 0.25          # stationary fraction of time in burst state
    switch_prob: float = 0.1          # per-arrival chance of re-drawing the state

    def times(self, n: int) -> list[float]:
        if self.rate <= 0:
            raise ValueError(f"arrival rate must be > 0, got {self.rate}")
        # rates chosen so the stationary mix averages to `rate`
        calm = self.rate * (1.0 - self.burst_prob * self.burst_factor
                            ) / (1.0 - self.burst_prob)
        calm = max(calm, 0.05 * self.rate)
        burst = self.rate * self.burst_factor
        rng = np.random.default_rng((self.seed, _ARRIVAL_STREAM, 1))
        t, out, bursting = 0.0, [], False
        for _ in range(n):
            if rng.random() < self.switch_prob:
                bursting = rng.random() < self.burst_prob
            t += rng.exponential(1.0 / (burst if bursting else calm))
            out.append(t)
        return out


@dataclass(frozen=True)
class DiurnalArrivals:
    """Slow sinusoidal load swing (a compressed day): a non-homogeneous
    Poisson process sampled by Lewis thinning against the peak rate."""

    rate: float                       # mean arrivals per virtual second
    seed: int = 0
    amplitude: float = 0.8            # peak swing as a fraction of `rate`
    period_s: float = 240.0           # one "day" of virtual time

    def times(self, n: int) -> list[float]:
        if self.rate <= 0:
            raise ValueError(f"arrival rate must be > 0, got {self.rate}")
        rng = np.random.default_rng((self.seed, _ARRIVAL_STREAM, 2))
        rmax = self.rate * (1.0 + self.amplitude)
        t, out = 0.0, []
        while len(out) < n:
            t += rng.exponential(1.0 / rmax)
            lam = self.rate * (1.0 + self.amplitude
                               * np.sin(2.0 * np.pi * t / self.period_s))
            if rng.random() * rmax < lam:
                out.append(t)
        return out


def make_arrivals(kind: str, rate: float, seed: int = 0, **kwargs):
    """Factory for the CLI/bench: ``poisson`` | ``bursty`` | ``diurnal``."""
    policies = {"poisson": PoissonArrivals, "bursty": BurstyArrivals,
                "diurnal": DiurnalArrivals}
    if kind not in policies:
        raise ValueError(f"unknown arrival policy {kind!r} "
                         f"(choose from {sorted(policies)})")
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    return policies[kind](rate=rate, seed=seed, **kwargs)


def assign_arrivals(trajectories: list[Trajectory], policy) -> None:
    """Stamp ``submit_time`` from an arrival policy, in trajectory order (GRPO
    groups arrive sample-by-sample: a serving front door sees requests, not
    groups)."""
    for t, at in zip(trajectories, policy.times(len(trajectories))):
        t.submit_time = float(at)


def replay_finished(trajectories: list[Trajectory]) -> list[Trajectory]:
    """Materialize plans into finished trajectories (predictor training data harvest)."""
    out = []
    for t in trajectories:
        plan: TrajectoryPlan = t.payload
        # same trajectory, materialized: reuse the id instead of burning the
        # global counter (keeps later batches' ids independent of this harvest)
        ft = Trajectory(traj_id=t.traj_id, prompt_id=t.prompt_id,
                        sample_id=t.sample_id, prompt_tokens=t.prompt_tokens,
                        context_tokens=t.prompt_tokens)
        for s in range(plan.num_steps):
            ft.record_step(StepRecord(s, plan.gen_tokens[s], plan.tool_latency[s],
                                      tool_failed=plan.tool_failed[s],
                                      tool_output_tokens=plan.tool_output_tokens[s]))
            ft.record_tool_output(plan.tool_output_tokens[s])
        ft.true_total_tokens = t.true_total_tokens
        ft.true_num_steps = t.true_num_steps
        ft.finished = True
        out.append(ft)
    return out
