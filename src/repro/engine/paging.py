"""Host-side block allocator for the paged KV pool.

The paged data plane splits every attention KV leaf into fixed-size **blocks**
of ``page_size`` token slots; a per-lane **page table** row maps logical page
index -> physical block id.  This module owns the host bookkeeping: which
blocks are free, who holds references to each block (prefix sharing is a
refcount bump, not a copy), and the occupancy telemetry the control plane and
the trace sanitizer consume.

Invariants:

* **Block 0 is reserved scratch.**  Unmapped page-table entries point at 0, so
  a masked/free lane's self-healing KV write lands in scratch instead of a
  resident block.  The allocator never hands block 0 out.
* **Determinism** — the free list is a min-heap, so allocation order is a pure
  function of the alloc/free history (lowest block id first), independent of
  dict/set iteration order.
* **Conservation** — every refcount increment is counted in ``allocated_total``
  and every decrement in ``freed_total``; at any instant
  ``allocated_total - freed_total == resident_blocks + shared_refs`` (live
  references = distinct blocks in use + extra shared references).  The drain
  check in ``analysis.sanitize`` enforces this through ``dispatch_stats``.
"""

from __future__ import annotations

import heapq


class PagePoolExhausted(RuntimeError):
    """No free blocks left (the caller grows the device pool, then retries)."""


class PagePool:
    """Refcounted block allocator over ``num_blocks`` device blocks.

    Blocks ``1 .. num_blocks-1`` are allocatable; block 0 is scratch.  A block
    with refcount 1 is **resident** (one owner); each additional reference is a
    **shared** ref (prefix sharing).  Freeing decrements; the block returns to
    the free heap only when its refcount reaches zero.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("PagePool needs >= 2 blocks (block 0 is scratch)")
        self.num_blocks = num_blocks
        self._free = list(range(1, num_blocks))        # already heap-ordered
        self._refs: dict[int, int] = {}                # block id -> refcount
        self.allocated_total = 0                       # cumulative ref increments
        self.freed_total = 0                           # cumulative ref decrements
        self.used_high_watermark = 0

    # ------------------------------------------------------------ queries
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def resident_blocks(self) -> int:
        """Distinct blocks holding at least one reference."""
        return len(self._refs)

    @property
    def shared_refs(self) -> int:
        """References beyond the first on each block (prefix-shared pages)."""
        return sum(self._refs.values()) - len(self._refs)

    def refcount(self, block: int) -> int:
        return self._refs.get(block, 0)

    # ------------------------------------------------------------ alloc / share / free
    def alloc(self, n: int) -> list[int]:
        """Take ``n`` fresh blocks (refcount 1 each), lowest ids first."""
        if n > len(self._free):
            raise PagePoolExhausted(
                f"need {n} blocks, {len(self._free)} free of {self.num_blocks}")
        out = [heapq.heappop(self._free) for _ in range(n)]
        for b in out:
            self._refs[b] = 1
        self.allocated_total += n
        self.used_high_watermark = max(self.used_high_watermark, len(self._refs))
        return out

    def share(self, blocks: list[int]) -> None:
        """Add one reference to each block (prefix sharing: no data moves)."""
        for b in blocks:
            if b not in self._refs:
                raise ValueError(f"share of unallocated block {b}")
            self._refs[b] += 1
        self.allocated_total += len(blocks)

    def free(self, blocks: list[int]) -> list[int]:
        """Drop one reference per block; returns the blocks that became free."""
        released = []
        for b in blocks:
            refs = self._refs.get(b, 0)
            if refs <= 0:
                raise ValueError(f"free of unallocated block {b}")
            if refs == 1:
                del self._refs[b]
                heapq.heappush(self._free, b)
                released.append(b)
            else:
                self._refs[b] = refs - 1
        self.freed_total += len(blocks)
        return released

    def grow(self, new_num_blocks: int) -> None:
        """Append blocks ``num_blocks .. new_num_blocks-1`` to the free heap
        (the caller has already grown the device-side pool to match)."""
        if new_num_blocks < self.num_blocks:
            raise ValueError("PagePool cannot shrink")
        for b in range(self.num_blocks, new_num_blocks):
            heapq.heappush(self._free, b)
        self.num_blocks = new_num_blocks

    # ------------------------------------------------------------ telemetry
    def stats(self) -> dict:
        """Occupancy counters (``dispatch_stats`` merges these under
        ``blocks_*`` keys; the sanitizer's drain check consumes them)."""
        return {
            "total": self.num_blocks - 1,              # scratch excluded
            "free": self.free_blocks,
            "resident": self.resident_blocks,
            "shared": self.shared_refs,
            "allocated_total": self.allocated_total,
            "freed_total": self.freed_total,
            "used_high_watermark": self.used_high_watermark,
        }
