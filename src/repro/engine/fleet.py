"""Heterogeneous model-parallel worker fleets (paper §6 on the real data plane).

Two pieces close the last control/data-plane seam:

* ``FleetSpec`` — the **single source of truth** for per-worker model-parallel
  degrees.  Everything that used to guess (``controller.degrees = [1] * n``
  stubs in the runtime) now derives from one spec: the controller's degree
  vector, the per-worker virtual token times, the placement DP's sort-and-zip
  mapping (§6.1: workers descend by MP degree, partitions descend by length),
  and the physical sub-meshes the workers are built on.

* ``RolloutFleet`` — owns the live ``RolloutWorker`` set.  Construction carves
  one disjoint ``("data", "model")`` sub-mesh per worker out of the visible
  device set (``launch.mesh.carve_worker_meshes``) and shards each worker's
  params and KV pool with the MaxText-style rules in ``distributed/sharding``;
  ``reconfigure`` executes the simulated-annealing allocator's split/merge
  moves on the live fleet between rollout steps — workers whose degree survives
  are reused (their radix caches stay warm), changed slots are rebuilt on fresh
  sub-meshes (weights re-sharded), and any resident sequences of retired
  workers are migrated lane-by-lane onto the new fleet (``migrate_out`` gathers
  to host, ``migrate_in`` re-implants under the destination's sharding, so
  moves cross MP degrees).

When the device set cannot host ``sum(degrees)`` accelerators — the un-forced
CPU tier-1 environment — every worker falls back to un-meshed execution while
the *declared* degrees keep driving the control plane, so heterogeneous
scheduling remains testable on one device and becomes physically real under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (CI) or on actual pods.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.engine.sampler import SamplerConfig
from repro.engine.worker import RolloutWorker
from repro.launch.mesh import carve_worker_meshes


@dataclass(frozen=True)
class FleetSpec:
    """Per-worker MP degrees, descending — the §6.1 sort-and-zip order."""

    degrees: tuple[int, ...]

    def __post_init__(self):
        if not self.degrees:
            raise ValueError("FleetSpec needs at least one worker")
        if any(int(d) < 1 for d in self.degrees):
            raise ValueError(f"MP degrees must be >= 1, got {self.degrees}")
        if list(self.degrees) != sorted(self.degrees, reverse=True):
            raise ValueError(
                "degrees must be descending (sort-and-zip mapping relies on "
                f"worker order == degree order), got {self.degrees}",
            )

    @property
    def n_workers(self) -> int:
        return len(self.degrees)

    @property
    def budget(self) -> int:
        """Total accelerators consumed (the Algorithm 2 budget N)."""
        return int(sum(self.degrees))

    @classmethod
    def homogeneous(cls, n_workers: int, mp: int = 1) -> "FleetSpec":
        return cls(tuple([int(mp)] * n_workers))

    @classmethod
    def from_degrees(cls, degrees: Sequence[int]) -> "FleetSpec":
        return cls(tuple(sorted((int(d) for d in degrees), reverse=True)))

    @classmethod
    def from_allocation(cls, allocation) -> "FleetSpec":
        """Adopt an AllocationResult (Algorithm 2 output) as the fleet shape."""
        return cls.from_degrees(allocation.degrees)


class RolloutFleet:
    """The live heterogeneous worker set and its between-steps reconfiguration."""

    def __init__(
        self,
        cfg,
        params,
        spec: FleetSpec,
        *,
        capacity: int,
        max_slots: int,
        sampler: SamplerConfig = SamplerConfig(),
        seed: int = 0,
        devices=None,
        **worker_kwargs,
    ):
        self.cfg = cfg
        self.params = params  # un-sharded reference copy (re-shard source)
        self.capacity = capacity
        self.max_slots = max_slots
        self.sampler = sampler
        self.seed = seed
        self.devices = devices
        self.worker_kwargs = dict(worker_kwargs)
        self.spec = spec
        self.reconfigurations = 0
        meshes = carve_worker_meshes(spec.degrees, devices)
        self.workers = []
        for i, (degree, mesh) in enumerate(zip(spec.degrees, meshes)):
            self.workers.append(self._build_worker(i, degree, mesh))

    def _build_worker(self, wid: int, degree: int, mesh) -> RolloutWorker:
        return RolloutWorker(
            self.cfg,
            self.params,
            capacity=self.capacity,
            max_slots=self.max_slots,
            worker_id=wid,
            sampler=self.sampler,
            seed=self.seed,
            mesh=mesh,
            mp=degree,
            **self.worker_kwargs,
        )

    def reconfigure(self, new_spec: FleetSpec) -> dict:
        """Realize ``new_spec`` on the live fleet (split / merge / redistribute).

        Worker slots whose degree is unchanged keep their engine (KV pool, radix
        cache, retired lanes all stay warm).  Changed or new slots get a fresh
        worker on a newly carved sub-mesh — the weight re-shard of a split/merge
        move.  Resident sequences of every retired engine are migrated onto the
        new fleet (same slot index when it exists, else the least-populated new
        worker), crossing MP degrees via the host-bounce re-implant.  Returns a
        report dict; the caller (runtime / controller) must re-sync
        ``controller.degrees`` from ``fleet.spec`` — ``FleetSpec`` stays the
        only authority.
        """
        old_spec, old_workers = self.spec, self.workers
        meshes = carve_worker_meshes(new_spec.degrees, self.devices)
        # a slot is reusable only if its degree, its mesh PRESENCE, and its
        # device block all survive: a fleet crossing in or out of the meshed
        # regime must re-place every worker (a reused un-meshed worker would
        # silently ignore its newly carved mesh), and an earlier split/merge
        # shifts every later carve offset, where a reused worker keeping its
        # old mesh would overlap a rebuilt neighbor's chips.
        old_off = [sum(old_spec.degrees[:i]) for i in range(old_spec.n_workers)]
        new_off = [sum(new_spec.degrees[:i]) for i in range(new_spec.n_workers)]
        reused = []
        workers = []
        for i, (degree, mesh) in enumerate(zip(new_spec.degrees, meshes)):
            same = i < len(old_workers) and old_spec.degrees[i] == degree
            if same:
                old_mesh = old_workers[i].mesh
                if (mesh is None) != (old_mesh is None):
                    same = False
                elif mesh is not None:
                    same = old_off[i] == new_off[i]
            if same:
                workers.append(old_workers[i])
                reused.append(i)
            else:
                workers.append(self._build_worker(i, degree, mesh))
        moves: dict[int, int] = {}  # seq_id -> destination worker index
        for i, old in enumerate(old_workers):
            if i in reused:
                continue
            for seq_id in list(old.store):
                pkg = old.migrate_out(seq_id)
                if i < len(workers):
                    dst = workers[i]
                else:  # fleet shrank past this slot: redistribute (elastic case)
                    dst = min(workers, key=lambda w: len(w.store))
                dst.migrate_in(pkg)
                moves[seq_id] = dst.worker_id
        self.spec = new_spec
        self.workers = workers
        self.reconfigurations += 1
        rebuilt = [i for i in range(new_spec.n_workers) if i not in reused]
        return {
            "from": list(old_spec.degrees),
            "to": list(new_spec.degrees),
            "reused": reused,
            "rebuilt": rebuilt,
            "migrated_residents": len(moves),
            "moves": moves,
        }
