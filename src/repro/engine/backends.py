"""Execution backends for the unified orchestrator (mechanics and cost only).

``core.orchestrator.Orchestrator`` owns the lifecycle state machine, the event
heap, the per-worker scheduler queues, preemption and migration *policy*; the
backends here own *how work advances and what it costs*:

* :class:`SimBackend` — the analytic cost models the discrete-event simulator
  always used (processor-sharing continuous batching, §5.2 interference, MP
  comm terms, the prefix-cache prefill-recompute model).  Interruptible: work
  settles in closed form at any instant, so the simulator scales to 64 workers
  and thousands of 40K-token trajectories.  With ``quantum`` set it instead
  mirrors the engine's quantized pricing exactly — the *engine-parity* mode the
  decision-trace harness runs.

* :class:`EngineBackend` — the real ``RolloutWorker``/``RolloutFleet`` data
  plane: real prefill, real batched decode into KV lanes, mask-flip preemption,
  lane migration with measured package bytes — on a deterministic virtual clock
  (a decode quantum of ``q`` tokens at batch ``b`` costs
  ``q * token_time * F(b)`` virtual seconds).  Non-interruptible: decode is
  quantized, so new arrivals wait for the running quantum.

Both backends price a quantum through :func:`quantum_seconds` and admission
through :func:`admission_seconds`, bit-identical arithmetic — that, plus the
shared orchestrator loop, is what makes sim-vs-engine decision traces equal.
"""

from __future__ import annotations

import math
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.faults import FaultPlan, RetryPolicy, resolve_tool_call
from repro.core.migration import kv_cache_bytes, migration_time
from repro.core.orchestrator import StepOutcome
from repro.core.trajectory import Trajectory


def quantum_seconds(q: int, token_time: float, interference, batch: int) -> float:
    """Virtual seconds for a ``q``-token decode quantum at batch ``batch``."""
    return q * token_time * float(interference(batch))


def _package_bytes(pkg: dict, jax) -> int:
    """Transfer size of a migration/checkpoint package.

    Paged workers stamp ``logical_bytes`` — resident pages + dense lane state,
    the bytes that actually move — so pricing no longer assumes a full
    preallocated lane.  Legacy packages fall back to summing the cache leaves
    (``.nbytes`` on the leaf itself: no host gather just to price a transfer)."""
    n = pkg.get("logical_bytes")
    if n is not None:
        return int(n)
    return sum(int(x.nbytes) for x in jax.tree.leaves(pkg["cache"]))


def admission_seconds(n_tokens: int, token_time: float, prefill_speedup: float) -> float:
    """Virtual seconds to prefill ``n_tokens`` (compute-bound vs decode)."""
    return n_tokens * token_time / prefill_speedup


# ---------------------------------------------------------------- simulator backend


class _SimWorker:
    """Processor-sharing continuous-batching cost model for one worker."""

    def __init__(self, wid: int, mp: int, token_time: float, interference):
        self.wid = wid
        self.mp = mp
        self.token_time = token_time  # t1 * ((1-o)/mp + o): control-plane view
        self.t1: Optional[float] = None  # data-plane comm model (set by SimBackend)
        self.comm_overlap = 0.0
        self.comm_batch_coef = 0.0
        self.ctx_coef = 0.0
        self.interference = interference
        self.active: dict[int, float] = {}  # traj_id -> remaining token-work
        self.trajs: dict[int, Trajectory] = {}
        self.last_update = 0.0
        self.tokens_done = 0.0
        # engine-parity (quantum) mode state
        self.clock = 0.0
        self.plan: Optional[tuple[list[int], int, float, float]] = None

    def rate(self) -> float:
        """Seconds per token-unit for each active trajectory (all advance together).

        Context-weighted interference: one decode step reads the weights once
        plus the KV cache of every resident sequence, so per-token time grows
        with the *total context tokens* in the batch, not just its size."""
        b = len(self.active)
        if b == 0:
            return math.inf
        total_ctx = sum(t.context_tokens for t in self.trajs.values())
        if self.t1 is None:  # control-plane-identical fallback
            return self.token_time * (self.interference(b) + self.ctx_coef * total_ctx)
        o, g = self.comm_overlap, self.comm_batch_coef
        scalable = (self.interference(b) + self.ctx_coef * total_ctx) / self.mp
        comm = (o * (1.0 + g * b)) if self.mp > 1 else 0.0
        return self.t1 * (
            (1.0 - o) * scalable + comm + (o / self.mp if self.mp == 1 else 0.0)
        )

    def settle(self, now: float) -> list[int]:
        """Progress all active trajectories to ``now``; pop + return finished."""
        dt = now - self.last_update
        self.last_update = now
        if not self.active or dt <= 0:
            return []
        progressed = dt / self.rate()
        done = []
        for tid in list(self.active):
            self.active[tid] -= progressed
            self.tokens_done += progressed
            if self.active[tid] <= 1e-9:
                done.append(tid)
                del self.active[tid]
                self.trajs.pop(tid, None)
        return done

    def horizon(self, now: float) -> Optional[float]:
        if not self.active:
            return None
        return now + max(min(self.active.values()), 0.0) * self.rate()


class SimBackend:
    """Analytic execution backend (the simulator's cost models, orchestrated).

    Default mode is the paper-scale processor-sharing model: interruptible
    closed-form settlement, prefill recompute on cache miss, analytic KV bytes
    for migration.  With ``quantum`` set the backend becomes the engine's
    *parity twin*: non-interruptible quantized decode priced with the exact
    arithmetic ``EngineBackend`` uses, admission charged to worker clocks, step
    work equal to plan generation tokens — same decisions, no model.
    """

    def __init__(
        self,
        degrees: Sequence[int],
        token_times: Sequence[float],
        interference,
        *,
        t1: Optional[float] = None,
        comm_overlap: float = 0.0,
        comm_batch_coef: float = 0.0,
        ctx_interference: float = 0.0,
        prefill_speedup: float = 100.0,
        measured_reuse_rate: Optional[float] = None,
        link_bandwidth: float = 50e9,
        kv_layers: int = 40,
        kv_heads: int = 8,
        kv_head_dim: int = 128,
        latency_scale: float = 1.0,
        quantum: Optional[int] = None,
        prompt_lens: Optional[dict[int, int]] = None,
        faults: Optional[FaultPlan] = None,
        retry: RetryPolicy = RetryPolicy(),
        page_size: int = 0,
    ):
        self.quantum = quantum
        self.faults = faults
        self.retry = retry
        self.interruptible = quantum is None
        self.interference = interference
        self.prefill_speedup = prefill_speedup
        self.measured_reuse_rate = measured_reuse_rate
        self.link_bandwidth = link_bandwidth
        self.kv_layers = kv_layers
        self.kv_heads = kv_heads
        self.kv_head_dim = kv_head_dim
        self.latency_scale = latency_scale
        # paged-KV twin: price migrated KV as resident *pages* (context rounded
        # up to the page grid), matching the engine's logical_bytes accounting.
        # 0 = dense lanes (exact context bytes, the pre-paging model).
        self.page_size = page_size
        self.prompt_lens = prompt_lens
        self.workers = [
            _SimWorker(i, mp, tt, interference)
            for i, (mp, tt) in enumerate(zip(degrees, token_times))
        ]
        if quantum is None:
            for w in self.workers:
                w.t1 = t1
                w.comm_overlap = comm_overlap
                w.comm_batch_coef = comm_batch_coef
                w.ctx_coef = ctx_interference
        self.suspended: dict[int, float] = {}  # preempted traj -> remaining work
        self.cache_home: dict[int, set[int]] = {}  # traj -> workers with its cache
        self.prompt_home: dict[int, set[int]] = {}  # prompt -> workers with its prompt
        self.miss_tokens = 0
        self.staged_epoch = 0  # latest weight epoch published to the fleet
        self._gen_time: dict[int, float] = {}

    @property
    def n_workers(self) -> int:
        return len(self.workers)

    # ------------------------------------------------------------ admission
    def admit(self, trajectories: Sequence[Trajectory], now: float = 0.0) -> None:
        if self.quantum is None:
            return  # paper mode prices prefill per step (cache model)
        for t in trajectories:
            w = self.workers[t.worker_id]
            n = (
                self.prompt_lens[t.traj_id]
                if self.prompt_lens is not None
                else t.prompt_tokens
            )
            # open loop: an idle clock can lag the arrival instant — prefill
            # starts at max(clock, now).  Closed loop (now=0) is unchanged.
            w.clock = max(w.clock, now) + admission_seconds(
                n, w.token_time, self.prefill_speedup
            )

    def ready_time(self, wid: int, now: float) -> float:
        return max(now, self.workers[wid].clock) if self.quantum else now

    # ------------------------------------------------------------ step mechanics
    def _step_work(self, traj: Trajectory) -> float:
        """Token-work for the upcoming step: generation + prefill recompute.

        Prefix-cache accounting: a worker holding the trajectory's own cache
        pays only the new tool output; a worker that has served any *group
        sibling* holds the shared prompt prefix (radix-cache reuse), so a fresh
        arrival there pays context - prompt, scaled by the engine's measured
        reuse rate when available."""
        plan = traj.payload
        gen = plan.gen_tokens[traj.num_steps]
        if self.quantum is not None:
            return float(gen)  # engine parity: admission paid at the clock
        wid = traj.worker_id
        if wid in self.cache_home.get(traj.traj_id, set()):
            prefill = (
                traj.steps[-1].tool_output_tokens if traj.steps else traj.prompt_tokens
            )
        elif wid in self.prompt_home.get(traj.prompt_id, set()):
            rate = self.measured_reuse_rate
            reusable = traj.prompt_tokens if rate is None else rate * traj.prompt_tokens
            prefill = max(traj.context_tokens - reusable, traj.prompt_tokens // 8)
            self.miss_tokens += int(prefill)
        else:
            prefill = traj.context_tokens or traj.prompt_tokens
            self.miss_tokens += int(prefill)
        return gen + prefill / self.prefill_speedup

    def dispatch(self, wid: int, traj: Trajectory, fresh: bool) -> float:
        w = self.workers[wid]
        tid = traj.traj_id
        work = self._step_work(traj) if fresh else self.suspended.pop(tid)
        w.active[tid] = work
        w.trajs[tid] = traj
        if self.quantum is None:
            self.cache_home.setdefault(tid, set()).add(wid)
            self.prompt_home.setdefault(traj.prompt_id, set()).add(wid)
        return work

    def preempt(self, wid: int, traj: Trajectory) -> None:
        w = self.workers[wid]
        self.suspended[traj.traj_id] = w.active.pop(traj.traj_id)
        w.trajs.pop(traj.traj_id, None)

    def advance(self, wid: int, now: float) -> list[int]:
        w = self.workers[wid]
        if self.quantum is None:
            return w.settle(now)
        if w.plan is None or now < w.plan[2] - 1e-12:
            return []
        ids, q, end, dt = w.plan
        w.plan = None
        w.clock = end
        done = []
        for tid in ids:
            w.active[tid] -= q
            w.tokens_done += q
            self._gen_time[tid] = self._gen_time.get(tid, 0.0) + dt
            if w.active[tid] <= 0:
                done.append(tid)
                del w.active[tid]
                w.trajs.pop(tid, None)
        return done

    def next_completion(self, wid: int, now: float) -> Optional[float]:
        w = self.workers[wid]
        if not w.active:
            w.plan = None
            return None
        if self.quantum is None:
            return w.horizon(now)
        ids = sorted(w.active)
        q = min(self.quantum, int(min(w.active[t] for t in ids)))
        dt = quantum_seconds(q, w.token_time, self.interference, len(ids))
        end = max(now, w.clock) + dt
        w.plan = (ids, q, end, dt)
        return end

    # ------------------------------------------------------------ tools / migration
    def tool_submit(self, traj: Trajectory) -> StepOutcome:
        plan = traj.payload
        s = traj.num_steps
        lat = float(plan.tool_latency[s]) * self.latency_scale
        # step_cap first: a degraded trajectory ends at its tightened budget
        # regardless of the plan — the engine's step_outcome orders the check
        # identically, so injection arithmetic stays bit-equal across backends
        terminal = (traj.step_cap is not None and s + 1 >= traj.step_cap) \
            or s + 1 >= plan.num_steps
        attempts, injected = 1, 0
        if not terminal:
            # identical injection arithmetic to ToolEnvironment.invoke (terminal
            # steps run no tool on either backend, so nothing to inject there)
            trace = resolve_tool_call(self.faults, self.retry, traj.traj_id, s, lat)
            lat, attempts, injected = trace.latency, trace.attempts, trace.injected_faults
        return StepOutcome(
            gen_tokens=int(plan.gen_tokens[s]),
            terminal=terminal,
            tool_latency=lat,
            tool_failed=bool(plan.tool_failed[s]),
            tool_output_tokens=int(plan.tool_output_tokens[s]),
            gen_time=self._gen_time.pop(traj.traj_id, 0.0),
            tool_attempts=attempts,
            tool_injected_faults=injected,
        )

    def tool_absorb(self, traj: Trajectory) -> None:
        pass  # context growth is tracked on the Trajectory itself

    def can_migrate(self, traj: Trajectory) -> bool:
        return True

    def _paged_ctx(self, ctx: int) -> int:
        """Round a context up to the page grid when pricing paged transfers."""
        if self.page_size <= 0:
            return ctx
        return -(-ctx // self.page_size) * self.page_size

    def migrate_out(self, traj: Trajectory, dst: int) -> float:
        kv = kv_cache_bytes(
            self._paged_ctx(traj.context_tokens),
            self.kv_layers, self.kv_heads, self.kv_head_dim,
        )
        return migration_time(kv, self.link_bandwidth)

    def migrate_in(self, traj: Trajectory, dst: int) -> None:
        self.cache_home[traj.traj_id] = {dst}  # the KV moved with the trajectory

    def release(self, traj: Trajectory) -> None:
        # shed-from-queue cleanup: a preempted victim leaves suspended work
        self.suspended.pop(traj.traj_id, None)
        self._gen_time.pop(traj.traj_id, None)

    def stats(self, wid: int) -> dict:
        return {}  # nothing measured: the cost model *is* the assumption

    # ------------------------------------------------------------ failure realism
    def checkpoint(self, traj: Trajectory) -> None:
        pass  # analytic state: the Trajectory record IS the tool-boundary snapshot

    def restore(self, traj: Trajectory, dst: int) -> float:
        """Re-admit from the last tool boundary: price the KV re-materialization
        as a transfer of the boundary context (the analytic twin of re-implanting
        the engine's host-gathered checkpoint lane)."""
        tid = traj.traj_id
        self.suspended.pop(tid, None)  # partial progress died with the worker
        self._gen_time.pop(tid, None)
        self.cache_home[tid] = {dst}
        kv = kv_cache_bytes(
            self._paged_ctx(max(traj.context_tokens, traj.prompt_tokens)),
            self.kv_layers, self.kv_heads, self.kv_head_dim,
        )
        return migration_time(kv, self.link_bandwidth)

    def kill(self, wid: int) -> None:
        w = self.workers[wid]
        w.active.clear()
        w.trajs.clear()
        w.plan = None
        for homes in self.cache_home.values():  # its KV (and prefixes) are gone
            homes.discard(wid)
        for homes in self.prompt_home.values():
            homes.discard(wid)

    def revive(self, wid: int) -> None:
        pass  # kill() already cleared the state; replacement capacity joins cold

    # ------------------------------------------------------------ weight sync
    def stage_weights(self, params, epoch: int) -> None:
        """The analytic twin holds no tensors: staging records the epoch only
        (the orchestrator's drain fence decides when each worker cuts over)."""
        del params
        self.staged_epoch = epoch

    def sync_weights(self, wid: int, epoch: int) -> None:
        """Cut worker ``wid`` over to ``epoch``: drop its cache/prompt homes so
        no stale-weight prefix ever serves a post-sync admission — the analytic
        twin of the engine's ``reset_cache()``.  Zero residents guaranteed by
        the fence, so no cost model state needs settling."""
        del epoch
        for homes in self.cache_home.values():
            homes.discard(wid)
        for homes in self.prompt_home.values():
            homes.discard(wid)


# ---------------------------------------------------------------- engine backend


class _EngineView:
    """One real worker's runtime view: engine + virtual clock + quantum plan."""

    def __init__(self, wid: int, engine, token_time: float):
        self.wid = wid
        self.engine = engine
        self.token_time = token_time  # virtual s/token at batch 1 AT THIS MP
        self.clock = 0.0  # this worker's virtual time frontier
        self.plan: Optional[tuple[list[int], int, float, float]] = None


def _plan_budget(traj: Trajectory) -> int:
    """Default per-step generation budget: the trajectory plan's next step."""
    return int(traj.payload.gen_tokens[traj.num_steps])


class EngineBackend:
    """Real slot-pool data plane behind the orchestrator's virtual event clock.

    Decoded tokens are real (real model, real KV lanes, real sampling keys);
    time is virtual and deterministic.  The environment decides each step's
    tool outcome and terminality via ``env.step_outcome(traj, step, gen,
    context)`` — plan-driven (``ToolEnvironment``) for workload studies,
    task-driven (``rl.loop.TaskEnvironment``) for RL training, where
    ``stop_token``/``step_budget`` replace the pre-rolled plan.
    """

    interruptible = False

    def __init__(
        self,
        engines: Sequence,
        env,
        prompts: dict[int, list[int]],
        *,
        interference,
        quantum: int,
        token_times: Sequence[float],
        prefill_speedup: float = 100.0,
        link_bandwidth: float = 2e9,
        stop_token: Optional[int] = None,
        step_budget: Optional[Callable[[Trajectory], int]] = None,
        checkpoint_dir: Optional[str] = None,
    ):
        for i, w in enumerate(engines):
            if w.worker_id != i:
                raise ValueError(
                    f"worker_id {w.worker_id} at fleet position {i}: the "
                    "orchestrator indexes workers by position"
                )
        self.views = [
            _EngineView(w.worker_id, w, tt) for w, tt in zip(engines, token_times)
        ]
        self.env = env
        self.prompts = prompts
        self.interference = interference
        self.quantum = quantum
        self.prefill_speedup = prefill_speedup
        self.link_bandwidth = link_bandwidth
        self.stop_token = stop_token
        self.step_budget = step_budget if step_budget is not None else _plan_budget
        self.step_remaining: dict[int, int] = {}  # mid-step decode budget
        self._active: list[set[int]] = [set() for _ in self.views]  # decoding now
        self.pending_tool: dict[int, list[int]] = {}  # tool output awaiting absorb
        self.in_transit: dict[int, dict] = {}  # migrating traj -> lane package
        self._step_gen: dict[int, list[int]] = {}  # token ids decoded this step
        self._gen_time: dict[int, float] = {}
        self.total_tokens = 0  # real tokens decoded across all workers
        self.wall = 0.0  # real seconds spent in the data plane
        # failure realism: tool-boundary checkpoints (host-gathered lane
        # packages in migrate_out format) + dead-worker bookkeeping
        self.checkpoint_dir = checkpoint_dir
        self.ckpts: dict[int, dict] = {}
        self.dead: set[int] = set()
        # tool output absorbed since the last checkpoint: a boundary snapshot
        # pre-dates the absorb, so a restore must replay it into the lane
        self.last_absorb: dict[int, list[int]] = {}
        # in-flight weight sync: staged params by epoch, applied per worker as
        # the orchestrator's drain fence releases each one
        self._staged_params: dict[int, object] = {}

    @property
    def n_workers(self) -> int:
        return len(self.views)

    # ------------------------------------------------------------ admission
    def admit(self, trajectories: Sequence[Trajectory], now: float = 0.0) -> None:
        """Prefill each worker's group up front (lanes are memory; the
        scheduler gates decode *compute*).  Sibling-adjacent order maximizes
        radix-cache implants; admission cost lands on the worker's clock —
        from ``max(clock, now)`` so open-loop arrivals on an idle worker
        start prefilling at the arrival instant (closed loop: now=0)."""
        for view in self.views:
            mine = [t for t in trajectories if t.worker_id == view.wid]
            mine.sort(key=lambda t: (t.prompt_id, t.sample_id))
            t0 = time.perf_counter()
            for t in mine:
                toks = self.prompts[t.traj_id]
                view.engine.prefill(t.traj_id, toks)
                view.clock = max(view.clock, now) + admission_seconds(
                    len(toks), view.token_time, self.prefill_speedup
                )
            self.wall += time.perf_counter() - t0

    def ready_time(self, wid: int, now: float) -> float:
        return max(now, self.views[wid].clock)

    # ------------------------------------------------------------ step mechanics
    def dispatch(self, wid: int, traj: Trajectory, fresh: bool) -> float:
        tid = traj.traj_id
        if fresh:
            self.step_remaining[tid] = max(int(self.step_budget(traj)), 1)
            self._step_gen[tid] = []
            self._gen_time[tid] = 0.0
        # the lane is already resident; the next quantum's decode includes it
        self._active[wid].add(tid)
        return float(self.step_remaining[tid])

    def preempt(self, wid: int, traj: Trajectory) -> None:
        """Mask flip: the lane stays resident, ``step_remaining`` persists."""
        self.views[wid].engine.preempt(traj.traj_id)
        self._active[wid].discard(traj.traj_id)

    def advance(self, wid: int, now: float) -> list[int]:
        view = self.views[wid]
        if view.plan is None or now < view.plan[2] - 1e-12:
            return []
        ids, q, end, dt = view.plan
        view.plan = None
        t0 = time.perf_counter()
        out = view.engine.decode(ids, q, stop_token=self.stop_token)
        self.wall += time.perf_counter() - t0
        view.clock = end
        done = []
        for tid in ids:
            got = out[tid]
            self.total_tokens += len(got)
            self.step_remaining[tid] -= len(got)
            self._step_gen[tid].extend(got)
            self._gen_time[tid] += dt
            stopped = self.stop_token is not None and view.engine.store[tid].finished
            if self.step_remaining[tid] <= 0 or stopped:
                done.append(tid)
                del self.step_remaining[tid]
                self._active[wid].discard(tid)
        return done

    def next_completion(self, wid: int, now: float) -> Optional[float]:
        view = self.views[wid]
        ids = sorted(self._active[wid])
        if not ids:
            view.plan = None
            return None
        q = min(self.quantum, min(self.step_remaining[t] for t in ids))
        dt = quantum_seconds(q, view.token_time, self.interference, len(ids))
        end = max(now, view.clock) + dt
        view.plan = (ids, q, end, dt)
        return end

    # ------------------------------------------------------------ tools / migration
    def tool_submit(self, traj: Trajectory) -> StepOutcome:
        tid = traj.traj_id
        gen = self._step_gen.pop(tid, [])
        context = self.views[traj.worker_id].engine.store[tid].tokens
        out = self.env.step_outcome(traj, traj.num_steps, gen, context)
        if not out.terminal and out.output_tokens:
            self.pending_tool[tid] = list(out.output_tokens)
        return StepOutcome(
            gen_tokens=len(gen),
            terminal=bool(out.terminal),
            tool_latency=float(out.latency),
            tool_failed=bool(out.failed),
            tool_output_tokens=len(out.output_tokens),
            gen_time=self._gen_time.pop(tid, 0.0),
            tool_attempts=int(getattr(out, "attempts", 1)),
            tool_injected_faults=int(getattr(out, "injected_faults", 0)),
        )

    def tool_absorb(self, traj: Trajectory) -> None:
        toks = self.pending_tool.pop(traj.traj_id, None)
        self.last_absorb.pop(traj.traj_id, None)
        if toks:  # chunked prefill into the lane, wherever it lives now
            view = self.views[traj.worker_id]
            t0 = time.perf_counter()
            view.engine.extend(traj.traj_id, toks)
            self.wall += time.perf_counter() - t0
            self.last_absorb[traj.traj_id] = list(toks)

    def can_migrate(self, traj: Trajectory) -> bool:
        return traj.traj_id in self.views[traj.worker_id].engine.store

    def migrate_out(self, traj: Trajectory, dst: int) -> float:
        import jax  # local: backends must import without initializing jax early

        src = self.views[traj.worker_id]
        t0 = time.perf_counter()
        pkg = src.engine.migrate_out(traj.traj_id)
        self.wall += time.perf_counter() - t0
        self.in_transit[traj.traj_id] = pkg
        return migration_time(_package_bytes(pkg, jax), self.link_bandwidth)

    def migrate_in(self, traj: Trajectory, dst: int) -> None:
        pkg = self.in_transit.pop(traj.traj_id)
        t0 = time.perf_counter()
        self.views[dst].engine.migrate_in(pkg)  # lane lands in the new pool
        self.wall += time.perf_counter() - t0

    def release(self, traj: Trajectory) -> None:
        """Finished (or shed): the lane retires into the radix cache (prefix
        stays warm).  Shed-from-queue cleanup also drops any mid-step budget
        and parked tool output the trajectory left behind."""
        self.views[traj.worker_id].engine.release(traj.traj_id)
        self.ckpts.pop(traj.traj_id, None)
        self.last_absorb.pop(traj.traj_id, None)
        self.step_remaining.pop(traj.traj_id, None)
        self._step_gen.pop(traj.traj_id, None)
        self._gen_time.pop(traj.traj_id, None)
        self.pending_tool.pop(traj.traj_id, None)

    def stats(self, wid: int) -> dict:
        return self.views[wid].engine.dispatch_stats()

    # ------------------------------------------------------------ failure realism
    def checkpoint(self, traj: Trajectory) -> None:
        """Tool-boundary snapshot: host-gather the lane without evicting it.

        The package is ``migrate_out``'s exact wire format, so recovery is just
        a ``migrate_in`` on a survivor.  With ``checkpoint_dir`` set the cache
        tree is also persisted through ``repro.checkpoint`` (crash-atomic npz +
        manifest) for durability beyond this process."""
        tid = traj.traj_id
        view = self.views[traj.worker_id]
        if tid not in view.engine.store:
            return  # lane already on the wire; the transfer carries the state
        t0 = time.perf_counter()
        pkg = view.engine.checkpoint_out(tid)
        self.wall += time.perf_counter() - t0
        self.ckpts[tid] = pkg
        self.last_absorb.pop(tid, None)  # the new snapshot includes it
        if self.checkpoint_dir:
            from repro.checkpoint import checkpoint as ckpt

            # paged engines snapshot resident pages + dense state; dense
            # engines a full lane — persist whichever tree the package carries
            kv = ({"cache": pkg["cache"]} if "cache" in pkg
                  else {"pages": pkg["pages"], "state": pkg["state"]})
            extra = {
                "seq_id": int(pkg["seq_id"]),
                "tokens": [int(x) for x in pkg["tokens"]],
                "generated": int(pkg["generated"]),
            }
            if "pages" in pkg:
                extra.update(page_size=int(pkg["page_size"]),
                             capacity=int(pkg["capacity"]),
                             logical_bytes=int(pkg["logical_bytes"]))
            ckpt.save(
                f"{self.checkpoint_dir}/traj_{tid:05d}",
                {**kv, "key": np.asarray(pkg["key"])},
                step=traj.num_steps,
                extra=extra,
            )

    def restore(self, traj: Trajectory, dst: int) -> float:
        """Re-admit on ``dst`` from the last tool-boundary checkpoint.

        Everything decoded since that boundary died with the worker and is
        re-decoded (the step restarts fresh); a trajectory that never reached a
        boundary re-admits from its prompt.  Returns the virtual transfer (or
        re-prefill) seconds the recovery costs."""
        import jax  # local: backends must import without initializing jax early

        tid = traj.traj_id
        self.step_remaining.pop(tid, None)  # partial step state is gone
        self._step_gen.pop(tid, None)
        self._gen_time.pop(tid, None)
        self.in_transit.pop(tid, None)  # a wire copy to a corpse never lands
        view = self.views[dst]
        pkg = self.ckpts.get(tid)
        if pkg is None:
            toks = self.prompts[tid]
            t0 = time.perf_counter()
            view.engine.prefill(tid, toks)
            self.wall += time.perf_counter() - t0
            return admission_seconds(len(toks), view.token_time, self.prefill_speedup)
        t0 = time.perf_counter()
        view.engine.migrate_in(dict(pkg))
        extra = self.last_absorb.get(tid)
        if extra:  # tool output absorbed after the snapshot: replay it
            view.engine.extend(tid, extra)
        self.wall += time.perf_counter() - t0
        return migration_time(_package_bytes(pkg, jax), self.link_bandwidth)

    def kill(self, wid: int) -> None:
        """Worker death: every resident lane (live + retired prefix cache) is
        lost; pending tool outputs are host-side and survive."""
        view = self.views[wid]
        self.dead.add(wid)
        for tid in list(view.engine.store):
            self.step_remaining.pop(tid, None)
            self._step_gen.pop(tid, None)
            self._gen_time.pop(tid, None)
        self._active[wid].clear()
        view.plan = None
        view.engine.reset_cache()

    def revive(self, wid: int) -> None:
        """Replacement capacity joins in slot ``wid``: cold cache, same engine
        shell (kill() already dropped every lane and radix ref)."""
        self.dead.discard(wid)

    # ------------------------------------------------------------ weight sync
    def stage_weights(self, params, epoch: int) -> None:
        """Publish new policy weights as ``epoch``: staged host-side, applied
        per worker by ``sync_weights`` once the orchestrator's drain fence
        clears it.  ``params=None`` advances the epoch without new tensors
        (modeled trainers exercising only the control plane)."""
        self._staged_params[epoch] = params

    def sync_weights(self, wid: int, epoch: int) -> None:
        """Cut worker ``wid`` over to ``epoch``: swap the staged params in and
        ``reset_cache()`` — every retired prefix lane decoded under the old
        policy must never seed a post-sync admission.  The fence guarantees the
        worker holds zero resident lanes, so nothing live is destroyed."""
        params = self._staged_params.get(epoch)
        view = self.views[wid]
        if params is not None:
            view.engine.params = params
        # the global target epoch is monotone: once any worker syncs to
        # ``epoch``, no future sync will ask for an older stage
        for stale in [e for e in self._staged_params if e < epoch]:
            del self._staged_params[stale]
        view.engine.reset_cache()
