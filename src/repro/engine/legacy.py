"""Legacy per-sequence-cache rollout worker (the pre-slot-pool data plane).

Kept as the reference implementation for the slot-pool engine: every batched
``decode()`` call concatenates the per-sequence caches into a step batch and slices
them back afterwards — O(B * capacity) device copies per call, the step-centric
overhead the slot-pool engine in ``repro.engine.worker`` eliminates.  The parity
tests (tests/test_slot_pool.py) pin token-exact equivalence between the two, and
``benchmarks/bench_worker.py`` measures the gap.

Sampling uses the same per-sequence key discipline as the slot-pool engine
(key = fold_in(fold_in(PRNGKey(seed + worker_id), seq_id), context_len)) so the two
paths draw identical tokens at temperature > 0.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.sampler import SamplerConfig, sample_slots
from repro.models import model as M
from repro.models.config import ModelConfig


# ---------------------------------------------------------------- jitted steps

@partial(jax.jit, static_argnames=("cfg", "capacity"))
def _prefill(cfg: ModelConfig, params, tokens, capacity: int):
    logits, aux, cache = M.forward_full(cfg, params, {"tokens": tokens},
                                        capacity=capacity)
    return logits[:, -1], _bcast_pos(cache, tokens.shape[0])


@partial(jax.jit, static_argnames=("cfg",))
def _decode(cfg: ModelConfig, params, cache, tokens):
    return M.decode_step(cfg, params, cache, tokens)


@partial(jax.jit, static_argnames=("cfg",))
def _extend(cfg: ModelConfig, params, cache, tokens):
    """Teacher-forced absorption of ``tokens`` (B, L) into the cache (chunked prefill)."""

    def body(cache, tok):
        logits, cache = M.decode_step(cfg, params, cache, tok[:, None])
        return cache, logits

    cache, logits = jax.lax.scan(body, cache, tokens.T)
    return logits[-1], cache


def _bcast_pos(cache, batch):
    cache = dict(cache)
    cache["pos"] = jnp.broadcast_to(cache["pos"], (batch,)).astype(jnp.int32)
    return cache


def _slice_cache(cache, idx):
    """Select batch entries ``idx`` from a cache pytree (batch is axis 1 of blocks)."""
    pos = cache["pos"][idx]
    blocks = jax.tree.map(lambda x: x[:, idx], cache["blocks"])
    return {"pos": pos, "blocks": blocks}


def _concat_caches(caches):
    pos = jnp.concatenate([c["pos"] for c in caches])
    blocks = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                          *[c["blocks"] for c in caches])
    return {"pos": pos, "blocks": blocks}


# ---------------------------------------------------------------- worker

@dataclass
class Sequence:
    seq_id: int
    tokens: list[int]                    # full context (prompt + generated + tool)
    key: np.ndarray                      # (2,) uint32 per-sequence sampling key
    generated: int = 0
    cache: Optional[dict] = None         # single-sequence cache (batch dim 1)
    finished: bool = False


class LegacyRolloutWorker:
    """One rollout worker holding model params and a per-sequence cache store."""

    def __init__(self, cfg: ModelConfig, params, capacity: int = 256,
                 worker_id: int = 0, sampler: SamplerConfig = SamplerConfig(),
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.capacity = capacity
        self.worker_id = worker_id
        self.sampler = sampler
        self.base_key = jax.random.PRNGKey(seed + worker_id)
        self.store: dict[int, Sequence] = {}       # resident sequences (incl. preempted)
        from repro.engine.worker import PrefixCacheIndex
        self.prefix_index = PrefixCacheIndex()
        self.decode_steps = 0

    # ------------------------------------------------------------ lifecycle
    def prefill(self, seq_id: int, tokens: list[int]) -> None:
        """Admit a sequence: full-sequence forward builds its KV/state cache."""
        self.prefix_index.match_len(tokens)
        arr = jnp.asarray(tokens, jnp.int32)[None]
        _, cache = _prefill(self.cfg, self.params, arr, self.capacity)
        key = np.asarray(jax.random.fold_in(self.base_key, seq_id))
        self.store[seq_id] = Sequence(seq_id, list(tokens), key, cache=cache)
        self.prefix_index.insert(tokens)

    def extend(self, seq_id: int, tool_tokens: list[int]) -> None:
        """Absorb tool output into an existing cache (no prefix recompute)."""
        seq = self.store[seq_id]
        assert seq.cache is not None, "extend() on a sequence without resident cache"
        arr = jnp.asarray(tool_tokens, jnp.int32)[None]
        _, seq.cache = _extend(self.cfg, self.params, seq.cache, arr)
        seq.tokens.extend(int(t) for t in tool_tokens)

    def decode(self, seq_ids: list[int], n_tokens: int, stop_token: int | None = None
               ) -> dict[int, list[int]]:
        """Batched decode of resident sequences for up to ``n_tokens`` steps."""
        seqs = [self.store[s] for s in seq_ids]
        cache = _concat_caches([s.cache for s in seqs])
        last = jnp.asarray([[s.tokens[-1]] for s in seqs], jnp.int32)
        keys = jnp.asarray(np.stack([s.key for s in seqs]))
        out: dict[int, list[int]] = {s: [] for s in seq_ids}
        live = np.ones(len(seqs), bool)
        for _ in range(n_tokens):
            step_keys = jax.vmap(jax.random.fold_in)(
                keys, jnp.asarray([len(s.tokens) for s in seqs], jnp.int32))
            logits, cache = _decode(self.cfg, self.params, cache, last)
            toks = sample_slots(step_keys, logits, self.sampler)
            self.decode_steps += 1
            # the per-token host sync IS the legacy baseline: python-side stop
            # bookkeeping every step is the cost worker.py's fused _decode_loop
            # (lax.scan + on-device live mask) exists to eliminate
            toks_np = np.asarray(toks)  # heddle: noqa HDL003 -- pre-fusion baseline, measured as such
            for i, s in enumerate(seqs):
                if not live[i]:
                    continue
                t = int(toks_np[i])
                out[s.seq_id].append(t)
                s.tokens.append(t)
                s.generated += 1
                if stop_token is not None and t == stop_token:
                    live[i] = False
            last = toks_np[:, None]
            if not live.any():
                break
        # split the batched cache back into per-sequence stores
        for i, s in enumerate(seqs):
            s.cache = _slice_cache(cache, jnp.asarray([i]))
            self.prefix_index.insert(s.tokens)
        return out

    # ------------------------------------------------------------ control ops
    def preempt(self, seq_id: int) -> None:
        """Evict from the running batch but persist the KV cache (Alg. 1 line 7)."""
        assert seq_id in self.store

    def release(self, seq_id: int) -> None:
        self.store.pop(seq_id, None)

    def migrate_out(self, seq_id: int) -> dict:
        """Package a sequence's context + cache for transfer (§5.3 KV migration)."""
        seq = self.store.pop(seq_id)
        package = {
            "seq_id": seq.seq_id,
            "tokens": list(seq.tokens),
            "generated": seq.generated,
            "key": np.asarray(seq.key),
            "cache": jax.tree.map(np.asarray, seq.cache),  # heddle: noqa HDL005 -- legacy per-sequence engine predates the paged pool; host bounce is its only transport
        }
        return package

    def migrate_in(self, package: dict) -> None:
        cache = jax.tree.map(jnp.asarray, package["cache"])  # host -> this worker
        key = package.get("key")
        if key is None:
            key = np.asarray(jax.random.fold_in(self.base_key, package["seq_id"]))
        seq = Sequence(package["seq_id"], list(package["tokens"]), np.asarray(key),
                       generated=package["generated"], cache=cache)
        self.store[package["seq_id"]] = seq
        self.prefix_index.insert(seq.tokens)

    def kv_bytes(self, seq_id: int) -> int:
        seq = self.store[seq_id]
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(seq.cache))
