"""Tool manager: simulated elastic serverless backend (paper §3 'Tool Manager').

The paper offloads tool execution to FaaS and treats T_tool as elastic; we model each
task domain's tool with a lognormal latency distribution calibrated to the paper's
Table 1 means (coding 0.46s, search 1.42s, math 0.051s) plus a failure probability
(e.g. failing tests for the coding agent) that drives trajectory extension.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.faults import FaultPlan, RetryPolicy, resolve_tool_call


@dataclass(frozen=True)
class ToolProfile:
    name: str
    mean_latency: float              # seconds (Table 1)
    cv: float = 0.6                  # coefficient of variation (long-tailed)
    fail_rate: float = 0.0           # P(tool reports failure) -> rectification steps
    output_tokens_mean: int = 128    # tool output size folded back into context

    def sample_latency(self, rng: np.random.Generator, n: int | None = None):
        sigma = np.sqrt(np.log(1 + self.cv ** 2))
        mu = np.log(self.mean_latency) - sigma ** 2 / 2
        return rng.lognormal(mu, sigma, n)

    def sample_output_tokens(self, rng: np.random.Generator, failed: bool) -> int:
        base = self.output_tokens_mean * (2.0 if failed else 1.0)
        return int(max(1, rng.normal(base, base * 0.3)))


# Task domains evaluated in the paper (§7 'Workloads'), Table 1 tool-latency means.
TOOL_PROFILES: dict[str, ToolProfile] = {
    "coding": ToolProfile("sandbox", mean_latency=0.46, cv=0.8, fail_rate=0.35,
                          output_tokens_mean=160),
    "search": ToolProfile("web_search", mean_latency=1.42, cv=0.5, fail_rate=0.10,
                          output_tokens_mean=256),
    "math": ToolProfile("calculator", mean_latency=0.051, cv=0.4, fail_rate=0.20,
                        output_tokens_mean=48),
}


class ToolExecutor:
    """Elastic executor: unlimited concurrency (serverless), pay-per-invocation.

    Outcomes are seeded per ``(traj_id, step)``, NOT per call sequence: two
    backends (or two scheduling orders) invoking the same trajectory's steps
    must observe identical latencies/failures, and a shared sequential rng
    would entangle every trajectory's outcome with global dispatch order.

    Two failure channels, never conflated (see ``core.faults``): ``failed`` is
    the *task-level* outcome rolled from ``ToolProfile.fail_rate`` (the
    rectification signal the predictor features on), while a ``FaultPlan``
    injects *system-level* timeouts/transient errors that the executor absorbs
    via ``RetryPolicy`` — they stretch latency (and the retry telemetry) but
    cannot change the task outcome."""

    def __init__(self, profile: ToolProfile, seed: int = 0, *,
                 faults: Optional[FaultPlan] = None,
                 retry: RetryPolicy = RetryPolicy()):
        self.profile = profile
        self.seed = seed
        self.faults = faults
        self.retry = retry
        self.invocations = 0
        self.total_latency = 0.0
        self.retries = 0
        self.injected_faults = 0

    def invoke(self, traj_id: int, step: int) -> tuple[float, bool, int]:
        """Returns (latency_s, failed, output_tokens) for one (traj, step).

        The task-level roll consumes the rng stream identically with or without
        a fault plan, so chaos never perturbs plan-driven outcomes."""
        rng = np.random.default_rng((self.seed, traj_id, step))
        lat = float(self.profile.sample_latency(rng))
        failed = bool(rng.random() < self.profile.fail_rate)
        out = self.profile.sample_output_tokens(rng, failed)
        trace = resolve_tool_call(self.faults, self.retry, traj_id, step, lat)
        self.invocations += 1
        self.total_latency += trace.latency
        self.retries += trace.retries
        self.injected_faults += trace.injected_faults
        return trace.latency, failed, out
