"""Discrete-event rollout-cluster simulator (processor-sharing continuous batching).

Evaluates orchestration policies at paper scale (64 accelerators, thousands of
trajectories, 40K-token tails) where real 8B-32B decoding is impossible in this
container.  The performance model follows the paper's own profiler-based methodology
(§5.2): a worker running b concurrent trajectories advances each at per-token time
``T_w * F(b)`` where ``T_w`` is the worker's MP-dependent base per-token time and F the
profiled interference factor.  Prefill recompute on cache miss, preemption, migration
during tool calls and the transmission scheduler are all modeled explicitly.

Everything policy-like is pluggable so Heddle and the §7 baselines run on identical
substrate:
  scheduler:  pps | fcfs | rr | sjf                     (core.scheduler)
  placement:  heddle | cache_aware | least_load | hybrid (core.controller)
  resources:  adaptive (Algorithm 2) | fixed MP list
"""

from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.core.controller import (HeddleConfig, HeddleController, ROUTING_POLICIES)
from repro.core.migration import MigrationRequest, kv_cache_bytes, migration_time
from repro.core.placement import InterferenceModel
from repro.core.predictor import ProgressivePredictor
from repro.core.resource_manager import WorkerLatencyModel
from repro.core.scheduler import make_scheduler
from repro.core.trajectory import StepRecord, Trajectory, TrajectoryPhase
from repro.engine.workload import TrajectoryPlan


@dataclass(frozen=True)
class SimConfig:
    scheduler: str = "pps"
    placement: str = "heddle"            # heddle | cache_aware | least_load | hybrid
    degrees: tuple[int, ...] = ()        # worker MP degrees; () -> adaptive (Alg. 2)
    gpu_budget: int = 64                 # paper testbed: 64 GPUs
    mp_degrees: tuple[int, ...] = (1, 2, 4, 8)
    max_batch: int = 100                 # paper: batch 100 per rollout worker
    migration: bool = True
    work_aware_dp: bool = True           # beyond-paper DP cost; False = Formula 2
    # performance model
    base_token_time: float = 0.02        # s/token at MP=1, batch=1 (Qwen3-14B-ish)
    kv_weight_ratio: float = 0.010       # F(b) = 1 + r*b: the paper's group-size
    # interference premise (§5.1 "determined exclusively by the size of the group",
    # which the paper reports holds empirically on its workloads).  The default data
    # plane honors the premise so control-plane model and simulated hardware agree —
    # the paper-faithful configuration.
    # Beyond-paper robustness regime (EXPERIMENTS.md §Beyond): batched decode time can
    # instead scale with the *total KV context* resident in the batch (weights read
    # once, every sequence's KV streamed) — co-locating two 40K-context tails is then
    # expensive even at batch size 2.  Calibration: 40K-token KV ~ 3.3GB vs ~28GB
    # weights => coef ~ 3e-6.  Enabled by setting ctx_interference > 0.
    ctx_interference: float = 0.0        # F_worker += coef * sum(context_tokens)
    # Tensor-parallel communication: the per-step all-reduce moves activations whose
    # volume scales with the batch, so the comm fraction is o*(1 + gamma*b).  This is
    # the physics behind the paper's Fig 7 latency-throughput trade-off: high MP wins
    # per-token latency at small batch but loses per-chip throughput at saturation
    # (calibrated to ~0.5x per-chip throughput at MP=8, batch ~96 — vLLM/TGI-class TP
    # scaling).
    comm_overlap: float = 0.22
    comm_batch_coef: float = 0.087
    # Prefill is compute-bound while bs=1 decode is weight-read-bound, so one prefill
    # token costs ~1/100 of a decode token (14B bf16: ~0.1ms vs ~14ms on Hopper-class).
    prefill_speedup: float = 100.0
    # Measured radix-cache reuse (engine dispatch_stats -> controller
    # measured_reuse_rate): the fraction of a shared prompt a sibling arrival
    # actually implants instead of re-prefilling.  None keeps the paper's
    # assumption of full prompt reuse at a prompt-home worker (rate = 1.0).
    measured_reuse_rate: float | None = None
    link_bandwidth: float = 50e9         # migration link (GPU-Direct RDMA / ICI)
    model_layers: int = 40               # KV bytes model (Qwen3-14B-ish)
    model_kv_heads: int = 8
    model_head_dim: int = 128
    seed: int = 0


@dataclass
class SimResult:
    makespan: float
    total_tokens: int
    throughput: float                     # tokens / s
    queue_delay_p100: float               # queueing delay of the longest trajectory
    queue_delay_mean: float
    migrations: int
    preemptions: int
    cache_miss_prefill_tokens: int
    trajectories: list[Trajectory] = field(default_factory=list)
    timeline: list[tuple[float, int]] = field(default_factory=list)  # (t, active count)


class _Worker:
    """Processor-sharing continuous-batching worker."""

    def __init__(self, wid: int, mp: int, token_time: float,
                 interference: InterferenceModel, max_batch: int, scheduler_name: str):
        self.wid = wid
        self.mp = mp
        self.token_time = token_time      # t1 * ((1-o)/mp + o): control-plane view
        self.t1 = None                    # set by RolloutSimulator (data-plane model)
        self.comm_overlap = 0.0
        self.comm_batch_coef = 0.0
        self.interference = interference
        self.max_batch = max_batch
        self.scheduler = make_scheduler(scheduler_name)
        self.version = 0                          # event-staleness guard
        self.active: dict[int, float] = {}       # traj_id -> remaining token-work
        self.trajs: dict[int, Trajectory] = {}
        self.last_update = 0.0
        self.tokens_done = 0.0
        self.ctx_coef = 0.0                       # set by RolloutSimulator

    # -- processor sharing mechanics ------------------------------------------
    def rate(self) -> float:
        """Seconds per token-unit for each active trajectory (all advance together).

        Context-weighted interference: one decode step reads the weights once plus the
        KV cache of every resident sequence, so per-token time grows with the *total
        context tokens* in the batch, not just its size."""
        b = len(self.active)
        if b == 0:
            return math.inf
        total_ctx = sum(t.context_tokens for t in self.trajs.values())
        if self.t1 is None:               # control-plane-identical fallback
            return self.token_time * (self.interference(b) + self.ctx_coef * total_ctx)
        o, g = self.comm_overlap, self.comm_batch_coef
        scalable = (self.interference(b) + self.ctx_coef * total_ctx) / self.mp
        comm = (o * (1.0 + g * b)) if self.mp > 1 else 0.0
        return self.t1 * ((1.0 - o) * scalable + comm + (o / self.mp if self.mp == 1 else 0.0))

    def advance(self, now: float) -> list[int]:
        """Progress all active trajectories to ``now``; return finished traj_ids."""
        dt = now - self.last_update
        self.last_update = now
        if not self.active or dt <= 0:
            return []
        per_tok = self.rate()
        progressed = dt / per_tok
        done = []
        for tid in list(self.active):
            self.active[tid] -= progressed
            self.tokens_done += progressed
            if self.active[tid] <= 1e-9:
                done.append(tid)
        return done

    def next_completion(self, now: float) -> Optional[float]:
        if not self.active:
            return None
        per_tok = self.rate()
        rem = min(self.active.values())
        return now + max(rem, 0.0) * per_tok


class RolloutSimulator:
    def __init__(self, trajectories: Sequence[Trajectory], predictor: ProgressivePredictor,
                 config: SimConfig):
        self.cfg = config
        self.trajs = list(trajectories)
        self.predictor = predictor
        self.interference = InterferenceModel.analytic(config.kv_weight_ratio,
                                                       max_batch=max(4096, config.max_batch * 4))
        self.latency = WorkerLatencyModel(t1=config.base_token_time)
        self.controller = HeddleController(
            predictor, self.interference, self.latency, config.gpu_budget,
            HeddleConfig(scheduler=config.scheduler,
                         adaptive_resources=not config.degrees,
                         migration=config.migration and config.placement == "heddle",
                         mp_degrees=config.mp_degrees, sa_seed=config.seed,
                         max_group_count=4 * config.max_batch,
                         work_aware_dp=config.work_aware_dp),
            max_workers=config.gpu_budget)
        self.routing = None
        if config.placement != "heddle":
            self.routing = ROUTING_POLICIES[config.placement]()
        self.stats_migrations = 0
        self.stats_preemptions = 0
        self.stats_miss_tokens = 0

    # ------------------------------------------------------------------ setup
    def _make_workers(self) -> list[_Worker]:
        cfg = self.cfg
        degrees = list(cfg.degrees) if cfg.degrees else self.controller.provision(self.trajs)
        workers = [
            _Worker(i, mp, self.latency.base_token_time(mp), self.interference,
                    cfg.max_batch, cfg.scheduler)
            for i, mp in enumerate(degrees)
        ]
        for w in workers:
            w.ctx_coef = cfg.ctx_interference
            w.t1 = self.latency.t1
            w.comm_overlap = cfg.comm_overlap
            w.comm_batch_coef = cfg.comm_batch_coef
        return workers

    # ------------------------------------------------------------------ run
    def run(self) -> SimResult:
        cfg = self.cfg
        workers = self._make_workers()
        m = len(workers)
        loads = np.zeros(m)
        cache_home: dict[int, set[int]] = {}     # traj -> workers holding its prefix
        prompt_home: dict[int, set[int]] = {}    # prompt -> workers holding its prompt
        pending_tool: dict[int, float] = {}
        migration_target: dict[int, int] = {}
        migration_ready: dict[int, float] = {}

        # --- initial placement -------------------------------------------------
        for t in self.trajs:
            t.predicted_remaining = self.predictor.predict(t)
            t.priority = t.predicted_total
            t.submit_time = 0.0
        if cfg.placement == "heddle":
            self.controller.degrees = [w.mp for w in workers]
            self.controller.initial_placement(self.trajs)
        else:
            for t in self.trajs:
                t.worker_id = self.routing.initial_worker(t, loads)
                loads[t.worker_id] += 1

        # --- event loop ----------------------------------------------------------
        # events: (time, seq, kind, payload)
        evq: list[tuple[float, int, str, int]] = []
        seq = itertools.count()

        def push(t, kind, tid):
            heapq.heappush(evq, (t, next(seq), kind, tid))

        def worker_loads() -> np.ndarray:
            return np.asarray([len(w.active) + len(w.scheduler) for w in workers], float)

        def submit_step(traj: Trajectory, now: float):
            """Queue the next LLM generation step of ``traj`` on its worker."""
            w = workers[traj.worker_id]
            traj._queued_at = now
            w.scheduler.submit(traj, now)
            try_dispatch(w, now)

        def step_work(traj: Trajectory) -> float:
            """Token-work for the upcoming step: generation + prefill recompute.

            Prefix-cache accounting: a worker holding the trajectory's own cache pays
            only the new tool output; a worker that has served any *group sibling*
            holds the shared prompt prefix (radix-cache reuse), so a fresh arrival
            there pays context - prompt."""
            plan: TrajectoryPlan = traj.payload
            s = traj.num_steps
            gen = plan.gen_tokens[s]
            if traj.worker_id in cache_home.get(traj.traj_id, set()):
                prefill_tokens = (traj.steps[-1].tool_output_tokens if traj.steps
                                  else traj.prompt_tokens)
            elif traj.worker_id in prompt_home.get(traj.prompt_id, set()):
                # group-sibling arrival: the shared prompt is reusable.  Scale by
                # the engine's measured radix-cache reuse rate when available
                # instead of assuming the whole prompt implants.
                rate = self.cfg.measured_reuse_rate
                reusable = traj.prompt_tokens if rate is None \
                    else rate * traj.prompt_tokens
                prefill_tokens = max(traj.context_tokens - reusable,
                                     traj.prompt_tokens // 8)
                self.stats_miss_tokens += int(prefill_tokens)
            else:
                prefill_tokens = traj.context_tokens or traj.prompt_tokens
                self.stats_miss_tokens += int(prefill_tokens)
            return gen + prefill_tokens / cfg.prefill_speedup

        def start(w: _Worker, traj: Trajectory, now: float):
            for tid in w.advance(now):     # settle progress before batch size changes
                done_traj = w.trajs.pop(tid)
                del w.active[tid]
                finish_step(done_traj, now)
            traj.phase = TrajectoryPhase.GENERATING
            qd = now - getattr(traj, "_queued_at", now)
            traj._step_queue_delay = getattr(traj, "_step_queue_delay", 0.0) + qd
            if getattr(traj, "_preempt_remaining", None) is not None:
                w.active[traj.traj_id] = traj._preempt_remaining   # resume persisted work
                traj._preempt_remaining = None
            else:
                w.active[traj.traj_id] = step_work(traj)
            w.trajs[traj.traj_id] = traj
            cache_home.setdefault(traj.traj_id, set()).add(w.wid)
            prompt_home.setdefault(traj.prompt_id, set()).add(w.wid)
            reschedule(w, now)

        def reschedule(w: _Worker, now: float):
            w.version += 1
            nc = w.next_completion(now)
            if nc is not None:
                push(nc, "worker_check", (w.wid, w.version))

        def try_dispatch(w: _Worker, now: float):
            # fill free slots
            while len(w.active) < w.max_batch and len(w.scheduler):
                traj = w.scheduler.pop(now)
                if traj is None:
                    break
                start(w, traj, now)
            # preemptive execution (Algorithm 1 lines 5-10)
            if w.scheduler.preemptive and len(w.scheduler):
                active_trajs = [w.trajs[tid] for tid in w.active]
                victim = w.scheduler.preempt_victim(active_trajs)
                if victim is not None:
                    w.advance(now)
                    remaining = w.active.pop(victim.traj_id)
                    w.trajs.pop(victim.traj_id)
                    victim.preemptions += 1
                    victim.phase = TrajectoryPhase.PREEMPTED
                    victim._preempt_remaining = remaining
                    self.stats_preemptions += 1
                    victim._queued_at = now
                    w.scheduler.submit(victim, now)
                    nxt = w.scheduler.pop(now)
                    if nxt is not None:
                        start(w, nxt, now)
                    reschedule(w, now)

        def finish_step(traj: Trajectory, now: float):
            """Generation step done -> record, launch tool, maybe migrate (§5.3)."""
            plan: TrajectoryPlan = traj.payload
            s = traj.num_steps
            rec = StepRecord(s, plan.gen_tokens[s], plan.tool_latency[s],
                             tool_failed=plan.tool_failed[s],
                             tool_output_tokens=plan.tool_output_tokens[s],
                             queue_delay=getattr(traj, "_step_queue_delay", 0.0))
            traj._step_queue_delay = 0.0
            traj.record_step(rec)
            traj.record_tool_output(rec.tool_output_tokens)
            if traj.num_steps >= plan.num_steps:
                traj.finished = True
                traj.finish_time = now
                traj.phase = TrajectoryPhase.FINISHED
                if cfg.placement == "heddle":
                    self.controller.on_finish(traj)
                return
            traj.phase = TrajectoryPhase.TOOL_CALL
            tool_end = now + rec.tool_latency
            pending_tool[traj.traj_id] = tool_end
            # progressive prediction refresh + migration decision (masked by tool call)
            if cfg.placement == "heddle":
                req = self.controller.on_step_complete(traj, ())
                if req is not None and cfg.migration:
                    for batch_req in self.controller.transmission.next_batch():
                        launch_migration(batch_req, now)
            else:
                traj.predicted_remaining = self.predictor.predict(traj)
                traj.priority = traj.predicted_total
            push(tool_end, "tool_done", traj.traj_id)

        def launch_migration(req: MigrationRequest, now: float):
            if req.traj_id not in pending_tool:
                # trajectory already resumed generating: migrating now would stall the
                # critical path, so the router drops the request (paper §5.3 only
                # migrates during tool intervals).  abort, not commit: the worker
                # counts never moved for this request, so there is nothing to undo
                self.controller.transmission.complete(req.traj_id)
                self.controller.abort_migration(req.traj_id)
                return
            traj = traj_by_id[req.traj_id]
            self.controller.commit_migration(req.traj_id)
            kv = kv_cache_bytes(traj.context_tokens, cfg.model_layers,
                                cfg.model_kv_heads, cfg.model_head_dim)
            dur = migration_time(kv, cfg.link_bandwidth)
            migration_target[req.traj_id] = req.dst
            migration_ready[req.traj_id] = now + dur
            self.stats_migrations += 1
            traj.migrations += 1
            push(now + dur, "migration_done", req.traj_id)

        def tool_done(traj: Trajectory, now: float):
            pending_tool.pop(traj.traj_id, None)
            tid = traj.traj_id
            if tid not in migration_target:
                # resuming with an emitted-but-unlaunched migration: drop it —
                # its target was chosen from now-stale load/rank data
                self.controller.abort_migration(tid)
            if tid in migration_target:
                ready = migration_ready.get(tid, now)
                if ready <= now:           # migration fully masked by the tool call
                    traj.worker_id = migration_target.pop(tid)
                    migration_ready.pop(tid, None)
                    cache_home[tid] = {traj.worker_id}
                else:
                    # resume where the cache lives; re-dispatch when transfer lands
                    push(ready, "migration_resume", tid)
                    return
            elif cfg.placement != "heddle":
                w_new = self.routing.step_worker(traj, worker_loads())
                traj.worker_id = w_new
            submit_step(traj, now)

        def migration_done(tid: int, now: float):
            self.controller.transmission.complete(tid)
            for batch_req in self.controller.transmission.next_batch():
                launch_migration(batch_req, now)

        def migration_resume(tid: int, now: float):
            traj = traj_by_id[tid]
            if tid in migration_target:
                traj.worker_id = migration_target.pop(tid)
                migration_ready.pop(tid, None)
                cache_home[tid] = {traj.worker_id}
            submit_step(traj, now)

        traj_by_id = {t.traj_id: t for t in self.trajs}
        for t in self.trajs:
            submit_step(t, 0.0)

        timeline = []
        now = 0.0
        guard = 0
        while evq:
            guard += 1
            if guard > 5_000_000:
                raise RuntimeError("simulator event budget exceeded")
            now, _, kind, payload = heapq.heappop(evq)
            if kind == "worker_check":
                wid, ver = payload
                w = workers[wid]
                if ver != w.version:
                    continue                      # stale event superseded by reschedule
                for tid in w.advance(now):
                    traj = w.trajs.pop(tid)
                    del w.active[tid]
                    finish_step(traj, now)
                try_dispatch(w, now)
                reschedule(w, now)
            elif kind == "tool_done":
                tool_done(traj_by_id[payload], now)
            elif kind == "migration_done":
                migration_done(payload, now)
            elif kind == "migration_resume":
                migration_resume(payload, now)
            if guard % 256 == 0:
                timeline.append((now, sum(1 for t in self.trajs if not t.finished)))

        assert all(t.finished for t in self.trajs), "simulation ended with live trajectories"
        makespan = max(t.finish_time for t in self.trajs)
        total_tokens = sum(t.tokens_generated for t in self.trajs)
        delays = np.asarray([t.total_queue_delay for t in self.trajs])
        longest = max(self.trajs, key=lambda t: t.true_total_tokens)
        return SimResult(
            makespan=makespan,
            total_tokens=total_tokens,
            throughput=total_tokens / makespan,
            queue_delay_p100=longest.total_queue_delay,
            queue_delay_mean=float(delays.mean()),
            migrations=self.stats_migrations,
            preemptions=self.stats_preemptions,
            cache_miss_prefill_tokens=self.stats_miss_tokens,
            trajectories=self.trajs,
            timeline=timeline,
        )


def simulate(trajectories, predictor, **kw) -> SimResult:
    return RolloutSimulator(trajectories, predictor, SimConfig(**kw)).run()
