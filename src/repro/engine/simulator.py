"""Discrete-event rollout-cluster simulation = orchestrator + analytic backend.

Evaluates orchestration policies at paper scale (64 accelerators, thousands of
trajectories, 40K-token tails) where real 8B-32B decoding is impossible in this
container.  The performance model follows the paper's own profiler-based
methodology (§5.2): a worker running b concurrent trajectories advances each at
per-token time ``T_w * F(b)`` where ``T_w`` is the worker's MP-dependent base
per-token time and F the profiled interference factor.  Prefill recompute on
cache miss, preemption, migration during tool calls and the transmission
scheduler are all modeled explicitly.

Since the control-plane unification there is no simulator-private event loop:
``RolloutSimulator.run()`` wires the analytic cost models
(``engine.backends.SimBackend``) into the one canonical
``core.orchestrator.Orchestrator`` — the same loop that drives the real
``RolloutWorker`` data plane — so every scheduling/preemption/migration
decision here is made by exactly the code the engine runs.

Everything policy-like is pluggable so Heddle and the §7 baselines run on
identical substrate:
  scheduler:  pps | fcfs | rr | sjf                     (core.scheduler)
  placement:  heddle | cache_aware | least_load | hybrid (core.controller)
  resources:  adaptive (Algorithm 2) | fixed MP list
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core.controller import HeddleConfig, HeddleController, ROUTING_POLICIES
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.placement import InterferenceModel
from repro.core.predictor import ProgressivePredictor
from repro.core.resource_manager import WorkerLatencyModel
from repro.core.trajectory import Trajectory
from repro.engine.backends import SimBackend


@dataclass(frozen=True)
class SimConfig:
    scheduler: str = "pps"
    placement: str = "heddle"            # heddle | cache_aware | least_load | hybrid
    degrees: tuple[int, ...] = ()        # worker MP degrees; () -> adaptive (Alg. 2)
    gpu_budget: int = 64                 # paper testbed: 64 GPUs
    mp_degrees: tuple[int, ...] = (1, 2, 4, 8)
    max_batch: int = 100                 # paper: batch 100 per rollout worker
    migration: bool = True
    work_aware_dp: bool = True           # beyond-paper DP cost; False = Formula 2
    # performance model
    base_token_time: float = 0.02        # s/token at MP=1, batch=1 (Qwen3-14B-ish)
    kv_weight_ratio: float = 0.010       # F(b) = 1 + r*b: the paper's group-size
    # interference premise (§5.1 "determined exclusively by the size of the group",
    # which the paper reports holds empirically on its workloads).  The default data
    # plane honors the premise so control-plane model and simulated hardware agree —
    # the paper-faithful configuration.
    # Beyond-paper robustness regime (EXPERIMENTS.md §Beyond): batched decode time can
    # instead scale with the *total KV context* resident in the batch (weights read
    # once, every sequence's KV streamed) — co-locating two 40K-context tails is then
    # expensive even at batch size 2.  Calibration: 40K-token KV ~ 3.3GB vs ~28GB
    # weights => coef ~ 3e-6.  Enabled by setting ctx_interference > 0.
    ctx_interference: float = 0.0        # F_worker += coef * sum(context_tokens)
    # Tensor-parallel communication: the per-step all-reduce moves activations whose
    # volume scales with the batch, so the comm fraction is o*(1 + gamma*b).  This is
    # the physics behind the paper's Fig 7 latency-throughput trade-off: high MP wins
    # per-token latency at small batch but loses per-chip throughput at saturation
    # (calibrated to ~0.5x per-chip throughput at MP=8, batch ~96 — vLLM/TGI-class TP
    # scaling).
    comm_overlap: float = 0.22
    comm_batch_coef: float = 0.087
    # Prefill is compute-bound while bs=1 decode is weight-read-bound, so one prefill
    # token costs ~1/100 of a decode token (14B bf16: ~0.1ms vs ~14ms on Hopper-class).
    prefill_speedup: float = 100.0
    # Measured radix-cache reuse (engine dispatch_stats -> controller
    # measured_reuse_rate): the fraction of a shared prompt a sibling arrival
    # actually implants instead of re-prefilling.  None keeps the paper's
    # assumption of full prompt reuse at a prompt-home worker (rate = 1.0).
    measured_reuse_rate: float | None = None
    link_bandwidth: float = 50e9         # migration link (GPU-Direct RDMA / ICI)
    model_layers: int = 40               # KV bytes model (Qwen3-14B-ish)
    model_kv_heads: int = 8
    model_head_dim: int = 128
    seed: int = 0
    sanitize: bool = False               # TraceSanitizer over the decision stream


@dataclass
class SimResult:
    makespan: float
    total_tokens: int
    throughput: float                     # tokens / s
    queue_delay_p100: float               # queueing delay of the longest trajectory
    queue_delay_mean: float
    migrations: int
    preemptions: int
    cache_miss_prefill_tokens: int
    trajectories: list[Trajectory] = field(default_factory=list)
    timeline: list[tuple[float, int]] = field(default_factory=list)  # (t, active count)


class RolloutSimulator:
    """Paper-scale policy studies on the unified orchestrator (SimBackend)."""

    def __init__(self, trajectories: Sequence[Trajectory], predictor: ProgressivePredictor,
                 config: SimConfig):
        self.cfg = config
        self.trajs = list(trajectories)
        self.predictor = predictor
        self.interference = InterferenceModel.analytic(config.kv_weight_ratio,
                                                       max_batch=max(4096, config.max_batch * 4))
        self.latency = WorkerLatencyModel(t1=config.base_token_time)
        self.controller = HeddleController(
            predictor, self.interference, self.latency, config.gpu_budget,
            HeddleConfig(scheduler=config.scheduler,
                         adaptive_resources=not config.degrees,
                         migration=config.migration and config.placement == "heddle",
                         mp_degrees=config.mp_degrees, sa_seed=config.seed,
                         max_group_count=4 * config.max_batch,
                         work_aware_dp=config.work_aware_dp),
            max_workers=config.gpu_budget)
        self.routing = None
        if config.placement != "heddle":
            self.routing = ROUTING_POLICIES[config.placement]()

    # ------------------------------------------------------------------ run
    def run(self) -> SimResult:
        cfg = self.cfg
        degrees = list(cfg.degrees) if cfg.degrees else self.controller.provision(self.trajs)
        heddle = cfg.placement == "heddle"
        if heddle:
            self.controller.degrees = degrees
        backend = SimBackend(
            degrees, [self.latency.base_token_time(mp) for mp in degrees],
            self.interference,
            t1=self.latency.t1,
            comm_overlap=cfg.comm_overlap, comm_batch_coef=cfg.comm_batch_coef,
            ctx_interference=cfg.ctx_interference,
            prefill_speedup=cfg.prefill_speedup,
            measured_reuse_rate=cfg.measured_reuse_rate,
            link_bandwidth=cfg.link_bandwidth,
            kv_layers=cfg.model_layers, kv_heads=cfg.model_kv_heads,
            kv_head_dim=cfg.model_head_dim)
        orch = Orchestrator(
            backend, self.trajs,
            OrchestratorConfig(scheduler=cfg.scheduler, max_active=cfg.max_batch,
                               migration=cfg.migration and heddle,
                               max_events=5_000_000, timeline_every=256,
                               sanitize=cfg.sanitize),
            controller=self.controller if heddle else None,
            routing=self.routing, predictor=self.predictor)
        res = orch.run()

        assert all(t.finished for t in self.trajs), "simulation ended with live trajectories"
        total_tokens = sum(t.tokens_generated for t in self.trajs)
        delays = np.asarray([t.total_queue_delay for t in self.trajs])
        longest = max(self.trajs, key=lambda t: t.true_total_tokens)
        return SimResult(
            makespan=res.makespan,
            total_tokens=total_tokens,
            throughput=total_tokens / res.makespan,
            queue_delay_p100=longest.total_queue_delay,
            queue_delay_mean=float(delays.mean()),
            migrations=res.migrations,
            preemptions=res.preemptions,
            cache_miss_prefill_tokens=backend.miss_tokens,
            trajectories=self.trajs,
            timeline=res.timeline,
        )


def simulate(trajectories, predictor, **kw) -> SimResult:
    return RolloutSimulator(trajectories, predictor, SimConfig(**kw)).run()
