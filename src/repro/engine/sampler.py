"""Temperature / top-p token sampling (paper §7: temperature 1.0, top-p 0.9).

Two entry points:

  * ``sample``        — one shared key for a (B, V) batch; the legacy step-batched path.
  * ``sample_slots``  — per-slot keys plus an active-lane mask; the slot-pool engine
    samples every resident lane independently, so a lane's token stream is a pure
    function of (its key, its context) and survives re-batching, preemption and
    migration without perturbing its randomness.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 1.0
    top_p: float = 0.9


def top_p_filter(logits: jax.Array, top_p: float) -> jax.Array:
    """Mask logits outside the smallest prefix with cumulative mass >= top_p."""
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.argmax(cum >= top_p, axis=-1)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[..., None], axis=-1)
    return jnp.where(logits < cutoff, -jnp.inf, logits)


def sample(key: jax.Array, logits: jax.Array, cfg: SamplerConfig = SamplerConfig()
           ) -> jax.Array:
    """logits: (B, V) -> tokens (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_p < 1.0:
        logits = top_p_filter(logits, cfg.top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_slots(keys: jax.Array, logits: jax.Array,
                 cfg: SamplerConfig = SamplerConfig(),
                 active: jax.Array | None = None) -> jax.Array:
    """Masked per-slot sampling for the slot-pool decode loop.

    keys: (B, 2) uint32 per-slot PRNG keys; logits: (B, V); active: optional (B,)
    bool.  Returns (B,) int32 — inactive lanes yield -1 (never a valid token), which
    the engine uses as the "nothing emitted" sentinel.
    """
    if cfg.temperature <= 0.0:
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        scaled = logits.astype(jnp.float32) / cfg.temperature
        if cfg.top_p < 1.0:
            scaled = top_p_filter(scaled, cfg.top_p)
        toks = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    if active is not None:
        toks = jnp.where(active, toks, -1)
    return toks
