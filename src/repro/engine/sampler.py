"""Temperature / top-p token sampling (paper §7: temperature 1.0, top-p 0.9)."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 1.0
    top_p: float = 0.9


def sample(key: jax.Array, logits: jax.Array, cfg: SamplerConfig = SamplerConfig()
           ) -> jax.Array:
    """logits: (B, V) -> tokens (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative mass >= top_p
        cutoff_idx = jnp.argmax(cum >= cfg.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
