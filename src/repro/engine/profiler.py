"""Decode-throughput profiler (paper §5.2 'Interference Factor').

The paper derives F(batch) by profiling per-token time across batch sizes and feeding a
simulator.  This module does exactly that against the REAL JAX engine: batched decode
steps at increasing batch sizes on an actual (reduced) model, yielding an
``InterferenceModel`` the placement DP / SA can consume — closing the loop between the
real data plane and the control-plane cost model.

    profile = profile_decode(cfg, params, batch_sizes=(1, 2, 4, 8, 16))
    interference = InterferenceModel.from_profile(profile)
"""

from __future__ import annotations

import time
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.placement import InterferenceModel
from repro.models import model as M
from repro.models.config import ModelConfig


def profile_decode(cfg: ModelConfig, params, batch_sizes: Sequence[int] = (1, 2, 4, 8),
                   capacity: int = 128, context: int = 64, steps: int = 8,
                   warmup: int = 2, seed: int = 0) -> dict[int, float]:
    """Measure per-token decode time (seconds) at each batch size.

    Each sequence carries ``context`` cached tokens so the KV-read component of the
    interference (the term that grows with batch) is actually exercised.
    """
    key = jax.random.PRNGKey(seed)
    step_fn = jax.jit(lambda p, c, t: M.decode_step(cfg, p, c, t))
    profile: dict[int, float] = {}
    for b in batch_sizes:
        tokens = jax.random.randint(key, (b, context), 0, cfg.vocab)
        _, _, cache = M.forward_full(cfg, params, {"tokens": tokens},
                                     capacity=capacity)
        tok = jnp.zeros((b, 1), jnp.int32)
        for _ in range(warmup):                      # compile + stabilize
            logits, cache = step_fn(params, cache, tok)
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for _ in range(steps):
            logits, cache = step_fn(params, cache, tok)
        jax.block_until_ready(logits)
        profile[b] = (time.perf_counter() - t0) / steps
    return profile


def measured_interference(cfg: ModelConfig, params, **kw) -> InterferenceModel:
    """One-call helper: profile the real engine, return the paper's F(batch)."""
    profile = profile_decode(cfg, params, **kw)
    # enforce monotonicity (timer noise at tiny models): running max
    mono, best = {}, 0.0
    for b in sorted(profile):
        best = max(best, profile[b])
        mono[b] = best
    return InterferenceModel.from_profile(mono)
