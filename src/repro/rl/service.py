"""Rollout-as-a-service: the asynchronous plane between rollout and training.

``HeddleTrainer.rollout()`` is a synchronous barrier — every training
iteration waits for the full batch, so the long tail the paper attacks gates
*training* throughput even though the rollout plane itself schedules,
migrates and reconfigures around it.  This module disaggregates the two
planes (RollArt's rollout/training split, Libra's SLO-aware accounting —
see PAPERS.md):

* :class:`RolloutService` keeps one fleet resident across iterations and
  drives the shared :class:`~repro.core.orchestrator.Orchestrator` in
  open-loop + ``stream_harvest`` mode: FINISHED trajectories surface through
  ``harvest`` events on the versioned heap the moment they complete — no
  makespan barrier — while new work is injected mid-run and weight syncs are
  published in flight (each worker cuts over only once its resident lanes
  drain, so every trajectory finishes on the policy that admitted it).
* :class:`ReplayBuffer` is the bounded, group-aware buffer between harvest
  and the GRPO consumer: groups become consumable only when complete (GRPO
  advantages normalize within a group), and :meth:`ReplayBuffer.take`
  enforces the staleness bound — a group whose stamp lags the published
  epoch by more than ``max_staleness`` is discarded, never trained on.

Both backends implement the same harvest/weight-sync semantics, so the
decision-trace parity harness and the TraceSanitizer extend to this plane
(``tests/test_service.py``, ``benchmarks/bench_async.py``).  The lifecycle
is documented in docs/training.md.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Sequence

from repro.core.faults import FaultPlan
from repro.core.orchestrator import (
    Orchestrator,
    OrchestratorConfig,
    OrchestratorResult,
)
from repro.core.trajectory import Trajectory


@dataclass(frozen=True)
class ServiceConfig:
    """Consumer-side knobs of the async plane (the service itself has none:
    scheduling/migration/admission all come from the ``RuntimeConfig``)."""

    max_staleness: int = 1  # consume groups at most this many epochs old
    replay_capacity: int = 256  # trajectories held before eviction kicks in
    groups_per_update: int = 2  # complete groups consumed per GRPO update


class ReplayBuffer:
    """Bounded, group-aware buffer between trajectory harvest and GRPO.

    Trajectories land one at a time (harvest order); a group — keyed by
    ``prompt_id`` — becomes *ready* once all ``group_size`` siblings arrived.
    ``take`` pops ready groups FIFO, discarding any whose weight-epoch stamp
    exceeds the staleness bound.  When the buffer overflows ``capacity``, the
    oldest ready group is evicted (never a partial group: its siblings are
    still streaming in and dropping half a group would poison the advantage
    normalization).
    """

    def __init__(self, capacity: int, group_size: int):
        if group_size < 1:
            raise ValueError("group_size must be >= 1")
        self.capacity = capacity
        self.group_size = group_size
        self._groups: dict[int, list[Trajectory]] = {}  # prompt_id -> members
        self._ready: list[int] = []  # complete groups, completion order
        self.added = 0
        self.evicted = 0  # trajectories dropped by capacity eviction
        self.stale_discards = 0  # trajectories dropped by the staleness bound

    def __len__(self) -> int:
        return sum(len(g) for g in self._groups.values())

    @property
    def ready_groups(self) -> int:
        return len(self._ready)

    def add(self, traj: Trajectory) -> None:
        group = self._groups.setdefault(traj.prompt_id, [])
        group.append(traj)
        self.added += 1
        if len(group) == self.group_size:
            self._ready.append(traj.prompt_id)
        while len(self) > self.capacity and self._ready:
            oldest = self._ready.pop(0)
            self.evicted += len(self._groups.pop(oldest))

    def take(self, n_groups: int, *, epoch: int,
             max_staleness: int) -> list[list[Trajectory]]:
        """Pop up to ``n_groups`` complete groups fresh enough to train on.

        Freshness is per trajectory: a group is consumable iff **every**
        member's ``weight_epoch`` stamp is within ``max_staleness`` of the
        latest published ``epoch`` (siblings may have been admitted by
        different workers under different applied epochs).  Stale groups are
        discarded and counted — the staleness bound is a hard guarantee, not
        a preference.
        """
        out: list[list[Trajectory]] = []
        keep: list[int] = []
        for pid in self._ready:
            group = self._groups[pid]
            if any(epoch - t.weight_epoch > max_staleness for t in group):
                self.stale_discards += len(group)
                del self._groups[pid]
            elif len(out) < n_groups:
                out.append(group)
                del self._groups[pid]
            else:
                keep.append(pid)
        self._ready = keep
        return out


class RolloutService:
    """A persistent, streaming rollout fleet behind a tiny four-call API.

    ``submit()`` new work (before or during the run), iterate ``stream()`` to
    receive FINISHED trajectories the instant they harvest, ``sync_weights()``
    to publish a new policy epoch in flight, ``close()`` to drain.  The fleet
    — real engines or the analytic twin — stays resident the whole time; KV
    caches, radix prefixes and controller state survive across what used to
    be iteration barriers.
    """

    def __init__(self, backend, controller, config, *,
                 faults: Optional[FaultPlan] = None):
        self.backend = backend
        self.controller = controller
        self.cfg = config  # a RuntimeConfig (scheduler/migration/knobs source)
        self.faults = faults
        self._initial: list[Trajectory] = []
        self._orch: Optional[Orchestrator] = None
        self._stream: Optional[Iterator[Trajectory]] = None
        self.result: Optional[OrchestratorResult] = None

    # ------------------------------------------------------------ introspection
    @property
    def now(self) -> float:
        """Current virtual instant (0.0 before the stream starts)."""
        return self._orch.now if self._orch is not None else 0.0

    @property
    def epoch(self) -> int:
        """Latest *published* weight epoch (what staleness is measured from)."""
        return self._orch.published_epoch if self._orch is not None else 0

    @property
    def applied_epochs(self) -> list[int]:
        """Per-worker applied epochs (lag published until residents drain)."""
        if self._orch is None:
            return [0] * self.backend.n_workers
        return list(self._orch.applied_epoch)

    # ------------------------------------------------------------ the four calls
    def submit(self, trajectories: Sequence[Trajectory],
               prompts: Optional[dict[int, list[int]]] = None) -> None:
        """Queue new trajectories; mid-run they arrive at the current instant.

        ``prompts`` maps traj_id -> token ids for the engine backend (the sim
        prices prompts from ``prompt_tokens``/``prompt_lens`` instead).
        """
        if prompts:
            if hasattr(self.backend, "prompts"):
                self.backend.prompts.update(prompts)
            elif getattr(self.backend, "prompt_lens", None) is not None:
                self.backend.prompt_lens.update(
                    {tid: len(toks) for tid, toks in prompts.items()})
        if self._orch is None:
            self._initial.extend(trajectories)
        else:
            self._orch.inject(trajectories)

    def stream(self) -> Iterator[Trajectory]:
        """The harvest stream: yields each trajectory the moment it finishes.

        Lazily builds the orchestrator on first call; subsequent calls return
        the same generator, so consumers may break out, submit/sync, and
        resume iteration.
        """
        if self._stream is None:
            if not self._initial:
                raise ValueError("submit() work before opening the stream")
            cfg = self.cfg
            self._orch = Orchestrator(
                self.backend, self._initial,
                OrchestratorConfig(scheduler=cfg.scheduler,
                                   migration=cfg.migration,
                                   max_active=cfg.max_active,
                                   open_loop=True, stream_harvest=True,
                                   preemption_margin=cfg.preemption_margin,
                                   preemption_floor=cfg.preemption_floor,
                                   trace=cfg.trace, sanitize=cfg.sanitize),
                controller=self.controller, faults=self.faults)
            self._stream = self._orch.run_stream()
        return self._stream

    def sync_weights(self, params=None, *, at: Optional[float] = None) -> int:
        """Publish a new weight epoch in flight; returns the epoch number.

        ``at`` (virtual seconds, >= now) models training latency — the sync
        starts cutting workers over only when its heap event pops.  Workers
        adopt the epoch individually as their residents drain; nothing decoding
        is ever destroyed (``reset_cache`` fires only on drained workers).
        """
        if self._orch is None:
            raise RuntimeError("sync_weights() before stream(): no run yet")
        return self._orch.publish_weights(params, at=at)

    def close(self) -> OrchestratorResult:
        """Drain the stream (every submitted trajectory finishes or sheds)
        and return the run's :class:`OrchestratorResult`."""
        for _ in self.stream():
            pass
        self.result = self._orch._result
        return self.result


def service_on_sim(predictor, n_workers: int = 2, config=None,
                   **kwargs) -> RolloutService:
    """A :class:`RolloutService` on the analytic twin — no model, no engine.

    Same wiring as :func:`repro.engine.runtime.run_on_sim` (controller +
    engine-parity ``SimBackend``), wrapped as a streaming service.  Keyword
    arguments pass through to ``make_sim_components`` (``fleet``,
    ``prompt_lens``, ``faults``, ``serving``, ...).
    """
    from repro.engine.runtime import RuntimeConfig, make_sim_components

    cfg = config if config is not None else RuntimeConfig()
    faults = kwargs.get("faults")
    backend, controller = make_sim_components(predictor, n_workers, cfg, **kwargs)
    return RolloutService(backend, controller, cfg, faults=faults)


__all__ = [
    "ReplayBuffer",
    "RolloutService",
    "ServiceConfig",
    "service_on_sim",
]
