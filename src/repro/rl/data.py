"""Synthetic agentic task for the end-to-end driver (token-level, tool-in-the-loop).

A tiny "math agent" over a reduced vocab: the prompt encodes two operands; the agent may
emit TOOL_CALL, which invokes a calculator tool that appends the sum token to the
context; reward is 1 when the final ANSWER token matches the ground truth.  This gives
the real engine + GRPO loop genuine multi-step agentic semantics (LLM generation
interleaved with tool execution) at CPU scale.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# special tokens (vocab >= 512 in reduced configs)
PAD, BOS, TOOL_CALL, ANSWER, EOS = 0, 1, 2, 3, 4
NUM_BASE = 16            # numbers are encoded as NUM_BASE + value
MAX_VAL = 200


@dataclass(frozen=True)
class MathTask:
    a: int
    b: int

    @property
    def answer(self) -> int:
        return (self.a + self.b) % MAX_VAL

    def prompt_tokens(self) -> list[int]:
        return [BOS, NUM_BASE + self.a, NUM_BASE + self.b, ANSWER]

    def tool_result_tokens(self) -> list[int]:
        return [NUM_BASE + self.answer]

    def reward(self, generated: list[int]) -> float:
        """Shaped reward: 1.0 for producing the answer token, 0.25 for at least
        invoking the tool (dense early signal for the tiny e2e driver)."""
        target = NUM_BASE + self.answer
        if target in generated:
            return 1.0
        return 0.25 if TOOL_CALL in generated else 0.0


def sample_tasks(n: int, seed: int = 0) -> list[MathTask]:
    rng = np.random.default_rng(seed)
    return [MathTask(int(rng.integers(0, MAX_VAL // 2)), int(rng.integers(0, MAX_VAL // 2)))
            for _ in range(n)]


def pad_batch(token_lists: list[list[int]], prompt_lens: list[int], max_len: int
              ) -> tuple[np.ndarray, np.ndarray]:
    """Right-pad to (B, max_len); loss mask covers response tokens only."""
    B = len(token_lists)
    tokens = np.full((B, max_len), PAD, np.int32)
    mask = np.zeros((B, max_len), np.float32)
    for i, (toks, plen) in enumerate(zip(token_lists, prompt_lens)):
        toks = toks[:max_len]
        tokens[i, :len(toks)] = toks
        # next-token convention: position t predicts token t+1, so response tokens
        # (from plen onward) are supervised at positions plen-1 .. len-2
        mask[i, max(plen - 1, 0):max(len(toks) - 1, 0)] = 1.0
    return tokens, mask
