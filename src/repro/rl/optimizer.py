"""AdamW optimizer (pure-pytree, dependency-free)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    # f32 by default; the production dry-runs use bf16 moments so arctic-class models
    # fit a 256-chip pod (DESIGN.md §8) — moments are sharded exactly like params.
    moment_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        mdt = jnp.dtype(self.moment_dtype)
        def zeros():
            return jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros(), zeros())

    def update(self, grads, state: AdamWState, params):
        if self.grad_clip:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mdt = jnp.dtype(self.moment_dtype)
        mu = jax.tree.map(lambda m, g: (b1 * m.astype(jnp.float32)
                                        + (1 - b1) * g.astype(jnp.float32)).astype(mdt),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: (b2 * v.astype(jnp.float32)
                                        + (1 - b2) * jnp.square(g.astype(jnp.float32))
                                        ).astype(mdt),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step, mu, nu)
