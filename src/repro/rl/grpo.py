"""GRPO (Group Relative Policy Optimization) — the paper's training-phase algorithm.

The rollout phase (Heddle's target) produces groups of trajectories per prompt; GRPO
normalizes rewards within each group into advantages and optimizes the clipped
policy-ratio objective.  ``train_step`` is also what the multi-pod dry-run lowers for
``train_4k`` shapes.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rl.optimizer import AdamW, AdamWState

F32 = jnp.float32


def group_advantages(rewards: jax.Array, group_size: int) -> jax.Array:
    """GRPO advantage: per-group reward z-score.  rewards: (B,) with B % group == 0."""
    g = rewards.reshape(-1, group_size).astype(F32)
    mean = g.mean(axis=1, keepdims=True)
    std = g.std(axis=1, keepdims=True)
    return ((g - mean) / (std + 1e-6)).reshape(-1)


def token_logprobs(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """Log-prob of tokens[t+1] under logits[t] (next-token).  Shapes (B,S,V),(B,S).

    Computed as target_logit - logsumexp(logits): XLA fuses the reduction, so no full
    f32 log-softmax tensor is ever materialized (a multi-GiB saving at 150K vocabs)."""
    lse = jax.nn.logsumexp(logits.astype(F32), axis=-1)          # (B,S)
    tgt = tokens[:, 1:]
    tgt_logit = jnp.take_along_axis(logits[:, :-1], tgt[..., None], axis=-1)[..., 0]
    lp = tgt_logit.astype(F32) - lse[:, :-1]
    return jnp.pad(lp, ((0, 0), (0, 1)))            # (B,S), last position zero


def chunked_token_logprobs(cfg: ModelConfig, params, hidden: jax.Array,
                           tokens: jax.Array, chunk: int = 512) -> jax.Array:
    """Fused linear + cross-entropy over sequence chunks: the (chunk, V) logits tile is
    the only logits tensor that ever exists (forward AND backward via checkpointed scan
    body) — at 150K vocabs this replaces multi-GiB f32 log-softmax buffers."""
    head = params["tok_embed"].T if cfg.tie_embeddings else params["lm_head"]
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))           # predict t+1 from t
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    hc = hidden.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(_, args):
        xc, tg = args
        logits = xc @ head                                       # (B, chunk, V)
        lse = jax.nn.logsumexp(logits.astype(F32), axis=-1)
        tl = jnp.take_along_axis(logits, tg[..., None], axis=-1)[..., 0]
        return None, tl.astype(F32) - lse

    _, lp = jax.lax.scan(body, None, (hc, tc))                   # (nc, B, chunk)
    lp = lp.transpose(1, 0, 2).reshape(B, nc * chunk)[:, :S]
    return lp.at[:, -1].set(0.0)                                 # last position: no target


def policy_logprobs(cfg: ModelConfig, params, batch, remat: bool = False
                    ) -> tuple[jax.Array, jax.Array]:
    """(per-token logprobs, moe aux loss) without materializing full logits."""
    hidden, aux = M.forward_full(cfg, params, batch, remat=remat, return_hidden=True)
    return chunked_token_logprobs(cfg, params, hidden, batch["tokens"]), aux


@dataclass(frozen=True)
class GRPOConfig:
    clip_eps: float = 0.2
    kl_coef: float = 0.0                 # optional KL to reference (0 = DAPO-style off)
    aux_coef: float = 0.01               # MoE load-balance loss weight
    group_size: int = 16                 # samples per prompt (paper: 16)


def grpo_loss(cfg: ModelConfig, gcfg: GRPOConfig, params, batch) -> tuple[jax.Array, dict]:
    """batch: tokens (B,S) int32, loss_mask (B,S) f32 (1 on response tokens),
    advantages (B,) f32, old_logprobs (B,S) f32 (behavior policy), plus modality extras."""
    logp, aux = policy_logprobs(cfg, params, batch, remat=True)
    ratio = jnp.exp(logp - batch["old_logprobs"])
    adv = batch["advantages"][:, None].astype(F32)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1 - gcfg.clip_eps, 1 + gcfg.clip_eps) * adv
    mask = batch["loss_mask"].astype(F32)
    per_tok = -jnp.minimum(unclipped, clipped) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    pg_loss = per_tok.sum() / denom
    kl = ((logp - batch["old_logprobs"]) * mask).sum() / denom
    loss = pg_loss + gcfg.aux_coef * aux + gcfg.kl_coef * kl
    return loss, {"pg_loss": pg_loss, "aux_loss": aux, "approx_kl": kl}


def make_train_step(cfg: ModelConfig, gcfg: GRPOConfig | None = None,
                    opt: AdamW | None = None):
    """Jittable (params, opt_state, batch) -> (params', opt_state', metrics)."""
    gcfg = gcfg or GRPOConfig()
    opt = opt or AdamW()

    def train_step(params, opt_state: AdamWState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: grpo_loss(cfg, gcfg, p, batch), has_aux=True)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_lm_train_step(cfg: ModelConfig, opt: AdamW | None = None):
    """Plain next-token LM step (used by ablations and the quickstart example)."""
    opt = opt or AdamW()

    def loss_fn(params, batch):
        logp, aux = policy_logprobs(cfg, params, batch)
        mask = batch.get("loss_mask")
        mask = jnp.ones_like(logp) if mask is None else mask.astype(F32)
        loss = -(logp * mask).sum() / jnp.maximum(mask.sum(), 1.0) + 0.01 * aux
        return loss

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return train_step
