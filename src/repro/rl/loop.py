"""End-to-end agentic RL training loop: Heddle-orchestrated rollout + GRPO updates.

Two rollout planes feed the same GRPO update (paper §2.2):

* **synchronous** (:meth:`HeddleTrainer.train` → :meth:`HeddleTrainer.rollout`)
  — groups of trajectories per prompt, executed on real RolloutWorkers with
  tool calls in the loop, driven by the unified orchestration stack
  (``core.orchestrator`` + ``engine.backends.EngineBackend`` via
  ``RolloutRuntime``): per-worker PPS queues, preemptive execution,
  progressive prediction refresh, prefix-affine placement and tool-interval
  migration — the same control plane the serving path runs, not a side-car
  loop.  Each iteration barriers on the batch makespan; weight sync is a
  bulk republish (``w.params = self.params`` + ``reset_cache()``) between
  iterations.
* **asynchronous** (:meth:`HeddleTrainer.train_async`, docs/training.md) —
  a persistent :class:`~repro.rl.service.RolloutService` streams FINISHED
  trajectories into a bounded :class:`~repro.rl.service.ReplayBuffer` while
  the tail is still decoding; GRPO consumes partial batches of complete,
  at-most-``max_staleness``-epochs-old groups, and each update publishes an
  *in-flight* weight sync — workers cut over individually once their
  resident lanes drain, so every trajectory finishes on the policy that
  admitted it (the ``Trajectory.weight_epoch`` stamp).

Both planes share inference (old-policy logprobs) and the GRPO train step,
and both close the rollout→predictor feedback loop the way the paper harvests
history: finished trajectories are appended to a bounded history and the
``ProgressivePredictor`` is refit on it, so scheduler priorities sharpen as
training progresses (cold start uses a budget prior).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.controller import HeddleConfig, HeddleController
from repro.core.placement import InterferenceModel
from repro.core.predictor import ProgressivePredictor
from repro.core.resource_manager import WorkerLatencyModel
from repro.core.trajectory import Trajectory
from repro.engine.runtime import (
    RolloutRuntime,
    RuntimeConfig,
    RuntimeResult,
    ToolEnvironment,
    ToolResult,
)
from repro.engine.sampler import SamplerConfig
from repro.engine.tools import TOOL_PROFILES
from repro.engine.worker import RolloutWorker
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rl import data as D
from repro.rl.grpo import GRPOConfig, group_advantages, make_train_step, token_logprobs
from repro.rl.optimizer import AdamW


@dataclass
class RolloutRecord:
    tokens: list[int]
    prompt_len: int
    reward: float
    steps: int


@dataclass
class TrainerConfig:
    group_size: int = 4
    n_workers: int = 2
    max_steps_per_traj: int = 3  # agentic steps (gen -> tool -> gen ...)
    gen_tokens_per_step: int = 8
    max_seq: int = 64
    capacity: int = 96
    lr: float = 5e-4
    seed: int = 0
    # orchestration (the rollout phase runs the full Heddle control plane)
    scheduler: str = "pps"
    max_active: int = 2  # decode-concurrency slots per worker
    quantum: int = 4  # decode tokens per scheduling quantum
    migration: bool = True  # tool-interval KV migration (§5.3)
    token_time: float = 0.02  # virtual s/token (scheduling clock)
    history_cap: int = 512  # finished trajectories kept for refits


class _PriorPredictor:
    """Cold-start prior: a budget-sized total until any rollout history exists."""

    def __init__(self, total_budget: float):
        self.total = float(total_budget)

    def predict(self, traj: Trajectory) -> float:
        return max(self.total - traj.tokens_generated, 0.0)


class TaskEnvironment(ToolEnvironment):
    """Plan-less environment adapter: real task episodes under the orchestrator.

    Terminality and tool outcomes come from the *task*, not a pre-rolled plan:
    the episode ends on EOS, step budget exhaustion, or context-limit pressure;
    a TOOL_CALL token triggers the task's tool (the calculator result tokens,
    teacher-forced into the lane), with latency sampled from the task domain's
    ``ToolProfile`` seeded per ``(traj, step)`` — identical for the same
    trajectory under any backend or scheduling order.  Finished episodes are
    collected as ``RolloutRecord``s for the GRPO update.
    """

    def __init__(
        self,
        tasks: dict[int, D.MathTask],
        prompt_lens: dict[int, int],
        *,
        max_steps: int,
        max_seq: int,
        seed: int = 0,
    ):
        super().__init__(seed=seed, profile=TOOL_PROFILES["math"])
        self.tasks = tasks
        self.prompt_lens = prompt_lens
        self.max_steps = max_steps
        self.max_seq = max_seq
        self.records: dict[int, RolloutRecord] = {}

    def add_task(self, tid: int, task: D.MathTask, prompt_len: int) -> None:
        """Register a task mid-run (the async service injects work as it goes)."""
        self.tasks[tid] = task
        self.prompt_lens[tid] = prompt_len

    def step_outcome(
        self, traj: Trajectory, step: int, gen_tokens: list[int], context: list[int]
    ) -> ToolResult:
        tid = traj.traj_id
        task = self.tasks[tid]
        finished = (
            D.EOS in gen_tokens
            or step + 1 >= self.max_steps
            or len(context) >= self.max_seq - 8
        )
        if finished:
            plen = self.prompt_lens[tid]
            self.records[tid] = RolloutRecord(
                list(context), plen, task.reward(list(context[plen:])), step + 1
            )
            return ToolResult(0.0, False, [], terminal=True)
        if D.TOOL_CALL in gen_tokens:
            lat = self.sample_latency(tid, step)
            self.invocations += 1
            self.total_latency += lat
            # calculator returns the sum token (masked from loss via
            # teacher-forced extend; context grows, trajectory continues)
            return ToolResult(lat, False, task.tool_result_tokens())
        # no tool call: the trajectory thinks on — zero-latency requeue keeps
        # it flowing through the scheduler like any other step boundary
        return ToolResult(0.0, False, [])


class HeddleTrainer:
    """Small-scale but fully real: JAX model, tool loop, Heddle orchestration, GRPO."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig = TrainerConfig()):
        self.cfg = cfg
        self.tcfg = tcfg
        key = jax.random.PRNGKey(tcfg.seed)
        self.params = M.init_params(cfg, key)
        self.opt = AdamW(lr=tcfg.lr)
        self.opt_state = self.opt.init(self.params)
        grpo_cfg = GRPOConfig(group_size=tcfg.group_size)
        self.train_step = jax.jit(make_train_step(cfg, grpo_cfg, self.opt))
        step_budget_total = tcfg.max_steps_per_traj * tcfg.gen_tokens_per_step
        self.predictor = _PriorPredictor(step_budget_total)
        self.controller = HeddleController(
            self.predictor,
            InterferenceModel.analytic(0.02),
            WorkerLatencyModel(t1=tcfg.token_time),
            gpu_budget=tcfg.n_workers,
            config=HeddleConfig(
                scheduler=tcfg.scheduler,
                adaptive_resources=False,
                migration=tcfg.migration,
                migration_load_gap=1,
                migration_cooldown_steps=1,
                rank_hysteresis=0.2,
            ),
            max_workers=tcfg.n_workers,
        )
        self.workers = [
            RolloutWorker(
                cfg,
                self.params,
                capacity=tcfg.capacity,
                worker_id=i,
                sampler=SamplerConfig(temperature=1.0, top_p=0.95),
                seed=tcfg.seed,
            )
            for i in range(tcfg.n_workers)
        ]
        self._history: list[Trajectory] = []
        # instance-local trajectory-id base: ids seed per-(traj, step) tool
        # outcomes, so drawing them from the process-global counter would make
        # rollout behavior depend on whatever else ran in this process
        self._tid_base = 0
        self._pid_base = 0  # async plane: prompt ids unique across a service run
        self.last_rollout: RuntimeResult | None = None
        self.step_count = 0

    # ------------------------------------------------------------------ rollout
    def rollout(self, tasks: list[D.MathTask]) -> list[RolloutRecord]:
        tcfg = self.tcfg
        for w in self.workers:
            w.params = self.params  # weight sync (colocated update)
            # drop resident AND retired KV: stale-weight prefixes must never
            # be implanted into post-update admissions
            w.reset_cache()
        trajs: list[Trajectory] = []
        prompts: dict[int, list[int]] = {}
        tasks_by: dict[int, D.MathTask] = {}
        for pid, task in enumerate(tasks):
            ptoks = task.prompt_tokens()
            for g in range(tcfg.group_size):
                t = Trajectory(
                    traj_id=self._tid_base + len(trajs),
                    prompt_id=pid,
                    sample_id=g,
                    prompt_tokens=len(ptoks),
                    context_tokens=len(ptoks),
                )
                trajs.append(t)
                prompts[t.traj_id] = list(ptoks)
                tasks_by[t.traj_id] = task
        self._tid_base += len(trajs)
        env = TaskEnvironment(
            tasks_by,
            {tid: len(p) for tid, p in prompts.items()},
            max_steps=tcfg.max_steps_per_traj,
            max_seq=tcfg.max_seq,
            seed=tcfg.seed,
        )
        rcfg = RuntimeConfig(
            scheduler=tcfg.scheduler,
            migration=tcfg.migration,
            max_active=tcfg.max_active,
            quantum=tcfg.quantum,
            token_time=tcfg.token_time,
            seed=tcfg.seed,
        )
        runtime = RolloutRuntime(
            self.workers,
            self.controller,
            trajs,
            env,
            rcfg,
            prompts=prompts,
            stop_token=D.EOS,
            step_budget=lambda t: tcfg.gen_tokens_per_step,
        )
        self.last_rollout = runtime.run()
        self._refit_predictor(trajs)
        return [env.records[t.traj_id] for t in trajs]

    def _refit_predictor(self, trajectories: list[Trajectory]) -> None:
        """Close the §4.1 loop: harvest this rollout, refit, sharpen priorities."""
        for t in trajectories:
            t.true_total_tokens = t.tokens_generated
            t.true_num_steps = t.num_steps
        self._history.extend(trajectories)
        excess = len(self._history) - self.tcfg.history_cap
        if excess > 0:
            del self._history[:excess]
        if len(self._history) >= 2 * self.tcfg.group_size:
            self.predictor = ProgressivePredictor().fit_trajectories(self._history)
            self.controller.predictor = self.predictor

    # ------------------------------------------------------------------ update
    def update(self, records: list[RolloutRecord]) -> dict:
        tcfg = self.tcfg
        tokens, mask = D.pad_batch(
            [r.tokens for r in records],
            [r.prompt_len for r in records],
            tcfg.max_seq,
        )
        rewards = jnp.asarray([r.reward for r in records], jnp.float32)
        adv = group_advantages(rewards, tcfg.group_size)
        batch = {
            "tokens": jnp.asarray(tokens),
            "loss_mask": jnp.asarray(mask),
            "advantages": adv,
        }
        # old-policy logprobs (inference phase)
        logits, _ = M.forward_full(self.cfg, self.params, {"tokens": batch["tokens"]})
        old_logprobs = token_logprobs(logits, batch["tokens"])
        batch["old_logprobs"] = jax.lax.stop_gradient(old_logprobs)
        self.params, self.opt_state, metrics = self.train_step(
            self.params, self.opt_state, batch
        )
        self.step_count += 1
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["mean_reward"] = float(rewards.mean())
        if self.last_rollout is not None:
            metrics["rollout_preemptions"] = float(self.last_rollout.preemptions)
            metrics["rollout_migrations"] = float(self.last_rollout.migrations)
            metrics["rollout_queue_delay_mean"] = self.last_rollout.queue_delay_mean
        return metrics

    def train(
        self, n_iterations: int, tasks_per_iter: int = 4, seed: int = 0
    ) -> list[dict]:
        history = []
        for it in range(n_iterations):
            tasks = D.sample_tasks(tasks_per_iter, seed=seed + it)
            records = self.rollout(tasks)
            metrics = self.update(records)
            history.append(metrics)
        return history

    # ------------------------------------------------------------------ async
    def _spawn_group(
        self, task: D.MathTask, env: TaskEnvironment
    ) -> tuple[list[Trajectory], dict[int, list[int]]]:
        """One GRPO group for ``task``: fresh trajectory + prompt ids, the task
        registered with the persistent environment."""
        tcfg = self.tcfg
        pid = self._pid_base
        self._pid_base += 1
        ptoks = task.prompt_tokens()
        group: list[Trajectory] = []
        prompts: dict[int, list[int]] = {}
        for g in range(tcfg.group_size):
            t = Trajectory(
                traj_id=self._tid_base,
                prompt_id=pid,
                sample_id=g,
                prompt_tokens=len(ptoks),
                context_tokens=len(ptoks),
            )
            self._tid_base += 1
            group.append(t)
            prompts[t.traj_id] = list(ptoks)
            env.add_task(t.traj_id, task, len(ptoks))
        return group, prompts

    def train_async(
        self,
        n_updates: int,
        *,
        groups_per_update: int = 2,
        max_staleness: int = 1,
        backlog_groups: int = 4,
        replay_capacity: int = 64,
        seed: int = 0,
    ) -> list[dict]:
        """Asynchronous training: rollout-as-a-service + staleness-bounded GRPO.

        One persistent fleet streams finished trajectories while the tail is
        still decoding; an update fires as soon as at least one complete,
        fresh-enough group is buffered (a *partial* batch of up to
        ``groups_per_update`` groups), then publishes an in-flight weight sync
        and submits replacement groups to keep the backlog fed.  No update
        ever consumes a trajectory more than ``max_staleness`` epochs older
        than the latest published weights — stale groups are discarded by the
        replay buffer, not trained on.  Returns per-update metrics
        (``staleness``, ``groups_consumed``, ``weight_epoch`` included).
        """
        from repro.rl.service import ReplayBuffer, RolloutService

        tcfg = self.tcfg
        self.last_rollout = None  # sync-plane telemetry must not leak in
        for w in self.workers:
            w.params = self.params  # epoch-0 policy, cold caches
            w.reset_cache()
        env = TaskEnvironment(
            {},
            {},
            max_steps=tcfg.max_steps_per_traj,
            max_seq=tcfg.max_seq,
            seed=tcfg.seed,
        )
        rcfg = RuntimeConfig(
            scheduler=tcfg.scheduler,
            migration=tcfg.migration,
            max_active=tcfg.max_active,
            quantum=tcfg.quantum,
            token_time=tcfg.token_time,
            seed=tcfg.seed,
        )
        spawned = 0
        trajs: list[Trajectory] = []
        prompts: dict[int, list[int]] = {}
        for _ in range(backlog_groups):
            task = D.sample_tasks(1, seed=seed + 10_000 + spawned)[0]
            spawned += 1
            group, p = self._spawn_group(task, env)
            trajs.extend(group)
            prompts.update(p)
        # RolloutRuntime wires the engine backend (pricing, env, prompts) the
        # one sanctioned way; the service then drives the orchestrator itself
        runtime = RolloutRuntime(
            self.workers,
            self.controller,
            trajs,
            env,
            rcfg,
            prompts=prompts,
            stop_token=D.EOS,
            step_budget=lambda t: tcfg.gen_tokens_per_step,
        )
        svc = RolloutService(runtime.backend, self.controller, rcfg)
        svc.submit(trajs)
        buffer = ReplayBuffer(replay_capacity, tcfg.group_size)
        history: list[dict] = []
        for traj in svc.stream():
            buffer.add(traj)
            if len(history) >= n_updates:
                continue  # target reached: drain the stragglers untrained
            groups = buffer.take(
                groups_per_update, epoch=svc.epoch, max_staleness=max_staleness
            )
            if not groups:
                continue
            records = [env.records[t.traj_id] for g in groups for t in g]
            staleness = max(
                svc.epoch - t.weight_epoch for g in groups for t in g
            )
            metrics = self.update(records)
            metrics["groups_consumed"] = float(len(groups))
            metrics["staleness"] = float(staleness)
            history.append(metrics)
            if len(history) < n_updates:
                # in-flight sync: residents finish on their admitted policy
                metrics["weight_epoch"] = float(svc.sync_weights(self.params))
                for _ in range(len(groups)):  # keep the backlog fed
                    task = D.sample_tasks(1, seed=seed + 10_000 + spawned)[0]
                    spawned += 1
                    group, p = self._spawn_group(task, env)
                    svc.submit(group, p)
        res = svc.close()
        self._refit_predictor(res.trajectories)
        return history
