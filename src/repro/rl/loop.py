"""End-to-end agentic RL training loop: Heddle-orchestrated rollout + GRPO updates.

One training step (paper §2.2):
  1. rollout — groups of trajectories per prompt, executed on real RolloutWorkers with
     tool calls in the loop, placed/scheduled by the Heddle controller;
  2. inference — old-policy logprobs over the collected trajectories;
  3. training — GRPO update on the policy.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import InterferenceModel, place
from repro.engine.sampler import SamplerConfig
from repro.engine.worker import RolloutWorker
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rl import data as D
from repro.rl.grpo import GRPOConfig, group_advantages, make_train_step, token_logprobs
from repro.rl.optimizer import AdamW


@dataclass
class RolloutRecord:
    tokens: list[int]
    prompt_len: int
    reward: float
    steps: int


@dataclass
class TrainerConfig:
    group_size: int = 4
    n_workers: int = 2
    max_steps_per_traj: int = 3          # agentic steps (gen -> tool -> gen ...)
    gen_tokens_per_step: int = 8
    max_seq: int = 64
    capacity: int = 96
    lr: float = 5e-4
    seed: int = 0


class HeddleTrainer:
    """Small-scale but fully real: JAX model, tool loop, Heddle placement, GRPO."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig = TrainerConfig()):
        self.cfg = cfg
        self.tcfg = tcfg
        key = jax.random.PRNGKey(tcfg.seed)
        self.params = M.init_params(cfg, key)
        self.opt = AdamW(lr=tcfg.lr)
        self.opt_state = self.opt.init(self.params)
        self.train_step = jax.jit(make_train_step(cfg, GRPOConfig(
            group_size=tcfg.group_size), self.opt))
        self.interference = InterferenceModel.analytic(0.02)
        self.workers = [
            RolloutWorker(cfg, self.params, capacity=tcfg.capacity, worker_id=i,
                          sampler=SamplerConfig(temperature=1.0, top_p=0.95),
                          seed=tcfg.seed)
            for i in range(tcfg.n_workers)
        ]
        self.step_count = 0

    # ------------------------------------------------------------------ rollout
    def rollout(self, tasks: list[D.MathTask]) -> list[RolloutRecord]:
        tcfg = self.tcfg
        for w in self.workers:
            w.params = self.params                     # weight sync (colocated update)
            w.reset_cache()      # drop resident AND retired KV: stale-weight prefixes
                                 # must never be implanted into post-update admissions
        # trajectory-aware placement: predicted length ~ prompt length heuristic at t=0
        # (group_size samples per task, placed by the presorted DP)
        n = len(tasks) * tcfg.group_size
        lengths = [float(tcfg.max_steps_per_traj * tcfg.gen_tokens_per_step)] * n
        placement = place(lengths, len(self.workers), self.interference)
        assignment = np.zeros(n, int)
        for wid, group in enumerate(placement.groups):
            for idx in group:
                assignment[idx] = wid

        records: list[RolloutRecord] = []
        sid = 0
        live: list[tuple[int, D.MathTask, int, int]] = []   # (seq_id, task, worker, steps)
        for task in tasks:
            for g in range(tcfg.group_size):
                wid = int(assignment[sid])
                self.workers[wid].prefill(sid, task.prompt_tokens())
                live.append((sid, task, wid, 0))
                sid += 1

        prompt_lens = {s: len(t.prompt_tokens()) for s, t, _, _ in
                       [(x[0], x[1], x[2], x[3]) for x in live]}
        done: dict[int, RolloutRecord] = {}
        for agent_step in range(tcfg.max_steps_per_traj):
            next_live = []
            by_worker: dict[int, list[int]] = {}
            for s, task, wid, steps in live:
                by_worker.setdefault(wid, []).append(s)
            gen_out: dict[int, list[int]] = {}
            for wid, seqs in by_worker.items():
                gen_out.update(self.workers[wid].decode(seqs, tcfg.gen_tokens_per_step,
                                                        stop_token=D.EOS))
            for s, task, wid, steps in live:
                gen = gen_out.get(s, [])
                seq = self.workers[wid].store[s]
                finished = (D.EOS in gen) or (agent_step == tcfg.max_steps_per_traj - 1) \
                    or len(seq.tokens) >= tcfg.max_seq - 8
                if D.TOOL_CALL in gen and not finished:
                    # tool interval: calculator returns the sum token (masked from loss
                    # via teacher-forced extend; context grows, trajectory continues)
                    self.workers[wid].extend(s, task.tool_result_tokens())
                    next_live.append((s, task, wid, steps + 1))
                elif finished:
                    reward = task.reward(seq.tokens[prompt_lens[s]:])
                    done[s] = RolloutRecord(list(seq.tokens), prompt_lens[s], reward,
                                            steps + 1)
                    self.workers[wid].release(s)
                else:
                    next_live.append((s, task, wid, steps + 1))
            live = next_live
            if not live:
                break
        for s, task, wid, steps in live:
            seq = self.workers[wid].store[s]
            done[s] = RolloutRecord(list(seq.tokens), prompt_lens[s],
                                    task.reward(seq.tokens[prompt_lens[s]:]), steps)
            self.workers[wid].release(s)
        return [done[s] for s in sorted(done)]

    # ------------------------------------------------------------------ update
    def update(self, records: list[RolloutRecord]) -> dict:
        tcfg = self.tcfg
        tokens, mask = D.pad_batch([r.tokens for r in records],
                                   [r.prompt_len for r in records], tcfg.max_seq)
        rewards = jnp.asarray([r.reward for r in records], jnp.float32)
        adv = group_advantages(rewards, tcfg.group_size)
        batch = {"tokens": jnp.asarray(tokens), "loss_mask": jnp.asarray(mask),
                 "advantages": adv}
        # old-policy logprobs (inference phase)
        logits, _ = M.forward_full(self.cfg, self.params, {"tokens": batch["tokens"]})
        batch["old_logprobs"] = jax.lax.stop_gradient(
            token_logprobs(logits, batch["tokens"]))
        self.params, self.opt_state, metrics = self.train_step(
            self.params, self.opt_state, batch)
        self.step_count += 1
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["mean_reward"] = float(rewards.mean())
        return metrics

    def train(self, n_iterations: int, tasks_per_iter: int = 4, seed: int = 0) -> list[dict]:
        history = []
        for it in range(n_iterations):
            tasks = D.sample_tasks(tasks_per_iter, seed=seed + it)
            records = self.rollout(tasks)
            metrics = self.update(records)
            history.append(metrics)
        return history
