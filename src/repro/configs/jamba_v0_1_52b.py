"""Jamba-v0.1-52B [arXiv:2403.19887] — hybrid Mamba+attention 1:7 interleave, MoE.

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=65536; MoE 16 experts top-2 on
every other layer.  Period of 8 = {7 mamba, 1 attn}, MoE at odd positions (16 MoE layers).
Runs long_500k with SSM state + a sliding window applied to its 4 attention layers
(set by configs.combos for that shape, matching production hybrid long-context practice).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", arch_type="hybrid",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536,
    block_pattern=(
        "mamba+mlp", "mamba+moe", "mamba+mlp", "attn+moe",
        "mamba+mlp", "mamba+moe", "mamba+mlp", "mamba+moe",
    ),
    n_periods=4,
    activation="swiglu",
    n_experts=16, top_k=2, moe_d_ff=14336,
    ssm_state_dim=16, ssm_conv_width=4, ssm_expand=2,
)
