"""xLSTM-350M [arXiv:2405.04517] — sLSTM + mLSTM blocks, no separate MLP (d_ff=0).

24L, d_model=1024, 4 heads, vocab=50304.  Period of 6 = {5 mLSTM, 1 sLSTM}.  Matrix /
scalar recurrent memories -> O(1) decode state, runs long_500k natively.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", arch_type="ssm",
    d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
    n_periods=4,
    xlstm_expand=2,
)
