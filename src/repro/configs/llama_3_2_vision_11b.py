"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision] — cross-attn image layers.

40L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=128256; every 5th layer is a
gated image cross-attention layer (8 total).  The ViT vision encoder is STUBBED:
input_specs provides precomputed (B, 1600, d_model) patch embeddings fed through a
learned projector.  long_500k runs via the sliding-window self-attention variant.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", arch_type="vlm",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256,
    block_pattern=("attn+mlp", "attn+mlp", "attn+mlp", "attn+mlp", "xattn+mlp"),
    n_periods=8,
    activation="swiglu",
    image_seq=1600,
    # collective-bound under SP (§Perf pair b): residuals stay replicated-S
    sequence_parallel=False,
)
