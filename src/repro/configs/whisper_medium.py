"""Whisper-medium [arXiv:2212.04356] — encoder-decoder audio backbone.

24+24L, d_model=1024, 16 heads (MHA), d_ff=4096, vocab=51865, GELU, sinusoidal positions.
The mel-spectrogram + conv frontend is STUBBED: input_specs provides precomputed
(B, 1500, d_model) frame embeddings.  long_500k is SKIPPED (bounded decoder context is
intrinsic to the enc-dec design) — DESIGN.md §5.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", arch_type="audio",
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51865,
    block_pattern=("dec+mlp",), n_periods=24,
    encoder_layers=24, encoder_seq=1500,
    activation="gelu", norm="layernorm",
)
