"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family] — dense, qk-norm, GQA.

28L, d_model=2048, 16 heads (GQA kv=8, head_dim=128), d_ff=6144, vocab=151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", arch_type="dense",
    d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab=151936,
    block_pattern=("attn+mlp",), n_periods=28,
    activation="swiglu", qk_norm=True, tie_embeddings=True,
    rope_theta=1_000_000.0,
)
