"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small dense.

30L, d_model=576, 9 heads (GQA kv=3, head_dim=64), d_ff=1536, vocab=49152, SwiGLU, tied
embeddings.  9 heads / kv=3 do not divide a 16-way model axis -> attention replicates on
"model" while MLP (1536) and vocab (49152) shard (DESIGN.md §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", arch_type="dense",
    d_model=576, n_heads=9, n_kv_heads=3, head_dim=64,
    d_ff=1536, vocab=49152,
    block_pattern=("attn+mlp",), n_periods=30,
    activation="swiglu", tie_embeddings=True,
)
