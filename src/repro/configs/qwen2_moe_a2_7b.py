"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed experts top-4 + 4 shared.

24L, d_model=2048, 16 heads (kv=16, MHA), per-expert d_ff=1408, vocab=151936.  The 4
shared experts are fused into one 5632-wide MLP with a sigmoid gate (as in the HF impl).
60 experts do not divide a 16-way model axis -> expert weights replicate; see
EXPERIMENTS.md §Perf for the pad-to-64 expert-parallel variant.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", arch_type="moe",
    d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=151936,
    block_pattern=("attn+moe",), n_periods=24,
    activation="swiglu",
    n_experts=60, top_k=4, moe_d_ff=1408, shared_d_ff=5632,
)
