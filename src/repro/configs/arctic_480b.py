"""Snowflake Arctic-480B [hf:Snowflake/snowflake-arctic-base] — 128 experts top-2 +
parallel dense residual MLP.

35L, d_model=7168, 56 heads (GQA kv=8, head_dim=128), per-expert d_ff=4864, vocab=32000.
Every layer: MoE (128e, top-2) in parallel with a dense residual SwiGLU MLP.
128 experts shard cleanly over the 16-way model axis (8 experts/chip).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", arch_type="moe",
    d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab=32000,
    block_pattern=("attn+moe_dr",), n_periods=35,
    activation="swiglu",
    n_experts=128, top_k=2, moe_d_ff=4864, dense_residual_ff=4864,
)
