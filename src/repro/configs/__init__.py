"""Assigned-architecture registry: one module per architecture (+ paper's own models).

Every config cites its source model card / paper.  ``get_config(name)`` returns the full
production config; ``get_config(name).reduced()`` is the CPU smoke variant.
"""

from __future__ import annotations

import importlib

from repro.models.config import (INPUT_SHAPES, LONG_CONTEXT_WINDOW, InputShape,
                                 ModelConfig)

ARCHITECTURES = (
    "smollm_135m",
    "nemotron_4_15b",
    "phi3_medium_14b",
    "jamba_v0_1_52b",
    "qwen2_moe_a2_7b",
    "xlstm_350m",
    "whisper_medium",
    "llama_3_2_vision_11b",
    "qwen3_1_7b",
    "arctic_480b",
)

# canonical ids as assigned (dashes) -> module names
_ALIASES = {a.replace("_", "-"): a for a in ARCHITECTURES}
_ALIASES.update({
    "smollm-135m": "smollm_135m",
    "nemotron-4-15b": "nemotron_4_15b",
    "phi3-medium-14b": "phi3_medium_14b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "xlstm-350m": "xlstm_350m",
    "whisper-medium": "whisper_medium",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "qwen3-1.7b": "qwen3_1_7b",
    "arctic-480b": "arctic_480b",
})


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHITECTURES}


def combos(include_skipped: bool = False):
    """All assigned (arch, shape) combinations with the documented skips applied.

    Yields (arch_name, shape_name, config) — config already switched to the
    sliding-window variant for full-attention archs on long_500k (DESIGN.md §5).
    """
    for arch in ARCHITECTURES:
        cfg = get_config(arch)
        for shape_name, shape in INPUT_SHAPES.items():
            if shape_name == "long_500k":
                if cfg.arch_type == "audio":
                    if include_skipped:
                        yield arch, shape_name, None     # documented skip
                    continue
                if not cfg.is_subquadratic():
                    yield arch, shape_name, cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
                    continue
            yield arch, shape_name, cfg
