"""Phi-3-medium-14B [arXiv:2404.14219] — dense, RoPE + SwiGLU + GQA.

40L, d_model=5120, 40 heads (GQA kv=10, head_dim=128), d_ff=17920, vocab=100352.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", arch_type="dense",
    d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17920, vocab=100352,
    block_pattern=("attn+mlp",), n_periods=40,
    activation="swiglu",
)
