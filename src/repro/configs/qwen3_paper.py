"""Paper-faithful Qwen3 rollout configs (8B/14B/32B) used by §7 benchmarks [arXiv:2505.09388]."""
from repro.models.config import ModelConfig


def _qwen3(name, layers, d, heads, kv, ff):
    return ModelConfig(
        name=name, arch_type="dense",
        d_model=d, n_heads=heads, n_kv_heads=kv, head_dim=128,
        d_ff=ff, vocab=151936,
        block_pattern=("attn+mlp",), n_periods=layers,
        activation="swiglu", qk_norm=True, rope_theta=1_000_000.0,
    )


QWEN3_8B = _qwen3("qwen3-8b", 36, 4096, 32, 8, 12288)
QWEN3_14B = _qwen3("qwen3-14b", 40, 5120, 40, 8, 17408)
QWEN3_32B = _qwen3("qwen3-32b", 64, 5120, 64, 8, 25600)
