"""Nemotron-4-15B [arXiv:2402.16819] — dense, GQA, squared-ReLU MLP (non-gated).

32L, d_model=6144, 48 heads (GQA kv=8, head_dim=128), d_ff=24576, vocab=256000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", arch_type="dense",
    d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=256000,
    block_pattern=("attn+mlp",), n_periods=32,
    activation="relu2",
)
