"""Training launcher: agentic GRPO with Heddle-orchestrated rollout.

Local (real execution, reduced model on this host):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --iters 20

Production dry-run (lower + compile the full config for the pod mesh):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --dry-run [--multi-pod]
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--group-size", type=int, default=8)
    ap.add_argument("--tasks-per-iter", type=int, default=4)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--lr", type=float, default=8e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the FULL config for the production mesh instead "
                         "of training the reduced one locally")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    if args.dry_run:
        # delegate to the dry-run module (it must own process start: device count is
        # locked at first jax init)
        from repro.launch import dryrun
        dr_args = ["--arch", args.arch, "--shape", "train_4k"]
        if args.multi_pod:
            dr_args.append("--multi-pod")
        return dryrun.main(dr_args)

    from repro.checkpoint import checkpoint as ckpt
    from repro.configs import get_config
    from repro.rl import data as D
    from repro.rl.loop import HeddleTrainer, TrainerConfig

    cfg = get_config(args.arch).reduced(n_periods=2)
    trainer = HeddleTrainer(cfg, TrainerConfig(
        group_size=args.group_size, n_workers=args.workers, lr=args.lr,
        seed=args.seed))
    print(f"training {cfg.name} (reduced, {cfg.n_layers}L) — {args.iters} iterations, "
          f"{args.workers} workers, GRPO group {args.group_size}")
    t0 = time.time()
    for it in range(args.iters):
        tasks = D.sample_tasks(args.tasks_per_iter, seed=args.seed * 10_000 + it)
        records = trainer.rollout(tasks)
        metrics = trainer.update(records)
        print(f"iter {it+1:4d}  reward {metrics['mean_reward']:.3f}  "
              f"loss {metrics['loss']:+.4f}  kl {metrics['approx_kl']:+.4f}  "
              f"({time.time()-t0:5.1f}s)", flush=True)
        if args.checkpoint_dir and (it + 1) % args.checkpoint_every == 0:
            path = f"{args.checkpoint_dir}/step{it+1}"
            ckpt.save(path, trainer.params, step=it + 1)
            print(f"  checkpoint -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
