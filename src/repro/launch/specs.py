"""ShapeDtypeStruct input specs + shardings for every (arch x input-shape) combination.

This is the contract between the model zoo and the multi-pod dry-run: for each mode
(train / prefill / decode) it returns the jittable step function, the argument specs
(no device allocation — ShapeDtypeStruct only, weak-type-correct) and matching
NamedShardings derived from the logical-axis rules.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (cache_shardings, logical_pspec,
                                        param_shardings)
from repro.models import model as M
from repro.models.config import InputShape, ModelConfig
from repro.rl.grpo import GRPOConfig, grpo_loss
from repro.rl.optimizer import AdamW


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _named(mesh, *axes):
    return NamedSharding(mesh, P(*axes))


def _batch_sharding(mesh, shape, ndim):
    """Sharding for a (B, ...) tensor: batch over ("pod","data") when divisible."""
    dims = ["batch"] + [None] * (ndim - 1)
    return NamedSharding(mesh, logical_pspec(shape, dims, mesh))


def batch_specs(cfg: ModelConfig, shape: InputShape, mode: str, mesh) -> tuple[dict, dict]:
    """(specs, shardings) for the data batch of one step."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs: dict[str, Any] = {}
    if mode == "train":
        specs["tokens"] = _sds((B, S), jnp.int32)
        specs["loss_mask"] = _sds((B, S), jnp.float32)
        specs["advantages"] = _sds((B,), jnp.float32)
        specs["old_logprobs"] = _sds((B, S), jnp.float32)
    elif mode == "prefill":
        specs["tokens"] = _sds((B, S), jnp.int32)
    if cfg.arch_type == "audio":
        specs["encoder_embeds"] = _sds((B, cfg.encoder_seq, cfg.d_model), dt)
    if cfg.arch_type == "vlm":
        specs["image_embeds"] = _sds((B, cfg.image_seq, cfg.d_model), dt)
    shardings = {k: _batch_sharding(mesh, v.shape, v.ndim) for k, v in specs.items()}
    return specs, shardings


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(M.init_params, cfg),
                          jax.random.PRNGKey(0))


def make_optimizer(cfg: ModelConfig) -> AdamW:
    # bf16 moments: production choice so arctic-class optimizer state fits the pod
    return AdamW(lr=1e-4, moment_dtype="bfloat16" if cfg.dtype == "bfloat16"
                 else "float32")


def decode_capacity(cfg: ModelConfig, shape: InputShape) -> int:
    if cfg.sliding_window:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


def build(cfg: ModelConfig, shape: InputShape, mesh):
    """Returns (step_fn, args_specs (tuple), in_shardings (tuple)) for lowering.

    train   -> GRPO train_step(params, opt_state, batch)
    prefill -> forward_full(params, batch) with cache materialization
    decode  -> decode_step(params, cache, tokens) with a seq_len KV/state cache
    """
    mode = shape.mode
    pspecs = param_specs(cfg)
    pshard = param_shardings(pspecs, mesh)

    if mode == "train":
        opt = make_optimizer(cfg)
        ospecs = jax.eval_shape(opt.init, pspecs)
        opt_shard = type(ospecs)(NamedSharding(mesh, P()),
                                 param_shardings(ospecs.mu, mesh),
                                 param_shardings(ospecs.nu, mesh))
        bspecs, bshard = batch_specs(cfg, shape, mode, mesh)
        gcfg = GRPOConfig()

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: grpo_loss(cfg, gcfg, p, batch), has_aux=True)(params)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss

        return train_step, (pspecs, ospecs, bspecs), (pshard, opt_shard, bshard)

    if mode == "prefill":
        bspecs, bshard = batch_specs(cfg, shape, mode, mesh)
        capacity = decode_capacity(cfg, shape)

        def prefill_step(params, batch):
            logits, aux, cache = M.forward_full(cfg, params, batch, capacity=capacity)
            return logits[:, -1], cache

        return prefill_step, (pspecs, bspecs), (pshard, bshard)

    # ---- decode: serve_step over a seq_len-context cache ------------------------
    B = shape.global_batch
    capacity = decode_capacity(cfg, shape)
    enc_spec = None
    if cfg.arch_type == "audio":
        enc_spec = _sds((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    elif cfg.arch_type == "vlm":
        enc_spec = _sds((B, cfg.image_seq, cfg.d_model), cfg.dtype)

    def _cache():
        enc = (jnp.zeros(enc_spec.shape, enc_spec.dtype)
               if enc_spec is not None else None)
        return M.init_cache(cfg, None, B, capacity, enc_out=enc,
                            start_pos=shape.seq_len - 1)

    cspecs = jax.eval_shape(_cache)
    cshard = cache_shardings(cspecs, mesh)
    tok_spec = _sds((B, 1), jnp.int32)
    tok_shard = _batch_sharding(mesh, (B, 1), 2)

    def serve_step(params, cache, tokens):
        return M.decode_step(cfg, params, cache, tokens)

    return serve_step, (pspecs, cspecs, tok_spec), (pshard, cshard, tok_shard)
