import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape) combination on
# the production meshes — 16x16 single pod and 2x16x16 two-pod — using ShapeDtypeStruct
# inputs only (no allocation).  Prints memory_analysis / cost_analysis and records the
# roofline source terms (HLO FLOPs, HLO bytes, per-collective bytes) to a JSON file that
# benchmarks/roofline.py and EXPERIMENTS.md consume.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out FILE]

import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHITECTURES, combos, get_config
from repro.distributed.sharding import axis_rules
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models.config import INPUT_SHAPES, LONG_CONTEXT_WINDOW

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1,
                "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# effective bytes-on-the-wire multiplier per output byte (ring algorithms, N large)
_WIRE_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum operand/output bytes of every collective op in the (post-SPMD) HLO."""
    out: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    counts: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for coll in _COLLECTIVES:
            token = f" {coll}("
            if token in line or f" {coll}-start(" in line:
                lhs = line.split("=", 1)[0] if "=" in line else ""
                rhs_head = line.split("=", 1)[1].split("(", 1)[0] if "=" in line else line
                total = 0.0
                for dt, dims in _SHAPE_RE.findall(rhs_head):
                    if dt not in _DTYPE_BYTES:
                        continue
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    total += n * _DTYPE_BYTES[dt]
                out[coll] += total * _WIRE_MULT[coll]
                counts[coll] += 1
                break
    out["_counts"] = counts
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool = False, verbose: bool = True
            ) -> dict:
    """Lower + compile one combination; returns the roofline source record."""
    cfg = None
    for a, s, c in combos():
        if a == arch.replace("-", "_").replace(".", "_") or a == arch:
            if s == shape_name:
                cfg = c
                break
    if cfg is None:
        cfg = get_config(arch)
        if shape_name == "long_500k":
            if cfg.arch_type == "audio":
                return {"arch": arch, "shape": shape_name, "status": "skipped",
                        "reason": "encoder-decoder: bounded decoder context (DESIGN.md §5)"}
            if not cfg.is_subquadratic():
                cfg = cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name, "mode": shape.mode,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "chips": int(np.prod(mesh.devices.shape))}
    t0 = time.time()
    # (§Perf pair c, refuted iteration: donate_argnums on the decode cache RAISED the
    # static bytes-accessed metric 1.25x on the CPU backend — input-output aliasing is
    # still the right call on real TPUs, but it does not register in this proxy, so
    # the dry-run keeps donation off for metric comparability.)
    with mesh, axis_rules(mesh):
        fn, args, shardings = SP.build(cfg, shape, mesh)
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            rec[k] = int(getattr(mem, k, 0) or 0)
    if isinstance(cost, list):          # older XLA clients: one dict per partition
        cost = cost[0] if cost else None
    if cost:
        rec["hlo_flops"] = float(cost.get("flops", 0.0))
        rec["hlo_bytes"] = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes(text)
    rec["collective_counts"] = coll.pop("_counts")
    rec["collective_bytes"] = coll
    rec["collective_total_bytes"] = float(sum(coll.values()))
    rec["status"] = "ok"
    if verbose:
        print(f"[{rec['mesh']}] {arch:22s} {shape_name:12s} "
              f"lower={rec['lower_s']:6.1f}s compile={rec['compile_s']:6.1f}s "
              f"flops={rec.get('hlo_flops', 0):.3e} "
              f"coll={rec['collective_total_bytes']:.3e}B", flush=True)
        if mem is not None:
            print(f"    memory: args={rec.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"out={rec.get('output_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temp={rec.get('temp_size_in_bytes', 0)/2**30:.2f}GiB (per device)",
                  flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, choices=[*INPUT_SHAPES, None],
                    help="input shape (default: all)")
    ap.add_argument("--all", action="store_true", help="run every combination")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 two-pod mesh (default 16x16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSON records to this file")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCHITECTURES)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records, failed = [], []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    rec = run_one(arch, shape, multi_pod=mp)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "status": "failed",
                           "mesh": "2x16x16" if mp else "16x16", "error": str(e)[:2000]}
                    failed.append((arch, shape, mp))
                records.append(rec)
                if rec.get("status") == "skipped":
                    print(f"SKIP {arch} {shape}: {rec['reason']}")
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(records, f, indent=1)
    ok = sum(1 for r in records if r["status"] == "ok")
    sk = sum(1 for r in records if r["status"] == "skipped")
    print(f"\ndry-run: {ok} ok, {sk} skipped, {len(failed)} failed / {len(records)} total")
    if failed:
        print("FAILED:", failed)
        sys.exit(1)


if __name__ == "__main__":
    main()
