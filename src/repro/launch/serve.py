"""Serving launcher: event-driven Heddle rollout over real workers.

Runs the full trajectory-centric runtime (``repro.engine.runtime``) on a seeded
long-tail agentic workload: real multi-step trajectories (generate → tool call →
absorb → repeat) across multiple ``RolloutWorker``s, with per-worker scheduler
queues, preemptive execution, progressive prediction refresh, and tool-interval
KV migration.

Local (real execution, reduced model):
    PYTHONPATH=src python -m repro.launch.serve --requests 16 --steps 3 \
        --scheduler pps [--migration on|off] [--tool-latency 1.0]

Open-loop serving (Poisson ingress, tenant SLOs, admission control):
    PYTHONPATH=src python -m repro.launch.serve --requests 24 --arrival poisson \
        --qps 4 --tenants 'gold:0.25:30,best:0.75:10' [--admission on|off]

Rollout-as-a-service (streaming harvest + in-flight weight sync):
    PYTHONPATH=src python -m repro.launch.serve --requests 16 --stream 4

Production dry-run (lower + compile serve_step for the pod mesh):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --dry-run \
        [--shape decode_32k] [--multi-pod]
"""

from __future__ import annotations

import argparse
import sys
import time


def _validate_args(ap, args):
    """Reject nonsensical flag combinations up front with one-line errors."""
    for flag, value in (("--requests", args.requests), ("--workers", args.workers),
                        ("--group-size", args.group_size),
                        ("--max-active", args.max_active),
                        ("--quantum", args.quantum),
                        ("--max-tokens", args.max_tokens),
                        ("--capacity", args.capacity)):
        if value < 1:
            ap.error(f"{flag} must be >= 1 (got {value})")
    if args.steps < 0:
        ap.error(f"--steps must be >= 0 (got {args.steps})")
    if args.stream < 0:
        ap.error(f"--stream must be >= 0 (got {args.stream})")
    if args.tool_latency <= 0:
        ap.error(f"--tool-latency must be > 0 (got {args.tool_latency})")
    if args.degrees:
        try:
            degrees = [int(d) for d in args.degrees.split(",")]
        except ValueError:
            ap.error(f"--degrees must be comma-separated integers "
                     f"(got {args.degrees!r})")
        if not degrees or any(d < 1 for d in degrees):
            ap.error(f"--degrees entries must be >= 1 (got {args.degrees!r})")
    if args.checkpoint_dir and args.chaos_seed is None:
        ap.error("--checkpoint-dir is the chaos-recovery store; it needs "
                 "--chaos-seed (nothing restores without a fault plan)")
    open_loop = args.arrival != "closed"
    if open_loop and args.qps <= 0:
        ap.error(f"--arrival {args.arrival} is open-loop and needs --qps > 0")
    if not open_loop and args.qps > 0:
        ap.error("--qps only applies to open-loop ingress; pick an --arrival "
                 "policy (poisson|bursty|diurnal)")
    if args.tenants and not open_loop:
        ap.error("--tenants only applies to open-loop ingress; pick an "
                 "--arrival policy (poisson|bursty|diurnal)")
    if args.tenants:
        from repro.core.tenancy import parse_tenants
        try:
            parse_tenants(args.tenants)
        except ValueError as e:
            ap.error(f"--tenants: {e}")


def build_runtime(args, cfg, params):
    """Workload + predictor + controller + worker fleet + runtime for one run."""
    from repro.engine.fleet import FleetSpec
    from repro.engine.runtime import (RuntimeConfig, build_workbench,
                                      make_runtime)

    gsz = max(1, args.group_size)
    max_steps = args.steps if args.steps > 0 else None
    batch, predictor = build_workbench(
        task=args.task, n_prompts=-(-args.requests // gsz), group_size=gsz,
        seed=args.seed, base_steps=1.5 if max_steps is not None else 3.0,
        max_steps=max_steps, max_total_tokens=args.max_tokens)
    batch = batch[:args.requests]
    open_loop = args.arrival != "closed"
    serving = None
    if open_loop:
        from repro.core.tenancy import (DEFAULT_TENANTS, ServingConfig,
                                        assign_tenants, parse_tenants)
        from repro.engine.workload import assign_arrivals, make_arrivals

        # arrivals first (tenant deadlines are absolute: submit + deadline_s)
        assign_arrivals(batch, make_arrivals(args.arrival, rate=args.qps,
                                             seed=args.seed))
        tenants = parse_tenants(args.tenants) if args.tenants else DEFAULT_TENANTS
        assign_tenants(batch, tenants, seed=args.seed)
        per_worker = 4 * args.max_active
        serving = ServingConfig(admission_control=args.admission == "on",
                                queue_bound_per_worker=per_worker,
                                queue_bound_global=per_worker * args.workers,
                                shed_pressure=2.0, degrade_pressure=3.0)
    rcfg = RuntimeConfig(scheduler=args.scheduler,
                         migration=args.migration == "on",
                         max_active=args.max_active, quantum=args.quantum,
                         tool_latency_scale=args.tool_latency,
                         trace=args.trace > 0, seed=args.seed,
                         checkpoint_dir=args.checkpoint_dir or None,
                         open_loop=open_loop)
    fleet = None
    if args.degrees:
        fleet = FleetSpec.from_degrees(
            [int(d) for d in args.degrees.split(",")])
    faults = None
    if args.chaos_seed is not None:
        from repro.core.faults import FaultPlan
        n_workers = fleet.n_workers if fleet is not None else args.workers
        # horizon estimate for scheduling the death: serial decode work split
        # across the fleet (an upper-ish bound is fine — kill_frac lands the
        # death mid-run for any reasonable workload)
        horizon = (sum(t.payload.total_tokens for t in batch)
                   * rcfg.token_time / max(1, n_workers))
        faults = FaultPlan.chaos(seed=args.chaos_seed, n_workers=n_workers,
                                 horizon=horizon)
    return make_runtime(cfg, params, batch, predictor,
                        n_workers=args.workers, config=rcfg,
                        capacity=args.capacity, fleet=fleet, faults=faults,
                        serving=serving)


def _run_service(args, runtime):
    """The --stream demo: rollout-as-a-service over the built runtime.

    Streams FINISHED trajectories as they harvest (no makespan barrier) and
    publishes a weight epoch every N harvests; each worker adopts the new
    epoch only once its resident lanes drain, so every printed stamp names
    the policy that actually generated that trajectory.
    """
    from repro.rl.service import RolloutService

    svc = RolloutService(runtime.backend, runtime.controller, runtime.cfg,
                         faults=runtime.faults)
    svc.submit(runtime.trajs)
    total = len(runtime.trajs)
    t0 = time.time()
    harvested = 0
    for traj in svc.stream():
        harvested += 1
        line = (f"[{svc.now:8.3f}s] harvest {harvested:3d}/{total}  "
                f"traj {traj.traj_id:4d}  worker {traj.worker_id}  "
                f"epoch stamp {traj.weight_epoch}")
        if harvested % args.stream == 0 and harvested < total:
            epoch = svc.sync_weights()
            line += f"  -> published weight epoch {epoch}"
        print(line)
    res = svc.close()
    dt = time.time() - t0
    print(f"\nstreamed {harvested} harvests in {dt:.1f}s wall; "
          f"published {svc.epoch} weight epochs, "
          f"applied per worker {svc.applied_epochs}")
    print(f"virtual makespan {res.makespan:.2f}s, preemptions "
          f"{res.preemptions}, tool-interval migrations {res.migrations}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--group-size", type=int, default=4,
                    help="GRPO group size: requests per shared prompt (prefix-"
                         "affine placement keeps a group together so the radix "
                         "cache implants the shared prompt for siblings)")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--degrees", default="",
                    help="heterogeneous fleet: comma-separated per-worker MP "
                         "degrees, e.g. '4,2,1,1' (§6; overrides --workers; "
                         "mp>1 workers run on carved sub-meshes when the "
                         "device set allows, un-meshed otherwise)")
    ap.add_argument("--steps", type=int, default=3,
                    help="agentic steps per trajectory (plans truncated here; "
                         "easy samples finish earlier; 0 = no cap, keeping the "
                         "workload's full step-count tail)")
    ap.add_argument("--scheduler", default="pps",
                    choices=["pps", "fcfs", "rr", "sjf"])
    ap.add_argument("--migration", default="on", choices=["on", "off"],
                    help="tool-interval KV migration (§5.3)")
    ap.add_argument("--tool-latency", type=float, default=1.0,
                    help="scale on the workload's sampled tool latencies")
    ap.add_argument("--task", default="coding", choices=["coding", "search", "math"])
    ap.add_argument("--max-active", type=int, default=3,
                    help="decode-concurrency slots per worker")
    ap.add_argument("--quantum", type=int, default=8,
                    help="decode tokens per scheduling quantum")
    ap.add_argument("--max-tokens", type=int, default=48,
                    help="longest trajectory's total generated tokens")
    ap.add_argument("--capacity", type=int, default=160)
    ap.add_argument("--trace", type=int, default=0,
                    help="print the first N entries of the orchestrator's "
                         "(event, traj, worker) decision trace — the sequence "
                         "the sim/engine parity harness compares")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arrival", default="closed",
                    choices=["closed", "poisson", "bursty", "diurnal"],
                    help="ingress mode: 'closed' submits the whole batch at "
                         "t=0 (training-style); the rest generate open-loop "
                         "arrival times at --qps (serving-style)")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="offered load for open-loop --arrival policies "
                         "(mean trajectory arrivals per virtual second)")
    ap.add_argument("--tenants", default="",
                    help="tenant classes as 'name:share[:deadline_s],...' "
                         "(e.g. 'gold:0.25:30,silver:0.35:60,best:0.4:15'); "
                         "tiers follow list order, the last class is sheddable; "
                         "empty = built-in gold/silver/best_effort mix")
    ap.add_argument("--admission", default="on", choices=["on", "off"],
                    help="deadline-aware admission control for open-loop "
                         "ingress (off = admit everything, queue bounds and "
                         "the degradation ladder still apply)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="run under a seeded FaultPlan.chaos schedule: one "
                         "mid-run worker death + revival and injected tool "
                         "timeouts/errors absorbed by capped-backoff retries "
                         "(trajectories recover from tool-boundary checkpoints)")
    ap.add_argument("--checkpoint-dir", default="",
                    help="also persist tool-boundary checkpoints to this "
                         "directory (crash-atomic npz, one per trajectory)")
    ap.add_argument("--stream", type=int, default=0,
                    help="run as a rollout service: stream each trajectory the "
                         "moment it finishes (no makespan barrier) and publish "
                         "an in-flight weight sync every N harvests — workers "
                         "cut over as their resident lanes drain (0 = off)")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="decode_32k",
                    choices=["prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)
    _validate_args(ap, args)

    if args.dry_run:
        from repro.launch import dryrun
        dr_args = ["--arch", args.arch, "--shape", args.shape]
        if args.multi_pod:
            dr_args.append("--multi-pod")
        return dryrun.main(dr_args)

    import jax
    from repro.configs import get_config
    from repro.models import model as M

    if args.degrees:
        degrees = [int(d) for d in args.degrees.split(",")]
        if max(degrees) > len(jax.devices()):
            ap.error(f"--degrees asks for an MP-{max(degrees)} worker but only "
                     f"{len(jax.devices())} device(s) are visible")

    cfg = get_config(args.arch).reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    runtime = build_runtime(args, cfg, params)
    controller = runtime.controller

    if args.stream > 0:
        return _run_service(args, runtime)

    t0 = time.time()
    res = runtime.run()
    dt = time.time() - t0

    for ws in runtime.workers:
        stats = ws.engine.dispatch_stats()
        served = sum(1 for t in res.trajectories
                     if t.worker_id == ws.wid and t.finished)
        print(f"worker {ws.wid}: finished {served} trajectories, "
              f"{stats['decode_steps']} decode steps, "
              f"prefix reuse {stats['reused_tokens']}/"
              f"{stats['reused_tokens'] + stats['prefilled_tokens']} admit tokens, "
              f"{stats['retired_lanes']} retired lanes")
        if "blocks_total" in stats:                   # paged KV pool occupancy
            print(f"  pages: {stats['blocks_resident']}/{stats['blocks_total']}"
                  f" blocks resident (peak {stats['blocks_used_high_watermark']}"
                  f", {stats['blocks_shared']} shared refs, page size "
                  f"{stats['page_size']}), alloc/free "
                  f"{stats['blocks_allocated_total']}/"
                  f"{stats['blocks_freed_total']}, {stats['block_grows']} grows")
    steps = sum(t.num_steps for t in res.trajectories)
    multi = sum(1 for t in res.trajectories if t.num_steps > 1)
    rate = controller.measured_reuse_rate
    print(f"\nserved {len(res.trajectories)} trajectories "
          f"({steps} agentic steps, {multi} multi-step) across "
          f"{len(runtime.workers)} workers: {res.total_tokens} real tokens "
          f"in {dt:.1f}s wall")
    print(f"virtual makespan {res.makespan:.2f}s "
          f"({res.throughput:.1f} tok/s), queue delay mean {res.queue_delay_mean:.3f}s "
          f"p99 {res.queue_delay_p99:.3f}s")
    print(f"preemptions {res.preemptions}, tool-interval migrations "
          f"{res.migrations}, tool invocations {runtime.env.invocations}, "
          f"measured prefix reuse rate {0.0 if rate is None else rate:.2f}")
    if args.arrival != "closed":
        print(f"open-loop ingress ({args.arrival} @ {args.qps:g} qps, "
              f"admission {args.admission}): {res.arrivals} arrivals, "
              f"{res.admitted} admitted, {res.deferred} deferred, "
              f"{res.shed} shed, {res.degraded} degraded")
        for name, st in res.tenant_report.items():
            print(f"  tenant {name:12s} arrived {st['arrived']:3d}  "
                  f"attainment {st['attainment']:.2f}  "
                  f"shed rate {st['shed_rate']:.2f}  "
                  f"latency p50 {st['latency_p50_s']:.2f}s "
                  f"p99 {st['latency_p99_s']:.2f}s")
    if args.chaos_seed is not None:
        print(f"chaos (seed {args.chaos_seed}): worker deaths "
              f"{res.worker_deaths}, checkpoint recoveries {res.recoveries}, "
              f"tool retries {res.tool_retries}, injected tool faults "
              f"{res.injected_tool_faults}")
    if args.trace > 0:
        print(f"\ndecision trace (first {args.trace} of {len(res.trace)}):")
        for kind, tid, wid in res.trace[:args.trace]:
            print(f"  {kind:12s} traj {tid:4d}  worker {wid}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
