"""Serving launcher: Heddle-orchestrated batched rollout serving.

Local (real execution, reduced model):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --requests 16

Production dry-run (lower + compile serve_step for the pod mesh):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --dry-run \
        [--shape decode_32k] [--multi-pod]
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--gen-tokens", type=int, default=24)
    ap.add_argument("--scheduler", default="pps", choices=["pps", "fcfs", "rr", "sjf"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="decode_32k",
                    choices=["prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun
        dr_args = ["--arch", args.arch, "--shape", args.shape]
        if args.multi_pod:
            dr_args.append("--multi-pod")
        return dryrun.main(dr_args)

    import jax
    from repro.configs import get_config
    from repro.core.placement import InterferenceModel, place
    from repro.engine.sampler import SamplerConfig
    from repro.engine.worker import RolloutWorker
    from repro.models import model as M

    cfg = get_config(args.arch).reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = {i: [5 + int(t) for t in rng.integers(0, 100, rng.integers(3, 9))]
               for i in range(args.requests)}

    # trajectory-aware placement of the request batch (predicted length ~ prompt len)
    lengths = [float(len(p)) * 8 for p in prompts.values()]
    placement = place(lengths, args.workers, InterferenceModel.analytic(0.02))
    assignment = {}
    for w, group in enumerate(placement.groups):
        for idx in group:
            assignment[idx] = w

    # size each worker's slot pool for its assigned group (pools auto-grow if the
    # scheduler later routes extra trajectories their way)
    group_sizes = [max(2, len(g)) for g in placement.groups]
    workers = [RolloutWorker(cfg, params, capacity=128, max_slots=group_sizes[i],
                             worker_id=i, sampler=SamplerConfig(temperature=0.8),
                             seed=args.seed)
               for i in range(args.workers)]

    t0 = time.time()
    for rid, prompt in prompts.items():
        workers[assignment[rid]].prefill(rid, prompt)
    by_worker: dict[int, list[int]] = {}
    for rid, w in assignment.items():
        by_worker.setdefault(w, []).append(rid)
    done = 0
    for w, rids in by_worker.items():
        out = workers[w].decode(rids, args.gen_tokens)
        done += sum(len(v) for v in out.values())
        print(f"worker {w}: served {len(rids)} requests "
              f"({sum(len(v) for v in out.values())} tokens)")
    dt = time.time() - t0
    print(f"\nserved {args.requests} requests, {done} tokens in {dt:.1f}s "
          f"({done/dt:.1f} tok/s on CPU)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
