"""Serving launcher: Heddle-orchestrated batched rollout serving.

Local (real execution, reduced model):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --requests 16

Production dry-run (lower + compile serve_step for the pod mesh):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --dry-run \
        [--shape decode_32k] [--multi-pod]
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--group-size", type=int, default=4,
                    help="GRPO group size: requests per shared prompt (prefix-"
                         "affine placement keeps a group on one worker so the "
                         "radix cache implants the shared prompt for siblings)")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--gen-tokens", type=int, default=24)
    ap.add_argument("--scheduler", default="pps", choices=["pps", "fcfs", "rr", "sjf"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="decode_32k",
                    choices=["prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun
        dr_args = ["--arch", args.arch, "--shape", args.shape]
        if args.multi_pod:
            dr_args.append("--multi-pod")
        return dryrun.main(dr_args)

    import jax
    from repro.configs import get_config
    from repro.core.placement import InterferenceModel, place
    from repro.engine.sampler import SamplerConfig
    from repro.engine.worker import RolloutWorker
    from repro.models import model as M

    cfg = get_config(args.arch).reduced(n_periods=2)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    # GRPO-style workload: requests in groups of --group-size share one prompt
    gsz = max(1, args.group_size)
    n_groups = -(-args.requests // gsz)
    group_prompts = [[5 + int(t) for t in rng.integers(0, 100, rng.integers(3, 9))]
                     for _ in range(n_groups)]
    prompts = {i: group_prompts[i // gsz] for i in range(args.requests)}

    # trajectory-aware placement of the request *groups* (prefix affinity: the whole
    # group lands on one worker, so siblings hit the radix cache); predicted group
    # length ~ group_size * prompt length
    lengths = [float(len(p)) * 8 * gsz for p in group_prompts]
    placement = place(lengths, args.workers, InterferenceModel.analytic(0.02))
    assignment = {}
    for w, group in enumerate(placement.groups):
        for gid in group:
            for rid in range(gid * gsz, min((gid + 1) * gsz, args.requests)):
                assignment[rid] = w

    # size each worker's slot pool for its assigned requests (pools auto-grow if the
    # scheduler later routes extra trajectories their way)
    pool_sizes = [max(2, sum(1 for rid in assignment if assignment[rid] == i))
                  for i in range(args.workers)]
    workers = [RolloutWorker(cfg, params, capacity=128, max_slots=pool_sizes[i],
                             worker_id=i, sampler=SamplerConfig(temperature=0.8),
                             seed=args.seed)
               for i in range(args.workers)]

    t0 = time.time()
    for rid, prompt in prompts.items():
        workers[assignment[rid]].prefill(rid, prompt)
    by_worker: dict[int, list[int]] = {}
    for rid, w in assignment.items():
        by_worker.setdefault(w, []).append(rid)
    done = 0
    for w, rids in by_worker.items():
        out = workers[w].decode(rids, args.gen_tokens)
        done += sum(len(v) for v in out.values())
        stats = workers[w].dispatch_stats()
        print(f"worker {w}: served {len(rids)} requests "
              f"({sum(len(v) for v in out.values())} tokens), "
              f"prefix reuse {stats['reused_tokens']}/"
              f"{stats['reused_tokens'] + stats['prefilled_tokens']} admit tokens, "
              f"{stats['full_hits']} full + {stats['partial_hits']} partial hits")
    dt = time.time() - t0

    # surface measured reuse into the control plane's dispatch stats: this is the
    # number the simulator's cache model consumes (SimConfig.measured_reuse_rate)
    from repro.core.controller import HeddleController
    from repro.core.predictor import ProgressivePredictor
    from repro.core.resource_manager import WorkerLatencyModel
    controller = HeddleController(ProgressivePredictor(),
                                  InterferenceModel.analytic(0.02),
                                  WorkerLatencyModel(), gpu_budget=args.workers)
    for w in workers:
        controller.record_worker_stats(w.worker_id, w.dispatch_stats())
    rate = controller.measured_reuse_rate
    print(f"\nserved {args.requests} requests, {done} tokens in {dt:.1f}s "
          f"({done/dt:.1f} tok/s on CPU); measured prefix reuse rate "
          f"{0.0 if rate is None else rate:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
