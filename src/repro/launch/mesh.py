"""Production mesh construction (function, not module constant — importing this module
must never touch jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip v5e pod, or 2x16x16 = 512-chip two-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by sharding tests."""
    return jax.make_mesh((data, model), ("data", "model"))
