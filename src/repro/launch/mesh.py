"""Production mesh construction (function, not module constant — importing this module
must never touch jax device state)."""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip v5e pod, or 2x16x16 = 512-chip two-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by sharding tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def carve_worker_meshes(degrees, devices=None):
    """Carve one disjoint ("data", "model") sub-mesh per rollout worker.

    Worker ``i`` with model-parallel degree ``degrees[i]`` gets a ``(1, degrees[i])``
    mesh over the next contiguous block of the device list, so a heterogeneous fleet
    like {4, 2, 1, 1} occupies eight accelerators without overlap.  Degree-1 workers
    in a meshed fleet get a trivial (1, 1) mesh over their reserved device — leaving
    them un-meshed would land their params/KV on the *default* device, a chip already
    owned by worker 0's sub-mesh, while the reserved chip idles.  An all-mp1 fleet
    returns ``None`` for every worker (nothing to shard; the module-level jit cache
    stays shared), as does any fleet the visible device set cannot cover
    (``sum(degrees) > len(devices)`` — the un-forced CPU tier-1 environment); the
    *declared* degrees still drive the control plane (placement, virtual token
    times), only the physical sharding degrades.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    degrees = [int(d) for d in degrees]
    if sum(degrees) > len(devices) or all(d == 1 for d in degrees):
        return [None] * len(degrees)
    meshes: list[Mesh | None] = []
    off = 0
    for d in degrees:
        block = np.asarray(devices[off:off + d]).reshape(1, d)
        meshes.append(Mesh(block, ("data", "model")))
        off += d
    return meshes
