"""Flash attention with a memory-correct custom VJP (pure JAX, scan-blocked).

Forward: nested scan (q blocks x kv blocks) with online softmax; saves only
(q, k, v, out, lse) — O(S) residuals.  Backward: recomputes block scores from the
residuals (the flash-attention backward), so training never materializes an S x T
score tensor nor the per-block scan intermediates naive autodiff would save.

Layout: q (B, KV, G, S, hd) — GQA query heads grouped onto their KV head;
k, v (B, T, KV, hd); positions (S,) / (T,) int32 (negative = padding).
Masking: causal (q_pos >= k_pos) and optional sliding window (q_pos - k_pos < window).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32
NEG = -1e30
_INT_MAX = jnp.iinfo(jnp.int32).max


def _mask_bias(qp, kp, causal: bool, window: int):
    """Additive (qb, kb) f32 mask: 0 where attendable, -1e30 elsewhere.

    An additive bias fuses into the score computation; a boolean ``where`` operand gets
    broadcast to the full (B, KV, G, qb, kb) score shape and hoisted across scan
    iterations by XLA (observed: a 14 GiB pred buffer on arctic-480b train)."""
    m = (qp[:, None] >= 0) & (kp[None, :] >= 0) & (kp[None, :] < _INT_MAX)
    if causal:
        m &= qp[:, None] >= kp[None, :]
    if window:
        m &= qp[:, None] - kp[None, :] < window
    return jnp.where(m, 0.0, NEG).astype(F32)


def _pad_to(x, n, axis, value=0):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, n - x.shape[axis])
    return jnp.pad(x, pad, constant_values=value) if n != x.shape[axis] else x


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def flash_attention(q, k, v, q_pos, kv_pos, scale, causal, window, q_block, kv_block):
    out, _ = _flash_fwd_impl(q, k, v, q_pos, kv_pos, scale, causal, window,
                             q_block, kv_block)
    return out


def _flash_fwd_impl(q, k, v, q_pos, kv_pos, scale, causal, window, qb, kb):
    B, KV, G, S, hd = q.shape
    T = k.shape[1]
    qb, kb = min(qb, S), min(kb, T)
    nq, nk = -(-S // qb), -(-T // kb)
    q = _pad_to(q, nq * qb, 3)
    q_pos = _pad_to(q_pos, nq * qb, 0, -1)
    k = _pad_to(k, nk * kb, 1)
    v = _pad_to(v, nk * kb, 1)
    kv_pos = _pad_to(kv_pos, nk * kb, 0, _INT_MAX)

    qs = q.reshape(B, KV, G, nq, qb, hd).transpose(3, 0, 1, 2, 4, 5)
    ks = k.reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4)      # (nk,B,KV,kb,hd)
    vs = v.reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4)
    qps = q_pos.reshape(nq, qb)
    kps = kv_pos.reshape(nk, kb)

    def q_blk(_, args):
        qi, qp = args

        def kv_blk(carry, kv_args):
            m, lsum, acc = carry
            ki, vi, kp = kv_args
            s = jnp.einsum("bkgqd,bktd->bkgqt", qi.astype(F32), ki.astype(F32)) * scale
            s = s + _mask_bias(qp, kp, causal, window)[None, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            return (m_new, lsum * corr + p.sum(-1),
                    acc * corr[..., None] + jnp.einsum("bkgqt,bktd->bkgqd", p,
                                                       vi.astype(F32))), None

        m0 = jnp.full((B, KV, G, qb), -jnp.inf, F32)
        l0 = jnp.zeros((B, KV, G, qb), F32)
        a0 = jnp.zeros((B, KV, G, qb, hd), F32)
        (m, lsum, acc), _ = lax.scan(kv_blk, (m0, l0, a0), (ks, vs, kps))
        out = acc / jnp.maximum(lsum, 1e-30)[..., None]
        lse = jnp.where(jnp.isfinite(m), m + jnp.log(jnp.maximum(lsum, 1e-30)), 0.0)
        return None, (out, lse)

    _, (outs, lses) = lax.scan(q_blk, None, (qs, qps))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, KV, G, nq * qb, hd)[..., :S, :]
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, nq * qb)[..., :S]
    return out.astype(q.dtype), lse


def _flash_fwd(q, k, v, q_pos, kv_pos, scale, causal, window, qb, kb):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, kv_pos, scale, causal, window, qb, kb)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _flash_bwd(scale, causal, window, qb, kb, res, dout):
    q, k, v, q_pos, kv_pos, out, lse = res
    B, KV, G, S, hd = q.shape
    T = k.shape[1]
    qb, kb = min(qb, S), min(kb, T)
    nq, nk = -(-S // qb), -(-T // kb)
    delta = jnp.sum(dout.astype(F32) * out.astype(F32), axis=-1)        # (B,KV,G,S)

    qs = _pad_to(q, nq * qb, 3).reshape(B, KV, G, nq, qb, hd).transpose(3, 0, 1, 2, 4, 5)
    dos = _pad_to(dout, nq * qb, 3).reshape(B, KV, G, nq, qb, hd).transpose(3, 0, 1, 2, 4, 5)
    lses = _pad_to(lse, nq * qb, 3).reshape(B, KV, G, nq, qb).transpose(3, 0, 1, 2, 4)
    dels = _pad_to(delta, nq * qb, 3).reshape(B, KV, G, nq, qb).transpose(3, 0, 1, 2, 4)
    qps = _pad_to(q_pos, nq * qb, 0, -1).reshape(nq, qb)
    ks = _pad_to(k, nk * kb, 1).reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4)
    vs = _pad_to(v, nk * kb, 1).reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4)
    kps = _pad_to(kv_pos, nk * kb, 0, _INT_MAX).reshape(nk, kb)

    def q_blk(carry, args):
        dk_acc, dv_acc = carry                       # (nk,B,KV,kb,hd) f32
        qi, doi, lsei, deli, qp = args

        def kv_blk(dq_acc, kv_args):
            ki, vi, kp = kv_args
            s = jnp.einsum("bkgqd,bktd->bkgqt", qi.astype(F32), ki.astype(F32)) * scale
            s = s + _mask_bias(qp, kp, causal, window)[None, None, None]
            p = jnp.exp(s - lsei[..., None])                            # (B,KV,G,qb,kb)
            dp = jnp.einsum("bkgqd,bktd->bkgqt", doi.astype(F32), vi.astype(F32))
            ds = p * (dp - deli[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bkgqt,bktd->bkgqd", ds, ki.astype(F32))
            dk_i = jnp.einsum("bkgqt,bkgqd->bktd", ds, qi.astype(F32))
            dv_i = jnp.einsum("bkgqt,bkgqd->bktd", p, doi.astype(F32))
            return dq_acc, (dk_i, dv_i)

        dq0 = jnp.zeros((B, KV, G, qi.shape[3], hd), F32)
        dqi, (dks, dvs) = lax.scan(kv_blk, dq0, (ks, vs, kps))
        return (dk_acc + dks, dv_acc + dvs), dqi

    z = jnp.zeros((nk, B, KV, kb, hd), F32)
    (dk_s, dv_s), dqs = lax.scan(q_blk, (z, z), (qs, dos, lses, dels, qps))
    dq = dqs.transpose(1, 2, 3, 0, 4, 5).reshape(B, KV, G, nq * qb, hd)[..., :S, :]
    dk = dk_s.transpose(1, 0, 3, 2, 4).reshape(B, nk * kb, KV, hd)[:, :T]
    dv = dv_s.transpose(1, 0, 3, 2, 4).reshape(B, nk * kb, KV, hd)[:, :T]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), None, None)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
