"""Layer primitives shared by all 10 assigned architectures.

Every primitive has a *full-sequence* form (train / prefill) and a *step* form (decode
with cached state).  Memory-sensitive paths are blocked:

  * full attention uses a flash-style nested-scan (online softmax over KV blocks) above a
    sequence threshold, so prefill_32k never materializes an S x S score matrix;
  * mLSTM uses the chunk-recurrent linear-attention form (inter-chunk state carry);
  * Mamba uses an associative scan over the diagonal SSM recurrence;
  * MoE uses capacity-based sort dispatch (compute scales with top_k, not n_experts).

Activation sharding constraints use logical axis names via ``repro.distributed.shard``.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig

F32 = jnp.float32


# ----------------------------------------------------------------- norms / rope

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    return (x.astype(F32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale + bias


def block_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=F32) / half)
    ang = positions.astype(F32)[..., None] * freqs          # (..., S, half)
    if ang.ndim == 2:                                        # (S, half) -> broadcast batch
        ang = ang[None]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (B,S,1,half)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def activate(h: jax.Array, g: Optional[jax.Array], kind: str) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(g) * h
    if kind == "relu2":
        return jnp.square(jax.nn.relu(h))
    if kind == "gelu":
        return jax.nn.gelu(h)
    raise ValueError(f"unknown activation {kind!r}")


# ----------------------------------------------------------------- full attention

FLASH_THRESHOLD = 2048
_QBLK, _KBLK = 512, 1024


def _plain_attention(q, k, v, mask, scale):
    # q: (B,S,KV,G,hd)  k,v: (B,T,KV,hd)  mask: broadcastable to (B,KV,G,S,T) or None
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(F32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)


def _flash_attention(q, k, v, q_pos, kv_pos, scale, causal, window):
    """Flash attention (models/flash.py): scan-blocked online softmax with a
    memory-correct custom VJP (backward recomputes block scores)."""
    from repro.models.flash import flash_attention
    qt = q.transpose(0, 2, 3, 1, 4)                       # (B,S,KV,G,hd)->(B,KV,G,S,hd)
    out = flash_attention(qt, k, v, q_pos, kv_pos, scale, bool(causal), int(window),
                          _QBLK, _KBLK)
    return out.transpose(0, 3, 1, 2, 4)                   # -> (B,S,KV,G,hd)


def attention_full(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    causal: bool = True,
    use_rope: bool = True,
    kv_input: Optional[jax.Array] = None,
    window: int = 0,
) -> jax.Array:
    """Full-sequence (GQA, optionally cross) attention."""
    B, S, _ = x.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    H = cfg.n_heads
    G = H // KV
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    src = x if kv_input is None else kv_input
    k = jnp.einsum("btd,dnk->btnk", src, p["wk"])
    v = jnp.einsum("btd,dnk->btnk", src, p["wv"])
    q = shard(q, ("batch", None, "heads", None))
    k = shard(k, ("batch", None, "kv_heads", None))
    v = shard(v, ("batch", None, "kv_heads", None))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    is_cross = kv_input is not None
    if use_rope and not is_cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    T = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, KV, G, hd)
    if max(S, T) >= FLASH_THRESHOLD and not is_cross:
        kv_pos = positions if positions.ndim == 1 else positions[0]
        out = _flash_attention(qg, k, v, positions, kv_pos, scale,
                               causal, window)
    else:
        mask = None
        if causal and not is_cross:
            pos = positions if positions.ndim == 1 else positions[0]
            m = pos[:, None] >= pos[None, :]
            if window:
                m &= pos[:, None] - pos[None, :] < window
            mask = m[None, None, None]
        out = _plain_attention(qg, k, v, mask, scale)
    out = out.reshape(B, S, H, hd)
    out = shard(out, ("batch", None, "heads", None))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_decode(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
    use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode: write new KV at ``pos`` (ring-indexed if windowed), attend.

    cache_k/v: (B, C, KV, hd); pos: (B,) int32 — per-slot positions (continuous
    batching: every sequence in the batch may be at a different decode offset).
    Returns (out (B,1,d_model), new_cache_k, new_cache_v).
    """
    from repro.kernels import ops as kops
    B = x.shape[0]
    KV, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", x, p["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    pos = jnp.broadcast_to(pos, (B,))
    if use_rope:
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)
    C = cache_k.shape[1]
    slot = (pos % C) if window else jnp.minimum(pos, C - 1)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, slot].set(v[:, 0].astype(cache_v.dtype))
    cache_k = shard(cache_k, ("batch", "kv_seq", "kv_heads", None))
    cache_v = shard(cache_v, ("batch", "kv_seq", "kv_heads", None))
    valid_len = jnp.minimum(pos + 1, C)
    out = kops.decode_attention(q.reshape(B, KV, H // KV, hd), cache_k, cache_v,
                                valid_len, force_pallas=cfg.use_pallas_decode)
    out = out.reshape(B, 1, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache_k, cache_v


def attention_decode_paged(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    k_pool: jax.Array,
    v_pool: jax.Array,
    page_table: jax.Array,
    pos: jax.Array,
    *,
    use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token paged decode: scatter new KV into the lane's current block, attend
    through the page table.

    k_pool/v_pool: (NB, page_size, KV, hd) physical blocks shared by every lane;
    page_table: (B, num_pages) int32 (block 0 = scratch for unmapped entries);
    pos: (B,) int32.  A lane's write lands at block ``page_table[b, pos//ps]``,
    offset ``pos % ps`` — free/masked lanes whose rows are unmapped (or whose pos
    sits past capacity) write into scratch, which is the paged form of the dense
    pool's self-healing invariant.  Returns (out (B,1,d_model), k_pool', v_pool').
    """
    from repro.kernels import ops as kops
    B = x.shape[0]
    KV, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", x, p["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    pos = jnp.broadcast_to(pos, (B,))
    if use_rope:
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)
    ps = k_pool.shape[1]
    num_pages = page_table.shape[1]
    cap = num_pages * ps
    bidx = jnp.arange(B)
    page = jnp.clip(pos // ps, 0, num_pages - 1)
    blk = jnp.where(pos < cap, page_table[bidx, page], 0)   # overflow -> scratch
    off = pos % ps
    k_pool = k_pool.at[blk, off].set(k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[blk, off].set(v[:, 0].astype(v_pool.dtype))
    k_pool = shard(k_pool, (None, "kv_seq", "kv_heads", None))
    v_pool = shard(v_pool, (None, "kv_seq", "kv_heads", None))
    valid_len = jnp.minimum(pos + 1, cap)
    out = kops.paged_decode_attention(q.reshape(B, KV, H // KV, hd), k_pool,
                                      v_pool, page_table, valid_len,
                                      force_pallas=cfg.use_pallas_decode)
    out = out.reshape(B, 1, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), k_pool, v_pool


def attention_prefill_chunk_paged(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    k_pool: jax.Array,
    v_pool: jax.Array,
    pt_row: jax.Array,
    off: jax.Array,
    length: jax.Array,
    *,
    use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fixed-shape chunk prefill straight into a lane's pages.

    x: (1, C, d) normed hidden states (rows >= ``length`` are padding); pt_row:
    (num_pages,) int32, mapped far enough to cover ``off + length`` tokens.  The
    chunk's K/V rows scatter to their absolute (block, offset) slots — padding
    and out-of-capacity rows route to scratch block 0 — then each query ``i``
    attends to positions ``t <= off + i`` through the gathered page view.  The
    suffix of a prefix-shared admission runs through this path attending to the
    *shared* pages in place: zero prefix KV copies.  Returns
    (out (1, C, d_model), k_pool', v_pool').
    """
    B, Cn, _ = x.shape
    KV, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    G = H // KV
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", x, p["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    positions = (off + jnp.arange(Cn))[None]                  # (1, C) absolute
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    ps = k_pool.shape[1]
    num_pages = pt_row.shape[0]
    cap = num_pages * ps
    rows = off + jnp.arange(Cn)
    valid = (jnp.arange(Cn) < length) & (rows < cap)
    page = jnp.clip(rows // ps, 0, num_pages - 1)
    blk = jnp.where(valid, pt_row[page], 0)                   # padding -> scratch
    slot = rows % ps
    k_pool = k_pool.at[blk, slot].set(k[0].astype(k_pool.dtype))
    v_pool = v_pool.at[blk, slot].set(v[0].astype(v_pool.dtype))
    kg = k_pool[pt_row][None].reshape(1, cap, KV, hd)
    vg = v_pool[pt_row][None].reshape(1, cap, KV, hd)
    mask = jnp.arange(cap)[None, :] <= rows[:, None]          # (C, cap)
    qg = q.reshape(B, Cn, KV, G, hd)
    out = _plain_attention(qg, kg, vg, mask[None, None, None],
                           1.0 / math.sqrt(hd))
    out = out.reshape(B, Cn, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), k_pool, v_pool


def attention_prefill_chunk(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    cache_k: jax.Array,
    cache_v: jax.Array,
    off: jax.Array,
    length: jax.Array,
    *,
    use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fixed-shape chunk prefill for one lane: process ``C`` tokens at offset ``off``.

    x: (1, C, d) normed hidden states (rows >= ``length`` are padding); cache_k/v:
    (1, cap, KV, hd) with positions ``[0, off)`` resident.  Writes the chunk's K/V into
    the lane slice ``[off, off+C)`` (padding rows keep the old cache contents, so the
    masked-decode self-healing invariant carries over), then attends each query ``i``
    against cache slots ``t <= off + i`` — the resident prefix plus the chunk's own
    causal keys, which were just written.  ``off``/``length`` are traced scalars, so one
    compiled kernel serves every (offset, tail-length) — prefill at offset 0 and tool
    absorption at offset > 0 are the same code path.  Non-windowed linear caches only
    (ring writes would let later chunk rows overwrite slots earlier queries need).
    Returns (out (1, C, d_model), new_cache_k, new_cache_v).
    """
    B, Cn, _ = x.shape
    KV, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    G = H // KV
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", x, p["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    positions = (off + jnp.arange(Cn))[None]                  # (1, C) absolute
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    # write-then-attend: scatter valid rows to their ABSOLUTE slots.  A
    # dynamic_update_slice would clamp its start when off + C > cap and smear the
    # tail chunk over resident positions; per-row scatter keeps every key at
    # off + j even when the fixed-shape window hangs past the capacity edge
    # (out-of-capacity rows blend the old contents back — true overflow, which the
    # decode path also cannot represent).
    cap = cache_k.shape[1]
    rows = off + jnp.arange(Cn)
    valid = ((jnp.arange(Cn) < length) & (rows < cap))[None, :, None, None]
    slots = jnp.clip(rows, 0, cap - 1)
    cache_k = cache_k.at[:, slots].set(
        jnp.where(valid, k.astype(cache_k.dtype), cache_k[:, slots]))
    cache_v = cache_v.at[:, slots].set(
        jnp.where(valid, v.astype(cache_v.dtype), cache_v[:, slots]))
    mask = jnp.arange(cap)[None, :] <= (off + jnp.arange(Cn))[:, None]   # (C, cap)
    qg = q.reshape(B, Cn, KV, G, hd)
    out = _plain_attention(qg, cache_k, cache_v, mask[None, None, None],
                           1.0 / math.sqrt(hd))
    out = out.reshape(B, Cn, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache_k, cache_v


def cross_attention_decode(p, x, cfg, cross_k, cross_v):
    """Decode-time cross-attention against fixed encoder/image KV."""
    from repro.kernels import ops as kops
    B = x.shape[0]
    KV, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    T = cross_k.shape[1]
    out = kops.decode_attention(q.reshape(B, KV, H // KV, hd), cross_k, cross_v,
                                jnp.asarray(T, jnp.int32),
                                force_pallas=cfg.use_pallas_decode)
    out = out.reshape(B, 1, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ----------------------------------------------------------------- MLPs / MoE

def mlp(p: dict, x: jax.Array, activation: str) -> jax.Array:
    h = x @ p["w_in"]
    g = x @ p["w_gate"] if activation == "swiglu" else None
    h = shard(activate(h, g, activation), ("batch", None, "d_ff"))
    return h @ p["w_out"]


def moe(p: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Capacity-based top-k MoE with grouped sort dispatch.

    Tokens are split into one dispatch group per data shard (``dispatch_groups``), each
    with its own capacity — so the dispatch buffer is O(local_tokens) per device and
    GSPMD lowers the buffer movement to an all-to-all when experts shard over the model
    axis.  Compute scales with T * top_k * capacity_factor, not n_experts (overflow
    tokens drop, standard TPU practice).  Returns (output, aux_loss).
    """
    from repro.distributed.sharding import dispatch_groups
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    G = dispatch_groups(T)
    Tg = T // G
    cap = max(1, int(math.ceil(Tg * K / E * cfg.capacity_factor)))
    xf = x.reshape(T, D)

    logits = (xf @ p["router"]).astype(F32)                 # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = lax.top_k(gates, K)                      # (T, K)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    def dispatch_one(xg, eg, gg):
        """One group: xg (Tg, D), eg (Tg, K) expert ids, gg (Tg, K) gate weights."""
        eid = eg.reshape(-1)                                # (Tg*K,)
        tid = jnp.broadcast_to(jnp.arange(Tg)[:, None], (Tg, K)).reshape(-1)
        gat = gg.reshape(-1)
        order = jnp.argsort(eid)
        eid_s, tid_s, gat_s = eid[order], tid[order], gat[order]
        counts = jax.ops.segment_sum(jnp.ones_like(eid_s, F32), eid_s, num_segments=E)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(Tg * K) - starts[eid_s].astype(jnp.int32)
        keep = (rank < cap).astype(xg.dtype)
        rank_c = jnp.clip(rank, 0, cap - 1)
        buf = jnp.zeros((E, cap, D), xg.dtype)
        buf = buf.at[eid_s, rank_c].add(xg[tid_s] * keep[:, None])
        return buf, (eid_s, tid_s, gat_s, keep, rank_c)

    xg = x.reshape(G, Tg, D)
    eg = top_e.reshape(G, Tg, K)
    gg = top_g.reshape(G, Tg, K)
    buf, meta = jax.vmap(dispatch_one)(xg, eg, gg)          # buf: (G, E, cap, D)
    buf = shard(buf, ("dispatch", "experts", None, None))

    h = jnp.einsum("gecd,edf->gecf", buf, p["we_in"])
    g = jnp.einsum("gecd,edf->gecf", buf, p["we_gate"]) if cfg.activation == "swiglu" else None
    h = activate(h, g, cfg.activation if cfg.activation != "gelu" else "gelu")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["we_out"])
    out_buf = shard(out_buf, ("dispatch", "experts", None, None))

    def combine_one(ob, meta_g):
        eid_s, tid_s, gat_s, keep, rank_c = meta_g
        yflat = ob[eid_s, rank_c] * (gat_s.astype(ob.dtype) * keep)[:, None]
        return jax.ops.segment_sum(yflat, tid_s, num_segments=Tg)

    y = jax.vmap(combine_one)(out_buf, meta).reshape(B, S, D)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    assigned = jax.nn.one_hot(top_e.reshape(-1), E, dtype=F32).sum(0)
    frac_tokens = assigned / jnp.maximum(assigned.sum(), 1.0)
    frac_prob = gates.mean(0)
    aux = E * jnp.sum(frac_tokens * frac_prob)

    if cfg.shared_d_ff:                                     # qwen2-moe shared experts
        sh = xf @ p["ws_in"]
        sg = xf @ p["ws_gate"]
        s_out = (jax.nn.silu(sg) * sh) @ p["ws_out"]
        gate = jax.nn.sigmoid((xf @ p["shared_gate"]).astype(F32))[:, None]
        y = y + (gate.astype(xf.dtype) * s_out).reshape(B, S, D)
    if cfg.dense_residual_ff:                               # arctic dense residual
        y = y + mlp({"w_in": p["wd_in"], "w_gate": p["wd_gate"], "w_out": p["wd_out"]},
                    x, "swiglu")
    return y, aux


# ----------------------------------------------------------------- Mamba (SSM)

def _mamba_inner(p, x_conv, cfg):
    """Shared math after the causal conv: returns (a, b, C) scan ingredients."""
    dbc = x_conv @ p["m_xproj"]                              # (..., R + 2N)
    R = p["m_dtproj"].shape[0]
    N = cfg.ssm_state_dim
    dt_r, Bm, Cm = dbc[..., :R], dbc[..., R:R + N], dbc[..., R + N:]
    dt = jax.nn.softplus(dt_r @ p["m_dtproj"]).astype(F32)   # (..., di)
    A = -jnp.exp(p["m_Alog"].astype(F32))                    # (di, N)
    a = jnp.exp(dt[..., None] * A)                           # (..., di, N)
    b = (dt * x_conv.astype(F32))[..., None] * Bm.astype(F32)[..., None, :]
    return a, b, Cm


MAMBA_CHUNK = 512


def _mamba_scan_chunked(a: jax.Array, b: jax.Array, h0: jax.Array,
                        Cm: Optional[jax.Array] = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = a_t h_{t-1} + b_t via chunked associative scan.

    A full-sequence associative scan materializes O(log S) copies of the (B,S,di,N)
    state tensor (observed: ~100 GiB of f32 scan buffers on jamba train_4k).  Chunking
    runs an outer sequential lax.scan over S/CHUNK chunks (checkpointed, so backward
    recomputes instead of storing inner intermediates) with the associative scan inside
    — peak scan memory drops by ~S/CHUNK while keeping intra-chunk parallelism.

    With ``Cm`` (B,S,N): the output contraction y_t = <h_t, C_t> is FUSED into each
    chunk, so the full (B,S,di,N) state sequence is never written to HBM — the scan
    emits (B,S,di) instead (EXPERIMENTS.md §Perf iteration 2: N-fold output shrink).
    Returns (y_or_h, h_last (B,di,N)).
    """
    B, S, di, N = a.shape
    cs = min(MAMBA_CHUNK, S)
    nc = -(-S // cs)
    pad = nc * cs - S
    if pad:  # pad with identity elements: a=1, b=0
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if Cm is not None:
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    ac = a.reshape(B, nc, cs, di, N).transpose(1, 0, 2, 3, 4)
    bc = b.reshape(B, nc, cs, di, N).transpose(1, 0, 2, 3, 4)
    cc = (Cm.astype(F32).reshape(B, nc, cs, N).transpose(1, 0, 2, 3)
          if Cm is not None else None)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    @jax.checkpoint
    def chunk(h0, args):
        a_i, b_i, c_i = args                             # (B,cs,di,N), c_i may be None
        aprod, bacc = lax.associative_scan(combine, (a_i, b_i), axis=1)
        h = aprod * h0[:, None] + bacc                   # seed with the carry state
        out = h if c_i is None else jnp.einsum("bsdn,bsn->bsd", h, c_i)
        return h[:, -1], out

    h_last, outs = lax.scan(chunk, h0, (ac, bc, cc))
    if Cm is None:
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nc * cs, di, N)[:, :S]
    else:
        out = outs.transpose(1, 0, 2, 3).reshape(B, nc * cs, di)[:, :S]
    return out, h_last


def _mamba_scan_fused(p, xc, cfg) -> jax.Array:
    """Fully fused chunked SSM scan: discretization (a = exp(dt A), b = dt x B),
    recurrence AND the C-contraction all happen inside each chunk, so the only
    HBM-resident sequence tensors are the (B,S,di) projections — the (B,S,di,N)
    discretized pair is never materialized (EXPERIMENTS.md §Perf iteration 3)."""
    B, S, di = xc.shape
    N = cfg.ssm_state_dim
    R = p["m_dtproj"].shape[0]
    dbc = xc @ p["m_xproj"]                                  # (B,S,R+2N)
    dt_r, Bm, Cm = dbc[..., :R], dbc[..., R:R + N], dbc[..., R + N:]
    dt = jax.nn.softplus(dt_r @ p["m_dtproj"]).astype(F32)   # (B,S,di)
    A = -jnp.exp(p["m_Alog"].astype(F32))                    # (di,N)

    cs = min(MAMBA_CHUNK, S)
    nc = -(-S // cs)
    pad = nc * cs - S
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))         # dt=0 -> a=1, b=0
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    else:
        xc_p = xc

    def to_chunks(t):
        return t.reshape(B, nc, cs, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    @jax.checkpoint
    def chunk(h0, args):
        dt_i, B_i, C_i, x_i = args                           # (B,cs,di)/(B,cs,N)
        a_i = jnp.exp(dt_i[..., None] * A)                   # (B,cs,di,N) — chunk only
        b_i = (dt_i * x_i.astype(F32))[..., None] * B_i.astype(F32)[..., None, :]
        aprod, bacc = lax.associative_scan(combine, (a_i, b_i), axis=1)
        h = aprod * h0[:, None] + bacc
        y_i = jnp.einsum("bsdn,bsn->bsd", h, C_i.astype(F32))
        return h[:, -1], y_i

    h0 = jnp.zeros((B, di, N), F32)
    _, ys = lax.scan(chunk, h0, (to_chunks(dt), to_chunks(Bm), to_chunks(Cm),
                                 to_chunks(xc_p)))
    return ys.transpose(1, 0, 2, 3).reshape(B, nc * cs, di)[:, :S]


def mamba_full(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, D = x.shape
    xi = x @ p["m_in"]                                       # (B,S,di)
    z = x @ p["m_z"]
    xi = shard(xi, ("batch", None, "d_inner"))
    W = cfg.ssm_conv_width
    xp = jnp.pad(xi, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(xp[:, i:i + S] * p["m_conv"][i] for i in range(W))
    xc = jax.nn.silu(conv)
    y = _mamba_scan_fused(p, xc, cfg)                        # fused discretize+scan+C
    y = (y + p["m_D"].astype(F32) * xc.astype(F32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["m_out"]


def mamba_step(p: dict, x: jax.Array, cfg: ModelConfig, state: dict
               ) -> tuple[jax.Array, dict]:
    """One-token decode.  state = {"h": (B,di,N) f32, "conv": (B,W-1,di)}."""
    B = x.shape[0]
    xi = (x[:, 0] @ p["m_in"])                               # (B,di)
    z = x[:, 0] @ p["m_z"]
    W = cfg.ssm_conv_width
    hist = jnp.concatenate([state["conv"], xi[:, None]], axis=1)   # (B,W,di)
    conv = jnp.einsum("bwd,wd->bd", hist, p["m_conv"])
    xc = jax.nn.silu(conv)
    a, b, Cm = _mamba_inner(p, xc, cfg)                      # (B,di,N)
    h = a * state["h"] + b
    y = jnp.einsum("bdn,bn->bd", h, Cm.astype(F32))
    y = (y + p["m_D"].astype(F32) * xc.astype(F32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["m_out"])[:, None]
    return out, {"h": h, "conv": hist[:, 1:]}


# ----------------------------------------------------------------- xLSTM

def _mlstm_qkv(p, xi):
    q = jnp.einsum("...d,dhk->...hk", xi, p["l_q"])
    k = jnp.einsum("...d,dhk->...hk", xi, p["l_k"])
    v = jnp.einsum("...d,dhk->...hk", xi, p["l_v"])
    i_pre = jnp.einsum("...d,dh->...h", xi, p["l_ig"]).astype(F32)
    f_pre = jnp.einsum("...d,dh->...h", xi, p["l_fg"]).astype(F32)
    return q, k, v, i_pre, f_pre


MLSTM_CHUNK = 256


def mlstm_full(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Chunk-recurrent mLSTM (matrix-memory, exponential gating, stabilized).

    Within a chunk: parallel attention-like computation with decay matrix.
    Across chunks: (C, n, m) state carry — the linear-attention chunked form.
    """
    B, S, D = x.shape
    xi = x @ p["l_up"]
    z = jax.nn.silu(x @ p["l_z"])
    xi = shard(xi, ("batch", None, "d_inner"))
    di = xi.shape[-1]
    H = cfg.n_heads
    hd = di // H
    q, k, v, i_pre, f_pre = _mlstm_qkv(p, xi)                # (B,S,H,hd), (B,S,H)
    q = q.transpose(0, 2, 1, 3)                              # (B,H,S,hd)
    k = k.transpose(0, 2, 1, 3) / math.sqrt(hd)
    v = v.transpose(0, 2, 1, 3)
    i_pre = i_pre.transpose(0, 2, 1)                         # (B,H,S)
    logf = jax.nn.log_sigmoid(f_pre.transpose(0, 2, 1))      # (B,H,S)

    cs = min(MLSTM_CHUNK, S)
    nc = -(-S // cs)
    pad = nc * cs - S
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0))) for t in (q, k, v))
        i_pre = jnp.pad(i_pre, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, 0), (0, pad)))
    qc = q.reshape(B, H, nc, cs, hd).transpose(2, 0, 1, 3, 4)   # (nc,B,H,cs,hd)
    kc = k.reshape(B, H, nc, cs, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, H, nc, cs, hd).transpose(2, 0, 1, 3, 4)
    ic = i_pre.reshape(B, H, nc, cs).transpose(2, 0, 1, 3)      # (nc,B,H,cs)
    fc = logf.reshape(B, H, nc, cs).transpose(2, 0, 1, 3)

    def chunk(carry, args):
        Cst, nst, mst = carry                                # (B,H,hd,hd),(B,H,hd),(B,H)
        qi, ki, vi, ii, fi = args                            # ii: log-input-gate pre, fi: log f
        kif = ki.astype(F32)
        vif = vi.astype(F32)
        qif = qi.astype(F32)
        fcum = jnp.cumsum(fi, axis=-1)                       # (B,H,cs): sum_{u<=t} log f_u
        ftot = fcum[..., -1]
        # --- outputs: per-position stabilizer m_out_t = fcum_t + max(mst, cummax(ii - fcum))
        runmax = lax.cummax(ii - fcum, axis=ii.ndim - 1)
        m_out = fcum + jnp.maximum(mst[..., None], runmax)   # (B,H,cs)
        dec_q = jnp.exp(mst[..., None] + fcum - m_out)       # inter-chunk decay per query
        inter = jnp.einsum("bhsd,bhde->bhse", qif, Cst) * dec_q[..., None]
        n_inter = jnp.einsum("bhsd,bhd->bhs", qif, nst) * dec_q
        # intra weights: D[t1,t2] = exp(ii_t2 + fcum_t1 - fcum_t2 - m_out_t1), t2 <= t1
        dmat = jnp.exp((ii - fcum)[..., None, :] + (fcum - m_out)[..., :, None])
        causal = jnp.tril(jnp.ones((cs, cs), bool))
        dmat = jnp.where(causal, dmat, 0.0)
        s = jnp.einsum("bhsd,bhtd->bhst", qif, kif)
        intra = jnp.einsum("bhst,bhtd->bhsd", s * dmat, vif)
        n_intra = jnp.sum(s * dmat, axis=-1)
        n_vec = n_inter + n_intra
        h = (inter + intra) / jnp.maximum(jnp.abs(n_vec), jnp.exp(-m_out))[..., None]
        # --- state update to chunk end: key t weight log w_t = ii_t + ftot - fcum_t
        wlog = ii + (ftot[..., None] - fcum)
        m_new = jnp.maximum(mst + ftot, jnp.max(wlog, axis=-1))
        wk = jnp.exp(wlog - m_new[..., None])
        decay = jnp.exp(mst + ftot - m_new)
        C_new = Cst * decay[..., None, None] + jnp.einsum(
            "bhtd,bhte->bhde", kif * wk[..., None], vif)
        n_new = nst * decay[..., None] + jnp.einsum("bhtd,bht->bhd", kif, wk)
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((B, H, hd, hd), F32)
    n0 = jnp.zeros((B, H, hd), F32)
    m0 = jnp.full((B, H), -1e30, F32)
    _, hs = lax.scan(chunk, (C0, n0, m0), (qc, kc, vc, ic, fc))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, nc * cs, hd)[:, :, :S]
    h = h.transpose(0, 2, 1, 3).reshape(B, S, di).astype(x.dtype)
    h = h * z
    out = h + p["l_skip"] * xi
    return out @ p["l_down"]


def mlstm_step(p: dict, x: jax.Array, cfg: ModelConfig, state: dict
               ) -> tuple[jax.Array, dict]:
    """One-token mLSTM.  state = {"C": (B,H,hd,hd), "n": (B,H,hd), "m": (B,H)} (f32)."""
    B = x.shape[0]
    xi = x[:, 0] @ p["l_up"]
    z = jax.nn.silu(x[:, 0] @ p["l_z"])
    di = xi.shape[-1]
    H = cfg.n_heads
    hd = di // H
    q, k, v, i_pre, f_pre = _mlstm_qkv(p, xi)                # (B,H,hd), (B,H)
    k = k / math.sqrt(hd)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    fw = jnp.exp(logf + state["m"] - m_new)[..., None]
    iw = jnp.exp(i_pre - m_new)[..., None]
    C = state["C"] * fw[..., None] + iw[..., None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(F32), v.astype(F32))
    n = state["n"] * fw + iw * k.astype(F32)
    num = jnp.einsum("bhde,bhd->bhe", C, q.astype(F32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q.astype(F32))),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, di).astype(x.dtype)
    h = h * z
    out = h + p["l_skip"] * xi
    return (out @ p["l_down"])[:, None], {"C": C, "n": n, "m": m_new}


def _slstm_cell(p, xt, state):
    """xt: (B, 4, H, hd) pre-activations from input; state h/c/n/m: (B,H,hd)."""
    rh = jnp.einsum("bhd,ghde->bghe", state["h"].astype(F32), p["s_r"].astype(F32))
    pre = xt.astype(F32) + rh + p["s_b"].astype(F32)
    i_pre, f_pre, z_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + state["m"] - m_new)
    zt = jnp.tanh(z_pre)
    ot = jax.nn.sigmoid(o_pre)
    c = f_g * state["c"] + i_g * zt
    n = f_g * state["n"] + i_g
    h = ot * c / jnp.maximum(n, 1e-6)
    return {"h": h, "c": c, "n": n, "m": m_new}


def slstm_full(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    xt = jnp.einsum("bsd,dghe->bsghe", x, p["s_w"])          # (B,S,4,H,hd)
    state = {k: jnp.zeros((B, H, hd), F32) for k in ("h", "c", "n")}
    state["m"] = jnp.full((B, H, hd), -1e30, F32)

    def step(st, xt_t):
        st = _slstm_cell(p, xt_t, st)
        return st, st["h"]

    _, hs = lax.scan(step, state, xt.transpose(1, 0, 2, 3, 4))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    return h @ p["s_out"]


def slstm_step(p: dict, x: jax.Array, cfg: ModelConfig, state: dict
               ) -> tuple[jax.Array, dict]:
    B = x.shape[0]
    H = cfg.n_heads
    hd = x.shape[-1] // H
    xt = jnp.einsum("bd,dghe->bghe", x[:, 0], p["s_w"])
    st = _slstm_cell(p, xt, state)
    h = st["h"].reshape(B, -1).astype(x.dtype)
    return (h @ p["s_out"])[:, None], st
