"""Model assembly: init, full-sequence forward (train / prefill) and one-token decode.

Layers are stacked as (pattern position x period): parameters and caches carry a leading
``n_periods`` dim and ``jax.lax.scan`` runs over periods, with a Python loop over the
(short) pattern inside the scan body.  This keeps HLO size O(pattern) instead of
O(n_layers) for 30-40 layer models while expressing heterogeneous interleaves.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.config import ModelConfig

F32 = jnp.float32


# ------------------------------------------------------------------ init

def _norm_params(cfg: ModelConfig, dim: int, dtype) -> dict:
    p = {"scale": jnp.ones((dim,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def _init_attn(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    s = 0.02
    p = {
        "wq": jax.random.normal(ks[0], (d, H, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, KV, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, KV, hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (H, hd, d), dtype) * (s / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    if cross:
        p["xgate"] = jnp.zeros((), dtype)
    return p


def _init_mlp(key, cfg: ModelConfig, d_ff: int, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    s = 0.02
    p = {
        "w_in": jax.random.normal(ks[0], (d, d_ff), dtype) * s,
        "w_out": jax.random.normal(ks[1], (d_ff, d), dtype) * (s / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.activation == "swiglu":
        p["w_gate"] = jax.random.normal(ks[2], (d, d_ff), dtype) * s
    return p


def _init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, E, eff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 8)
    s = 0.02
    p = {
        "router": jax.random.normal(ks[0], (d, E), F32) * s,
        "we_in": jax.random.normal(ks[1], (E, d, eff), dtype) * s,
        "we_out": jax.random.normal(ks[2], (E, eff, d), dtype) * (s / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.activation == "swiglu":
        p["we_gate"] = jax.random.normal(ks[3], (E, d, eff), dtype) * s
    if cfg.shared_d_ff:
        p["ws_in"] = jax.random.normal(ks[4], (d, cfg.shared_d_ff), dtype) * s
        p["ws_gate"] = jax.random.normal(ks[5], (d, cfg.shared_d_ff), dtype) * s
        p["ws_out"] = jax.random.normal(ks[6], (cfg.shared_d_ff, d), dtype) * s
        p["shared_gate"] = jax.random.normal(ks[7], (d,), dtype) * s
    if cfg.dense_residual_ff:
        kd = jax.random.split(ks[7], 3)
        p["wd_in"] = jax.random.normal(kd[0], (d, cfg.dense_residual_ff), dtype) * s
        p["wd_gate"] = jax.random.normal(kd[1], (d, cfg.dense_residual_ff), dtype) * s
        p["wd_out"] = jax.random.normal(kd[2], (cfg.dense_residual_ff, d), dtype) * s
    return p


def _init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state_dim
    R = cfg.ssm_dt_rank or -(-d // 16)
    W = cfg.ssm_conv_width
    ks = jax.random.split(key, 6)
    s = 0.02
    return {
        "m_in": jax.random.normal(ks[0], (d, di), dtype) * s,
        "m_z": jax.random.normal(ks[1], (d, di), dtype) * s,
        "m_conv": jax.random.normal(ks[2], (W, di), dtype) * (1.0 / math.sqrt(W)),
        "m_xproj": jax.random.normal(ks[3], (di, R + 2 * N), dtype) * s,
        "m_dtproj": jax.random.normal(ks[4], (R, di), dtype) * (1.0 / math.sqrt(R)),
        "m_Alog": jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=F32), (di, N))),
        "m_D": jnp.ones((di,), F32),
        "m_out": jax.random.normal(ks[5], (di, d), dtype) * (s / math.sqrt(2 * cfg.n_layers)),
    }


def _init_mlstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.xlstm_expand * d
    H = cfg.n_heads
    hd = di // H
    ks = jax.random.split(key, 8)
    s = 0.02
    return {
        "l_up": jax.random.normal(ks[0], (d, di), dtype) * s,
        "l_z": jax.random.normal(ks[1], (d, di), dtype) * s,
        "l_q": jax.random.normal(ks[2], (di, H, hd), dtype) * s,
        "l_k": jax.random.normal(ks[3], (di, H, hd), dtype) * s,
        "l_v": jax.random.normal(ks[4], (di, H, hd), dtype) * s,
        "l_ig": jax.random.normal(ks[5], (di, H), dtype) * s,
        "l_fg": jax.random.normal(ks[6], (di, H), dtype) * s + 1.0,  # bias toward remember
        "l_skip": jnp.ones((di,), dtype),
        "l_down": jax.random.normal(ks[7], (di, d), dtype) * (s / math.sqrt(2 * cfg.n_layers)),
    }


def _init_slstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 3)
    s = 0.02
    return {
        "s_w": jax.random.normal(ks[0], (d, 4, H, hd), dtype) * s,
        "s_r": jax.random.normal(ks[1], (4, H, hd, hd), dtype) * s,
        "s_b": jnp.zeros((4, H, hd), dtype),
        "s_out": jax.random.normal(ks[2], (d, d), dtype) * (s / math.sqrt(2 * cfg.n_layers)),
    }


def _init_layer(key, cfg: ModelConfig, kind: str, dtype) -> dict:
    mixer, _, mlp_kind = kind.partition("+")
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": _norm_params(cfg, cfg.d_model, dtype)}
    if mixer in ("attn", "enc_attn"):
        p["mixer"] = _init_attn(ks[0], cfg, dtype)
    elif mixer == "dec":
        p["mixer"] = _init_attn(ks[0], cfg, dtype)
        p["norm_x"] = _norm_params(cfg, cfg.d_model, dtype)
        p["xattn"] = _init_attn(ks[3], cfg, dtype, cross=True)
    elif mixer == "xattn":
        p["mixer"] = _init_attn(ks[0], cfg, dtype, cross=True)
    elif mixer == "mamba":
        p["mixer"] = _init_mamba(ks[0], cfg, dtype)
    elif mixer == "mlstm":
        p["mixer"] = _init_mlstm(ks[0], cfg, dtype)
    elif mixer == "slstm":
        p["mixer"] = _init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if mlp_kind == "mlp":
        p["norm2"] = _norm_params(cfg, cfg.d_model, dtype)
        p["mlp"] = _init_mlp(ks[1], cfg, cfg.d_ff, dtype)
    elif mlp_kind in ("moe", "moe_dr"):
        p["norm2"] = _norm_params(cfg, cfg.d_model, dtype)
        p["mlp"] = _init_moe(ks[1], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "tok_embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "final_norm": _norm_params(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[1], (cfg.d_model, cfg.vocab), dtype) * 0.02

    def stack_layers(key, kinds, periods):
        def one_period(k):
            ks = jax.random.split(k, len(kinds))
            return {f"{i:02d}_{kind}": _init_layer(ks[i], cfg, kind, dtype)
                    for i, kind in enumerate(kinds)}
        pkeys = jax.random.split(key, periods)
        trees = [one_period(k) for k in pkeys]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    params["blocks"] = stack_layers(keys[2], cfg.block_pattern, cfg.n_periods)
    if cfg.arch_type == "audio":
        params["enc_blocks"] = stack_layers(keys[3], ("enc_attn+mlp",), cfg.encoder_layers)
        params["enc_norm"] = _norm_params(cfg, cfg.d_model, dtype)
    if cfg.arch_type == "vlm":
        params["enc_proj"] = jax.random.normal(keys[4], (cfg.d_model, cfg.d_model), dtype) * 0.02
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ------------------------------------------------------------------ helpers

def _sinusoidal(seq: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(seq, dtype=F32)[:, None]
    dim = jnp.arange(d // 2, dtype=F32)[None]
    ang = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _use_rope(cfg: ModelConfig) -> bool:
    return cfg.arch_type != "audio"


def _logits(cfg: ModelConfig, params, x) -> jax.Array:
    x = L.block_norm(cfg, params["final_norm"], x)
    head = params["tok_embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return shard(logits, ("batch", None, "vocab"))


def _encoder(cfg: ModelConfig, params, embeds) -> jax.Array:
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    B, T, D = embeds.shape
    x = embeds + _sinusoidal(T, D, embeds.dtype)[None]
    positions = jnp.arange(T)

    def body(x, p):
        lp = p["00_enc_attn+mlp"]
        h = L.block_norm(cfg, lp["norm1"], x)
        x = x + L.attention_full(lp["mixer"], h, cfg, positions, causal=False,
                                 use_rope=False)
        h = L.block_norm(cfg, lp["norm2"], x)
        x = x + L.mlp(lp["mlp"], h, cfg.activation)
        return x, None

    x, _ = lax.scan(body, x, params["enc_blocks"])
    return L.block_norm(cfg, params["enc_norm"], x)


def _cross_source(cfg: ModelConfig, params, batch) -> Optional[jax.Array]:
    if cfg.arch_type == "audio":
        return _encoder(cfg, params, batch["encoder_embeds"])
    if cfg.arch_type == "vlm":
        return batch["image_embeds"] @ params["enc_proj"]
    return None


# ------------------------------------------------------------------ full forward

def _layer_full(cfg, kind, p, x, ctx, capacity=None):
    """One layer, full sequence.  Returns (x, cache_slice_or_None, aux)."""
    mixer, _, mlp_kind = kind.partition("+")
    aux = jnp.zeros((), F32)
    cache = None
    h = L.block_norm(cfg, p["norm1"], x)
    # Megatron-SP boundary: gather the sequence-sharded residual HERE, on the bf16
    # post-norm tensor — otherwise GSPMD places the all-gather on an f32 upcast inside
    # the mixer and doubles the wire bytes (EXPERIMENTS.md §Perf, vision train: 38 GiB
    # of f32[16,4096,4096] gathers per scan body).
    h = shard(h, ("batch", None, None))
    if mixer in ("attn", "dec", "enc_attn"):
        out = L.attention_full(p["mixer"], h, cfg, ctx["positions"],
                               causal=mixer != "enc_attn",
                               use_rope=_use_rope(cfg), window=cfg.sliding_window)
        x = x + out
        if capacity is not None:
            cache = _kv_from_full(cfg, p["mixer"], h, ctx, capacity)
        if mixer == "dec":
            hx = L.block_norm(cfg, p["norm_x"], x)
            xout = L.attention_full(p["xattn"], hx, cfg, ctx["positions"],
                                    causal=False, use_rope=False,
                                    kv_input=ctx["enc_out"])
            x = x + xout
            if capacity is not None:
                cache.update(_cross_kv(cfg, p["xattn"], ctx["enc_out"]))
    elif mixer == "xattn":
        out = L.attention_full(p["mixer"], h, cfg, ctx["positions"], causal=False,
                               use_rope=False, kv_input=ctx["enc_out"])
        x = x + jnp.tanh(p["mixer"]["xgate"]) * out
        if capacity is not None:
            cache = _cross_kv(cfg, p["mixer"], ctx["enc_out"])
    elif mixer == "mamba":
        x = x + L.mamba_full(p["mixer"], h, cfg)
        if capacity is not None:
            cache = _mamba_state_from_full(cfg, p["mixer"], h)
    elif mixer == "mlstm":
        x = x + L.mlstm_full(p["mixer"], h, cfg)
        if capacity is not None:
            cache = _mlstm_state_from_full(cfg, p["mixer"], h)
    elif mixer == "slstm":
        x = x + L.slstm_full(p["mixer"], h, cfg)
        if capacity is not None:
            cache = _slstm_state_from_full(cfg, p["mixer"], h)
    else:
        raise ValueError(mixer)
    if mlp_kind:
        h = L.block_norm(cfg, p["norm2"], x)
        h = shard(h, ("batch", None, None))      # bf16 SP gather (see above)
        if mlp_kind == "mlp":
            x = x + L.mlp(p["mlp"], h, cfg.activation)
        else:
            out, aux = L.moe(p["mlp"], h, cfg)
            x = x + out
    return x, cache, aux


def forward_full(cfg: ModelConfig, params, batch, capacity: Optional[int] = None,
                 remat: bool = False, return_hidden: bool = False):
    """Full-sequence forward.  batch["tokens"]: (B, S).

    Returns (logits, aux_loss) or, with ``capacity``, (logits, aux_loss, cache) where
    cache decodes from position S onward.  ``remat=True`` checkpoints each period
    (training memory: only the per-period residual stream is stored, and it is
    sequence-sharded on the model axis, Megatron-SP style).  ``return_hidden=True``
    returns the final-normed hidden states instead of logits — used by the fused
    chunked cross-entropy (rl/grpo.py) so full logits are never materialized.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    seq_ax = "act_seq" if cfg.sequence_parallel else None
    x = params["tok_embed"][tokens]
    x = shard(x, ("batch", seq_ax, None))
    if cfg.arch_type == "audio":
        x = x + _sinusoidal(S, cfg.d_model, x.dtype)[None]
    ctx = {"positions": jnp.arange(S), "enc_out": _cross_source(cfg, params, batch)}

    def body(carry, p_period):
        x, aux = carry
        caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            keyname = f"{i:02d}_{kind}"
            x, cache, a = _layer_full(cfg, kind, p_period[keyname], x, ctx, capacity)
            aux = aux + a
            if capacity is not None:
                caches[keyname] = cache
        x = shard(x, ("batch", seq_ax, None))        # (sequence-parallel) residual store
        return (x, aux), (caches if capacity is not None else None)

    if remat:
        body = jax.checkpoint(body)
    (x, aux), stacked_caches = lax.scan(body, (x, jnp.zeros((), F32)), params["blocks"])
    if return_hidden:
        return L.block_norm(cfg, params["final_norm"], x), aux
    logits = _logits(cfg, params, x)
    if capacity is None:
        return logits, aux
    cache = {"pos": jnp.full((B,), S, jnp.int32), "blocks": stacked_caches}
    return logits, aux, cache


# ---- cache construction from a full forward (prefill) -------------------------

def _kv_from_full(cfg, p, h, ctx, capacity):
    B, S, _ = h.shape
    k = jnp.einsum("btd,dnk->btnk", h, p["wk"])
    v = jnp.einsum("btd,dnk->btnk", h, p["wv"])
    if cfg.qk_norm:
        k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if _use_rope(cfg):
        k = L.rope(k, ctx["positions"], cfg.rope_theta)
    KV, hd = cfg.n_kv_heads, cfg.hd
    dtype = k.dtype
    ck = jnp.zeros((B, capacity, KV, hd), dtype)
    cv = jnp.zeros((B, capacity, KV, hd), dtype)
    if capacity >= S:
        ck = lax.dynamic_update_slice(ck, k, (0, 0, 0, 0))
        cv = lax.dynamic_update_slice(cv, v, (0, 0, 0, 0))
    else:  # sliding window: keep last `capacity` tokens at ring slots pos % capacity
        keep = jnp.arange(S - capacity, S)
        slots = keep % capacity
        ck = ck.at[:, slots].set(k[:, keep])
        cv = cv.at[:, slots].set(v[:, keep])
    return {"k": ck, "v": cv}


def _cross_kv(cfg, p, enc_out):
    xk = jnp.einsum("btd,dnk->btnk", enc_out, p["wk"])
    xv = jnp.einsum("btd,dnk->btnk", enc_out, p["wv"])
    return {"xk": xk, "xv": xv}


def _mamba_state_from_full(cfg, p, h):
    B, S, _ = h.shape
    xi = h @ p["m_in"]
    W = cfg.ssm_conv_width
    xp = jnp.pad(xi, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(xp[:, i:i + S] * p["m_conv"][i] for i in range(W))
    xc = jax.nn.silu(conv)
    a, b, _ = L._mamba_inner(p, xc, cfg)
    h0 = jnp.zeros(a.shape[:1] + a.shape[2:], jnp.float32)
    _, h_last = L._mamba_scan_chunked(a, b, h0)
    return {"h": h_last, "conv": xp[:, S:S + W - 1] if W > 1 else
            jnp.zeros((B, 0, xi.shape[-1]), xi.dtype)}


def _mlstm_state_from_full(cfg, p, h):
    # Rerun the chunked scan, keep final carry.  (Shares math with mlstm_full; the
    # small recompute keeps the public API simple.)
    B, S, _ = h.shape
    di = p["l_up"].shape[1]
    H = cfg.n_heads
    hd = di // H
    xi = h @ p["l_up"]
    q, k, v, i_pre, f_pre = L._mlstm_qkv(p, xi)
    state = {"C": jnp.zeros((B, H, hd, hd), F32), "n": jnp.zeros((B, H, hd), F32),
             "m": jnp.full((B, H), -1e30, F32)}

    def step(st, args):
        kt, vt, it, ft = args
        kt = kt / math.sqrt(hd)
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + st["m"], it)
        fw = jnp.exp(logf + st["m"] - m_new)[..., None]
        iw = jnp.exp(it - m_new)[..., None]
        C = st["C"] * fw[..., None] + iw[..., None] * jnp.einsum(
            "bhd,bhe->bhde", kt.astype(F32), vt.astype(F32))
        n = st["n"] * fw + iw * kt.astype(F32)
        return {"C": C, "n": n, "m": m_new}, None

    xs = (k.transpose(1, 0, 2, 3), v.transpose(1, 0, 2, 3),
          i_pre.transpose(1, 0, 2), f_pre.transpose(1, 0, 2))
    state, _ = lax.scan(step, state, xs)
    return state


def _slstm_state_from_full(cfg, p, h):
    B, S, D = h.shape
    H = cfg.n_heads
    hd = D // H
    xt = jnp.einsum("bsd,dghe->bsghe", h, p["s_w"])
    state = {k: jnp.zeros((B, H, hd), F32) for k in ("h", "c", "n")}
    state["m"] = jnp.full((B, H, hd), -1e30, F32)

    def step(st, xt_t):
        return L._slstm_cell(p, xt_t, st), None

    state, _ = lax.scan(step, state, xt.transpose(1, 0, 2, 3, 4))
    return state


# ------------------------------------------------------------------ decode

def _merge_state(active, new, old):
    """Keep ``old`` state on inactive lanes (slot-pool masked decode).

    Only recurrent mixers need this: their state update is destructive.  Attention KV
    caches are *self-healing* under a frozen ``pos`` — a masked step writes at the same
    slot the resuming token will overwrite — so they skip the merge (see docs/engine.md).
    """
    if active is None:
        return new

    def sel(n, o):
        m = active.reshape(active.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o.astype(n.dtype))

    return jax.tree.map(sel, new, old)


def _layer_step(cfg, kind, p, x, cache, pos, active=None, page_table=None):
    mixer, _, mlp_kind = kind.partition("+")
    new_cache = cache
    h = L.block_norm(cfg, p["norm1"], x)
    if mixer == "attn" and page_table is not None:
        out, ck, cv = L.attention_decode_paged(p["mixer"], h, cfg, cache["k"],
                                               cache["v"], page_table, pos,
                                               use_rope=_use_rope(cfg))
        x = x + out
        new_cache = dict(cache, k=ck, v=cv)
    elif mixer in ("attn", "dec"):
        out, ck, cv = L.attention_decode(p["mixer"], h, cfg, cache["k"], cache["v"],
                                         pos, window=cfg.sliding_window,
                                         use_rope=_use_rope(cfg))
        x = x + out
        new_cache = dict(cache, k=ck, v=cv)
        if mixer == "dec":
            hx = L.block_norm(cfg, p["norm_x"], x)
            x = x + L.cross_attention_decode(p["xattn"], hx, cfg,
                                             cache["xk"], cache["xv"])
    elif mixer == "xattn":
        out = L.cross_attention_decode(p["mixer"], h, cfg, cache["xk"], cache["xv"])
        x = x + jnp.tanh(p["mixer"]["xgate"]) * out
    elif mixer == "mamba":
        out, new_cache = L.mamba_step(p["mixer"], h, cfg, cache)
        new_cache = _merge_state(active, new_cache, cache)
        x = x + out
    elif mixer == "mlstm":
        out, new_cache = L.mlstm_step(p["mixer"], h, cfg, cache)
        new_cache = _merge_state(active, new_cache, cache)
        x = x + out
    elif mixer == "slstm":
        out, new_cache = L.slstm_step(p["mixer"], h, cfg, cache)
        new_cache = _merge_state(active, new_cache, cache)
        x = x + out
    else:
        raise ValueError(mixer)
    if mlp_kind:
        h = L.block_norm(cfg, p["norm2"], x)
        if mlp_kind == "mlp":
            x = x + L.mlp(p["mlp"], h, cfg.activation)
        else:
            out, _ = L.moe(p["mlp"], h, cfg)
            x = x + out
    return x, new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens, active=None):
    """One decode step.  tokens: (B, 1) int32; cache["pos"]: (B,) per-slot positions
    (continuous batching).  Returns (logits (B, V), cache').

    ``active``: optional (B,) bool slot mask.  Inactive lanes do not advance ``pos``
    and keep their recurrent state; their attention KV write lands at the frozen
    ``pos`` slot and is overwritten when the lane resumes, so a preempted / finished /
    free lane can ride along in the batch at zero bookkeeping cost (slot-pool
    continuous batching — the lane's emitted logits are garbage and must be masked
    by the caller).
    """
    pos = cache["pos"]
    page_table = cache.get("page_table")       # paged pool: blocks are shared pages
    x = params["tok_embed"][tokens]
    x = shard(x, ("batch", None, None))
    if cfg.arch_type == "audio":
        d = cfg.d_model
        x = x + _sinusoidal_at(pos, d, x.dtype)

    def body(x, xs):
        p_period, c_period = xs
        new_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            keyname = f"{i:02d}_{kind}"
            x, new_c[keyname] = _layer_step(cfg, kind, p_period[keyname], x,
                                            c_period[keyname], pos, active,
                                            page_table)
        return x, new_c

    x, new_blocks = lax.scan(body, x, (params["blocks"], cache["blocks"]))
    logits = _logits(cfg, params, x)
    new_pos = pos + 1 if active is None else pos + active.astype(jnp.int32)
    new_cache = {"pos": new_pos, "blocks": new_blocks}
    if page_table is not None:
        new_cache["page_table"] = page_table
    return logits[:, 0], new_cache


# ------------------------------------------------------------------ chunked prefill
#
# ``prefill_chunk`` processes a fixed-shape (1, C) token chunk at an arbitrary
# position offset against an existing batch-1 lane cache: attention layers write the
# chunk's K/V into the lane slice and attend to resident + own-causal keys
# (layers.attention_prefill_chunk); recurrent layers run their exact one-token step
# cells over the chunk inside a single fused scan, masking padding rows so the state
# carry is position-exact.  A prompt of any length runs as ceil(S/C) reuses of ONE
# compiled kernel (off/length are traced), and suffix prefill at offset > 0 — tool
# absorption, prefix-reuse admission — is the same code path.  Logits are not
# computed: the engine's decode loop re-feeds the last context token, exactly as it
# does after a full prefill.

def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked prefill serves linear (non-ring) caches without cross-attention or MoE
    (MoE capacity dispatch would let padding rows displace real tokens)."""
    for kind in cfg.block_pattern:
        mixer, _, mlp_kind = kind.partition("+")
        if mixer not in ("attn", "mamba", "mlstm", "slstm"):
            return False
        if mlp_kind not in ("", "mlp"):
            return False
    return cfg.sliding_window == 0 and cfg.arch_type not in ("audio", "vlm")


def supports_prefix_reuse(cfg: ModelConfig) -> bool:
    """Prefix KV implanting needs position-sliceable caches: attention-only stacks
    (recurrent mixers only retain their *final* state, not per-position snapshots)."""
    return supports_chunked_prefill(cfg) and all(
        k.partition("+")[0] == "attn" for k in cfg.block_pattern)


def _recurrent_chunk(step_fn, p, h, cfg, state, length):
    """Run a one-token recurrent step cell over a (1, C) chunk inside one scan.

    Padding rows (index >= ``length``) keep the previous state (recurrent updates are
    destructive, unlike the self-healing KV writes).  Returns (out (1, C, d), state')."""
    Cn = h.shape[1]

    def body(st, inp):
        h_t, idx = inp                               # h_t: (1, d)
        out, new = step_fn(p, h_t[:, None], cfg, st)
        valid = idx < length
        new = jax.tree.map(lambda n, o: jnp.where(valid, n, o.astype(n.dtype)),
                           new, st)
        return new, out[:, 0]

    state, outs = lax.scan(body, state, (h.transpose(1, 0, 2), jnp.arange(Cn)))
    return outs.transpose(1, 0, 2), state


def _layer_chunk(cfg, kind, p, x, cache, off, length):
    mixer, _, mlp_kind = kind.partition("+")
    new_cache = cache
    h = L.block_norm(cfg, p["norm1"], x)
    if mixer == "attn":
        out, ck, cv = L.attention_prefill_chunk(p["mixer"], h, cfg, cache["k"],
                                                cache["v"], off, length,
                                                use_rope=_use_rope(cfg))
        x = x + out
        new_cache = dict(cache, k=ck, v=cv)
    elif mixer == "mamba":
        out, new_cache = _recurrent_chunk(L.mamba_step, p["mixer"], h, cfg, cache,
                                          length)
        x = x + out
    elif mixer == "mlstm":
        out, new_cache = _recurrent_chunk(L.mlstm_step, p["mixer"], h, cfg, cache,
                                          length)
        x = x + out
    elif mixer == "slstm":
        out, new_cache = _recurrent_chunk(L.slstm_step, p["mixer"], h, cfg, cache,
                                          length)
        x = x + out
    else:
        raise ValueError(f"prefill_chunk: unsupported mixer {mixer!r} "
                         "(see supports_chunked_prefill)")
    if mlp_kind == "mlp":
        h = L.block_norm(cfg, p["norm2"], x)
        x = x + L.mlp(p["mlp"], h, cfg.activation)
    elif mlp_kind:
        raise ValueError("prefill_chunk: MoE layers are not chunk-safe "
                         "(padding rows would consume expert capacity)")
    return x, new_cache


def prefill_chunk(cfg: ModelConfig, params, cache: dict, tokens: jax.Array,
                  length) -> dict:
    """Teacher-force a fixed-shape (1, C) chunk into a batch-1 lane cache.

    ``tokens``: (1, C) int32, rows >= ``length`` are padding; ``length``: traced
    scalar count of valid tokens.  The chunk lands at positions
    ``pos .. pos + length`` where ``pos = cache["pos"][0]``.  Returns the updated
    lane with ``pos`` advanced by ``length``.
    """
    assert tokens.shape[0] == 1, "prefill_chunk operates on one lane (batch 1)"
    off = cache["pos"][0]
    length = jnp.asarray(length, jnp.int32)
    x = params["tok_embed"][tokens]
    x = shard(x, ("batch", None, None))

    def body(x, xs):
        p_period, c_period = xs
        new_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            keyname = f"{i:02d}_{kind}"
            x, new_c[keyname] = _layer_chunk(cfg, kind, p_period[keyname], x,
                                             c_period[keyname], off, length)
        return x, new_c

    _, new_blocks = lax.scan(body, x, (params["blocks"], cache["blocks"]))
    return {"pos": cache["pos"] + length, "blocks": new_blocks}


def copy_prefix(pool: dict, src_slot, lane: dict, n) -> dict:
    """Implant the first ``n`` cache positions of pool lane ``src_slot`` into a
    batch-1 ``lane`` (radix-cache prefix reuse: GRPO siblings / multi-turn
    re-entries pay O(suffix) prefill instead of O(full prompt)).

    Attention-only caches: every blocks leaf is (P, B, cap, KV, hd) with the
    position axis at 2.  ``src_slot``/``n`` are traced, so one compiled kernel
    serves every (source lane, match length).  Sets ``lane["pos"] = n``.
    """
    src_slot = jnp.asarray(src_slot, jnp.int32)
    n = jnp.asarray(n, jnp.int32)

    def blend(dst, src):
        src_lane = lax.dynamic_slice_in_dim(src, src_slot, 1, axis=1)
        keep = jnp.arange(dst.shape[2])[None, None, :, None, None] < n
        return jnp.where(keep, src_lane.astype(dst.dtype), dst)

    blocks = jax.tree.map(blend, lane["blocks"], pool["blocks"])
    pos = jnp.full_like(lane["pos"], n)
    return {"pos": pos, "blocks": blocks}


def _sinusoidal_at(pos, d, dtype):
    pos = jnp.atleast_1d(pos).astype(F32)                    # (B,) per-slot positions
    dim = jnp.arange(d // 2, dtype=F32)
    ang = pos[:, None] / jnp.power(10_000.0, 2 * dim / d)[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)[:, None].astype(dtype)


def init_cache(cfg: ModelConfig, params, batch_size: int, capacity: int,
               enc_out: Optional[jax.Array] = None, start_pos: int = 0) -> dict:
    """Empty decode cache (used by the dry-run's serve_step input_specs and the engine).

    ``capacity`` is the KV slot count (window size when cfg.sliding_window is set).
    """
    dtype = jnp.dtype(cfg.dtype)
    KV, hd = cfg.n_kv_heads, cfg.hd
    B, P = batch_size, cfg.n_periods

    def per_kind(kind):
        mixer = kind.partition("+")[0]
        if mixer == "attn":
            return {"k": jnp.zeros((P, B, capacity, KV, hd), dtype),
                    "v": jnp.zeros((P, B, capacity, KV, hd), dtype)}
        if mixer == "dec":
            c = {"k": jnp.zeros((P, B, capacity, KV, hd), dtype),
                 "v": jnp.zeros((P, B, capacity, KV, hd), dtype)}
            c.update(_stack_cross(kind))
            return c
        if mixer == "xattn":
            return _stack_cross(kind)
        if mixer == "mamba":
            di = cfg.ssm_expand * cfg.d_model
            return {"h": jnp.zeros((P, B, di, cfg.ssm_state_dim), F32),
                    "conv": jnp.zeros((P, B, cfg.ssm_conv_width - 1, di), dtype)}
        if mixer == "mlstm":
            di = cfg.xlstm_expand * cfg.d_model
            hdi = di // cfg.n_heads
            return {"C": jnp.zeros((P, B, cfg.n_heads, hdi, hdi), F32),
                    "n": jnp.zeros((P, B, cfg.n_heads, hdi), F32),
                    "m": jnp.full((P, B, cfg.n_heads), -1e30, F32)}
        if mixer == "slstm":
            hdm = cfg.d_model // cfg.n_heads
            st = {k: jnp.zeros((P, B, cfg.n_heads, hdm), F32) for k in ("h", "c", "n")}
            st["m"] = jnp.full((P, B, cfg.n_heads, hdm), -1e30, F32)
            return st
        raise ValueError(kind)

    def _stack_cross(kind):
        assert enc_out is not None, "cross-attention cache needs encoder output"
        # same cross KV per period position: recompute per period via stacked params
        idx = [i for i, k in enumerate(cfg.block_pattern) if k == kind]
        del idx
        return {"xk": jnp.zeros((P, B, enc_out.shape[1], KV, hd), dtype),
                "xv": jnp.zeros((P, B, enc_out.shape[1], KV, hd), dtype)}

    blocks = {f"{i:02d}_{kind}": per_kind(kind)
              for i, kind in enumerate(cfg.block_pattern)}
    return {"pos": jnp.full((batch_size,), start_pos, jnp.int32), "blocks": blocks}


# ------------------------------------------------------------------ slot-pool ops
#
# A slot-pool cache is an ordinary decode cache whose batch dimension is a pool of
# ``max_slots`` lanes.  Sequences are admitted by writing a batch-1 cache into a free
# lane (``write_slot``), decode runs over the whole pool with an active-slot mask
# (``decode_step(..., active=mask)``), preemption is a mask flip, and migration moves
# one lane (``gather_slots`` -> host -> ``write_slot`` on the destination pool).
# Blocks leaves are laid out (n_periods, B, ...): the lane axis is axis 1; ``pos`` is
# (B,).

def write_slot(pool: dict, lane: dict, slot) -> dict:
    """Write a batch-1 cache ``lane`` into lane ``slot`` of a slot-pool cache.

    Uses ``lax.dynamic_update_slice`` so, under jit with the pool donated, XLA updates
    the lane in place — admission cost is O(lane), not O(pool).
    """
    slot = jnp.asarray(slot, jnp.int32)
    zero = jnp.zeros((), jnp.int32)

    def upd(dst, src):
        start = (zero, slot) + (zero,) * (dst.ndim - 2)
        return lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)

    blocks = jax.tree.map(upd, pool["blocks"], lane["blocks"])
    pos = lax.dynamic_update_slice(
        pool["pos"], lane["pos"].astype(pool["pos"].dtype), (slot,))
    return {"pos": pos, "blocks": blocks}


def gather_slots(pool: dict, idx) -> dict:
    """Extract lanes ``idx`` from a slot-pool cache as a standalone batch-len(idx)
    cache (KV migration packages one lane; parity tests compare lanes)."""
    idx = jnp.asarray(idx, jnp.int32)
    return {"pos": pool["pos"][idx],
            "blocks": jax.tree.map(lambda x: x[:, idx], pool["blocks"])}


def concat_pools(a: dict, b: dict) -> dict:
    """Concatenate two slot-pool caches along the lane axis (pool growth)."""
    return {"pos": jnp.concatenate([a["pos"], b["pos"]]),
            "blocks": jax.tree.map(lambda x, y: jnp.concatenate([x, y], axis=1),
                                   a["blocks"], b["blocks"])}


# ------------------------------------------------------------------ paged-KV pool ops
#
# A paged pool replaces the per-lane (P, B, capacity, KV, hd) attention leaves with
# physical block pools (P, num_blocks, page_size, KV, hd) shared by every lane, plus
# a (B, num_pages) ``page_table`` mapping logical page index -> block id per lane
# (block 0 is reserved scratch: unmapped entries — and any masked lane's self-healing
# write — resolve there).  Recurrent state, cross-KV and ``pos`` keep their dense
# per-lane layout: only position-indexed attention KV pages.  Host-side block
# bookkeeping (alloc/free/refcount sharing) lives in ``engine.paging.PagePool``.

def supports_paged_kv(cfg: ModelConfig) -> bool:
    """Paged KV serves linear (non-ring) decoder-only stacks: sliding-window ring
    writes would wrap across page boundaries, and cross-attention KV is not
    position-paged.  MoE and recurrent mixers are fine — recurrent state simply
    stays dense per-lane."""
    for kind in cfg.block_pattern:
        if kind.partition("+")[0] not in ("attn", "mamba", "mlstm", "slstm"):
            return False
    return cfg.sliding_window == 0 and cfg.arch_type not in ("audio", "vlm")


def _paged_kind(kind: str) -> bool:
    return kind.partition("+")[0] == "attn"


def init_paged_pool(cfg: ModelConfig, params, max_lanes: int, num_blocks: int,
                    page_size: int, num_pages: int) -> dict:
    """Empty paged pool: block pools for attention KV, dense lanes for the rest."""
    base = init_cache(cfg, params, max_lanes, capacity=0)   # attn leaves are empty
    dtype = jnp.dtype(cfg.dtype)
    KV, hd, P = cfg.n_kv_heads, cfg.hd, cfg.n_periods
    blocks = {}
    for key, c in base["blocks"].items():
        if _paged_kind(key[3:]):
            blocks[key] = {"k": jnp.zeros((P, num_blocks, page_size, KV, hd), dtype),
                           "v": jnp.zeros((P, num_blocks, page_size, KV, hd), dtype)}
        else:
            blocks[key] = c
    return {"pos": base["pos"],
            "page_table": jnp.zeros((max_lanes, num_pages), jnp.int32),
            "blocks": blocks}


def _layer_chunk_paged(cfg, kind, p, x, cache, pt_row, slot, off, length):
    mixer, _, mlp_kind = kind.partition("+")
    new_cache = cache
    h = L.block_norm(cfg, p["norm1"], x)
    if mixer == "attn":
        out, ck, cv = L.attention_prefill_chunk_paged(
            p["mixer"], h, cfg, cache["k"], cache["v"], pt_row, off, length,
            use_rope=_use_rope(cfg))
        x = x + out
        new_cache = dict(cache, k=ck, v=cv)
    elif mixer in ("mamba", "mlstm", "slstm"):
        step_fn = {"mamba": L.mamba_step, "mlstm": L.mlstm_step,
                   "slstm": L.slstm_step}[mixer]
        state = jax.tree.map(lambda s: lax.dynamic_slice_in_dim(s, slot, 1, axis=0),
                             cache)
        out, state = _recurrent_chunk(step_fn, p["mixer"], h, cfg, state, length)
        x = x + out
        new_cache = jax.tree.map(
            lambda c, s: lax.dynamic_update_slice_in_dim(c, s.astype(c.dtype),
                                                         slot, axis=0),
            cache, state)
    else:
        raise ValueError(f"prefill_chunk_paged: unsupported mixer {mixer!r} "
                         "(see supports_paged_kv)")
    if mlp_kind == "mlp":
        h = L.block_norm(cfg, p["norm2"], x)
        x = x + L.mlp(p["mlp"], h, cfg.activation)
    elif mlp_kind:
        raise ValueError("prefill_chunk_paged: MoE layers are not chunk-safe "
                         "(padding rows would consume expert capacity)")
    return x, new_cache


def prefill_chunk_paged(cfg: ModelConfig, params, pool: dict, slot,
                        tokens: jax.Array, length) -> dict:
    """Teacher-force a fixed-shape (1, C) chunk straight into lane ``slot``'s pages.

    The paged analogue of :func:`prefill_chunk`, minus the gather/implant round
    trip: attention K/V scatters to the lane's mapped blocks at absolute
    positions, queries attend through the gathered page view (resident prefix —
    possibly *shared* pages — plus the chunk's own causal keys), and recurrent
    state updates its dense lane row in place.  ``slot``/``length`` are traced,
    so one compiled kernel serves every (lane, offset, tail-length).
    """
    assert tokens.shape[0] == 1, "prefill_chunk_paged operates on one lane"
    slot = jnp.asarray(slot, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    off = pool["pos"][slot]
    pt_row = pool["page_table"][slot]
    x = params["tok_embed"][tokens]
    x = shard(x, ("batch", None, None))

    def body(x, xs):
        p_period, c_period = xs
        new_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            keyname = f"{i:02d}_{kind}"
            x, new_c[keyname] = _layer_chunk_paged(cfg, kind, p_period[keyname], x,
                                                   c_period[keyname], pt_row, slot,
                                                   off, length)
        return x, new_c

    _, new_blocks = lax.scan(body, x, (params["blocks"], pool["blocks"]))
    return {"pos": pool["pos"].at[slot].add(length),
            "page_table": pool["page_table"], "blocks": new_blocks}


def paged_set_lane(pool: dict, slot, row, pos0) -> dict:
    """Map lane ``slot``: write its page-table row and reset its position.
    ``row``: (num_pages,) int32, unmapped tail zeroed (scratch)."""
    slot = jnp.asarray(slot, jnp.int32)
    return {"pos": pool["pos"].at[slot].set(jnp.asarray(pos0, jnp.int32)),
            "page_table": pool["page_table"].at[slot].set(
                jnp.asarray(row, jnp.int32)),
            "blocks": pool["blocks"]}


def paged_copy_block(pool: dict, dst, src) -> dict:
    """Device-to-device copy of one physical block across every paged leaf
    (the boundary partial page of a prefix share is privately copied so the
    sibling's suffix writes never touch the shared block)."""
    dst = jnp.asarray(dst, jnp.int32)
    src = jnp.asarray(src, jnp.int32)
    blocks = {}
    for key, c in pool["blocks"].items():
        if _paged_kind(key[3:]):
            blocks[key] = {
                name: leaf.at[:, dst].set(
                    lax.dynamic_slice_in_dim(leaf, src, 1, axis=1)[:, 0])
                for name, leaf in c.items()}
        else:
            blocks[key] = c
    return {**pool, "blocks": blocks}


def paged_write_lane(pool: dict, lane: dict, slot, row, n) -> dict:
    """Implant a dense batch-1 ``lane`` into the paged pool: scatter its first
    ``n`` KV positions into the blocks mapped by ``row``, write its dense
    per-lane leaves into lane ``slot`` (non-chunkable admission and the
    cross-degree migration/restore fallback)."""
    slot = jnp.asarray(slot, jnp.int32)
    n = jnp.asarray(n, jnp.int32)
    row = jnp.asarray(row, jnp.int32)
    num_pages = row.shape[0]
    zero = jnp.zeros((), jnp.int32)
    blocks = {}
    for key, c in pool["blocks"].items():
        src = lane["blocks"][key]
        if _paged_kind(key[3:]):
            ps = c["k"].shape[2]
            cap = src["k"].shape[2]
            j = jnp.arange(cap)
            page = jnp.clip(j // ps, 0, num_pages - 1)
            blk = jnp.where(j < n, row[page], 0)        # padding -> scratch
            off = j % ps
            blocks[key] = {
                name: c[name].at[:, blk, off].set(
                    src[name][:, 0].astype(c[name].dtype))
                for name in c}
        else:
            def upd(dst, s):
                start = (zero, slot) + (zero,) * (dst.ndim - 2)
                return lax.dynamic_update_slice(dst, s.astype(dst.dtype), start)
            blocks[key] = jax.tree.map(upd, c, src)
    pos = pool["pos"].at[slot].set(lane["pos"][0].astype(pool["pos"].dtype))
    return {"pos": pos, "page_table": pool["page_table"].at[slot].set(row),
            "blocks": blocks}


def paged_gather_pages(pool: dict, blocks_idx) -> dict:
    """Pull physical blocks ``blocks_idx`` out of every paged leaf as compact
    (P, n, page_size, KV, hd) stacks — the D2D migration payload (only the
    lane's *resident* pages move, never the full preallocated lane)."""
    idx = jnp.asarray(blocks_idx, jnp.int32)
    return {key: {name: leaf[:, idx] for name, leaf in c.items()}
            for key, c in pool["blocks"].items() if _paged_kind(key[3:])}


def paged_gather_state(pool: dict, slot: int) -> dict:
    """Batch-1 view of lane ``slot``'s dense (non-paged) leaves + ``pos``."""
    blocks = {key: jax.tree.map(lambda x: x[:, slot:slot + 1], c)
              for key, c in pool["blocks"].items() if not _paged_kind(key[3:])}
    return {"pos": pool["pos"][slot:slot + 1], "blocks": blocks}


def paged_scatter_pages(pool: dict, pages: dict, blocks_idx) -> dict:
    """Write page stacks (from :func:`paged_gather_pages`) into physical blocks
    ``blocks_idx`` — the D2D migration ingress."""
    idx = jnp.asarray(blocks_idx, jnp.int32)
    blocks = dict(pool["blocks"])
    for key, pg in pages.items():
        c = blocks[key]
        blocks[key] = {name: c[name].at[:, idx].set(
            jnp.asarray(pg[name]).astype(c[name].dtype)) for name in c}
    return {**pool, "blocks": blocks}


def paged_write_state(pool: dict, state: dict, slot, row) -> dict:
    """Write a batch-1 dense-leaf ``state`` (from :func:`paged_gather_state`)
    into lane ``slot`` and map its page-table row."""
    slot = jnp.asarray(slot, jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    blocks = dict(pool["blocks"])
    for key, c in state["blocks"].items():
        def upd(dst, src):
            start = (zero, slot) + (zero,) * (dst.ndim - 2)
            return lax.dynamic_update_slice(dst, jnp.asarray(src).astype(dst.dtype),
                                            start)
        blocks[key] = jax.tree.map(upd, pool["blocks"][key], c)
    pos = pool["pos"].at[slot].set(jnp.asarray(state["pos"])[0])
    return {"pos": pos,
            "page_table": pool["page_table"].at[slot].set(jnp.asarray(row, jnp.int32)),
            "blocks": blocks}


def pages_to_lane(pages: dict, state: dict, capacity: int) -> dict:
    """Reassemble a dense batch-1 lane from gathered pages + lane state (the
    cross-degree / checkpoint-restore fallback: page stacks flatten back to a
    contiguous (P, 1, capacity, KV, hd) lane, zero-padded past the resident
    span)."""
    blocks = {key: jax.tree.map(jnp.asarray, c) for key, c in state["blocks"].items()}
    for key, pg in pages.items():
        out = {}
        for name, x in pg.items():
            x = jnp.asarray(x)
            P, n, ps = x.shape[:3]
            flat = x.reshape((P, n * ps) + x.shape[3:])
            pad = capacity - n * ps
            if pad > 0:
                flat = jnp.pad(flat, ((0, 0), (0, pad)) + ((0, 0),) * (flat.ndim - 2))
            else:
                flat = flat[:, :capacity]
            out[name] = flat[:, None]                   # add the lane axis
        blocks[key] = out
    return {"pos": jnp.asarray(state["pos"]), "blocks": blocks}


def grow_paged_blocks(pool: dict, extra: int) -> dict:
    """Append ``extra`` zeroed physical blocks to every paged leaf (block-pool
    growth: page tables are unaffected — block ids are stable)."""
    blocks = {}
    for key, c in pool["blocks"].items():
        if _paged_kind(key[3:]):
            blocks[key] = {
                name: jnp.concatenate(
                    [leaf, jnp.zeros((leaf.shape[0], extra) + leaf.shape[2:],
                                     leaf.dtype)], axis=1)
                for name, leaf in c.items()}
        else:
            blocks[key] = c
    return {**pool, "blocks": blocks}


def grow_paged_lanes(cfg: ModelConfig, pool: dict, extra: int) -> dict:
    """Append ``extra`` empty lanes: dense per-lane leaves and page-table rows
    grow; the physical block pools are untouched (lane count and block count
    scale independently — the whole point of paging)."""
    fresh = init_cache(cfg, None, extra, capacity=0)
    blocks = {}
    for key, c in pool["blocks"].items():
        if _paged_kind(key[3:]):
            blocks[key] = c
        else:
            blocks[key] = jax.tree.map(
                lambda x, y: jnp.concatenate([x, y.astype(x.dtype)], axis=1),
                c, fresh["blocks"][key])
    num_pages = pool["page_table"].shape[1]
    return {"pos": jnp.concatenate([pool["pos"], fresh["pos"]]),
            "page_table": jnp.concatenate(
                [pool["page_table"], jnp.zeros((extra, num_pages), jnp.int32)]),
            "blocks": blocks}
