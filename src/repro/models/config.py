"""Unified model configuration for the 10 assigned architectures.

A model is a stack of *periods*: ``block_pattern`` lists the layer kinds of one period
(``"<mixer>+<mlp>"``), repeated ``n_periods`` times.  Homogeneous stacks are a period of
one layer.  This lets jax.lax.scan run over periods (stacked params) while heterogeneous
interleaves (jamba's 7:1 mamba:attn, llama-3.2-vision's every-5th cross-attention) stay
expressible.

Mixers: attn | mamba | mlstm | slstm | xattn (cross-attention) | dec (self+cross)
MLPs:   mlp | moe | moe_dr (MoE + parallel dense residual, arctic) | none
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                       # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    block_pattern: Tuple[str, ...]       # one period of layer kinds
    n_periods: int
    head_dim: int = 0                    # 0 -> d_model // n_heads
    activation: str = "swiglu"           # swiglu | relu2 | gelu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                    # per-expert hidden size
    shared_d_ff: int = 0                 # fused shared-experts hidden size (qwen2-moe)
    dense_residual_ff: int = 0           # parallel dense MLP hidden (arctic)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM / xLSTM ---------------------------------------------------------
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0                 # 0 -> ceil(d_model / 16)
    xlstm_expand: int = 2

    # --- encoder-decoder (audio) --------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0                 # precomputed frame embeddings (frontend stub)

    # --- VLM -----------------------------------------------------------------
    image_seq: int = 0                   # precomputed patch embeddings (frontend stub)

    # --- attention variant ----------------------------------------------------
    sliding_window: int = 0              # 0 = full attention
    # Route decode-phase attention through the Pallas flash-decode kernel
    # (kernels/decode_attention.py).  Off-TPU the kernel runs in interpret mode —
    # correct but slow, so the default stays on the jnp oracle except on TPU.
    use_pallas_decode: bool = False

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # Megatron-SP residual sharding: big memory saver for deep dense stacks, but a
    # collective-term loser for cross-attention-heavy archs (EXPERIMENTS.md §Perf
    # pair b: vision train is collective-bound; SP-off cut the dominant term 0.61x).
    sequence_parallel: bool = True

    # ------------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        return len(self.block_pattern) * self.n_periods

    @property
    def q_groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    def layer_kinds(self) -> list[str]:
        return list(self.block_pattern) * self.n_periods

    def has_kv_cache(self) -> bool:
        return any(k.split("+")[0] in ("attn", "dec") for k in self.block_pattern)

    def is_subquadratic(self) -> bool:
        """Can this config decode with O(1)-per-token state at unbounded context?"""
        mixers = {k.split("+")[0] for k in self.block_pattern}
        attn_like = mixers & {"attn", "dec"}
        return not attn_like or self.sliding_window > 0

    def with_sliding_window(self, window: int) -> "ModelConfig":
        return replace(self, sliding_window=window)

    def reduced(self, n_periods: int | None = None, **kw) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (<=2 layers, d<=512, <=4 experts)."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, heads)
        heads = (heads // kv) * kv or kv
        defaults = dict(
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_periods=n_periods if n_periods is not None else 1,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            shared_d_ff=min(self.shared_d_ff, 128) if self.shared_d_ff else 0,
            dense_residual_ff=min(self.dense_residual_ff, 128) if self.dense_residual_ff else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            image_seq=min(self.image_seq, 32) if self.image_seq else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            dtype="float32",
        )
        defaults.update(kw)
        return replace(self, **defaults)


@dataclass(frozen=True)
class InputShape:
    """Assigned input shapes (training / prefill / decode / long-context decode)."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                            # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Sliding window used by full-attention archs for the long_500k variant (DESIGN.md §5).
LONG_CONTEXT_WINDOW = 8_192
