"""Jitted dispatch wrappers: Pallas kernel on TPU, pure-jnp oracle elsewhere.

The model code calls these; the backend choice is a deployment detail.  Setting
``REPRO_FORCE_PALLAS=1`` runs the Pallas kernels in interpret mode on CPU (slow —
used by the kernel test sweeps, not by the engine or dry-run).
"""

from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import (decode_attention_pallas,
                                            paged_decode_attention_pallas)
from repro.kernels.mamba_scan import mamba_scan_pallas, mamba_scan_ref


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: jax.Array, *, force_pallas: bool = False) -> jax.Array:
    """Flash-decode GQA attention: q (B,KV,G,hd) vs cache (B,C,KV,hd).

    ``force_pallas`` routes through the Pallas kernel regardless of backend
    (interpret mode off-TPU) — the ``ModelConfig.use_pallas_decode`` wire.
    """
    if force_pallas or _use_pallas():
        interpret = jax.default_backend() != "tpu"
        return decode_attention_pallas(q, k, v, valid_len, interpret=interpret)
    return ref.decode_attention_ref(q, k, v, valid_len)


def paged_decode_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           page_table: jax.Array, valid_len: jax.Array, *,
                           force_pallas: bool = False) -> jax.Array:
    """Paged flash-decode: q (B,KV,G,hd) vs block pools (NB,ps,KV,hd) gathered
    through a (B,num_pages) page table.  Same dispatch contract as
    :func:`decode_attention`; the reference path gathers the lane view and is
    bit-exact with the dense layout over the valid region."""
    if force_pallas or _use_pallas():
        interpret = jax.default_backend() != "tpu"
        return paged_decode_attention_pallas(q, k_pool, v_pool, page_table,
                                             valid_len, interpret=interpret)
    return ref.paged_decode_attention_ref(q, k_pool, v_pool, page_table, valid_len)


def mamba_scan(dt: jax.Array, b_in: jax.Array, c_in: jax.Array, x: jax.Array,
               a_log: jax.Array) -> jax.Array:
    """Fused SSM selective scan (see kernels/mamba_scan.py)."""
    if _use_pallas():
        interpret = jax.default_backend() != "tpu"
        return mamba_scan_pallas(dt, b_in, c_in, x, a_log, interpret=interpret)
    # pure-JAX lowering path: the chunked fused scan in models/layers.py is used by
    # the model directly; this oracle covers direct ops-level callers
    return mamba_scan_ref(dt, b_in, c_in, x, a_log)
