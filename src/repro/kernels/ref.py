"""Pure-jnp oracles for every Pallas kernel (the correctness reference)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

F32 = jnp.float32


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid_len: jax.Array) -> jax.Array:
    """GQA decode attention oracle.

    q: (B, KV, G, hd) — one new token's queries, grouped onto KV heads.
    k, v: (B, C, KV, hd) — KV cache (C slots; only the first ``valid_len`` count —
      ring caches pass C once full, so slot order never matters for the softmax).
    valid_len: scalar or (B,) int32.
    Returns (B, KV, G, hd).
    """
    B, KV, G, hd = q.shape
    C = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    # f32 ACCUMULATION without materializing f32 copies of the (multi-GiB) KV cache:
    # preferred_element_type upcasts inside the MXU/dot instead of writing k.astype(F32)
    # back to HBM (EXPERIMENTS.md §Perf pair c: the astype copies were ~8.6 GB/step of
    # the 17.4 GB/step HBM traffic on nemotron decode_32k).
    s = jnp.einsum("bkgd,bckd->bkgc", q, k,
                   preferred_element_type=F32) * scale
    vl = jnp.asarray(valid_len)
    vl = jnp.broadcast_to(vl, (B,))
    mask = jnp.arange(C)[None] < vl[:, None]                  # (B, C)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=F32)
    return out.astype(q.dtype)


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                               page_table: jax.Array, valid_len: jax.Array
                               ) -> jax.Array:
    """Paged decode attention oracle: gather blocks, then dense attention.

    q: (B, KV, G, hd); k_pool, v_pool: (NB, page_size, KV, hd) physical blocks;
    page_table: (B, num_pages) int32 (unmapped entries point at scratch block 0
    — their slots are masked out by ``valid_len``); valid_len: scalar or (B,).

    The gathered (B, num_pages * page_size, KV, hd) view is bit-identical to a
    dense lane layout over the valid region, so paged-vs-dense token parity is
    exact through this path.  Returns (B, KV, G, hd).
    """
    B = q.shape[0]
    num_pages, ps = page_table.shape[1], k_pool.shape[1]
    kg = k_pool[page_table].reshape(B, num_pages * ps, *k_pool.shape[2:])
    vg = v_pool[page_table].reshape(B, num_pages * ps, *v_pool.shape[2:])
    return decode_attention_ref(q, kg, vg, valid_len)
