"""Flash-decode GQA attention Pallas kernel (TPU target, validated interpret=True).

The rollout hot spot Heddle's resource manager accelerates is decode-phase attention
against a long KV cache.  This kernel implements the TPU-native adaptation: the KV cache
streams HBM -> VMEM in ``block_c``-sized tiles (BlockSpec), the (G x hd) query tile stays
resident in VMEM, and an online-softmax accumulator lives in VMEM scratch across the
sequential kv-block grid axis.  GQA is handled by grouping the G query heads of one KV
head into a single (G, hd) x (hd, block_c) MXU matmul — no KV replication.

Grid: (B, KV, num_kv_blocks); the last axis is sequential on TPU, enabling accumulation.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
DEFAULT_BLOCK_C = 512


def _decode_attn_kernel(vlen_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, block_c: int, num_blocks: int):
    blk = pl.program_id(2)

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = pl.program_id(0)
    q = q_ref[0, 0].astype(F32)                      # (G, hd)
    k = k_ref[0, :, 0].astype(F32)                   # (block_c, hd)
    v = v_ref[0, :, 0].astype(F32)                   # (block_c, hd)
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale   # (G, block_c)
    vlen = vlen_ref[b]
    pos = blk * block_c + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < vlen, s, -1e30)

    m_prev = m_ref[...]                               # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                            # (G, block_c)
    corr = jnp.exp(m_prev - m_new)                    # (G, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    m_ref[...] = m_new

    @pl.when(blk == num_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_attn_kernel(pt_ref, vlen_ref, q_ref, k_ref, v_ref, o_ref,
                              m_ref, l_ref, acc_ref, *, page_size: int,
                              num_pages: int):
    """Ragged paged variant: the grid's last axis walks the lane's page table.

    The physical block streamed into ``k_ref``/``v_ref`` at step ``i`` is chosen
    by the BlockSpec index_map from the scalar-prefetched page table
    (``pt_ref[b, i]``), so the gather over non-contiguous KV blocks happens in
    the HBM->VMEM pipeline — no (B, capacity, KV, hd) contiguous view is ever
    materialized.  Pages past the lane's resident length resolve to block 0
    (scratch); their scores are masked to -1e30 like any tail padding.
    """
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = pl.program_id(0)
    q = q_ref[0, 0].astype(F32)                      # (G, hd)
    k = k_ref[0, :, 0].astype(F32)                   # (page_size, hd)
    v = v_ref[0, :, 0].astype(F32)                   # (page_size, hd)
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale  # (G, page_size)
    vlen = vlen_ref[b]
    pos = i * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < vlen, s, -1e30)

    m_prev = m_ref[...]                               # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                            # (G, page_size)
    corr = jnp.exp(m_prev - m_new)                    # (G, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    m_ref[...] = m_new

    @pl.when(i == num_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, page_table: jax.Array,
                                  valid_len: jax.Array, *,
                                  interpret: bool = True) -> jax.Array:
    """Paged flash-decode: gather KV blocks through a page table.

    q: (B, KV, G, hd); k_pool, v_pool: (NB, page_size, KV, hd) physical block
    pools; page_table: (B, num_pages) int32 (block 0 = scratch for unmapped
    entries); valid_len: scalar or (B,) int32 resident token counts.
    Returns (B, KV, G, hd).
    """
    B, KV, G, hd = q.shape
    page_size = k_pool.shape[1]
    num_pages = page_table.shape[1]
    vlen = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (B,))
    pt = page_table.astype(jnp.int32)

    kernel = functools.partial(_paged_decode_attn_kernel, page_size=page_size,
                               num_pages=num_pages)
    grid = (B, KV, num_pages)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, h, i, pt, vl: (b, h, 0, 0)),
                pl.BlockSpec((1, page_size, 1, hd),
                             lambda b, h, i, pt, vl: (pt[b, i], 0, h, 0)),
                pl.BlockSpec((1, page_size, 1, hd),
                             lambda b, h, i, pt, vl: (pt[b, i], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, h, i, pt, vl: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), F32),       # running max m
                pltpu.VMEM((G, 1), F32),       # running denom l
                pltpu.VMEM((G, hd), F32),      # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(pt, vlen, q, k_pool, v_pool)
    return out


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            valid_len: jax.Array, *, block_c: int = DEFAULT_BLOCK_C,
                            interpret: bool = True) -> jax.Array:
    """q: (B, KV, G, hd); k, v: (B, C, KV, hd); valid_len: scalar or (B,) int32."""
    B, KV, G, hd = q.shape
    C = k.shape[1]
    block_c = min(block_c, C)
    num_blocks = -(-C // block_c)
    pad = num_blocks * block_c - C
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vlen = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (B,))

    kernel = functools.partial(_decode_attn_kernel, block_c=block_c,
                               num_blocks=num_blocks)
    grid = (B, KV, num_blocks)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, h, c, vl: (b, h, 0, 0)),
                pl.BlockSpec((1, block_c, 1, hd), lambda b, h, c, vl: (b, c, h, 0)),
                pl.BlockSpec((1, block_c, 1, hd), lambda b, h, c, vl: (b, c, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, c, vl: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), F32),       # running max m
                pltpu.VMEM((G, 1), F32),       # running denom l
                pltpu.VMEM((G, hd), F32),      # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(vlen, q, k, v)
    return out
