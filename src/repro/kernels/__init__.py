"""Pallas TPU kernels for the rollout hot spots, with pure-jnp oracles in ref.py.

decode_attention — flash-decode GQA attention over blocked KV (BlockSpec VMEM tiling,
                   online-softmax scratch across the sequential kv-block grid axis).
mamba_scan       — fused selective-scan: discretize + recur + contract in VMEM, state
                   carried in scratch across the sequential chunk grid axis.
"""
