"""Mamba selective-scan Pallas kernel (TPU target, validated interpret=True).

The §Perf pair-(a) hillclimb showed the SSM recurrence is memory-bound: the fused-JAX
version still writes per-chunk state tensors to HBM.  This kernel is the TPU-native
endpoint of that optimization line: discretization (a = exp(dt A), b = dt x B), the
recurrence h_t = a_t h_{t-1} + b_t AND the output contraction y_t = <h_t, C_t> all
happen in VMEM — HBM sees only the (B,S,di)/(B,S,N) projections in and (B,S,di) out.
The hidden state lives in a VMEM scratch carried across the sequential chunk axis of
the grid; the d_inner dimension is tiled to a VMEM/lane-friendly block.

Grid: (B, di_blocks, n_chunks) — the last axis is sequential on TPU, so the scratch
state carries across chunks exactly like the lax.scan carry in the pure-JAX version.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
DEFAULT_CHUNK = 256
DEFAULT_DI_BLOCK = 512


def _mamba_scan_kernel(dt_ref, b_in_ref, c_in_ref, x_ref, a_log_ref, y_ref, h_ref,
                       *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = -jnp.exp(a_log_ref[...].astype(F32))             # (di_blk, N)

    def step(t, _):
        dt_t = dt_ref[0, t, :].astype(F32)               # (di_blk,)
        a_t = jnp.exp(dt_t[:, None] * A)                 # (di_blk, N)
        bx = dt_t * x_ref[0, t, :].astype(F32)           # (di_blk,)
        b_t = bx[:, None] * b_in_ref[0, t, :].astype(F32)[None, :]
        h = a_t * h_ref[...] + b_t
        h_ref[...] = h
        y = jnp.sum(h * c_in_ref[0, t, :].astype(F32)[None, :], axis=1)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "di_block", "interpret"))
def mamba_scan_pallas(dt: jax.Array, b_in: jax.Array, c_in: jax.Array, x: jax.Array,
                      a_log: jax.Array, *, chunk: int = DEFAULT_CHUNK,
                      di_block: int = DEFAULT_DI_BLOCK,
                      interpret: bool = True) -> jax.Array:
    """Fused selective scan.

    dt, x: (B, S, di) — softplus'd step sizes and conv'd inputs;
    b_in, c_in: (B, S, N) — input/output projections; a_log: (di, N).
    Returns y (B, S, di) f32 with y_t = <h_t, C_t>, h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t.
    """
    B, S, di = dt.shape
    N = b_in.shape[-1]
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:   # identity padding: dt=0 -> a=1, b=0
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    di_block = min(di_block, di)
    nd = -(-di // di_block)
    if di % di_block:
        raise ValueError(f"d_inner {di} must divide into {di_block} blocks")

    grid = (B, nd, nc)
    out = pl.pallas_call(
        functools.partial(_mamba_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, di_block), lambda b, d, c: (b, c, d)),   # dt
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),          # B
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),          # C
            pl.BlockSpec((1, chunk, di_block), lambda b, d, c: (b, c, d)),   # x
            pl.BlockSpec((di_block, N), lambda b, d, c: (d, 0)),             # A_log
        ],
        out_specs=pl.BlockSpec((1, chunk, di_block), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, nc * chunk, di), F32),
        scratch_shapes=[pltpu.VMEM((di_block, N), F32)],                     # h state
        interpret=interpret,
    )(dt, b_in, c_in, x, a_log)
    return out[:, :S]


def mamba_scan_ref(dt, b_in, c_in, x, a_log):
    """Naive sequential oracle."""
    B, S, di = dt.shape
    A = -jnp.exp(a_log.astype(F32))
    h = jnp.zeros((B, di, a_log.shape[-1]), F32)
    ys = []
    for t in range(S):
        a_t = jnp.exp(dt[:, t, :, None].astype(F32) * A[None])
        b_t = (dt[:, t] * x[:, t]).astype(F32)[..., None] * b_in[:, t].astype(F32)[:, None, :]
        h = a_t * h + b_t
        ys.append(jnp.einsum("bdn,bn->bd", h, c_in[:, t].astype(F32)))
    return jnp.stack(ys, axis=1)
