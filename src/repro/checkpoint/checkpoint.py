"""Pytree checkpointing (npz + structure manifest, no external deps).

``save`` is crash-atomic: the three files are written into a fresh temp
directory next to the target and swapped into place with ``os.replace``, so a
crash mid-save leaves either the previous complete checkpoint or none — never
a half-written directory that ``restore`` would half-load.  ``restore``
validates both shape *and* dtype against the checkpoint (a silent cast of,
e.g., bf16 KV lanes into f32 templates corrupts restored state undetected).
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def save(path: str, tree, step: int = 0, extra: dict | None = None) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    # stage in a sibling temp dir (same filesystem, so the final rename is the
    # single atomic commit point); a stable suffix keeps retries self-cleaning
    tmp = os.path.abspath(path).rstrip(os.sep) + ".tmp"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "leaves.npz"),
             **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)})
    manifest = {"n_leaves": len(leaves), "treedef": str(treedef), "step": step,
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # structure file for restore: we re-flatten the caller's template on load, so we
    # only need leaf order + dtype/shape validation data
    with open(os.path.join(tmp, "shapes.json"), "w") as f:
        json.dump([[list(np.asarray(x).shape), str(np.asarray(x).dtype)]
                   for x in leaves], f)
    target = os.path.abspath(path).rstrip(os.sep)
    if os.path.isdir(target):  # os.replace cannot clobber a non-empty dir
        old = target + ".old"
        if os.path.isdir(old):
            shutil.rmtree(old)
        os.replace(target, old)
        os.replace(tmp, target)
        shutil.rmtree(old)
    else:
        os.replace(tmp, target)


def restore(path: str, template):
    """Restore into the structure of ``template`` (shape AND dtype validated).

    A dtype mismatch raises instead of silently casting: ``shapes.json``
    records the dtype each leaf was saved with, and loading those bytes into a
    template of another dtype is state corruption, not a convenience.
    """
    data = np.load(os.path.join(path, "leaves.npz"))
    leaves, treedef = jax.tree.flatten(template)
    if len(leaves) != len(data.files):
        raise ValueError(f"checkpoint has {len(data.files)} leaves, template {len(leaves)}")
    with open(os.path.join(path, "shapes.json")) as f:
        saved = json.load(f)
    new_leaves = []
    for i, leaf in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"leaf {i}: checkpoint {arr.shape} != template {np.shape(leaf)}")
        want = np.dtype(getattr(leaf, "dtype", None) or np.asarray(leaf).dtype)
        have = np.dtype(saved[i][1]) if i < len(saved) else arr.dtype
        if have != want:
            raise ValueError(
                f"leaf {i}: checkpoint dtype {have} != template dtype {want}; "
                "refusing to cast silently — convert explicitly if intended")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree.unflatten(treedef, new_leaves)


def load_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]
