"""Pytree checkpointing (npz + structure manifest, no external deps)."""

from __future__ import annotations

import json
import os

import jax
import numpy as np


def save(path: str, tree, step: int = 0, extra: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    np.savez(os.path.join(path, "leaves.npz"),
             **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)})
    manifest = {"n_leaves": len(leaves), "treedef": str(treedef), "step": step,
                "extra": extra or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # structure file for restore: we re-flatten the caller's template on load, so we
    # only need leaf order + dtype/shape validation data
    with open(os.path.join(path, "shapes.json"), "w") as f:
        json.dump([[list(np.asarray(x).shape), str(np.asarray(x).dtype)]
                   for x in leaves], f)


def restore(path: str, template):
    """Restore into the structure of ``template`` (shape/dtype validated)."""
    data = np.load(os.path.join(path, "leaves.npz"))
    leaves, treedef = jax.tree.flatten(template)
    if len(leaves) != len(data.files):
        raise ValueError(f"checkpoint has {len(data.files)} leaves, template {len(leaves)}")
    new_leaves = []
    for i, leaf in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"leaf {i}: checkpoint {arr.shape} != template {np.shape(leaf)}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree.unflatten(treedef, new_leaves)


def load_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]
