"""heddle-lint: AST linter for Heddle's control-plane invariants.

Usage::

    python -m repro.analysis.lint src/repro            # lint a tree
    python -m repro.analysis.lint path/to/file.py      # lint one file
    python -m repro.analysis.lint --select HDL002 src  # one rule only

Rules (catalog + rationale in docs/analysis.md):

* **HDL001** — no wall-clock / unseeded-RNG calls in control-plane modules
  (``core/``, ``engine/``, ``rl/``); ``time.perf_counter`` additionally
  banned in ``core/`` (virtual time only).
* **HDL002** — no iteration over a set or ``dict.keys()`` in control-plane
  loops (hash-order traversal breaks decision-trace parity).
* **HDL003** — jit sites must pin mesh/config parameters static; no
  host-sync calls inside decode/prefill loops.
* **HDL004** — every event kind pushed onto an orchestrator heap has a
  handler branch, and tuple payloads carry a version/token stamp.
* **HDL005** — no host-gather (``np.asarray`` / ``jax.device_get``) of KV
  buffers inside migration/checkpoint/restore paths; same-process moves
  D2D-copy resident pages (durability bounces carry a justified noqa).

Suppression: append ``# heddle: noqa HDL002`` (comma-separate multiple ids,
bare ``# heddle: noqa`` silences all rules) to the flagged line, with a
justification after ``--``::

    for tid in live_set:  # heddle: noqa HDL002 -- feeds an order-insensitive sum

Exit status is the number of unsuppressed violations (0 = clean), capped at
the shell's 8-bit range by the CLI.
"""

from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path
from typing import Iterable, Optional, Sequence

from repro.analysis.rules import ALL_RULES
from repro.analysis.rules.base import FileContext, Scope, Violation

_NOQA = re.compile(r"#\s*heddle:\s*noqa(?:\s+(?P<ids>HDL\d{3}(?:\s*,\s*HDL\d{3})*))?",
                   re.I)

#: path fragments that place a file in the decision-making planes
_CONTROL_FRAGMENTS = ("repro/core/", "repro/engine/", "repro/rl/")
_CORE_FRAGMENT = "repro/core/"


def scope_for_path(path: str) -> Scope:
    p = path.replace("\\", "/")
    scope = Scope.NONE
    if any(f in p for f in _CONTROL_FRAGMENTS):
        scope |= Scope.CONTROL
    if _CORE_FRAGMENT in p:
        scope |= Scope.CORE
    return scope


def _noqa_ids(line: str) -> Optional[set[str]]:
    """Rule ids suppressed on this line; empty set = all rules; None = none."""
    m = _NOQA.search(line)
    if m is None:
        return None
    ids = m.group("ids")
    if not ids:
        return set()
    return {i.strip().upper() for i in ids.split(",")}


def _suppressed(v: Violation, lines: list[str]) -> bool:
    if not 1 <= v.line <= len(lines):
        return False
    ids = _noqa_ids(lines[v.line - 1])
    if ids is None and v.line >= 2:
        # multi-line statements report the first line; accept a noqa on the
        # physical line above (decorators, wrapped calls)
        ids = _noqa_ids(lines[v.line - 2])
    if ids is None:
        return False
    return not ids or v.rule in ids


def lint_source(source: str, path: str = "<memory>",
                scope: Optional[Scope] = None,
                select: Optional[Iterable[str]] = None) -> list[Violation]:
    """Lint one module's source; returns unsuppressed violations sorted by
    position.  ``scope`` overrides path-derived scoping (tests force
    CONTROL|CORE on fixtures that live outside src/repro)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Violation("HDL000", path, exc.lineno or 1, 0,
                          f"syntax error: {exc.msg}")]
    ctx = FileContext(path=path, source=source, tree=tree,
                      scope=scope_for_path(path) if scope is None else scope)
    wanted = set(select) if select else set(ALL_RULES)
    out: list[Violation] = []
    for rule_id, rule in ALL_RULES.items():
        if rule_id not in wanted:
            continue
        if rule.scope is not Scope.NONE and not ctx.scope & rule.scope:
            continue
        out.extend(rule.check(ctx))
    out = [v for v in out if not _suppressed(v, ctx.lines)]
    return sorted(out, key=lambda v: (v.line, v.col, v.rule))


def _iter_py_files(paths: Sequence[str]) -> Iterable[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            yield from sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts)
        elif p.suffix == ".py":
            yield p


def lint_paths(paths: Sequence[str],
               select: Optional[Iterable[str]] = None) -> list[Violation]:
    """Lint every ``.py`` under ``paths`` (files or trees)."""
    out: list[Violation] = []
    for f in _iter_py_files(paths):
        try:
            rel = f.resolve().relative_to(Path.cwd())
        except ValueError:
            rel = f
        out.extend(lint_source(f.read_text(), path=str(rel), select=select))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="heddle-lint: control-plane determinism linter")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--select", action="append", metavar="HDLxxx",
                    help="restrict to these rule ids (repeatable)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-violation lines; print only the summary")
    args = ap.parse_args(argv)
    violations = lint_paths(args.paths, select=args.select)
    if not args.quiet:
        for v in violations:
            print(v.render())
    n = len(violations)
    print(f"heddle-lint: {n} violation{'s' if n != 1 else ''}"
          f" ({', '.join(sorted(args.select)) if args.select else 'HDL001-HDL005'})",
          file=sys.stderr)
    return min(n, 125)


if __name__ == "__main__":
    sys.exit(main())
