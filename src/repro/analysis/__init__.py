"""Static analysis + runtime sanitization for Heddle's control plane.

The control-plane guarantees the rest of the repo leans on — deterministic
decision traces (the sim/engine parity harness), jit-cache discipline (mesh as
a static argument, fixed-shape kernels), and the versioned event heap — were
enforced by convention until this package.  Three tools turn them into
machine-checked rules:

* :mod:`repro.analysis.lint` — an AST linter (``python -m repro.analysis.lint
  src/repro``) with codebase-specific rules HDL001–HDL004 (wall-clock/unseeded
  RNG, unordered-set iteration in decision paths, jit hygiene + host syncs in
  decode loops, event-heap discipline).  See docs/analysis.md for the catalog
  and the ``# heddle: noqa HDLxxx`` suppression syntax.
* :mod:`repro.analysis.protocol` — an ``ExecutionBackend`` conformance checker
  that statically diffs SimBackend/EngineBackend (and any future backend)
  against the protocol so the implementations cannot silently drift.
* :mod:`repro.analysis.sanitize` — ``TraceSanitizer``, a runtime validator the
  orchestrator drives over every emitted decision event (monotone virtual
  time, liveness, slot conservation, migration balance, tenancy legality).
"""

# lazy attribute access: `python -m repro.analysis.lint` must not pre-import
# the submodule through the package (runpy double-import), and the
# orchestrator's sanitize hook must not pay for the linter's ast machinery
_EXPORTS = {
    "Violation": "repro.analysis.lint",
    "lint_paths": "repro.analysis.lint",
    "lint_source": "repro.analysis.lint",
    "check_backend": "repro.analysis.protocol",
    "TraceSanitizer": "repro.analysis.sanitize",
    "TraceViolationError": "repro.analysis.sanitize",
}


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")


__all__ = [
    "Violation",
    "lint_paths",
    "lint_source",
    "check_backend",
    "TraceSanitizer",
    "TraceViolationError",
]
